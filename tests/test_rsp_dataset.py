"""RSPDataset facade tests: backend registry dispatch + auto-selection,
cross-backend partition equivalence, save/open round-trips, partition-time
summary sketches, and the RSPStore manifest cache / atomic writes."""

import glob
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro import rsp
from repro.core import RSPSpec, RSPStore, is_partition
from repro.core.partition import two_stage_partition_np

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tests skip below; the rest of the module runs
    HAVE_HYPOTHESIS = False


def _data(n, f=5, seed=0, num_classes=2):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, f - 1)).astype(np.float32)
    y = (rng.random(n) < 0.4).astype(np.float32)
    return np.concatenate([x, y[:, None]], axis=1)


# ---------------------------------------------------------------------------
# Backend equivalence: every backend yields a valid, deterministic partition
# of the same record multiset
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["np", "jax", "pallas"])
@pytest.mark.parametrize("P,K", [(8, 8), (4, 8)])
def test_backend_is_partition_and_deterministic(backend, P, K):
    data = _data(1600)
    kw = dict(blocks=K, original_blocks=P, seed=11, backend=backend)
    ds = rsp.partition(data, **kw)
    assert ds.backend == backend
    assert ds.stacked().shape == (K, 1600 // K, data.shape[1])
    assert is_partition(ds.stacked(), data)
    ds2 = rsp.partition(data, **kw)
    np.testing.assert_array_equal(ds.stacked(), ds2.stacked())


def test_backends_share_record_multiset():
    data = _data(800)
    sets = []
    for backend in ("np", "jax", "pallas"):
        ds = rsp.partition(data, blocks=4, seed=5, backend=backend)
        flat = ds.stacked().reshape(-1, data.shape[1])
        sets.append(np.sort(flat.view(np.uint8).reshape(flat.shape[0], -1), axis=0))
    np.testing.assert_array_equal(sets[0], sets[1])
    np.testing.assert_array_equal(sets[0], sets[2])


def test_np_backend_matches_free_function():
    data = _data(1440)
    ds = rsp.partition(data, blocks=6, seed=3, backend="np")
    spec = RSPSpec(
        num_records=1440, num_blocks=6, num_original_blocks=6,
        record_shape=(5,), dtype="float32", seed=3,
    )
    np.testing.assert_array_equal(ds.stacked(), two_stage_partition_np(data, spec))


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        p_log=st.integers(0, 3),
        k_log=st.integers(0, 3),
        delta=st.integers(1, 5),
        seed=st.integers(0, 2**31 - 1),
        backend=st.sampled_from(["np", "jax", "pallas"]),
    )
    def test_backend_partition_property(p_log, k_log, delta, seed, backend):
        P, K = 2**p_log, 2**k_log
        N = P * K * delta
        rng = np.random.default_rng(seed)
        data = rng.normal(size=(N, 3)).astype(np.float32)
        ds = rsp.partition(
            data, blocks=K, original_blocks=P, seed=seed, backend=backend
        )
        assert ds.stacked().shape == (K, N // K, 3)
        assert is_partition(ds.stacked(), data)
        ds2 = rsp.partition(
            data, blocks=K, original_blocks=P, seed=seed, backend=backend
        )
        np.testing.assert_array_equal(ds.stacked(), ds2.stacked())

else:

    def test_backend_partition_property():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# backend="auto" selection rules (acceptance criteria)
# ---------------------------------------------------------------------------

def test_auto_selects_pallas_for_2d_float_on_tpu(monkeypatch):
    from repro.rsp import backends

    # on a TPU host the kernel compiles; off-TPU it would interpret, so the
    # auto rule only prefers pallas when a TPU backend is attached
    monkeypatch.setattr(backends.jax, "default_backend", lambda: "tpu")
    data = _data(640)
    spec = RSPSpec(num_records=640, num_blocks=4, num_original_blocks=4, seed=0)
    chosen = rsp.select_backend(rsp.PartitionRequest(data=data, spec=spec))
    assert chosen.name == "pallas"


def test_auto_prefers_np_off_tpu():
    import jax

    if jax.default_backend() == "tpu":
        pytest.skip("TPU attached: auto legitimately picks pallas here")
    ds = rsp.partition(_data(640), blocks=4, seed=0, backend="auto")
    assert ds.backend == "np"  # interpret-mode pallas declines auto-selection


def test_auto_selects_np_when_kernel_constraints_fail(monkeypatch):
    from repro.rsp import backends

    monkeypatch.setattr(backends.jax, "default_backend", lambda: "tpu")

    def chosen(data, blocks, **kw):
        spec = RSPSpec(
            num_records=np.shape(data)[0], num_blocks=blocks,
            num_original_blocks=blocks, seed=0,
        )
        return rsp.select_backend(rsp.PartitionRequest(data=data, spec=spec, **kw)).name

    tokens = np.arange(64 * 9, dtype=np.int32).reshape(64, 9)  # int dtype
    assert chosen(tokens, 4) == "np"
    cube = np.zeros((64, 3, 3), dtype=np.float32)              # 3-D records
    assert chosen(cube, 4) == "np"
    # assignment permutation is intrinsic to the pallas tile dealing
    assert chosen(_data(640), 4, permute_assignment=False) == "np"


def test_auto_selects_shard_map_when_mesh_supplied():
    import jax

    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    data = _data(256)
    ds = rsp.partition(data, blocks=1, seed=0, backend="auto", mesh=mesh)
    assert ds.backend == "shard_map"
    assert is_partition(ds.stacked(), data)
    # mesh supplied but P=K != mesh size -> predicate fails, falls through
    ds2 = rsp.partition(data, blocks=4, seed=0, backend="auto", mesh=mesh)
    assert ds2.backend in ("pallas", "np")  # next eligible by platform


@pytest.mark.slow
def test_auto_shard_map_multidevice_subprocess():
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro import rsp
from repro.core import is_partition
mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
data = np.random.default_rng(0).normal(size=(1600, 5)).astype(np.float32)
ds = rsp.partition(data, blocks=4, seed=2, backend="auto", mesh=mesh)
assert ds.backend == "shard_map", ds.backend
assert is_partition(ds.stacked(), data)
print("AUTO_SHARD_MAP_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "AUTO_SHARD_MAP_OK" in proc.stdout


def test_explicit_backend_refusal_and_unknown():
    data = _data(640)
    with pytest.raises(ValueError, match="cannot serve"):
        rsp.partition(data, blocks=4, seed=0, backend="shard_map")  # no mesh
    with pytest.raises(ValueError, match="unknown backend"):
        rsp.partition(data, blocks=4, seed=0, backend="spark")


def test_backend_eligibility_reasons():
    data = _data(640)
    spec = RSPSpec(num_records=640, num_blocks=4, num_original_blocks=4, seed=0)
    elig = rsp.backend_eligibility(rsp.PartitionRequest(data=data, spec=spec))
    assert elig["np"] is None and elig["jax"] is None and elig["pallas"] is None
    assert "mesh" in elig["shard_map"]


# ---------------------------------------------------------------------------
# save / open round-trip (stored RSP with sketches in the manifest)
# ---------------------------------------------------------------------------

def test_save_open_roundtrip(tmp_path):
    data = _data(1024)
    ds = rsp.partition(data, blocks=8, seed=9, backend="np", num_classes=2)
    out = ds.save(str(tmp_path / "corpus"))
    assert out is ds  # chainable

    got = rsp.open(str(tmp_path / "corpus"))
    assert got.spec == ds.spec
    assert got.backend == "np" and got.num_classes == 2
    for k in range(8):
        np.testing.assert_array_equal(got.block(k), ds.block(k))
    # sketches came from the manifest, not a re-scan
    for a, b in zip(got.summaries, ds.summaries):
        np.testing.assert_allclose(a.mean, b.mean)
        np.testing.assert_array_equal(a.label_hist, b.label_hist)
    assert is_partition(got.stacked(), data)


def test_out_of_range_labels_rejected():
    data = _data(512)
    data[7, -1] = 5.0  # not a valid class for num_classes=2
    with pytest.raises(ValueError, match="label column"):
        rsp.partition(data, blocks=4, seed=0, backend="np", num_classes=2)


def test_store_backed_ensemble_reads_only_sampled_blocks(tmp_path, monkeypatch):
    data = _data(1024)
    rsp.partition(data, blocks=8, seed=2, backend="np", num_classes=2).save(
        str(tmp_path / "s")
    )
    ds = rsp.open(str(tmp_path / "s"))
    loaded: set[int] = set()
    orig = RSPStore.load_block

    def spying(self, block_id, **kw):
        loaded.add(block_id)
        return orig(self, block_id, **kw)

    monkeypatch.setattr(RSPStore, "load_block", spying)
    learner = rsp.make_logreg(data.shape[1] - 1, 2, steps=20)
    ds.ensemble(
        learner, eval_x=data[:64, :-1], eval_y=data[:64, -1].astype(np.int32),
        g=3, batches=1, seed=0,
    )
    assert len(loaded) == 3  # one batch of g blocks, nothing else


def test_summaries_combine_to_full_data_moments():
    data = _data(2048)
    ds = rsp.partition(data, blocks=8, seed=1, backend="np")
    stats = ds.moments()  # all blocks, sketch-combined
    wide = data.astype(np.float64)
    np.testing.assert_allclose(stats.mean, wide.mean(0), rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(stats.std, wide.std(0, ddof=1), rtol=1e-9, atol=1e-12)
    assert stats.count == 2048


def test_dataset_sample_estimate_and_loader(tmp_path):
    data = _data(1024)
    ds = rsp.partition(data, blocks=8, seed=2, backend="np", num_classes=2)
    ids = ds.sample(3, seed=4)
    assert len(ids) == 3 and len(set(ids)) == 3
    est = ds.estimate(lambda b: b.mean(0), g=4, seed=0)
    assert np.abs(est - data.mean(0)).max() < 0.2
    assert 0.0 <= ds.label_divergence() <= 1.0
    loader = ds.loader(batch_size=64, seed=1)
    batches = [loader.next_batch() for _ in range(16)]  # 16*64 = one epoch
    allb = np.concatenate(batches)
    flat = ds.stacked().reshape(-1, data.shape[1])
    a = np.sort(allb.view(np.uint8).reshape(allb.shape[0], -1), axis=0)
    b = np.sort(flat.view(np.uint8).reshape(flat.shape[0], -1), axis=0)
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# RSPStore: atomic writes leave no temp files; manifest cache invalidates
# on mtime change
# ---------------------------------------------------------------------------

def test_store_write_leaves_no_temp_files(tmp_path):
    data = _data(512)
    rsp.partition(data, blocks=4, seed=0, backend="np").save(str(tmp_path / "s"))
    leftovers = glob.glob(str(tmp_path / "s" / "*.tmp*"))
    assert leftovers == []


def test_store_manifest_cache_and_invalidation(tmp_path):
    data = _data(512)
    ds = rsp.partition(data, blocks=4, seed=0, backend="np", num_classes=2)
    ds.save(str(tmp_path / "s"))
    store = RSPStore(str(tmp_path / "s"))

    assert store.num_blocks() == 4
    first = store._manifest()
    assert store._manifest() is first  # cached: same parsed object
    store.load_block(1, verify=True)
    assert store._manifest() is first  # verify path reuses the cache

    # a re-write (new mtime) must invalidate the cache
    time.sleep(0.01)
    spec2 = RSPSpec(num_records=512, num_blocks=2, num_original_blocks=2, seed=1)
    store.write_partition(two_stage_partition_np(data, spec2), spec2)
    assert store.num_blocks() == 2
    assert store._manifest() is not first
    # stale blocks from the 4-block partition are gone, not served silently
    assert not os.path.exists(store._block_path(2))
    with pytest.raises(IndexError):
        store.load_block(3)

    # an external writer (fresh store handle) is picked up via mtime too
    time.sleep(0.01)
    other = RSPStore(str(tmp_path / "s"))
    cached = other._manifest()
    spec3 = RSPSpec(num_records=512, num_blocks=4, num_original_blocks=4, seed=2)
    store.write_partition(two_stage_partition_np(data, spec3), spec3)
    assert other.num_blocks() == 4
    assert other._manifest() is not cached
