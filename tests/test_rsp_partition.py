"""Tests for Algorithm 1 (two-stage partitioning): Definitions 2/3, Lemma 1,
Theorem 1, and equivalence of the three implementations."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tests skip below; the rest of the module runs
    HAVE_HYPOTHESIS = False

from repro.core import (
    RSPSpec,
    empirical_cdf,
    is_partition,
    two_stage_partition_jax,
    two_stage_partition_np,
)
from repro.data import make_higgs_like, make_nonrandom_higgs_like


def _data(n, f=6, seed=0):
    x, y = make_higgs_like(n, num_features=f, seed=seed)
    return np.concatenate([x, y[:, None].astype(np.float32)], axis=1)


# ---------------------------------------------------------------------------
# Definition 2: output is a partition (disjoint cover, as multisets)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P,K", [(4, 4), (2, 8), (8, 2), (1, 16)])
def test_np_partition_is_partition(P, K):
    data = _data(1600)
    spec = RSPSpec(num_records=1600, num_blocks=K, num_original_blocks=P, seed=1)
    blocks = two_stage_partition_np(data, spec)
    assert blocks.shape == (K, 1600 // K, data.shape[1])
    assert is_partition(blocks, data)


def test_jax_partition_is_partition():
    data = _data(1280)
    blocks = two_stage_partition_jax(
        jnp.asarray(data), jax.random.PRNGKey(3), num_blocks=8, num_original_blocks=4
    )
    assert blocks.shape == (8, 160, data.shape[1])
    assert is_partition(np.asarray(blocks), data)


def test_spec_validation():
    with pytest.raises(ValueError):
        RSPSpec(num_records=100, num_blocks=3, num_original_blocks=1)
    with pytest.raises(ValueError):
        RSPSpec(num_records=100, num_blocks=10, num_original_blocks=3)
    with pytest.raises(ValueError):
        # N/P = 25 not divisible by K = 10
        RSPSpec(num_records=100, num_blocks=10, num_original_blocks=4)


def test_np_partition_rejects_unsatisfiable_spec_clearly():
    """A hand-built spec bypassing RSPSpec validation (e.g. a spec-like
    object) must fail at entry with a clear message, not a reshape error."""
    fields = dict(num_records=100, num_blocks=3, num_original_blocks=2,
                  record_shape=(), dtype="float64", seed=0)
    spec = object.__new__(RSPSpec)  # skip __post_init__ like a foreign object
    for name, value in fields.items():
        object.__setattr__(spec, name, value)
    with pytest.raises(ValueError, match=r"unsatisfiable.*P\*K"):
        two_stage_partition_np(np.zeros(100), spec)


def test_is_partition_rejects_column_multiset_false_positive():
    """Regression: the old column-wise byte sort validated any pair with
    equal per-column byte multisets.  These two record sets differ as row
    multisets ({01, 10} vs {00, 11}) but match per column."""
    data = np.array([[0.0, 1.0], [1.0, 0.0]], dtype=np.float32)
    fake_blocks = np.array([[[0.0, 0.0]], [[1.0, 1.0]]], dtype=np.float32)
    assert not is_partition(fake_blocks, data)
    real_blocks = np.array([[[1.0, 0.0]], [[0.0, 1.0]]], dtype=np.float32)
    assert is_partition(real_blocks, data)


def test_is_partition_shape_and_duplicate_handling():
    data = np.array([[1.0, 2.0], [1.0, 2.0], [3.0, 4.0], [5.0, 6.0]])
    blocks = data.reshape(2, 2, 2)[::-1]  # reordered blocks still a partition
    assert is_partition(blocks, data)
    # dropping one duplicate and doubling another is NOT a partition
    tampered = np.array([[1.0, 2.0], [3.0, 4.0], [3.0, 4.0], [5.0, 6.0]])
    assert not is_partition(tampered.reshape(2, 2, 2), data)
    # record-shape mismatch is a clean False, not a crash
    assert not is_partition(np.zeros((2, 2, 3)), data)
    # zero-record inputs are a trivially-true partition, not a reshape crash
    assert is_partition(np.zeros((2, 0, 2)), np.zeros((0, 2)))


# ---------------------------------------------------------------------------
# Lemma 1: E[F_k(x)] = F(x) -- block CDFs are unbiased for the data CDF.
# Empirical test: average block CDF over many partition draws converges to
# the full-data CDF at random thresholds.
# ---------------------------------------------------------------------------

def test_lemma1_block_cdf_unbiased():
    rng = np.random.default_rng(0)
    data = rng.normal(size=(2000, 1)).astype(np.float32)
    thresholds = np.quantile(data, [0.1, 0.25, 0.5, 0.75, 0.9])
    full_cdf = empirical_cdf(data, thresholds)
    accum = np.zeros_like(full_cdf)
    draws = 40
    for s in range(draws):
        spec = RSPSpec(num_records=2000, num_blocks=10, num_original_blocks=10, seed=s)
        blocks = two_stage_partition_np(data, spec)
        accum += empirical_cdf(blocks[0], thresholds)  # block 0 of each draw
    avg_cdf = accum / draws
    # SE of a binomial proportion with n=200 per draw, 40 draws ~ 0.005
    np.testing.assert_allclose(avg_cdf, full_cdf, atol=0.02)


def test_rsp_fixes_nonrandom_data():
    """Sequential chunking of class-sorted data gives single-class blocks;
    the two-stage partition restores balanced label distributions."""
    x, y = make_nonrandom_higgs_like(4000, seed=4)
    data = np.concatenate([x, y[:, None].astype(np.float32)], axis=1)
    labels = data[:, -1]
    seq_blocks = data.reshape(10, 400, -1)
    seq_balance = np.array([b[:, -1].mean() for b in seq_blocks])
    assert seq_balance.max() - seq_balance.min() > 0.9  # broken: single-class blocks

    spec = RSPSpec(num_records=4000, num_blocks=10, num_original_blocks=10, seed=2)
    rsp_blocks = two_stage_partition_np(data, spec)
    rsp_balance = np.array([b[:, -1].mean() for b in rsp_blocks])
    assert np.all(np.abs(rsp_balance - labels.mean()) < 0.1)


# ---------------------------------------------------------------------------
# Theorem 1: proportional unions of RSP blocks are RSP blocks of the union.
# ---------------------------------------------------------------------------

def test_theorem1_union_unbiased():
    rng = np.random.default_rng(1)
    a = rng.normal(0.0, 1.0, size=(1000, 1)).astype(np.float32)
    b = rng.normal(2.0, 1.5, size=(2000, 1)).astype(np.float32)  # N1/N2 = 1/2
    union = np.concatenate([a, b])
    thresholds = np.quantile(union, [0.2, 0.5, 0.8])
    full_cdf = empirical_cdf(union, thresholds)
    accum = np.zeros_like(full_cdf)
    draws = 40
    for s in range(draws):
        sa = RSPSpec(num_records=1000, num_blocks=10, num_original_blocks=10, seed=s)
        sb = RSPSpec(num_records=2000, num_blocks=10, num_original_blocks=10, seed=1000 + s)
        a1 = two_stage_partition_np(a, sa)[0]  # n1 = 100
        b1 = two_stage_partition_np(b, sb)[0]  # n2 = 200 -> n1/n2 == N1/N2
        accum += empirical_cdf(np.concatenate([a1, b1]), thresholds)
    np.testing.assert_allclose(accum / draws, full_cdf, atol=0.02)


# ---------------------------------------------------------------------------
# Property-based: partition invariants hold for arbitrary shapes/seeds
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        p_log=st.integers(0, 3),
        k_log=st.integers(0, 3),
        delta=st.integers(1, 7),
        seed=st.integers(0, 2**31 - 1),
        features=st.integers(1, 5),
    )
    def test_partition_property(p_log, k_log, delta, seed, features):
        P, K = 2**p_log, 2**k_log
        N = P * K * delta
        rng = np.random.default_rng(seed)
        data = rng.normal(size=(N, features)).astype(np.float32)
        spec = RSPSpec(num_records=N, num_blocks=K, num_original_blocks=P, seed=seed)
        blocks = two_stage_partition_np(data, spec)
        assert blocks.shape == (K, N // K, features)
        assert is_partition(blocks, data)
        # determinism
        blocks2 = two_stage_partition_np(data, spec)
        np.testing.assert_array_equal(blocks, blocks2)

else:

    def test_partition_property():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# jax vs np implementations agree on the statistical contract
# ---------------------------------------------------------------------------

def test_jax_partition_deterministic():
    data = jnp.asarray(_data(640))
    k = jax.random.PRNGKey(11)
    b1 = two_stage_partition_jax(data, k, num_blocks=4, num_original_blocks=4)
    b2 = two_stage_partition_jax(data, k, num_blocks=4, num_original_blocks=4)
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
