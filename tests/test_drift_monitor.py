"""DriftMonitor (Sec.-10 extension): clean RSP blocks pass, shifted /
corrupted blocks are flagged."""

import numpy as np

from repro.core import RSPSpec, two_stage_partition_np
from repro.core.monitor import DriftMonitor
from repro.data import make_higgs_like


def _blocks(seed=0, n=20000, k=20):
    x, _ = make_higgs_like(n, seed=seed)
    spec = RSPSpec(num_records=n, num_blocks=k, num_original_blocks=k, seed=1)
    return two_stage_partition_np(x, spec)


def test_clean_blocks_not_flagged():
    blocks = _blocks()
    mon = DriftMonitor(blocks[:5], seed=0)
    for i in range(5, 15):
        r = mon.score(blocks[i], block_id=i)
        assert not r.drifted, f"clean block {i} flagged: mmd={r.mmd2}, z={r.max_mean_z}"
    assert mon.drifted_blocks() == []


def test_mean_shifted_block_flagged():
    blocks = _blocks()
    mon = DriftMonitor(blocks[:5], seed=0)
    bad = blocks[10] + 1.5
    r = mon.score(bad, block_id=10)
    assert r.drifted and r.max_mean_z > mon.z_threshold


def test_different_distribution_flagged():
    """Blocks from a 'different data centre' (different covariance) are
    caught by MMD even with matching means."""
    blocks = _blocks()
    mon = DriftMonitor(blocks[:5], seed=0)
    rng = np.random.default_rng(7)
    other = rng.standard_t(df=1.5, size=blocks[0].shape).astype(np.float32)
    other = other - other.mean(0) + blocks[:5].reshape(-1, blocks.shape[-1]).mean(0)
    r = mon.score(other, block_id=99)
    assert r.drifted and r.mmd2 > mon.mmd_threshold


def test_corrupted_shard_tripwire():
    blocks = _blocks()
    mon = DriftMonitor(blocks[:5], seed=0)
    corrupted = blocks[12].copy()
    corrupted[:, 3] = 0.0  # dead feature (e.g. bad decode of one column)
    r = mon.score(corrupted, block_id=12)
    assert r.drifted
