"""Algorithm 2 (asymptotic ensemble learning) tests reproducing the paper's
Fig-6 claims on synthetic HIGGS-like data."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    RSPSpec,
    asymptotic_ensemble_learn,
    ensemble_vs_single_model,
    make_logreg,
    make_mlp,
    train_base_models_vmapped,
    two_stage_partition_np,
)
from repro.data import make_higgs_like


@pytest.fixture(scope="module")
def higgs_blocks():
    N, Ne, K = 20000, 4000, 20
    x, y = make_higgs_like(N + Ne, seed=2, class_sep=1.5)
    xe, ye = x[N:], y[N:]
    x, y = x[:N], y[:N]
    data = np.concatenate([x, y[:, None].astype(np.float32)], axis=1)
    spec = RSPSpec(num_records=N, num_blocks=K, num_original_blocks=K, seed=5)
    blocks = two_stage_partition_np(data, spec)
    return (
        jnp.asarray(blocks[:, :, :-1]),
        jnp.asarray(blocks[:, :, -1].astype(np.int32)),
        jnp.asarray(xe),
        jnp.asarray(ye),
    )


def test_vmapped_base_models_match_sequential(higgs_blocks):
    bx, by, xe, ye = higgs_blocks
    learner = make_logreg(bx.shape[-1], 2, steps=50, lr=0.5)
    key = jax.random.PRNGKey(0)
    stacked = train_base_models_vmapped(learner, key, bx[:3], by[:3])
    keys = jax.random.split(key, 3)
    for i in range(3):
        solo = learner.fit(learner.init(keys[i]), bx[i], by[i])
        for name in solo:
            np.testing.assert_allclose(
                np.asarray(jax.tree.map(lambda a: a[i], stacked)[name]),
                np.asarray(solo[name]),
                rtol=2e-3,
                atol=2e-4,
            )


def test_ensemble_accuracy_plateaus(higgs_blocks):
    bx, by, xe, ye = higgs_blocks
    learner = make_logreg(bx.shape[-1], 2, steps=150, lr=0.5)
    ens, hist = asymptotic_ensemble_learn(
        bx, by, learner=learner, eval_x=xe, eval_y=ye, g=4, seed=0
    )
    assert len(hist.accuracy) >= 2
    assert hist.accuracy[-1] > 0.70  # far above chance
    # termination before exhausting all blocks (plateau detected), Fig 6
    assert ens.num_models <= bx.shape[0]


def test_ensemble_matches_single_full_data_model(higgs_blocks):
    """Paper's central Fig-6 claim: block ensemble ~ single full-data model."""
    bx, by, xe, ye = higgs_blocks
    learner = make_logreg(bx.shape[-1], 2, steps=150, lr=0.5)
    ens_acc, single_acc = ensemble_vs_single_model(
        bx, by, xe, ye, learner=learner, seed=0
    )
    assert ens_acc >= single_acc - 0.01  # equivalent within 1 pt


def test_ensemble_beats_single_block_model(higgs_blocks):
    bx, by, xe, ye = higgs_blocks
    learner = make_mlp(bx.shape[-1], 2, hidden=16, steps=150, lr=0.05)
    ens, hist = asymptotic_ensemble_learn(
        bx, by, learner=learner, eval_x=xe, eval_y=ye, g=4, seed=1, max_batches=2
    )
    params = learner.fit(learner.init(jax.random.PRNGKey(9)), bx[0], by[0])
    single_block_acc = float(
        (jnp.argmax(learner.predict_proba(params, xe), -1) == ye).mean()
    )
    assert ens.accuracy(xe, ye) >= single_block_acc - 0.02


def test_ensemble_history_monotone_blocks(higgs_blocks):
    bx, by, xe, ye = higgs_blocks
    learner = make_logreg(bx.shape[-1], 2, steps=50, lr=0.5)
    _, hist = asymptotic_ensemble_learn(
        bx, by, learner=learner, eval_x=xe, eval_y=ye, g=3, seed=2, max_batches=3
    )
    assert hist.blocks_used == sorted(hist.blocks_used)
    assert all(b % 3 == 0 for b in hist.blocks_used)
