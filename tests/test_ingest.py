"""Out-of-core streaming partitioner tests (``repro.rsp.ingest``):
streamed-vs-in-memory bit equivalence across chunkings, direct-to-store
writes with partition-time sketches, crash atomicity, and the ``np_stream``
backend registry entry."""

import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tests skip below; the rest of the module runs
    HAVE_HYPOTHESIS = False

from repro import rsp
from repro.core import RSPSpec, two_stage_partition_np
from repro.rsp.backends import PartitionRequest, select_backend
from repro.rsp.ingest import (
    ArrayChunkSource,
    DirectoryChunkSource,
    IterChunkSource,
    NpyChunkSource,
    as_chunk_source,
    is_stream_source,
    stream_partition,
)
from repro.rsp.summaries import summarize_blocks


def _data(n, f=5, seed=0, num_classes=None):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, f)).astype(np.float32)
    if num_classes is not None:
        x[:, -1] = rng.integers(0, num_classes, size=n)
    return x


def _spec(n, K, P, seed=3, f=5):
    return RSPSpec(num_records=n, num_blocks=K, num_original_blocks=P,
                   record_shape=(f,), dtype="float32", seed=seed)


# ---------------------------------------------------------------------------
# Bit-for-bit equivalence with the in-memory reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [7, 100, 480, 481, 1920])  # 480 aligns with R=480
def test_streamed_equals_in_memory(chunk):
    data = _data(1920)
    spec = _spec(1920, K=8, P=4)
    ref = two_stage_partition_np(data, spec)
    got, _ = stream_partition(ArrayChunkSource(data, chunk_records=chunk), spec)
    assert got.dtype == ref.dtype
    np.testing.assert_array_equal(got, ref)


def test_streamed_equals_in_memory_sync_workers():
    data = _data(960)
    spec = _spec(960, K=4, P=2)
    ref = two_stage_partition_np(data, spec)
    for workers in (0, 1, 4):
        got, _ = stream_partition(
            ArrayChunkSource(data, chunk_records=111), spec, workers=workers
        )
        np.testing.assert_array_equal(got, ref)


def test_streamed_no_assignment_permutation():
    data = _data(960)
    spec = _spec(960, K=4, P=2)
    ref = two_stage_partition_np(data, spec, permute_assignment=False)
    got, _ = stream_partition(
        ArrayChunkSource(data, chunk_records=77), spec, permute_assignment=False
    )
    np.testing.assert_array_equal(got, ref)


def test_streamed_scalar_records():
    rng = np.random.default_rng(1)
    data = rng.normal(size=(640,)).astype(np.float64)
    spec = RSPSpec(num_records=640, num_blocks=4, num_original_blocks=4,
                   record_shape=(), dtype="float64", seed=5)
    ref = two_stage_partition_np(data, spec)
    got, _ = stream_partition(ArrayChunkSource(data, chunk_records=99), spec)
    np.testing.assert_array_equal(got, ref)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        chunk=st.integers(min_value=1, max_value=640),
        pk=st.sampled_from([(1, 4), (2, 4), (4, 2), (4, 4), (8, 1)]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_streamed_equivalence_property(chunk, pk, seed):
        P, K = pk
        data = _data(640, f=3, seed=2)
        spec = RSPSpec(num_records=640, num_blocks=K, num_original_blocks=P,
                       record_shape=(3,), dtype="float32", seed=seed)
        ref = two_stage_partition_np(data, spec)
        got, _ = stream_partition(ArrayChunkSource(data, chunk_records=chunk), spec)
        np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# Direct-to-store ingest: atomic publish, checksums, folded sketches
# ---------------------------------------------------------------------------

def test_store_ingest_bit_identical_and_verified(tmp_path):
    data = _data(1920, num_classes=2)
    spec = _spec(1920, K=8, P=4)
    ref = two_stage_partition_np(data, spec)
    store, summaries = stream_partition(
        ArrayChunkSource(data, chunk_records=333), spec,
        out=str(tmp_path / "rsp"), num_classes=2,
    )
    assert store.num_blocks() == 8
    for k in range(8):
        np.testing.assert_array_equal(
            np.asarray(store.load_block(k, verify=True)), ref[k]
        )
    # sketches folded during the write match a post-hoc full summarize
    exact = summarize_blocks(ref, label_column=-1, num_classes=2)
    for s, e in zip(summaries, exact):
        assert s.count == e.count
        np.testing.assert_allclose(s.mean, e.mean, rtol=1e-9, atol=1e-11)
        np.testing.assert_allclose(s.m2, e.m2, rtol=1e-7, atol=1e-9)
        np.testing.assert_array_equal(s.min, e.min)
        np.testing.assert_array_equal(s.max, e.max)
        np.testing.assert_array_equal(s.label_hist, e.label_hist)
    # and they landed in the manifest: reopening sees them without any reads
    ds = rsp.open(str(tmp_path / "rsp"))
    assert ds.has_summaries and ds.num_classes == 2
    assert ds.backend == "np_stream"


def test_crash_mid_ingest_publishes_nothing_and_reingest_succeeds(tmp_path):
    data = _data(960)
    spec = _spec(960, K=4, P=2)
    out = str(tmp_path / "rsp")

    def exploding_chunks():
        for a in range(0, 960, 120):
            if a >= 360:
                raise RuntimeError("source died mid-stream")
            yield data[a : a + 120]

    src = IterChunkSource(exploding_chunks(), num_records=960,
                          record_shape=(5,), dtype=np.float32)
    with pytest.raises(RuntimeError, match="died mid-stream"):
        stream_partition(src, spec, out=out)
    # no manifest published, no temps left behind
    assert not os.path.exists(os.path.join(out, "manifest.json"))
    assert [f for f in os.listdir(out) if f.endswith(".tmp.npy")] == []
    with pytest.raises(FileNotFoundError):
        rsp.open(out)
    # re-ingest into the same root succeeds and is bit-identical
    store, _ = stream_partition(ArrayChunkSource(data, chunk_records=120), spec, out=out)
    ref = two_stage_partition_np(data, spec)
    for k in range(4):
        np.testing.assert_array_equal(
            np.asarray(store.load_block(k, verify=True)), ref[k]
        )


def test_short_source_aborts(tmp_path):
    data = _data(960)
    spec = _spec(960, K=4, P=2)
    src = IterChunkSource([data[:480]])  # half the records the spec promises
    with pytest.raises(ValueError, match="960"):
        stream_partition(src, spec, out=str(tmp_path / "rsp"))
    assert not os.path.exists(os.path.join(str(tmp_path / "rsp"), "manifest.json"))


# ---------------------------------------------------------------------------
# ChunkSource adapters
# ---------------------------------------------------------------------------

def test_npy_and_directory_sources(tmp_path):
    data = _data(1280, f=4)
    npy = tmp_path / "corpus.npy"
    np.save(npy, data)
    src = as_chunk_source(str(npy), chunk_records=300)
    assert isinstance(src, NpyChunkSource)
    assert (src.num_records, src.record_shape, src.dtype) == (1280, (4,), np.float32)
    np.testing.assert_array_equal(np.concatenate(list(src.chunks())), data)

    # directory of chunk files, concatenated in sorted order
    d = tmp_path / "chunks"
    d.mkdir()
    np.save(d / "part_000.npy", data[:500])
    np.save(d / "part_001.npy", data[500:900])
    np.save(d / "part_002.npy", data[900:])
    dsrc = as_chunk_source(str(d))
    assert isinstance(dsrc, DirectoryChunkSource)
    assert dsrc.num_records == 1280
    np.testing.assert_array_equal(np.concatenate(list(dsrc.chunks())), data)

    spec = RSPSpec(num_records=1280, num_blocks=4, num_original_blocks=4,
                   record_shape=(4,), dtype="float32", seed=11)
    ref = two_stage_partition_np(data, spec)
    got, _ = stream_partition(dsrc, spec)
    np.testing.assert_array_equal(got, ref)


def test_iter_source_one_shot_guard():
    chunks = iter([np.zeros((4, 2), np.float32)])
    src = IterChunkSource(chunks, num_records=4, record_shape=(2,), dtype=np.float32)
    list(src.chunks())
    with pytest.raises(RuntimeError, match="already"):
        list(src.chunks())
    with pytest.raises(ValueError, match="up front"):
        IterChunkSource(iter([]))


def test_buffer_reusing_producer_is_safe():
    """A source that yields the SAME preallocated buffer every batch must not
    corrupt the partition: async scatter workers read segments after the
    producer has already overwritten the buffer."""
    data = _data(1920)
    spec = _spec(1920, K=8, P=4)
    ref = two_stage_partition_np(data, spec)

    def reused_buffer_batches():
        buf = np.empty((120, 5), dtype=np.float32)
        for a in range(0, 1920, 120):
            buf[:] = data[a : a + 120]
            yield buf  # same object every time

    src = IterChunkSource(reused_buffer_batches(), num_records=1920,
                          record_shape=(5,), dtype=np.float32)
    got, _ = stream_partition(src, spec, workers=4)
    np.testing.assert_array_equal(got, ref)


def test_eligibility_does_not_raise_on_broken_path_sources(tmp_path):
    """Capability predicates keep their reason-or-None contract even when
    adapter construction itself fails (e.g. an empty chunk directory)."""
    empty = tmp_path / "empty_dir"
    empty.mkdir()
    spec = _spec(960, K=4, P=2)
    reasons = rsp.backend_eligibility(PartitionRequest(data=str(empty), spec=spec))
    assert "not chunkable" in reasons["np_stream"]
    # ...while the facade surfaces the adapter's detailed reason
    with pytest.raises(ValueError, match="no .npy chunk files"):
        rsp.partition(str(empty), blocks=4)


def test_is_stream_source_classification(tmp_path):
    arr = np.zeros((8, 2), np.float32)
    assert not is_stream_source(arr)                      # in-RAM array -> np path
    np.save(tmp_path / "c.npy", arr)
    assert is_stream_source(str(tmp_path / "c.npy"))      # path streams
    mm = np.load(tmp_path / "c.npy", mmap_mode="r")
    assert is_stream_source(mm)                           # memmap streams
    assert not is_stream_source(object())                 # unadaptable


# ---------------------------------------------------------------------------
# Backend registry + facade wiring
# ---------------------------------------------------------------------------

def test_np_stream_registered_and_auto_selected(tmp_path):
    assert "np_stream" in rsp.available_backends()
    data = _data(960)
    spec = _spec(960, K=4, P=2)
    npy = tmp_path / "corpus.npy"
    np.save(npy, data)
    src = as_chunk_source(str(npy))
    assert select_backend(PartitionRequest(data=src, spec=spec)).name == "np_stream"
    # plain in-RAM arrays keep the np path unless out= asks for a store
    assert select_backend(PartitionRequest(data=data, spec=spec)).name == "np"
    assert (
        select_backend(
            PartitionRequest(data=data, spec=spec, out=str(tmp_path / "s"))
        ).name
        == "np_stream"
    )
    # in-memory backends refuse streaming sources with a clear reason
    reasons = rsp.backend_eligibility(PartitionRequest(data=src, spec=spec))
    assert reasons["np_stream"] is None
    for name in ("np", "jax", "shard_map", "pallas"):
        assert "np_stream" in reasons[name]


def test_memmap_still_served_by_explicit_in_memory_backends(tmp_path):
    """Regression: a memmap is a plain ndarray to the in-memory backends --
    explicit backend='np'/'jax' must keep working on it (auto still prefers
    the streaming path for memmaps)."""
    data = _data(960)
    np.save(tmp_path / "c.npy", data)
    mm = np.load(tmp_path / "c.npy", mmap_mode="r")
    spec = _spec(960, K=4, P=2, seed=13)
    ref = two_stage_partition_np(data, spec)
    ds = rsp.partition(mm, blocks=4, original_blocks=2, seed=13, backend="np")
    np.testing.assert_array_equal(ds.stacked(), ref)
    ds_jax = rsp.partition(mm, blocks=4, original_blocks=2, seed=13, backend="jax")
    assert ds_jax.backend == "jax"
    assert select_backend(PartitionRequest(data=mm, spec=spec)).name == "np_stream"


def test_run_partition_resolves_path_source_once(tmp_path, monkeypatch):
    """Raw-registry dispatch with a path input must build the chunk-source
    adapter once, not once per capability predicate."""
    import repro.rsp.ingest as ingest_mod
    from repro.rsp.backends import run_partition

    data = _data(960)
    np.save(tmp_path / "c.npy", data)
    spec = _spec(960, K=4, P=2)
    calls = []
    orig = ingest_mod.NpyChunkSource.__init__

    def counting(self, path, **kw):
        calls.append(path)
        orig(self, path, **kw)

    monkeypatch.setattr(ingest_mod.NpyChunkSource, "__init__", counting)
    result, chosen = run_partition(
        PartitionRequest(data=str(tmp_path / "c.npy"), spec=spec)
    )
    assert chosen == "np_stream" and len(calls) == 1
    np.testing.assert_array_equal(result, two_stage_partition_np(data, spec))


def test_facade_partition_from_path_and_from_source(tmp_path):
    data = _data(1920)
    spec = _spec(1920, K=8, P=8, seed=21)
    ref = two_stage_partition_np(data, spec)
    npy = tmp_path / "corpus.npy"
    np.save(npy, data)

    ds = rsp.partition(str(npy), blocks=8, seed=21, out=str(tmp_path / "st"))
    assert ds.backend == "np_stream" and ds.store is not None
    np.testing.assert_array_equal(ds.take(range(8)), ref)
    assert ds.has_summaries  # folded during the write, no extra scan

    # from_source forces streaming even for an in-RAM array, store-less
    ds2 = rsp.from_source(data, blocks=8, seed=21, chunk_records=217)
    assert ds2.backend == "np_stream"
    np.testing.assert_array_equal(ds2.stacked(), ref)
    ds.close()


def test_facade_streamed_query_matches_full_scan(tmp_path):
    data = _data(4096, f=6, seed=8)
    npy = tmp_path / "corpus.npy"
    np.save(npy, data)
    ds = rsp.from_source(str(npy), blocks=16, out=str(tmp_path / "st"), seed=2)
    before = ds.executor.stats()
    res = ds.query(["mean", "count"])
    assert res.from_sketches
    assert (ds.executor.stats() - before).blocks_fetched == 0
    np.testing.assert_allclose(
        res["mean"].estimate, data.mean(axis=0, dtype=np.float64), atol=1e-6
    )
    assert float(res["count"].estimate) == 4096
    ds.close()
