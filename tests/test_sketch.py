"""Unified sketch subsystem: registry round-trips, manifest schema
versioning (checked-in v1 fixture -> lazy upgrade), the v2 sidecar store
layout, and merge/accuracy contracts of the KLL and KMV members."""

import json
import os

import numpy as np
import pytest

from repro import rsp
from repro.core.registry import RSPStore
from repro.rsp.sketch import (
    SKETCH_SCHEMA_VERSION,
    DistinctSketch,
    HistogramSketch,
    KLLSketch,
    MomentsSketch,
    SketchSuite,
    kll_rank_error_bound,
    load_summaries,
    merge_suites,
    sketch_from_dict,
    sketch_schema_descriptor,
)
from repro.rsp.summaries import BlockSummary, summarize_block, summarize_blocks

V1_FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "v1_store")


def _rows(n=512, f=4, seed=0):
    rng = np.random.default_rng(seed)
    return rng.lognormal(mean=0.3, sigma=1.1, size=(n, f))


# ---------------------------------------------------------------------------
# Registry / versioned serialization
# ---------------------------------------------------------------------------

def _roundtrip(sk):
    """to_dict -> real JSON -> from_dict (via the registry), twice."""
    once = sketch_from_dict(json.loads(json.dumps(sk.to_dict())))
    twice = sketch_from_dict(json.loads(json.dumps(once.to_dict())))
    return once, twice


def test_all_four_kinds_roundtrip_bit_exact():
    rows = _rows()
    kinds = {
        "moments": MomentsSketch().update(rows),
        "histogram": HistogramSketch(
            16, rows.min(axis=0), rows.max(axis=0)
        ).update(rows),
        "kll": KLLSketch(64, seed=9).update(rows),
        "distinct": DistinctSketch(128).update(rows),
    }
    for kind, sk in kinds.items():
        once, twice = _roundtrip(sk)
        assert type(once) is type(sk), kind
        # bit-exact: every float survives JSON (which round-trips float64
        # exactly) and re-serializes to the identical payload
        assert once.to_dict() == sk.to_dict(), kind
        assert twice.to_dict() == sk.to_dict(), kind
    # revived sketches answer identically, not just serialize identically
    m, _ = _roundtrip(kinds["moments"])
    np.testing.assert_array_equal(m.mean, kinds["moments"].mean)
    np.testing.assert_array_equal(m.variance, kinds["moments"].variance)
    k, _ = _roundtrip(kinds["kll"])
    qs = [0.1, 0.5, 0.95]
    np.testing.assert_array_equal(k.quantile(qs), kinds["kll"].quantile(qs))
    d, _ = _roundtrip(kinds["distinct"])
    np.testing.assert_array_equal(d.estimate(), kinds["distinct"].estimate())


def test_unknown_kind_rejected():
    with pytest.raises(ValueError, match="unknown sketch kind"):
        sketch_from_dict({"kind": "nope"})


def test_suite_roundtrip_and_merge_matches_bulk():
    rows = _rows(600, 3, seed=1)
    halves = [rows[:301], rows[301:]]
    suites = [
        summarize_block(h.astype(np.float32), i, kll_k=64, kmv_k=128)
        for i, h in enumerate(halves)
    ]
    revived = load_summaries([json.loads(json.dumps(s.to_dict())) for s in suites])
    for orig, back in zip(suites, revived):
        assert back.to_dict() == orig.to_dict()
    merged = merge_suites(revived)
    bulk = summarize_block(rows.astype(np.float32), 0, kll_k=64, kmv_k=128)
    assert merged.count == bulk.count == rows.shape[0]
    np.testing.assert_allclose(merged.mean, bulk.mean, rtol=1e-12)
    np.testing.assert_allclose(merged.m2, bulk.m2, rtol=1e-9)
    np.testing.assert_array_equal(merged.min, bulk.min)
    np.testing.assert_array_equal(merged.max, bulk.max)
    # inputs must not be mutated by the merge
    assert revived[0].to_dict() == suites[0].to_dict()


def test_kll_merged_rank_error_within_bound():
    rng = np.random.default_rng(3)
    parts = [rng.lognormal(0.0, 1.4, size=(800, 2)) for _ in range(6)]
    k = 128
    suites = []
    for i, p in enumerate(parts):
        suites.append(SketchSuite.create(i, kll_k=k, kinds=["moments", "kll"]).update(p))
    kll = merge_suites(suites).get("kll")
    eps = kll_rank_error_bound(k)
    assert eps == kll.rank_error_bound()
    full = np.sort(np.concatenate(parts, axis=0), axis=0)
    n = full.shape[0]
    for q in (0.05, 0.5, 0.95):
        est = kll.quantile([q])[:, 0]
        lo = full[max(int(np.floor((q - eps) * n)), 0)]
        hi = full[min(int(np.ceil((q + eps) * n)), n - 1)]
        assert np.all(est >= lo) and np.all(est <= hi)


def test_kmv_merge_equals_union_sketch():
    rng = np.random.default_rng(5)
    a = rng.integers(0, 5000, size=(4000, 2)).astype(np.float64)
    b = rng.integers(2500, 9000, size=(4000, 2)).astype(np.float64)
    da, db = DistinctSketch(256).update(a), DistinctSketch(256).update(b)
    both = DistinctSketch(256).update(np.concatenate([a, b], axis=0))
    np.testing.assert_array_equal(da.merge(db).estimate(), both.estimate())


# ---------------------------------------------------------------------------
# v1 manifests: the checked-in fixture opens unchanged
# ---------------------------------------------------------------------------

def test_v1_fixture_opens_through_new_schema_path():
    store = RSPStore(V1_FIXTURE)
    assert store.sketch_schema() is None  # predates suite schemas
    raw = store.summaries()
    assert raw and "sketches" not in raw[0]  # genuinely v1 on disk
    ds = rsp.open(V1_FIXTURE)
    try:
        assert ds.has_summaries and isinstance(ds.summaries[0], SketchSuite)
        # sketch-only moments still work -- and still read zero blocks
        before = ds.executor.stats()
        res = ds.query(["mean", "count"])
        assert res.from_sketches
        assert (ds.executor.stats() - before).blocks_fetched == 0
        data = np.concatenate(
            [np.asarray(store.load_block(k)) for k in range(store.num_blocks())]
        ).astype(np.float64)
        assert float(res["count"].estimate) == data.shape[0]
        np.testing.assert_allclose(res["mean"].estimate, data.mean(axis=0), rtol=1e-12)
    finally:
        ds.close()


def test_v1_lazy_upgrade_answers_identical_moments():
    store = RSPStore(V1_FIXTURE)
    for d in store.summaries():
        legacy = BlockSummary.from_dict(d)
        suite = SketchSuite.from_dict(d)  # lazy v1 upgrade
        assert suite.block_id == legacy.block_id
        assert suite.count == legacy.count
        for attr in ("mean", "m2", "min", "max", "variance", "std"):
            np.testing.assert_array_equal(
                getattr(suite, attr), getattr(legacy, attr), err_msg=attr
            )
        np.testing.assert_array_equal(suite.label_hist, legacy.label_hist)
        # richer kinds are honestly absent, not fabricated
        assert suite.get("kll") is None and suite.get("distinct") is None


def test_v1_upgrade_rewrites_as_v2_without_changing_answers(tmp_path):
    ds = rsp.open(V1_FIXTURE)
    try:
        v1_mean = ds.query(["mean"])["mean"].estimate
        out = str(tmp_path / "upgraded.rsp")
        ds.save(out)
    finally:
        ds.close()
    # the rewrite keeps the v1 layout for upgraded (moments-only) suites:
    # a moments+labels suite has no schema descriptor worth pinning
    ds2 = rsp.open(out)
    try:
        np.testing.assert_array_equal(ds2.query(["mean"])["mean"].estimate, v1_mean)
    finally:
        ds2.close()


# ---------------------------------------------------------------------------
# v2 stores: sidecar layout + full-suite round-trip
# ---------------------------------------------------------------------------

def test_v2_store_sidecar_roundtrips_suites_bit_exact(tmp_path):
    rng = np.random.default_rng(11)
    blocks = rng.normal(size=(4, 64, 3)).astype(np.float32)
    suites = summarize_blocks(blocks, kll_k=64, kmv_k=64)
    schema = sketch_schema_descriptor(suites)
    assert schema["version"] == SKETCH_SCHEMA_VERSION
    assert set(schema["kinds"]) == {"moments", "kll", "distinct"}

    root = str(tmp_path / "v2.rsp")
    store = RSPStore(root)
    from repro.core.types import RSPSpec

    spec = RSPSpec(num_records=256, num_blocks=4, num_original_blocks=4,
                   record_shape=(3,), dtype="float32")
    store.write_partition(blocks, spec, summaries=suites, sketch_schema=schema)

    # manifest stays light; the payload lives in the sidecar
    with open(os.path.join(root, RSPStore.MANIFEST)) as f:
        manifest = json.load(f)
    assert "summaries" not in manifest
    assert manifest["sketches_file"] == RSPStore.SKETCHES
    assert manifest["sketch_schema"] == schema
    assert os.path.isfile(os.path.join(root, RSPStore.SKETCHES))

    reopened = RSPStore(root)
    assert reopened.sketch_schema() == schema
    got = load_summaries(reopened.summaries())
    assert len(got) == len(suites)
    for back, orig in zip(got, suites):
        assert back.to_dict() == orig.to_dict()  # all kinds, bit-exact


def test_v2_dataset_reopen_keeps_sketch_answers(tmp_path):
    rng = np.random.default_rng(13)
    data = rng.lognormal(0.2, 1.0, size=(8192, 2)).astype(np.float32)
    ds = rsp.partition(data, blocks=16, seed=5)
    out = str(tmp_path / "q.rsp")
    ds.save(out)
    want = ds.query(["p50", "count"], use_sketches=True)
    ds2 = rsp.open(out)
    try:
        before = ds2.executor.stats()
        got = ds2.query(["p50", "count"], use_sketches=True)
        assert got.from_sketches
        assert (ds2.executor.stats() - before).blocks_fetched == 0
        np.testing.assert_array_equal(
            got["p50"].estimate, want["p50"].estimate
        )
        assert float(got["count"].estimate) == data.shape[0]
    finally:
        ds2.close()
