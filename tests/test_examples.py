"""Examples must run end to end (subprocess; fast configs only)."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(script, *args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", script), *args],
        env=env, capture_output=True, text=True, timeout=timeout,
    )


@pytest.mark.slow
def test_quickstart_runs():
    proc = _run("quickstart.py")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "ensemble accuracy per batch" in proc.stdout
    assert "worst label divergence 0.0" in proc.stdout


@pytest.mark.slow
def test_serve_queries_runs():
    proc = _run("serve_queries.py")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "outcome=deadline" in proc.stdout
    assert "anytime CI covers the full-scan mean: True" in proc.stdout
    assert "saturated service rejected the second tenant" in proc.stdout


@pytest.mark.slow
def test_train_lm_rsp_preempt_restart():
    proc = _run("train_lm_rsp.py", "--steps", "10", "--preempt-at", "5")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "(OK)" in proc.stdout
    assert "restart resumed exactly" in proc.stdout
