"""Block-level sampler (Definition 4) tests: without-replacement semantics,
determinism, O(1) resumability, host dealing + failure redistribution."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tests skip below; the rest of the module runs
    HAVE_HYPOTHESIS = False

from repro.core import BlockSampler, deal_blocks


def test_without_replacement_within_epoch():
    s = BlockSampler(num_blocks=20, seed=3)
    seen = []
    for _ in range(4):
        ids = s.sample(5)
        assert len(ids) == 5
        seen.extend(ids)
    assert sorted(seen) == list(range(20))  # exactly one epoch, no repeats


def test_epoch_rollover_reshuffles():
    s = BlockSampler(num_blocks=6, seed=0)
    e0 = s.sample(6)
    e1 = s.sample(6)
    assert sorted(e0) == sorted(e1) == list(range(6))
    assert e0 != e1  # overwhelmingly likely with 6! orders


def test_determinism_same_seed():
    a = BlockSampler(num_blocks=50, seed=9)
    b = BlockSampler(num_blocks=50, seed=9)
    assert a.sample(30) == b.sample(30)


def test_resume_equals_uninterrupted():
    ref = BlockSampler(num_blocks=40, seed=5)
    ref_ids = [ref.sample(7) for _ in range(8)]

    live = BlockSampler(num_blocks=40, seed=5)
    got = [live.sample(7) for _ in range(3)]
    state = live.state_dict()  # "checkpoint"
    resumed = BlockSampler.from_state_dict(40, state)
    got += [resumed.sample(7) for _ in range(5)]
    assert got == ref_ids


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        k=st.integers(1, 200),
        g=st.integers(1, 50),
        batches=st.integers(1, 20),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_sampler_property(k, g, batches, seed):
        s = BlockSampler(num_blocks=k, seed=seed)
        out = []
        for _ in range(batches):
            ids = s.sample(g)
            assert len(ids) == g
            assert all(0 <= i < k for i in ids)
            out.extend(ids)
        # within any epoch-aligned window of k draws, ids are a permutation
        for start in range(0, (len(out) // k) * k, k):
            window = out[start : start + k]
            assert sorted(window) == list(range(k))

else:

    def test_sampler_property():
        pytest.importorskip("hypothesis")


def test_deal_blocks_covers_all():
    a = deal_blocks(num_blocks=33, num_hosts=4, seed=1)
    all_blocks = sorted(b for h in range(4) for b in a.blocks_for(h))
    assert all_blocks == list(range(33))


def test_redistribute_on_host_failure():
    a = deal_blocks(num_blocks=32, num_hosts=4, seed=1)
    before = {h: list(a.blocks_for(h)) for h in range(4)}
    b = a.redistribute([2])
    assert b.blocks_for(2) == []
    survivors = sorted(x for h in (0, 1, 3) for x in b.blocks_for(h))
    assert survivors == list(range(32))
    # survivors keep their original blocks (only orphans move)
    for h in (0, 1, 3):
        assert set(before[h]).issubset(set(b.blocks_for(h)))


def test_redistribute_all_failed_raises():
    a = deal_blocks(num_blocks=8, num_hosts=2, seed=0)
    with pytest.raises(ValueError):
        a.redistribute([0, 1])


def test_batches_iterator_respects_epoch():
    s = BlockSampler(num_blocks=10, seed=2)
    batches = list(s.batches(4))
    assert [len(b) for b in batches] == [4, 4, 2]
    assert sorted(sum(batches, [])) == list(range(10))
