"""``repro.obs`` tests: metrics registry semantics, tracer sampling and
Chrome export, convergence traces, cross-thread trace-context propagation
through engine/query/serve (including the deadline sweeper), and the
``QueryService.metrics()`` <-> registry reconciliation."""

import json
import math
import threading
import time

import numpy as np
import pytest

from repro import obs, rsp
from repro.obs.convergence import ConvergenceStep, ConvergenceTrace
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import DROPPED, Tracer
from repro.rsp.engine import BlockExecutor, MemoryFetcher


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with telemetry off and empty."""
    obs.reset()
    yield
    obs.reset()


def _data(blocks=16, n=512, f=3, seed=0):
    rng = np.random.default_rng(seed)
    return rng.lognormal(0.0, 1.0, size=(blocks * n, f)).astype(np.float32)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "help", route="a")
    c.inc()
    c.inc(2)
    assert c.value == 3
    assert reg.counter("c_total", route="a") is c  # stable handle
    assert reg.counter("c_total", route="b").value == 0  # sibling label set
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g")
    g.set(5)
    g.add(-2)
    assert g.value == 3


def test_kind_mismatch_rejected():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x")


def test_histogram_buckets_and_quantile():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", start=1e-3, factor=2.0, buckets=10)
    for v in [0.001, 0.002, 0.004, 0.1]:
        h.observe(v)
    assert h.count == 4
    assert h.sum == pytest.approx(0.107)
    assert h.mean == pytest.approx(0.107 / 4)
    assert h.quantile(0.5) <= h.quantile(1.0)
    snap = h.snapshot()
    assert sum(snap["buckets"].values()) == 4
    assert math.inf in snap["buckets"]  # overflow bucket always present


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("hits_total", "cache hits", kind="memory").inc(3)
    h = reg.histogram("fetch_seconds", "latency", start=1e-3, buckets=4)
    h.observe(0.002)
    h.observe(100.0)  # overflow
    text = reg.to_prometheus()
    assert '# TYPE hits_total counter' in text
    assert 'hits_total{kind="memory"} 3.0' in text
    assert '# TYPE fetch_seconds histogram' in text
    assert 'le="+Inf"' in text
    assert "fetch_seconds_count 2" in text
    # buckets are cumulative: the +Inf series equals the count
    inf_line = [ln for ln in text.splitlines() if 'le="+Inf"' in ln][0]
    assert inf_line.endswith(" 2")


def test_registry_json_roundtrips():
    reg = MetricsRegistry()
    reg.counter("a_total").inc()
    reg.histogram("b_seconds").observe(0.5)
    parsed = json.loads(reg.to_json())
    assert parsed["a_total"]["series"][0]["value"] == 1.0
    assert parsed["b_seconds"]["kind"] == "histogram"


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_span_parenting_and_chrome_events():
    tr = Tracer()
    root = tr.start_span("root", attrs={"q": 1})
    child = tr.start_span("child", parent=root.ctx)
    child.end()
    child.end()  # idempotent: must not double-record
    root.end()
    assert len(tr) == 2
    events = tr.chrome_events()
    xs = [e for e in events if e["ph"] == "X"]
    by_name = {e["name"]: e for e in xs}
    assert by_name["child"]["args"]["trace_id"] == by_name["root"]["args"]["trace_id"]
    assert by_name["child"]["args"]["parent_id"] == by_name["root"]["args"]["span_id"]
    assert by_name["root"]["args"]["q"] == 1
    assert all(e["dur"] >= 1 for e in xs)
    assert any(e["ph"] == "M" and e["name"] == "thread_name" for e in events)


def test_unsampled_root_suppresses_children():
    tr = Tracer(sample_rate=0.0)
    root = tr.start_span("root")
    child = tr.start_span("child", parent=root.ctx)
    assert root.ctx is DROPPED and child.ctx is DROPPED
    root.end()
    child.end()
    assert len(tr) == 0


def test_bounded_buffer_counts_drops():
    tr = Tracer(max_events=4)
    for i in range(6):
        tr.start_span(f"s{i}").end()
    assert len(tr) == 4
    assert tr.dropped == 2


def test_export_chrome_is_loadable(tmp_path):
    tr = Tracer()
    with tr.span("op", attrs={"k": "v"}):
        pass
    path = tmp_path / "trace.json"
    n = tr.export_chrome(path)
    payload = json.loads(path.read_text())
    assert len(payload["traceEvents"]) == n
    assert any(e["name"] == "op" for e in payload["traceEvents"])


# ---------------------------------------------------------------------------
# Convergence traces
# ---------------------------------------------------------------------------

def test_convergence_trace_report_and_dict():
    trace = ConvergenceTrace(confidence=0.95, target_rel_err=0.05)
    for b, err in [(1, 0.5), (2, 0.1), (3, 0.04)]:
        trace.record(ConvergenceStep(
            blocks_read=b, block_id=b - 1, max_rel_err=err,
            estimates={"mean": 1.0}, half_widths={"mean": err},
            cum_fetch_s=0.01 * b, elapsed_s=0.02 * b,
        ))
    assert len(trace) == 3
    assert trace.blocks == [1, 2, 3]
    assert trace.half_widths("mean") == [0.5, 0.1, 0.04]
    d = trace.to_dict()
    assert d["steps"][2]["max_rel_err"] == 0.04
    rep = trace.report()
    assert "3 steps" in rep and "<- target met" in rep


# ---------------------------------------------------------------------------
# Global toggle
# ---------------------------------------------------------------------------

def test_disabled_by_default_and_hot_paths_stay_silent():
    assert not obs.enabled()
    ds = rsp.partition(_data(blocks=8), blocks=8, seed=0)
    ds.query("median", target_rel_err=0.2, use_sketches=False, seed=1)
    ds.close()
    assert obs.get_registry().snapshot() == {}
    assert len(obs.get_tracer()) == 0


def test_env_init(monkeypatch):
    monkeypatch.setenv("REPRO_OBS", "on")
    monkeypatch.setenv("REPRO_OBS_SAMPLE", "0.25")
    obs._init_from_env()
    assert obs.enabled()
    assert obs.get_tracer().sample_rate == 0.25


# ---------------------------------------------------------------------------
# Trace-context propagation (tentpole wiring)
# ---------------------------------------------------------------------------

def test_engine_fetch_metrics_by_outcome():
    obs.enable()
    blocks = np.random.default_rng(0).normal(size=(4, 32, 3)).astype(np.float32)
    with BlockExecutor(MemoryFetcher(blocks), prefetch=0, cache_blocks=4) as ex:
        ex.fetch(0)  # miss
        ex.fetch(0)  # hit
        ex.fetch(1)  # miss
    snap = obs.get_registry().snapshot()
    series = {
        dict(s["labels"])["outcome"]: s["value"]
        for s in snap["rsp_engine_fetch_total"]["series"]
    }
    assert series == {"hit": 1.0, "miss": 2.0}
    assert snap["rsp_engine_rows_fetched_total"]["series"][0]["value"] == 64.0


def test_query_spans_propagate_to_engine_workers():
    obs.enable()
    ds = rsp.partition(_data(blocks=16), blocks=16, seed=0)
    res = ds.query("median", target_rel_err=0.02, use_sketches=False, seed=1)
    ds.close()
    assert res.blocks_read > 0
    xs = [e for e in obs.get_tracer().chrome_events() if e["ph"] == "X"]
    roots = [e for e in xs if e["name"] == "query"]
    fetches = [e for e in xs if e["name"] == "engine.fetch"]
    assert len(roots) == 1 and fetches
    root = roots[0]
    assert all(f["args"]["trace_id"] == root["args"]["trace_id"] for f in fetches)
    assert all(f["args"]["parent_id"] == root["args"]["span_id"] for f in fetches)
    # the dataset executor prefetches: fetch spans run on pool threads
    assert any(f["tid"] != root["tid"] for f in fetches)


class _SlowFetcher:
    """MemoryFetcher with a per-fetch delay: keeps serve queries alive long
    enough for deadlines to fire deterministically."""

    def __init__(self, blocks, delay: float):
        self._inner = MemoryFetcher(blocks)
        self._delay = delay

    @property
    def num_blocks(self) -> int:
        return self._inner.num_blocks

    def fetch(self, block_id: int):
        time.sleep(self._delay)
        return self._inner.fetch(block_id)


def test_deadline_sweeper_span_parents_under_query():
    obs.enable()
    ds = rsp.partition(_data(blocks=32), blocks=32, seed=0)
    ds._executor = BlockExecutor(
        _SlowFetcher(ds._blocks, delay=0.03), prefetch=2, cache_blocks=64
    )
    with ds.serve(workers=2, seed=0) as svc:
        t = svc.submit(
            "median", target_rel_err=1e-9, use_sketches=False, deadline_ms=150
        )
        # wait on the ticket (NOT svc.result): only the sweeper thread can
        # finalize it, which is exactly the cross-thread hop under test
        assert t.wait(10.0)
        assert t.outcome == "deadline"
    ds.close()
    xs = [e for e in obs.get_tracer().chrome_events() if e["ph"] == "X"]
    roots = [e for e in xs if e["name"] == "query"]
    deadlines = [e for e in xs if e["name"] == "serve.deadline"]
    assert len(roots) == 1 and len(deadlines) == 1
    root, dl = roots[0], deadlines[0]
    assert dl["args"]["trace_id"] == root["args"]["trace_id"]
    assert dl["args"]["parent_id"] == root["args"]["span_id"]
    assert dl["tid"] != root["tid"]  # recorded from the sweeper thread


def test_mixed_serve_workload_trace_is_well_formed(tmp_path):
    obs.enable()
    ds = rsp.partition(_data(blocks=32, n=256), blocks=32, seed=0)
    tickets: list = []
    with ds.serve(capacity=64, workers=8, seed=1) as svc:
        def tenant(i: int) -> None:
            for j in range(2):
                if (i + j) % 3 == 0:
                    tickets.append(svc.submit("mean"))  # sketch fast path
                else:
                    tickets.append(svc.submit(
                        "median", target_rel_err=0.05, use_sketches=False,
                        deadline_ms=5000,
                    ))

        submitters = [threading.Thread(target=tenant, args=(i,)) for i in range(12)]
        for th in submitters:
            th.start()
        for th in submitters:
            th.join()
        for t in list(tickets):
            t.wait(30.0)
    ds.close()

    path = tmp_path / "trace.json"
    n = obs.get_tracer().export_chrome(path)
    payload = json.loads(path.read_text())
    events = payload["traceEvents"]
    assert len(events) == n
    xs = [e for e in events if e["ph"] == "X"]
    for e in xs:  # every span event fully formed
        assert isinstance(e["ts"], int) and isinstance(e["dur"], int) and e["dur"] >= 1
        assert {"trace_id", "span_id"} <= e["args"].keys()
    root_traces = {e["args"]["trace_id"] for e in xs if e["name"] == "query"}
    children = [e for e in xs if "parent_id" in e["args"]]
    assert children
    assert all(c["args"]["trace_id"] in root_traces for c in children)
    assert len({e["tid"] for e in xs}) >= 3  # submitters, workers, engine pool


# ---------------------------------------------------------------------------
# Convergence traces on live queries
# ---------------------------------------------------------------------------

def test_explain_records_per_block_trace():
    ds = rsp.partition(_data(blocks=16), blocks=16, seed=0)
    # 4% target: the KLL-seeded bootstrap grid resolves the quantile CI
    # honestly (no coarse-bin smoothing), which sits just above 3% here
    res = ds.query("median", target_rel_err=0.04, use_sketches=False,
                   seed=2, explain=True)
    ds.close()
    trace = res.trace
    assert trace is not None and len(trace) == res.blocks_read
    assert trace.blocks == list(range(1, res.blocks_read + 1))
    last = trace.steps[-1]
    r = res.aggregates[0]
    half = (np.asarray(r.ci_hi, float) - np.asarray(r.ci_lo, float)) / 2.0
    want = float(np.nanmax(half)) if np.any(~np.isnan(half)) else math.nan
    assert last.half_widths[r.name] == pytest.approx(want, rel=1e-12)
    assert last.max_rel_err <= 0.04  # it converged and the trace shows it
    assert "<- target met" in trace.report()


def test_sketch_answer_has_zero_block_trace():
    ds = rsp.partition(_data(blocks=8), blocks=8, seed=0)
    res = ds.query("mean", explain=True)
    ds.close()
    assert res.from_sketches
    assert res.trace is not None and len(res.trace) == 1
    step = res.trace.steps[0]
    assert step.blocks_read == 0 and step.cum_fetch_s == 0.0


# ---------------------------------------------------------------------------
# QueryService.metrics() as a registry view (satellite 3)
# ---------------------------------------------------------------------------

def test_service_metrics_reconcile_with_registry_and_callers():
    ds = rsp.partition(_data(blocks=16), blocks=16, seed=0)
    # prefetch=0: fetches run inline during steps, so per-caller counts are
    # settled the instant a ticket finalizes -- exact reconciliation below
    ds._executor = BlockExecutor(
        MemoryFetcher(ds._blocks), prefetch=0, cache_blocks=32
    )
    with ds.serve(capacity=2, max_queue=0, workers=2, seed=0) as svc:
        sketch = [svc.submit("mean") for _ in range(3)]
        prog, rejected = [], []
        for _ in range(6):
            t = svc.submit("median", target_rel_err=0.05, use_sketches=False,
                           on_reject="ticket")
            (rejected if t.outcome == "rejected" else prog).append(t)
        for t in sketch + prog:
            t.wait(30.0)
        m = svc.metrics()
        snap = svc.registry.snapshot()

    submitted = snap["rsp_serve_submitted_total"]["series"][0]["value"]
    outcomes = {
        dict(s["labels"])["outcome"]: s["value"]
        for s in snap["rsp_serve_queries_total"]["series"]
    }
    assert m.submitted == submitted == 3 + len(prog) + len(rejected)
    assert sum(outcomes.values()) == m.submitted  # every ticket is terminal
    assert m.rejected == len(rejected)
    assert m.sketch_answers == outcomes.get("sketch", 0) == 3
    assert m.completed == m.submitted - m.rejected

    # blocks: the registry counter, metrics(), and the per-caller stats on
    # the tickets' own results are the same number -- one book of record
    blocks_counter = snap["rsp_serve_blocks_fetched_total"]["series"][0]["value"]
    per_caller = sum(
        t.result.executor_stats.blocks_fetched
        for t in sketch + prog
        if t.result is not None
    )
    assert m.blocks_fetched == blocks_counter == per_caller

    prom = svc.registry.to_prometheus()
    assert "rsp_serve_submitted_total" in prom
    assert 'rsp_serve_queries_total{outcome="sketch"} 3.0' in prom
    ds.close()
