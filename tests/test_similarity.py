"""Sec.-7 similarity toolkit tests: MMD, Hotelling T^2, KS, label divergence
(Fig 2): RSP blocks are indistinguishable from the full data; sequential
blocks of sorted data are detectably different."""

import numpy as np
import jax.numpy as jnp

from repro.core import (
    RSPSpec,
    hotelling_t2,
    ks_statistic,
    label_distribution,
    max_label_divergence,
    median_heuristic_gamma,
    mmd2_rbf,
    mmd_block_vs_data,
    two_stage_partition_np,
)
from repro.data import make_higgs_like, make_nonrandom_higgs_like


def _blocks_and_data(shuffle: bool):
    maker = make_higgs_like if shuffle else make_nonrandom_higgs_like
    x, y = maker(8000, seed=3, class_sep=2.0)
    data = np.concatenate([x, y[:, None].astype(np.float32)], axis=1)
    return data


def test_mmd_rsp_block_small_sequential_block_large():
    data = _blocks_and_data(shuffle=False)  # class-sorted
    seq_block = data[:800]  # first sequential chunk: all class 0
    spec = RSPSpec(num_records=8000, num_blocks=10, num_original_blocks=10, seed=1)
    rsp_block = two_stage_partition_np(data, spec)[0]
    mmd_seq = mmd_block_vs_data(seq_block, data, seed=0)
    mmd_rsp = mmd_block_vs_data(rsp_block, data, seed=0)
    assert mmd_rsp < mmd_seq / 5
    assert abs(mmd_rsp) < 5e-3


def test_mmd_identical_distributions_near_zero():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(400, 8)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(400, 8)).astype(np.float32))
    gamma = median_heuristic_gamma(np.asarray(x))
    assert abs(float(mmd2_rbf(x, y, jnp.asarray(gamma)))) < 0.01


def test_mmd_shifted_distributions_large():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(400, 8)).astype(np.float32))
    y = jnp.asarray((rng.normal(size=(400, 8)) + 2.0).astype(np.float32))
    gamma = median_heuristic_gamma(np.asarray(x))
    assert float(mmd2_rbf(x, y, jnp.asarray(gamma))) > 0.1


def test_hotelling_t2_detects_mean_shift():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(300, 5))
    y_same = rng.normal(size=(300, 5))
    y_shift = rng.normal(size=(300, 5)) + 0.5
    _, _, p_same = hotelling_t2(x, y_same)
    _, _, p_shift = hotelling_t2(x, y_shift)
    assert p_same > 0.01       # fail to reject H0
    assert p_shift < 1e-6      # reject decisively


def test_hotelling_t2_rsp_block_vs_data():
    data = _blocks_and_data(shuffle=True)
    spec = RSPSpec(num_records=8000, num_blocks=10, num_original_blocks=10, seed=4)
    block = two_stage_partition_np(data, spec)[3]
    _, _, p = hotelling_t2(block[:, :-1], data[:500, :-1])
    assert p > 0.001  # block mean indistinguishable from data mean


def test_ks_statistic_basics():
    rng = np.random.default_rng(3)
    a = rng.normal(size=5000)
    b = rng.normal(size=5000)
    c = rng.normal(loc=1.0, size=5000)
    assert ks_statistic(a, b) < 0.05
    assert ks_statistic(a, c) > 0.3


def test_label_distribution_fig2a():
    """Fig 2a: label frequencies in RSP blocks track the whole data set."""
    x, y = make_nonrandom_higgs_like(6000, seed=5)
    data = np.concatenate([x, y[:, None].astype(np.float32)], axis=1)
    spec = RSPSpec(num_records=6000, num_blocks=10, num_original_blocks=10, seed=2)
    blocks = two_stage_partition_np(data, spec)
    full = label_distribution(y, 2)
    for k in range(10):
        div = max_label_divergence(blocks[k][:, -1], y, 2)
        assert div < 0.06, f"block {k} diverges {div}"
    # sequential chunking of the sorted data fails the same check
    seq = data[:600]
    assert max_label_divergence(seq[:, -1], y, 2) > 0.4
    assert np.isclose(full.sum(), 1.0)
