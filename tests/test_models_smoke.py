"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes and finiteness, plus a decode-vs-forward
consistency check for every family that serves."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.configs.shapes import ShapeCell
from repro.models import api, transformer
from repro.models.common import init_params, param_count
from repro.optim.adamw import adamw_init, adamw_update  # noqa: F401  (exercised in test_train)

CELL = ShapeCell("smoke", "train", 16, 2)


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCHS:
        cfg = smoke_config(arch)
        specs = api.model_specs(cfg)
        params = init_params(specs, jax.random.PRNGKey(0))
        out[arch] = (cfg, specs, params)
    return out


@pytest.mark.parametrize("arch", list(ARCHS))
def test_forward_and_loss(arch, built):
    cfg, specs, params = built[arch]
    batch = api.concrete_inputs(cfg, CELL, seed=1)
    loss, metrics = jax.jit(api.make_loss_fn(cfg))(params, batch)
    assert jnp.isfinite(loss), f"{arch} loss not finite"
    # near ln(vocab) at init (well-conditioned initialization)
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 3.0 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_forward_shapes(arch, built):
    cfg, specs, params = built[arch]
    batch = api.concrete_inputs(cfg, CELL, seed=2)
    fwd = jax.jit(api.make_forward_fn(cfg))
    logits = fwd(params, batch)
    S = batch["frames"].shape[1] if cfg.family == "encoder" else batch["tokens"].shape[1]
    assert logits.shape == (2, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", list(ARCHS))
def test_grad_step_decreases_loss(arch, built):
    cfg, specs, params = built[arch]
    batch = api.concrete_inputs(cfg, CELL, seed=3)
    loss_fn = api.make_loss_fn(cfg)

    @jax.jit
    def sgd(params, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        new = jax.tree.map(lambda p, g: p - 0.3 * g.astype(p.dtype), params, grads)
        return loss, new

    l0, params1 = sgd(params, batch)
    l1, _ = sgd(params1, batch)
    assert jnp.isfinite(l0) and jnp.isfinite(l1)
    assert float(l1) < float(l0), f"{arch}: {float(l0)} -> {float(l1)}"


@pytest.mark.parametrize(
    "arch",
    [a for a in ARCHS if ARCHS[a].family != "encoder"],
)
def test_decode_matches_forward(arch, built):
    """Greedy decode logits == teacher-forced forward logits, per position."""
    cfg, specs, params = built[arch]
    rng = np.random.default_rng(4)
    T = 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, T), np.int32))
    full_logits = jax.jit(api.make_forward_fn(cfg))(params, {"tokens": tokens})

    caches = transformer.init_caches(cfg, 2, T, dtype=jnp.float32)
    decode = jax.jit(api.make_decode_fn(cfg))
    got = []
    for t in range(T):
        logits, caches = decode(params, caches, {"tokens": tokens[:, t : t + 1]})
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(full_logits), rtol=8e-2, atol=8e-2
    )
    # argmax agreement (the thing that matters for greedy decoding)
    agree = (jnp.argmax(got, -1) == jnp.argmax(full_logits, -1)).mean()
    assert float(agree) > 0.9, f"{arch}: argmax agreement {float(agree)}"


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if ARCHS[a].family != "encoder"]
)
def test_prefill_then_decode(arch, built):
    cfg, specs, params = built[arch]
    rng = np.random.default_rng(5)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8), np.int32))
    caches = transformer.init_caches(cfg, 2, 16, dtype=jnp.float32)
    prefill = jax.jit(api.make_prefill_fn(cfg))
    logits, caches = prefill(params, caches, {"tokens": tokens[:, :7]})
    assert logits.shape == (2, 1, cfg.vocab_size)
    decode = jax.jit(api.make_decode_fn(cfg))
    logits2, caches = decode(params, caches, {"tokens": tokens[:, 7:8]})
    assert logits2.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())
    assert int(caches["pos"]) == 8


def test_exact_arch_dims():
    """The registry carries the exact assigned dimensions."""
    c = ARCHS["granite-20b"]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == (
        52, 6144, 48, 1, 24576, 49152,
    )
    c = ARCHS["qwen3-moe-30b-a3b"]
    assert (c.num_experts, c.num_experts_per_token, c.d_ff, c.vocab_size) == (128, 8, 768, 151936)
    c = ARCHS["zamba2-7b"]
    assert (c.num_layers, c.d_model, c.ssm_state, c.attn_every) == (81, 3584, 64, 6)
    c = ARCHS["rwkv6-1.6b"]
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size) == (24, 2048, 7168, 65536)
    c = ARCHS["hubert-xlarge"]
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size) == (48, 1280, 16, 5120, 504)
    assert not c.causal


def test_full_param_counts_sane():
    """Full-config parameter counts are in the advertised ballpark."""
    from repro.models.api import model_specs

    expect = {
        "llama3.2-1b": (1.0e9, 1.9e9),
        "granite-20b": (18e9, 23e9),
        "qwen3-14b": (12e9, 16.5e9),
        "qwen2-0.5b": (0.35e9, 0.75e9),
        "zamba2-7b": (6e9, 9e9),
        "chameleon-34b": (30e9, 37e9),
        "granite-moe-3b-a800m": (2.5e9, 4e9),
        "qwen3-moe-30b-a3b": (26e9, 33e9),
        "rwkv6-1.6b": (1.3e9, 2.2e9),
        "hubert-xlarge": (0.8e9, 1.3e9),
    }
    for arch, (lo, hi) in expect.items():
        n = param_count(model_specs(ARCHS[arch]))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]"
