"""End-to-end ``where=`` / ``columns=`` queries through the plan-compiled
fused kernels: predicate filtering with honest selectivity-aware CIs, the
sketch fast path declining filtered queries, column projection, grouped
filtered aggregates, weighted policies, and the serve path."""

import dataclasses

import numpy as np
import pytest

from repro import rsp


@pytest.fixture(scope="module")
def plain_ds():
    rng = np.random.default_rng(5)
    data = rng.normal(1.5, 2.0, size=(20000, 4)).astype(np.float32)
    return rsp.partition(data, blocks=50, seed=3), data


@pytest.fixture(scope="module")
def labelled_ds():
    rng = np.random.default_rng(1)
    n, k = 24000, 40
    x = rng.normal(1.5, 2.0, size=(n, 3)).astype(np.float32)
    y = rng.integers(0, 2, size=(n, 1)).astype(np.float32)
    data = np.concatenate([x, y], axis=1)
    return rsp.partition(data, blocks=k, seed=7, num_classes=2), data


def _masked(data, col, thresh):
    return data[data[:, col] > np.float32(thresh)].astype(np.float64)


def test_where_filtered_mean(plain_ds):
    ds, data = plain_ds
    res = ds.query("mean", where="c0 > 1.5", seed=3)
    truth = _masked(data, 0, 1.5).mean(0)
    assert not res.from_sketches
    assert res.blocks_read > 0
    # roughly half the rows pass (threshold at the distribution mean)
    assert 0.3 < res.selectivity < 0.7
    np.testing.assert_allclose(res["mean"].estimate, truth, atol=0.05)


def test_where_full_scan_is_exact(plain_ds):
    ds, data = plain_ds
    res = ds.query(
        ["mean", "count", "sum"], where="c1 < 1.0", min_blocks=50, seed=0
    )
    sel = data[data[:, 1] < np.float32(1.0)].astype(np.float64)
    assert res.blocks_read == res.total_blocks
    assert res.selectivity == pytest.approx(sel.shape[0] / data.shape[0])
    assert res["count"].estimate == pytest.approx(sel.shape[0], rel=1e-6)
    np.testing.assert_allclose(res["mean"].estimate, sel.mean(0), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(res["sum"].estimate, sel.sum(0), rtol=1e-4)


def test_where_conjunction_and_tuple_specs(plain_ds):
    ds, data = plain_ds
    res = ds.query("count", where=["c0 > 1.5", (2, "<", 2.0)], min_blocks=50)
    m = (data[:, 0] > np.float32(1.5)) & (data[:, 2] < np.float32(2.0))
    assert res["count"].estimate == pytest.approx(int(m.sum()), rel=1e-6)
    assert res.selectivity == pytest.approx(m.mean())


def test_where_ci_covers_truth(plain_ds):
    ds, data = plain_ds
    res = ds.query(
        rsp.Aggregate("mean", feature=1), where="c0 > 1.5", seed=11, max_blocks=15
    )
    truth = _masked(data, 0, 1.5).mean(0)[1]
    agg = res.aggregates[0]
    assert res.blocks_read == 15
    assert agg.ci_lo < truth < agg.ci_hi


def test_unfiltered_has_no_selectivity(plain_ds):
    ds, _ = plain_ds
    assert ds.query("mean").selectivity is None
    assert ds.query("median", use_sketches=False, max_blocks=5).selectivity is None


def test_where_declines_sketch_fast_path(plain_ds):
    ds, _ = plain_ds
    # the same aggregates WITHOUT a predicate take the zero-read fast path
    assert ds.query(["mean", "count"]).from_sketches
    res = ds.query(["mean", "count"], where="c0 > 1.5")
    assert not res.from_sketches and res.blocks_read > 0
    # forcing sketches on a filtered query is an error naming the culprit
    with pytest.raises(ValueError, match="where"):
        ds.query("mean", where="c0 > 1.5", use_sketches=True)


def test_columns_projection_stays_sketch_eligible(plain_ds):
    ds, data = plain_ds
    res = ds.query(["mean", "var"], columns=(2, 0))
    assert res.from_sketches  # projection alone needs no block reads
    full = data.astype(np.float64)
    np.testing.assert_allclose(
        res["mean"].estimate, full.mean(0)[[2, 0]], rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        res["var"].estimate, full.var(0, ddof=1)[[2, 0]], rtol=1e-3
    )


def test_where_with_columns_and_feature(plain_ds):
    ds, data = plain_ds
    # feature= indexes the projected axis: columns=(3, 1) -> feature 1 is c1
    res = ds.query(
        rsp.Aggregate("mean", feature=1),
        where="c0 > 1.5", columns=(3, 1), min_blocks=50,
    )
    truth = _masked(data, 0, 1.5).mean(0)[1]
    assert res.aggregates[0].estimate == pytest.approx(truth, abs=1e-4)


def test_where_quantile(plain_ds):
    ds, data = plain_ds
    res = ds.query(
        rsp.Aggregate("quantile", q=0.5, feature=0),
        where="c0 > 1.5", seed=2, min_blocks=50,
    )
    truth = np.median(_masked(data, 0, 1.5)[:, 0])
    assert res.aggregates[0].estimate == pytest.approx(truth, abs=0.05)


def test_grouped_filtered_mean(labelled_ds):
    ds, data = labelled_ds
    res = ds.query(
        rsp.Aggregate("mean", by_label=True),
        where="c0 > 1.5", columns=(0, 1, 2), min_blocks=40,
    )
    sel = data[data[:, 0] > np.float32(1.5)].astype(np.float64)
    est = np.asarray(res.aggregates[0].estimate)
    assert est.shape == (2, 3)
    for c in range(2):
        truth = sel[sel[:, 3] == c][:, :3].mean(0)
        np.testing.assert_allclose(est[c], truth, rtol=1e-4, atol=1e-4)


def test_weighted_policy_filtered_mean_is_honest(plain_ds):
    ds, data = plain_ds
    # non-uniform block sampling: the filtered mean must use the Hajek ratio
    # (HT sum / HT count), not the HH-over-N expansion
    res = ds.query(
        "mean", where="c0 > 1.5", policy="weighted", seed=13, min_blocks=20
    )
    truth = _masked(data, 0, 1.5).mean(0)
    np.testing.assert_allclose(res["mean"].estimate, truth, atol=0.08)


def test_where_spec_normalization():
    q = rsp.Query(
        aggregates=(rsp.Aggregate("mean"),), where="c0 > 0.5", columns=[2, 0]
    )
    assert q.where == (rsp.Predicate(0, "gt", 0.5),)
    assert q.columns == (2, 0)
    # dataclasses.replace re-runs normalization on already-normalized specs
    q2 = dataclasses.replace(q, seed=9)
    assert q2.where == q.where
    with pytest.raises(ValueError):
        rsp.Query(aggregates=(rsp.Aggregate("mean"),), columns=())


def test_stream_reports_selectivity_progressively(plain_ds):
    ds, _ = plain_ds
    seen = 0
    for res in ds.query_stream("mean", where="c0 > 1.5", max_blocks=5, seed=1):
        seen += 1
        assert 0.0 < res.selectivity < 1.0
        assert not res.from_sketches
    assert seen == 5


def test_serve_where_query(plain_ds):
    ds, data = plain_ds
    truth = _masked(data, 0, 1.5).mean(0)
    with ds.serve(capacity=4, workers=2, seed=3) as svc:
        t_plain = svc.submit(["mean", "count"])
        t_where = svc.submit("mean", where="c0 > 1.5")
        plain = svc.result(t_plain, timeout=60)
        res = svc.result(t_where, timeout=60)
    assert plain.from_sketches  # unfiltered stays on the zero-read fast path
    assert not res.from_sketches
    assert 0.3 < res.selectivity < 0.7
    np.testing.assert_allclose(res["mean"].estimate, truth, atol=0.05)
