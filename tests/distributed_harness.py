"""Reusable multi-process test harness for ``jax.distributed`` CPU meshes.

The old pattern -- inline ``subprocess.run(capture_output=True)`` with an
implicit PYTHONPATH -- had three silent-failure modes this harness fixes:

* **stderr swallowed on timeout**: ``subprocess.run(timeout=...)`` raises
  ``TimeoutExpired`` before the captured pipes are readable, so the reason a
  hung test hung was lost.  Here every process writes stdout/stderr to temp
  files that are read back whatever happens, and ``ProcResult`` carries
  them into the assertion message.
* **implicit PYTHONPATH**: the repo's ``src`` layout worked only when the
  parent's environment happened to carry it.  The harness always exports an
  explicit ``PYTHONPATH`` pointing at ``<repo>/src``.
* **no port isolation**: concurrent test runs racing for a hard-coded
  coordinator port deadlock ``jax.distributed.initialize``.  ``free_port``
  binds port 0 per invocation, so every test gets its own coordinator.

Usage::

    from tests.distributed_harness import run_processes, assert_ok

    results = run_processes(SOURCE, num_processes=4, timeout=120)
    assert_ok(results, marker="MY_TEST_OK")

The spawned source bootstraps its mesh with
``repro.distributed.mesh.init_from_env()``, which reads the
``RSP_COORDINATOR`` / ``RSP_NUM_PROCESSES`` / ``RSP_PROCESS_ID`` variables
this harness exports.  ``kill_after`` SIGKILLs selected processes after a
delay to exercise straggler/elastic paths.
"""

from __future__ import annotations

import dataclasses
import os
import signal
import socket
import subprocess
import sys
import tempfile
import time

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


@dataclasses.dataclass
class ProcResult:
    """Outcome of one spawned mesh process."""

    process_id: int
    returncode: int | None
    stdout: str
    stderr: str
    timed_out: bool = False
    killed: bool = False  # killed deliberately via kill_after

    @property
    def ok(self) -> bool:
        return self.returncode == 0 and not self.timed_out

    def describe(self) -> str:
        status = (
            "timed out" if self.timed_out
            else "killed (injected)" if self.killed
            else f"exit {self.returncode}"
        )
        return (
            f"--- process {self.process_id}: {status} ---\n"
            f"stdout:\n{self.stdout[-2000:]}\n"
            f"stderr:\n{self.stderr[-4000:]}\n"
        )


def free_port() -> int:
    """A free TCP port on localhost (bound momentarily, then released)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _base_env(env: dict | None) -> dict:
    out = dict(os.environ)
    out.update(env or {})
    out["PYTHONPATH"] = SRC + os.pathsep + out.get("PYTHONPATH", "")
    out.setdefault("JAX_PLATFORMS", "cpu")
    out.setdefault("REPRO_AUTOTUNE", "off")
    return out


def run_processes(
    source: str,
    *,
    num_processes: int,
    timeout: float = 300.0,
    env: dict | None = None,
    kill_after: dict[int, float] | None = None,
) -> list[ProcResult]:
    """Run ``source`` as ``num_processes`` coordinated CPU processes.

    Each process sees ``RSP_COORDINATOR`` (a fresh ``127.0.0.1:<port>``),
    ``RSP_NUM_PROCESSES``, and its ``RSP_PROCESS_ID`` -- exactly what
    ``repro.distributed.mesh.init_from_env()`` consumes.  ``kill_after``
    maps ``process_id -> seconds``: those processes are SIGKILLed after the
    delay (a crashed-host fault injection).  All processes share one hard
    deadline of ``timeout`` seconds; survivors past it are killed and
    marked ``timed_out`` with their streams intact.
    """
    if num_processes < 1:
        raise ValueError("num_processes must be >= 1")
    kill_after = dict(kill_after or {})
    coordinator = f"127.0.0.1:{free_port()}"

    with tempfile.TemporaryDirectory(prefix="rsp-mesh-") as tmp:
        script = os.path.join(tmp, "mesh_test.py")
        with open(script, "w") as f:
            f.write(source)

        procs: list[subprocess.Popen] = []
        outs, errs = [], []
        for pid in range(num_processes):
            penv = _base_env(env)
            penv["RSP_COORDINATOR"] = coordinator
            penv["RSP_NUM_PROCESSES"] = str(num_processes)
            penv["RSP_PROCESS_ID"] = str(pid)
            penv["RSP_TMPDIR"] = tmp
            out = open(os.path.join(tmp, f"out.{pid}"), "w+")
            err = open(os.path.join(tmp, f"err.{pid}"), "w+")
            outs.append(out)
            errs.append(err)
            procs.append(
                subprocess.Popen(
                    [sys.executable, script],
                    env=penv,
                    stdout=out,
                    stderr=err,
                    cwd=tmp,
                )
            )

        start = time.monotonic()
        deadline = start + timeout
        pending_kills = dict(kill_after)
        killed: set[int] = set()
        timed_out: set[int] = set()
        try:
            while True:
                now = time.monotonic()
                for pid, delay in list(pending_kills.items()):
                    if now - start >= delay and procs[pid].poll() is None:
                        procs[pid].send_signal(signal.SIGKILL)
                        killed.add(pid)
                        del pending_kills[pid]
                alive = [p for p in procs if p.poll() is None]
                if not alive:
                    break
                if now > deadline:
                    for pid, p in enumerate(procs):
                        if p.poll() is None:
                            p.send_signal(signal.SIGKILL)
                            timed_out.add(pid)
                    for p in procs:
                        p.wait()
                    break
                time.sleep(0.05)
        finally:
            for p in procs:
                if p.poll() is None:
                    p.send_signal(signal.SIGKILL)
                    p.wait()

        results = []
        for pid, p in enumerate(procs):
            outs[pid].flush()
            errs[pid].flush()
            outs[pid].seek(0)
            errs[pid].seek(0)
            results.append(
                ProcResult(
                    process_id=pid,
                    returncode=p.returncode,
                    stdout=outs[pid].read(),
                    stderr=errs[pid].read(),
                    timed_out=pid in timed_out,
                    killed=pid in killed,
                )
            )
            outs[pid].close()
            errs[pid].close()
        return results


def run_forced_devices(
    source: str, *, devices: int = 8, timeout: float = 300.0, env: dict | None = None
) -> ProcResult:
    """Run ``source`` in one subprocess with ``devices`` forced XLA host
    devices (``--xla_force_host_platform_device_count``) -- the harness for
    single-process multi-*device* tests (shard_map collectives)."""
    penv = _base_env(env)
    penv["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    with tempfile.TemporaryDirectory(prefix="rsp-xla-") as tmp:
        script = os.path.join(tmp, "forced_dev_test.py")
        with open(script, "w") as f:
            f.write(source)
        try:
            proc = subprocess.run(
                [sys.executable, script],
                env=penv,
                capture_output=True,
                text=True,
                timeout=timeout,
                cwd=tmp,
            )
            return ProcResult(0, proc.returncode, proc.stdout, proc.stderr)
        except subprocess.TimeoutExpired as e:
            return ProcResult(
                0,
                None,
                (e.stdout or b"").decode(errors="replace") if isinstance(e.stdout, bytes) else (e.stdout or ""),
                (e.stderr or b"").decode(errors="replace") if isinstance(e.stderr, bytes) else (e.stderr or ""),
                timed_out=True,
            )


def assert_ok(
    results: list[ProcResult] | ProcResult, marker: str | None = None
) -> None:
    """Assert every non-injected-kill process exited 0 (and printed
    ``marker``, when given), with full per-process streams on failure."""
    if isinstance(results, ProcResult):
        results = [results]
    report = "\n".join(r.describe() for r in results)
    for r in results:
        if r.killed:
            continue  # deliberately SIGKILLed hosts have no exit contract
        assert r.ok, f"process {r.process_id} failed\n{report}"
        if marker is not None:
            assert marker in r.stdout, (
                f"process {r.process_id} missing marker {marker!r}\n{report}"
            )
