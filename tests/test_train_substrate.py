"""Training substrate tests: optimizer, checkpoint/restart fault tolerance,
serve engine, end-to-end LM training on an RSP corpus."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store as ckpt
from repro.configs import smoke_config
from repro.core import RSPSpec, two_stage_partition_np
from repro.data import BlockSource, RSPLoader
from repro.data.synthetic import make_token_corpus
from repro.models import api
from repro.models.common import init_params
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.serve.engine import EnsembleServer, ServeConfig, Server
from repro.train import TrainConfig, Trainer, init_state, make_train_step


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([3.0, -2.0], jnp.float32)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    p = params
    for _ in range(200):
        g = jax.grad(loss)(p)
        state, p, _ = adamw_update(state, g, cfg, compute_dtype=jnp.float32)
    assert float(loss(p)) < 1e-3


def test_adamw_grad_clip_applies():
    params = {"w": jnp.ones((4,), jnp.float32)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0)
    huge = {"w": jnp.full((4,), 1e6, jnp.float32)}
    _, _, stats = adamw_update(state, huge, cfg)
    assert float(stats["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_schedule_shapes():
    s0 = float(warmup_cosine(0, warmup_steps=10, total_steps=100))
    s10 = float(warmup_cosine(10, warmup_steps=10, total_steps=100))
    s100 = float(warmup_cosine(100, warmup_steps=10, total_steps=100))
    assert s0 == 0.0 and s10 == pytest.approx(1.0) and s100 == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# checkpoint store
# ---------------------------------------------------------------------------

def _toy_state():
    return {
        "params": {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "opt": {"m": jnp.zeros((2, 3)), "step": jnp.asarray(7, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    state = _toy_state()
    ckpt.save(str(tmp_path), 10, state, extra={"loader": {"seed": 1}})
    like = jax.eval_shape(lambda: _toy_state())
    got, extra = ckpt.restore(str(tmp_path), 10, like)
    np.testing.assert_array_equal(np.asarray(got["params"]["a"]), np.asarray(state["params"]["a"]))
    assert extra["loader"]["seed"] == 1
    assert ckpt.latest_step(str(tmp_path)) == 10


def test_checkpoint_keep_last(tmp_path):
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, _toy_state(), keep_last=2)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, _toy_state())
    bad = jax.eval_shape(lambda: {"params": {"a": jnp.zeros((3, 3))},
                                  "opt": {"m": jnp.zeros((2, 3)), "step": jnp.asarray(0)}})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, bad)


def test_async_checkpointer(tmp_path):
    acp = ckpt.AsyncCheckpointer(str(tmp_path), keep_last=2)
    acp.save(5, _toy_state(), extra={"x": 1})
    acp.wait()
    assert ckpt.latest_step(str(tmp_path)) == 5


# ---------------------------------------------------------------------------
# end-to-end: train a small LM from an RSP corpus, kill it, resume
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def rsp_token_loader_factory():
    corpus = make_token_corpus(256, 17, vocab_size=256, seed=0)   # records = sequences
    spec = RSPSpec(num_records=256, num_blocks=16, num_original_blocks=16, seed=1)
    blocks = two_stage_partition_np(corpus, spec)

    def make(seed=3):
        return RSPLoader(BlockSource(blocks=blocks), batch_size=8, seed=seed)

    return make


def _trainer(tmp_path, loader, total_steps, ckpt_every=5):
    cfg = smoke_config("llama3.2-1b")
    tc = TrainConfig(
        total_steps=total_steps, warmup_steps=2, checkpoint_every=ckpt_every,
        log_every=2, seed=0,
    )
    return Trainer(
        cfg, AdamWConfig(lr=1e-2), tc, loader, str(tmp_path / "ckpt"),
        batch_transform=lambda b: {"tokens": jnp.asarray(b, jnp.int32)},
    )


def test_training_reduces_loss(tmp_path, rsp_token_loader_factory):
    trainer = _trainer(tmp_path, rsp_token_loader_factory(), total_steps=20)
    trainer.run()
    losses = [h["loss"] for h in trainer.history]
    assert losses[-1] < losses[0] - 0.1, losses


def test_restart_resumes_exactly(tmp_path, rsp_token_loader_factory):
    """Preempted-at-5 + resumed run must reproduce the uninterrupted run
    BIT-EXACTLY (same schedule horizon, same data order, exact state
    restore)."""
    # uninterrupted run
    t_ref = _trainer(tmp_path / "ref", rsp_token_loader_factory(), total_steps=10, ckpt_every=100)
    state_ref = t_ref.run()

    # preempted run: killed after 5 steps (checkpoint at 5), then resumed
    t_a = _trainer(tmp_path / "resume", rsp_token_loader_factory(), total_steps=10, ckpt_every=100)
    t_a.run(stop_after_steps=5)
    assert ckpt.latest_step(str(tmp_path / "resume" / "ckpt")) == 5

    t_b = _trainer(tmp_path / "resume", rsp_token_loader_factory(), total_steps=10, ckpt_every=100)
    state_b = t_b.run()

    for ref, got in zip(jax.tree.leaves(state_ref["opt"]["master"]), jax.tree.leaves(state_b["opt"]["master"])):
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    assert int(state_b["opt"]["step"]) == int(state_ref["opt"]["step"]) == 10


def test_schedule_horizon_mismatch_detectable(tmp_path, rsp_token_loader_factory):
    """A run checkpointed under a different total_steps (schedule horizon)
    diverges -- documents why the horizon is part of the train config."""
    t_short = _trainer(tmp_path / "short", rsp_token_loader_factory(), total_steps=5, ckpt_every=5)
    s_short = t_short.run()
    t_long = _trainer(tmp_path / "long", rsp_token_loader_factory(), total_steps=10, ckpt_every=100)
    s_long = t_long.run(stop_after_steps=5)
    diffs = [
        float(jnp.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max())
        for a, b in zip(jax.tree.leaves(s_short["opt"]["master"]), jax.tree.leaves(s_long["opt"]["master"]))
    ]
    assert max(diffs) > 0.0


def test_microbatch_accumulation_matches_full_batch(tmp_path, rsp_token_loader_factory):
    cfg = smoke_config("qwen2-0.5b")
    loader = rsp_token_loader_factory()
    batch = {"tokens": jnp.asarray(loader.next_batch(), jnp.int32)}

    tc_full = TrainConfig(total_steps=1, warmup_steps=0, microbatch=0)
    tc_micro = TrainConfig(total_steps=1, warmup_steps=0, microbatch=4)
    opt = AdamWConfig(lr=1e-2)
    state = init_state(cfg, seed=0)
    step_full = jax.jit(make_train_step(cfg, opt, tc_full))
    step_micro = jax.jit(make_train_step(cfg, opt, tc_micro))
    s1, m1 = step_full(state, batch)
    s2, m2 = step_micro(state, batch)
    # same data, same update (microbatching only changes reduction order)
    for a, b in zip(jax.tree.leaves(s1["opt"]["master"]), jax.tree.leaves(s2["opt"]["master"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-4)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_server_greedy_generation():
    cfg = smoke_config("llama3.2-1b")
    params = init_params(api.model_specs(cfg), jax.random.PRNGKey(0))
    params = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    server = Server(cfg, params)
    prompts = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 5), np.int32))
    out = server.generate(prompts, max_new_tokens=4)
    assert out.shape == (2, 9)
    np.testing.assert_array_equal(out[:, :5], np.asarray(prompts))


def test_ensemble_server_runs():
    cfg = smoke_config("qwen2-0.5b")
    k = 3
    stacked = jax.vmap(lambda key: init_params(api.model_specs(cfg), key))(
        jax.random.split(jax.random.PRNGKey(0), k)
    )
    stacked = jax.tree.map(lambda p: p.astype(jnp.float32), stacked)
    server = EnsembleServer(cfg, stacked)
    prompts = jnp.asarray(np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 4), np.int32))
    out = server.generate(prompts, max_new_tokens=3)
    assert out.shape == (2, 7)
