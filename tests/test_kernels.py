"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tests skip below; the rest of the module runs
    HAVE_HYPOTHESIS = False

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.mamba2_ssd import ops as ssd_ops
from repro.kernels.mamba2_ssd.ref import ssd_reference
from repro.kernels.rwkv6_wkv import ops as wkv_ops
from repro.kernels.rwkv6_wkv.ref import wkv6_scan
from repro.kernels.rsp_shuffle import ops as rs_ops
from repro.kernels.rsp_shuffle.ref import rsp_shuffle_ref
from repro.kernels.block_sketch import (
    batched_block_sketch,
    block_sketch,
    block_sketch_ref,
    merge_sketches,
)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Hkv,S,D,bq,bk",
    [
        (1, 4, 2, 64, 16, 16, 16),
        (2, 2, 2, 32, 32, 8, 16),   # MHA, uneven blocks
        (1, 8, 1, 48, 8, 16, 16),   # MQA, S not power of two
        (1, 2, 2, 128, 64, 128, 128),  # single block pair
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_sweep(dtype, B, H, Hkv, S, D, bq, bk, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D)).astype(dtype)
    got = fa_ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_flash_grouped_input_layout():
    """The model-native [B, Hkv, G, S, D] layout round-trips correctly."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, Hkv, G, S, D = 1, 2, 3, 32, 16
    q = jax.random.normal(ks[0], (B, Hkv, G, S, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    got = fa_ops.flash_attention(q, k, v, causal=True, block_q=8, block_k=8)
    assert got.shape == (B, Hkv, G, S, D)
    want = flash_attention_ref(q.reshape(B, Hkv * G, S, D), k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got.reshape(B, Hkv * G, S, D)), np.asarray(want), rtol=2e-5, atol=2e-5
    )


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        s_pow=st.integers(4, 7),
        d=st.sampled_from([8, 16, 32]),
        hkv=st.integers(1, 4),
        g=st.integers(1, 4),
        causal=st.booleans(),
        seed=st.integers(0, 1000),
    )
    def test_flash_property(s_pow, d, hkv, g, causal, seed):
        S = 2**s_pow
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (1, hkv * g, S, d))
        k = jax.random.normal(ks[1], (1, hkv, S, d))
        v = jax.random.normal(ks[2], (1, hkv, S, d))
        got = fa_ops.flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
        want = flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)

else:

    def test_flash_property():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# mamba2 ssd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "B,L,H,P,N,chunk,ht",
    [
        (1, 32, 2, 8, 4, 8, 2),
        (2, 64, 4, 16, 16, 16, 4),
        (1, 24, 2, 8, 8, 16, 1),   # L padded to chunk multiple
        (1, 16, 8, 4, 4, 16, 8),
    ],
)
def test_ssd_sweep(B, L, H, P, N, chunk, ht):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    xbar = jax.random.normal(ks[0], (B, L, H, P))
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    Bm = jax.random.normal(ks[2], (B, L, N))
    Cm = jax.random.normal(ks[3], (B, L, N))
    y1, h1 = ssd_ops.ssd(xbar, dA, Bm, Cm, chunk=chunk, head_tile=ht)
    y2, h2 = ssd_reference(xbar, dA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4, atol=2e-4)


def test_ssd_strong_decay_stable():
    """Very strong decay (dA << 0) must not produce inf/nan (the unstable
    factorization would)."""
    B, L, H, P, N = 1, 64, 2, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    xbar = jax.random.normal(ks[0], (B, L, H, P))
    dA = jnp.full((B, L, H), -30.0)
    Bm = jax.random.normal(ks[1], (B, L, N))
    Cm = jax.random.normal(ks[2], (B, L, N))
    y, h = ssd_ops.ssd(xbar, dA, Bm, Cm, chunk=16, head_tile=2)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(h).all())
    y2, h2 = ssd_reference(xbar, dA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "B,T,H,C,chunk",
    [(1, 32, 2, 8, 8), (2, 64, 1, 16, 16), (1, 20, 2, 8, 16), (1, 16, 4, 4, 4)],
)
def test_wkv_sweep(B, T, H, C, chunk):
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    r = jax.random.normal(ks[0], (B, T, H, C))
    k = jax.random.normal(ks[1], (B, T, H, C))
    v = jax.random.normal(ks[2], (B, T, H, C))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, C)))
    u = jnp.linspace(0.1, 0.9, H * C).reshape(H, C)
    y1, h1 = wkv_ops.wkv6(r, k, v, w, u, chunk=chunk)
    y2, h2 = wkv6_scan(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4, atol=2e-4)


def test_wkv_strong_decay_stable():
    B, T, H, C = 1, 32, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    r = jax.random.normal(ks[0], (B, T, H, C))
    k = jax.random.normal(ks[1], (B, T, H, C))
    v = jax.random.normal(ks[2], (B, T, H, C))
    w = jnp.full((B, T, H, C), 1e-6)  # near-total forgetting each step
    u = jnp.full((H, C), 0.5)
    y, h = wkv_ops.wkv6(r, k, v, w, u, chunk=8)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(h).all())
    y2, h2 = wkv6_scan(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# rsp shuffle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("R,D,T", [(64, 12, 8), (128, 4, 16), (32, 32, 32)])
def test_rsp_shuffle_sweep(dtype, R, D, T):
    if dtype == jnp.int32:
        x = jax.random.randint(jax.random.PRNGKey(6), (R, D), 0, 1000).astype(dtype)
    else:
        x = jax.random.normal(jax.random.PRNGKey(6), (R, D)).astype(dtype)
    tp, ip = rs_ops.make_permutations(jax.random.PRNGKey(7), R // T, T)
    got = rs_ops.rsp_shuffle(x, tp, ip, tile_rows=T)
    want = rsp_shuffle_ref(x, tp, ip, tile_rows=T)
    np.testing.assert_array_equal(np.asarray(got, np.float32), np.asarray(want, np.float32))


def test_rsp_randomize_block_is_permutation():
    x = jnp.arange(128 * 3, dtype=jnp.float32).reshape(128, 3)
    out = rs_ops.rsp_randomize_block(x, jax.random.PRNGKey(8), tile_rows=16)
    assert out.shape == x.shape
    # bijection: same multiset of rows, different order
    a = np.sort(np.asarray(out)[:, 0])
    b = np.sort(np.asarray(x)[:, 0])
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(np.asarray(out), np.asarray(x))


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        tiles=st.integers(2, 8),
        t_rows=st.sampled_from([4, 8, 16]),
        d=st.integers(1, 8),
        seed=st.integers(0, 10_000),
    )
    def test_rsp_shuffle_property(tiles, t_rows, d, seed):
        R = tiles * t_rows
        x = jax.random.normal(jax.random.PRNGKey(seed), (R, d))
        tp, ip = rs_ops.make_permutations(jax.random.PRNGKey(seed + 1), tiles, t_rows)
        got = rs_ops.rsp_shuffle(x, tp, ip, tile_rows=t_rows)
        want = rsp_shuffle_ref(x, tp, ip, tile_rows=t_rows)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

else:

    def test_rsp_shuffle_property():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# fused block sketch (moments + histogram in one pass)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["jax", "pallas"])
@pytest.mark.parametrize(
    "n,f,bins,tile", [(512, 8, 32, 128), (1000, 5, 64, 256), (130, 3, 16, 64)]
)
def test_block_sketch_impls_agree(impl, n, f, bins, tile):
    """Acceptance gate: ref / jax / pallas agree to 1e-5 on the same block."""
    rng = np.random.default_rng(12)
    x = rng.normal(1.5, 2.0, size=(n, f)).astype(np.float32)
    lo, hi = x.min(0) - 0.1, x.max(0) + 0.1
    ref = block_sketch_ref(x, bins=bins, lo=lo, hi=hi)
    got = block_sketch(x, bins=bins, lo=lo, hi=hi, impl=impl, tile_rows=tile)
    assert got.count == ref.count
    np.testing.assert_allclose(got.mean, ref.mean, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got.m2, ref.m2, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(got.min, ref.min, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(got.max, ref.max, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(got.hist, ref.hist)


def test_block_sketch_out_of_range_mass_clipped():
    """The fused histogram clips out-of-range mass into the edge bins -- the
    histogram always sums to n per feature."""
    rng = np.random.default_rng(13)
    x = rng.normal(0.0, 5.0, size=(400, 2)).astype(np.float32)
    for impl in ("ref", "jax", "pallas"):
        sk = block_sketch(x, bins=8, lo=-1.0, hi=1.0, impl=impl)
        np.testing.assert_array_equal(sk.hist.sum(axis=1), [400, 400])


def test_block_sketch_constant_feature_and_moments_only():
    x = np.concatenate(
        [np.full((256, 1), 3.0, np.float32),
         np.random.default_rng(14).normal(size=(256, 1)).astype(np.float32)],
        axis=1,
    )
    for impl in ("ref", "jax", "pallas"):
        sk = block_sketch(x, bins=4, lo=x.min(0), hi=x.max(0), impl=impl)
        assert sk.hist[0].tolist() == [256, 0, 0, 0]  # constant -> all mass bin 0
    m = block_sketch(x, impl="jax")  # bins=0: moments-only fast path
    assert m.hist is None
    np.testing.assert_allclose(m.mean, x.mean(0), rtol=1e-6, atol=1e-6)


def test_block_sketch_merge_matches_whole():
    rng = np.random.default_rng(15)
    x = rng.normal(size=(700, 4))
    a = block_sketch_ref(x[:300], bins=16, lo=-4, hi=4)
    b = block_sketch_ref(x[300:], bins=16, lo=-4, hi=4)
    m = merge_sketches(a, b)
    whole = block_sketch_ref(x, bins=16, lo=-4, hi=4)
    np.testing.assert_allclose(m.mean, whole.mean, rtol=1e-12)
    np.testing.assert_allclose(m.m2, whole.m2, rtol=1e-9)
    np.testing.assert_array_equal(m.hist, whole.hist)


def test_batched_block_sketch_matches_loop():
    import jax.numpy as _jnp

    rng = np.random.default_rng(16)
    blocks = rng.normal(size=(5, 200, 3)).astype(np.float32)
    lo = np.full(3, -4.0, np.float32)
    inv_w = np.full(3, 16 / 8.0, np.float32)
    mean, m2, mn, mx, hist = batched_block_sketch(
        _jnp.asarray(blocks), _jnp.asarray(lo), _jnp.asarray(inv_w), bins=16
    )
    for g in range(5):
        ref = block_sketch_ref(blocks[g], bins=16, lo=-4.0, hi=4.0)
        np.testing.assert_allclose(np.asarray(mean)[g], ref.mean, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(m2)[g], ref.m2, rtol=1e-5, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(hist)[g], ref.hist)
