"""Multi-host RSP tests on real ``jax.distributed`` CPU meshes.

Two harness shapes (``tests/distributed_harness.py``):

* ``run_forced_devices`` -- one subprocess with forced XLA host devices,
  for shard_map collectives (the Algorithm-1 all_to_all partition);
* ``run_processes`` -- N coordinated OS processes around a fresh
  coordination-service port, for the distributed query protocol.  Every
  process partitions the same seed-deterministic corpus, so each one can
  check its mesh answer bit-for-bit against the single-host reference it
  computes locally.
"""

import pytest

from distributed_harness import assert_ok, run_forced_devices, run_processes

# ---------------------------------------------------------------------------
# shard_map + all_to_all Algorithm-1 partition (multi-device, one process)
# ---------------------------------------------------------------------------

PARTITION_SOURCE = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import distributed_rsp_partition, is_partition, RSPSpec, two_stage_partition_np
from repro.core.similarity import max_label_divergence
from repro.data import make_nonrandom_higgs_like

mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(8), ("data",))

# class-sorted (worst case) data
x, y = make_nonrandom_higgs_like(6400, seed=1)
data = np.concatenate([x, y[:, None].astype(np.float32)], axis=1)

out = np.asarray(distributed_rsp_partition(jnp.asarray(data), jax.random.PRNGKey(7), mesh, axis="data"))
assert out.shape == (8, 800, 29), out.shape
assert is_partition(out, data), "not a partition"
for k in range(8):
    div = max_label_divergence(out[k][:, -1], y, 2)
    assert div < 0.06, f"block {k} label divergence {div}"

# determinism
out2 = np.asarray(distributed_rsp_partition(jnp.asarray(data), jax.random.PRNGKey(7), mesh, axis="data"))
np.testing.assert_array_equal(out, out2)

# non-square N must raise
try:
    distributed_rsp_partition(jnp.asarray(data[:100]), jax.random.PRNGKey(0), mesh, axis="data")
    raise SystemExit("expected ValueError")
except ValueError:
    pass
print("DISTRIBUTED_RSP_OK")
"""


@pytest.mark.slow
def test_distributed_rsp_partition_8dev():
    assert_ok(
        run_forced_devices(PARTITION_SOURCE, devices=8, timeout=600),
        marker="DISTRIBUTED_RSP_OK",
    )


# ---------------------------------------------------------------------------
# distributed query protocol (N real processes, coordination-service KV)
# ---------------------------------------------------------------------------

MESH_QUERY_SOURCE = r"""
import json
import numpy as np
from repro.distributed.mesh import init_from_env
from repro.rsp.dataset import RSPDataset

t = init_from_env()
rng = np.random.default_rng(7)
data = rng.normal(size=(32768, 4)).astype(np.float32)
data[:, 2] = rng.gamma(2.0, 1.0, size=32768).astype(np.float32)
ds = RSPDataset.partition(data, 32, seed=3)

kwargs = dict(
    aggregates=["mean", "p95"], target_rel_err=0.04, seed=11,
    policy="weighted", where="c2 > 0.5", max_blocks=32,
)
ref = ds.query(**kwargs)

dds = ds.distribute(t, straggler_grace=30.0, poll_interval=0.05)
res = dds.query(**kwargs)

def sig(r):
    return json.dumps({
        "est": {a.name: np.asarray(a.estimate).ravel().tolist() for a in r.aggregates},
        "lo": {a.name: None if a.ci_lo is None else np.asarray(a.ci_lo).ravel().tolist() for a in r.aggregates},
        "hi": {a.name: None if a.ci_hi is None else np.asarray(a.ci_hi).ravel().tolist() for a in r.aggregates},
        "blocks_read": r.blocks_read,
        "converged": r.converged,
    }, sort_keys=True)

assert sig(ref) == sig(res), "distributed != single-host:\n%s\n%s" % (sig(ref), sig(res))
assert len(dds.owned_blocks) > 0  # every host holds part of the deal
print("MESH_QUERY_OK", flush=True)
"""


@pytest.mark.slow
@pytest.mark.parametrize("num_processes", [2, 4])
def test_mesh_query_bit_identical(num_processes):
    results = run_processes(MESH_QUERY_SOURCE, num_processes=num_processes, timeout=300)
    assert_ok(results, marker="MESH_QUERY_OK")


# The last process connects to the mesh, then hangs without computing a
# single payload; the harness SIGKILLs it mid-query.  Survivors must hit the
# straggler grace deadline, steal its leases via the deterministic redeal,
# and still produce the bit-identical single-host answer.
DEAD_HOST_SOURCE = r"""
import json, os, time
import numpy as np
from repro.distributed.mesh import init_from_env
from repro.rsp.dataset import RSPDataset

t = init_from_env()
victim = t.host_id == t.num_hosts - 1

rng = np.random.default_rng(7)
data = rng.normal(size=(32768, 4)).astype(np.float32)
data[:, 2] = rng.gamma(2.0, 1.0, size=32768).astype(np.float32)
ds = RSPDataset.partition(data, 32, seed=3)

if victim:
    time.sleep(600)  # never participates; SIGKILLed by the harness

kwargs = dict(
    aggregates=["mean", "p95"], target_rel_err=0.04, seed=11,
    policy="weighted", where="c2 > 0.5", max_blocks=32,
)
ref = ds.query(**kwargs)
dds = ds.distribute(t, straggler_grace=3.0, poll_interval=0.05)
res = dds.query(**kwargs)

def sig(r):
    return json.dumps({
        "est": {a.name: np.asarray(a.estimate).ravel().tolist() for a in r.aggregates},
        "blocks_read": r.blocks_read, "converged": r.converged,
    }, sort_keys=True)

assert sig(ref) == sig(res), "survivor diverged:\n%s\n%s" % (sig(ref), sig(res))
assert sorted(dds.ownership.hosts()) == list(range(t.num_hosts - 1)), dds.ownership.hosts()

# survivors sync through the KV store before exiting: the coordinator
# (process 0) leaving early would tear the service down under its peer
t.put("done/%d" % t.host_id, b"1")
for h in range(t.num_hosts - 1):
    assert t.get("done/%d" % h, timeout=60.0) is not None
print("DEAD_HOST_OK", flush=True)
# skip jax.distributed atexit teardown: the coordinator would wait for the
# killed process's orderly shutdown that never comes
os._exit(0)
"""


@pytest.mark.slow
def test_mesh_query_survives_killed_host():
    results = run_processes(
        DEAD_HOST_SOURCE, num_processes=3, timeout=300, kill_after={2: 8.0}
    )
    # the victim has no exit contract at all: it is either SIGKILLed by the
    # harness or aborts itself when the finished coordinator tears down
    assert_ok([r for r in results if r.process_id != 2], marker="DEAD_HOST_OK")
