"""Distributed (shard_map + all_to_all) Algorithm-1 tests.

These must run with multiple XLA host devices; device count is locked at
first jax init, so they execute in a subprocess with XLA_FLAGS set.
"""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import distributed_rsp_partition, is_partition, RSPSpec, two_stage_partition_np
from repro.core.similarity import max_label_divergence
from repro.data import make_nonrandom_higgs_like

mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(8), ("data",))

# class-sorted (worst case) data
x, y = make_nonrandom_higgs_like(6400, seed=1)
data = np.concatenate([x, y[:, None].astype(np.float32)], axis=1)

out = np.asarray(distributed_rsp_partition(jnp.asarray(data), jax.random.PRNGKey(7), mesh, axis="data"))
assert out.shape == (8, 800, 29), out.shape
assert is_partition(out, data), "not a partition"
for k in range(8):
    div = max_label_divergence(out[k][:, -1], y, 2)
    assert div < 0.06, f"block {k} label divergence {div}"

# determinism
out2 = np.asarray(distributed_rsp_partition(jnp.asarray(data), jax.random.PRNGKey(7), mesh, axis="data"))
np.testing.assert_array_equal(out, out2)

# non-square N must raise
try:
    distributed_rsp_partition(jnp.asarray(data[:100]), jax.random.PRNGKey(0), mesh, axis="data")
    raise SystemExit("expected ValueError")
except ValueError:
    pass
print("DISTRIBUTED_RSP_OK")
"""


@pytest.mark.slow
def test_distributed_rsp_partition_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True, timeout=600
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "DISTRIBUTED_RSP_OK" in proc.stdout
