"""Streaming block-execution engine tests: fetcher round-trips, prefetch
pipeline equivalence + exception propagation, LRU cache, sampling policies
(HT unbiasedness on skewed data), and the similarity self-inclusion fix."""

import numpy as np
import pytest

from repro import rsp
from repro.core import RSPSpec, RSPStore
from repro.core.sampler import (
    StratifiedPolicy,
    UniformPolicy,
    WeightedPolicy,
    make_policy,
)
from repro.rsp.engine import (
    BlockExecutor,
    MemoryFetcher,
    MmapFetcher,
    StoreFetcher,
    as_fetcher,
)
from repro.rsp.summaries import combine_summaries, summarize_blocks


def _blocks(k=6, n=32, f=3, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(k, n, f)).astype(np.float32)


@pytest.fixture()
def store(tmp_path):
    blocks = _blocks(k=8, n=64, f=4)
    spec = RSPSpec(
        num_records=8 * 64, num_blocks=8, num_original_blocks=1, record_shape=(4,)
    )
    s = RSPStore(str(tmp_path / "rsp"))
    s.write_partition(blocks, spec)
    return s, blocks


# ---------------------------------------------------------------------------
# Executor primitives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prefetch", [0, 3])
def test_map_blocks_ordered_and_equivalent(store, prefetch):
    s, blocks = store
    with BlockExecutor(StoreFetcher(s), prefetch=prefetch) as ex:
        got = list(ex.map_blocks(None, [5, 1, 6, 2, 2]))
    for g, k in zip(got, [5, 1, 6, 2, 2]):
        np.testing.assert_array_equal(np.asarray(g), blocks[k])


def test_map_blocks_fn_and_with_ids(store):
    s, blocks = store
    with BlockExecutor(StoreFetcher(s), prefetch=2) as ex:
        got = list(ex.map_blocks(lambda b: b.sum(), [0, 3], with_ids=True))
    assert [bid for bid, _ in got] == [0, 3]
    for bid, v in got:
        np.testing.assert_allclose(v, blocks[bid].sum(), rtol=1e-6)


def test_take_matches_blocks(store):
    s, blocks = store
    with BlockExecutor(StoreFetcher(s), prefetch=4) as ex:
        np.testing.assert_array_equal(ex.take([2, 0, 7]), blocks[[2, 0, 7]])


@pytest.mark.parametrize("prefetch", [0, 2])
def test_stream_batches_cover_records(store, prefetch):
    s, blocks = store
    with BlockExecutor(StoreFetcher(s), prefetch=prefetch) as ex:
        batches = list(ex.stream_batches(range(8), 96, drop_last=False))
    assert all(b.shape[0] == 96 for b in batches[:-1])
    got = np.concatenate(batches)
    np.testing.assert_array_equal(got, blocks.reshape(-1, 4))


def test_stream_batches_prepare_runs_per_block(store):
    s, blocks = store
    with BlockExecutor(StoreFetcher(s), prefetch=2) as ex:
        batches = list(
            ex.stream_batches(
                range(8), 64, prepare=lambda bid, b: b + bid, drop_last=False
            )
        )
    got = np.concatenate(batches)
    want = np.concatenate([blocks[k] + k for k in range(8)]).reshape(-1, 4)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("prefetch", [0, 3])
def test_worker_exception_propagates(prefetch):
    class Flaky:
        num_blocks = 5

        def fetch(self, k):
            if k == 3:
                raise RuntimeError("disk on fire")
            return np.zeros((4, 2), np.float32)

    with BlockExecutor(Flaky(), prefetch=prefetch) as ex:
        it = ex.map_blocks(None, range(5))
        for _ in range(3):
            next(it)
        with pytest.raises(RuntimeError, match="disk on fire"):
            next(it)


def test_fetched_blocks_are_read_only(store, tmp_path):
    # blocks are shared between the LRU cache and consumers: in-place writes
    # must fail loudly instead of silently corrupting later reads
    s, blocks = store
    with BlockExecutor(StoreFetcher(s), prefetch=0, cache_blocks=4) as ex:
        b = ex.fetch(1)
        with pytest.raises(ValueError):
            b[0, 0] = 99.0
        np.testing.assert_array_equal(np.asarray(ex.fetch(1)), blocks[1])
    ds = rsp.RSPDataset(s.spec(), store=s)
    with pytest.raises(ValueError):
        ds.block(0)[0, 0] = 99.0


def test_loader_uses_dataset_fetcher(tmp_path):
    # ds.loader() must train on what the dataset's fetcher serves, not on
    # raw store bytes behind a custom fetcher's back
    data = _blocks(k=4, n=64, f=3).reshape(-1, 3)
    ds = rsp.partition(data, blocks=4, seed=0, backend="np").save(str(tmp_path / "c"))

    class ScalingFetcher:
        def __init__(self, store):
            self.inner = StoreFetcher(store)

        @property
        def num_blocks(self):
            return self.inner.num_blocks

        def fetch(self, k):
            return self.inner.fetch(k) * 10.0

    custom = rsp.RSPDataset(ds.spec, store=ds.store, fetcher=ScalingFetcher(ds.store))
    batch = custom.loader(batch_size=32, seed=1).next_batch()
    plain = rsp.open(str(tmp_path / "c")).loader(batch_size=32, seed=1).next_batch()
    np.testing.assert_allclose(batch, plain * 10.0, rtol=1e-6)


def test_lru_cache_hits_and_evicts():
    calls: list[int] = []

    class Counting:
        num_blocks = 6

        def fetch(self, k):
            calls.append(k)
            return np.full((2, 2), k, np.float32)

    ex = BlockExecutor(Counting(), prefetch=0, cache_blocks=2)
    ex.fetch(0), ex.fetch(0), ex.fetch(0)
    assert calls == [0]  # cached
    ex.fetch(1), ex.fetch(0)  # both resident (cap 2)
    assert calls == [0, 1]
    ex.fetch(2)  # evicts 1 (LRU order: 0 was touched last)
    ex.fetch(1)
    assert calls == [0, 1, 2, 1]


# ---------------------------------------------------------------------------
# Fetchers
# ---------------------------------------------------------------------------

def test_mmap_fetcher_roundtrip(store):
    s, blocks = store
    f = MmapFetcher(s)
    assert f.num_blocks == 8
    for k in range(8):
        got = f.fetch(k)
        assert isinstance(got, np.memmap)  # streamed, not materialized
        np.testing.assert_array_equal(np.asarray(got), np.asarray(s.load_block(k, mmap=False)))
    with BlockExecutor(f, prefetch=2) as ex:
        np.testing.assert_array_equal(ex.take(range(8)), blocks)


def test_as_fetcher_adapters(store, tmp_path):
    s, blocks = store
    assert isinstance(as_fetcher(blocks), MemoryFetcher)
    assert isinstance(as_fetcher(s), StoreFetcher)
    assert isinstance(as_fetcher(s, mode="mmap"), MmapFetcher)
    ds = rsp.RSPDataset(s.spec(), store=s)
    adapted = as_fetcher(ds)
    np.testing.assert_array_equal(np.asarray(adapted.fetch(3)), blocks[3])
    assert adapted.num_blocks == 8
    with pytest.raises(TypeError):
        as_fetcher(object())


def test_dataset_fetcher_modes(tmp_path):
    data = _blocks(k=4, n=64, f=3).reshape(-1, 3)
    ds = rsp.partition(data, blocks=4, seed=0, backend="np").save(str(tmp_path / "c"))
    for mode in ("auto", "memory", "store", "mmap"):
        got = rsp.open(str(tmp_path / "c"), fetcher=mode)
        np.testing.assert_array_equal(np.asarray(got.block(2)), np.asarray(ds.block(2)))
        np.testing.assert_array_equal(got.stacked(), ds.stacked())
    with pytest.raises(ValueError, match="unknown fetcher"):
        rsp.open(str(tmp_path / "c"), fetcher="carrier-pigeon").block(0)


# ---------------------------------------------------------------------------
# Sampling policies + HT reweighting
# ---------------------------------------------------------------------------

def _skewed_sketches(k=32, n=128, seed=1):
    rng = np.random.default_rng(seed)
    x = np.sort(rng.lognormal(mean=1.0, sigma=1.2, size=k * n))
    blocks = x.reshape(k, n, 1)
    return summarize_blocks(blocks), x.mean(), k * n


def test_uniform_policy_matches_block_sampler():
    from repro.core import BlockSampler

    pol = UniformPolicy(16, seed=5)
    ref = BlockSampler(16, seed=5)
    assert pol.sample(6) == ref.sample(6)
    state = pol.state_dict()
    pol2 = UniformPolicy(16, seed=0)
    pol2.load_state_dict(state)
    assert pol2.sample(4) == ref.sample(4)


def test_weighted_policy_ht_unbiased_and_beats_uniform():
    sketches, truth, n = _skewed_sketches()
    g, uni_err, w_err, w_est = 6, [], [], []
    for s in range(150):
        up = UniformPolicy(len(sketches), seed=s)
        ids = up.sample(g)
        uni_err.append(abs(combine_summaries([sketches[k] for k in ids]).mean[0] - truth))
        wp = WeightedPolicy(len(sketches), sketches, seed=s)
        ids = wp.sample(g)
        est = combine_summaries(
            [sketches[k] for k in ids], weights=wp.weights(ids), total_count=n
        ).mean[0]
        w_est.append(est)
        w_err.append(abs(est - truth))
    # unbiased: the average of HT estimates lands on the truth
    assert abs(np.mean(w_est) - truth) < 0.05 * truth
    # and on skewed (non-RSP) blocks, sketch-weighted selection wins clearly
    assert np.mean(w_err) < 0.5 * np.mean(uni_err)


def test_weighted_policy_determinism_and_state():
    sketches, _, _ = _skewed_sketches(k=8)
    a = WeightedPolicy(8, sketches, seed=3)
    b = WeightedPolicy(8, sketches, seed=3)
    assert a.sample(4) == b.sample(4)
    state = a.state_dict()
    c = WeightedPolicy(8, sketches, seed=0)
    c.load_state_dict(state)
    assert c.sample(4) == b.sample(4)


def test_stratified_policy_allocation_and_weights():
    # 6 blocks: 4 dominated by label 0, 2 by label 1
    blocks = np.zeros((6, 32, 2), np.float32)
    blocks[4:, :, 1] = 1.0
    sketches = summarize_blocks(blocks, label_column=1, num_classes=2)
    pol = StratifiedPolicy(6, sketches, seed=0)
    ids = pol.sample(3)
    assert len(ids) == 3 and len(set(ids)) == 3
    strata = {k: (0 if k < 4 else 1) for k in range(6)}
    drawn = [strata[i] for i in ids]
    assert drawn.count(0) == 2 and drawn.count(1) == 1  # proportional 4:2
    w = pol.weights(ids)
    np.testing.assert_allclose(w, [2.0, 2.0, 2.0])  # 4/2 and 2/1


def test_stratified_single_draw_stream_visits_all_strata():
    # regression: deterministic largest-remainder allocation starved small
    # strata at g=1 (the loader's refill pattern) -- remainder draws are now
    # randomized in proportion, so a g=1 stream covers every stratum
    blocks = np.zeros((10, 16, 2), np.float32)
    blocks[6:9, :, 1] = 1.0   # stratum sizes 6 / 3 / 1
    blocks[9:, :, 1] = 2.0
    sketches = summarize_blocks(blocks, label_column=1, num_classes=3)
    pol = StratifiedPolicy(10, sketches, seed=0)
    drawn = {pol.sample(1)[0] for _ in range(200)}
    assert 9 in drawn            # the single-block stratum is reachable
    assert drawn & set(range(6)) and drawn & {6, 7, 8}


def test_stratified_policy_requires_label_hists():
    sketches = summarize_blocks(_blocks(k=4))
    with pytest.raises(ValueError, match="label histograms"):
        StratifiedPolicy(4, sketches)


def test_make_policy_errors():
    with pytest.raises(ValueError, match="unknown sampling policy"):
        make_policy("thompson", 8)
    with pytest.raises(ValueError, match="summaries"):
        make_policy("weighted", 8)
    with pytest.raises(ValueError, match="summaries"):
        make_policy("stratified", 8)


def test_combine_summaries_weighted_exact_on_full_population():
    blocks = _blocks(k=5, n=16, f=2, seed=3)
    sketches = summarize_blocks(blocks)
    plain = combine_summaries(sketches)
    ht = combine_summaries(
        sketches, weights=np.ones(5), total_count=int(plain.count)
    )
    np.testing.assert_allclose(ht.mean, plain.mean, rtol=1e-9)
    np.testing.assert_allclose(ht.m2, plain.m2, rtol=1e-9, atol=1e-9)
    assert ht.count == plain.count


def test_combine_summaries_weight_validation():
    sketches = summarize_blocks(_blocks(k=3))
    with pytest.raises(ValueError, match="weights"):
        combine_summaries(sketches, weights=np.ones(2))
    with pytest.raises(ValueError, match="weights"):
        combine_summaries(sketches, weights=np.array([1.0, -1.0, 1.0]))


# ---------------------------------------------------------------------------
# Dataset surface: sample/moments/estimate with policies
# ---------------------------------------------------------------------------

def _labelled_dataset(n=2048, k=8, seed=2):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 3)).astype(np.float32)
    y = (rng.random(n) < 0.5).astype(np.float32)
    data = np.concatenate([x, y[:, None]], axis=1)
    return rsp.partition(data, blocks=k, seed=seed, backend="np", num_classes=2), data


def test_dataset_policy_surface(tmp_path):
    ds, data = _labelled_dataset()
    for policy in ("uniform", "weighted", "stratified"):
        ids = ds.sample(4, seed=1, policy=policy)
        assert len(ids) == 4 and all(0 <= i < 8 for i in ids)
        m = ds.moments(g=4, seed=1, policy=policy)
        assert np.abs(m.mean - data.astype(np.float64).mean(0)).max() < 0.5
    est = ds.estimate(lambda b: b.mean(0), g=4, seed=1, policy="weighted")
    assert np.abs(est - data.mean(0)).max() < 0.5
    with pytest.raises(ValueError, match="need g"):
        ds.moments(policy="weighted")
    with pytest.raises(ValueError, match="ids or a non-uniform policy"):
        ds.moments(ids=[0, 1], policy="weighted")  # no silent unweighted combine
    # store-backed too (sketches come from the manifest)
    ds.save(str(tmp_path / "c"))
    got = rsp.open(str(tmp_path / "c"))
    m = got.moments(g=4, seed=1, policy="stratified")
    assert np.isfinite(m.mean).all()


def test_dataset_estimator_streams_through_executor(tmp_path):
    ds, data = _labelled_dataset()
    ds.save(str(tmp_path / "c"))
    got = rsp.open(str(tmp_path / "c"), prefetch=3)
    est = got.estimator(g=6, seed=0)
    assert est.blocks_seen == 6
    ref = ds.estimator(g=6, seed=0)
    np.testing.assert_allclose(est.stats.mean, ref.stats.mean, rtol=1e-6)


# ---------------------------------------------------------------------------
# Similarity: the probed block must not ride in its own reference sample
# ---------------------------------------------------------------------------

def test_corpus_reference_excludes_probe(tmp_path):
    # constant-valued blocks make self-inclusion visible in the reference
    k, n = 4, 64
    blocks = np.stack([np.full((n, 1), float(i), np.float32) for i in range(k)])
    spec = RSPSpec(num_records=k * n, num_blocks=k, num_original_blocks=1, record_shape=(1,))
    store = RSPStore(str(tmp_path / "c"))
    store.write_partition(blocks, spec)
    ds = rsp.RSPDataset(spec, store=store)
    for probe in range(k):
        ref = ds._corpus_reference(4096, seed=0, exclude=probe)
        assert float(probe) not in set(np.unique(ref))
        assert ref.shape[0] >= n  # still a usable reference


def test_similarity_detects_outlier_block(tmp_path):
    ds, data = _labelled_dataset(n=2048, k=8)
    ds.save(str(tmp_path / "c"))
    got = rsp.open(str(tmp_path / "c"))
    # corrupt one stored block far away from the corpus
    bad = np.asarray(got.block(5)) + 50.0
    np.save(store_path := str(tmp_path / "c" / "block_00005.npy"), bad)
    got2 = rsp.open(str(tmp_path / "c"))
    sane = got2.similarity(1, metric="mmd", seed=0)
    outlier = got2.similarity(5, metric="mmd", seed=0)
    assert outlier > sane + 0.1


# ---------------------------------------------------------------------------
# Cache / prefetch instrumentation (ExecutorStats)
# ---------------------------------------------------------------------------

def test_stats_hits_misses_evictions():
    blocks = _blocks(k=6)
    with BlockExecutor(MemoryFetcher(blocks), prefetch=0, cache_blocks=2) as ex:
        assert ex.stats() == rsp.ExecutorStats()
        ex.fetch(0)          # miss
        ex.fetch(0)          # hit
        ex.fetch(1)          # miss (cache {0, 1})
        ex.fetch(2)          # miss -> evicts 0
        ex.fetch(0)          # miss -> evicts 1
        s = ex.stats()
    assert (s.hits, s.misses, s.evictions) == (1, 4, 2)
    assert s.blocks_fetched == 4


def test_stats_cache_disabled_counts_every_fetch_as_miss():
    blocks = _blocks(k=4)
    with BlockExecutor(MemoryFetcher(blocks), prefetch=0, cache_blocks=0) as ex:
        for _ in range(3):
            ex.fetch(1)
        s = ex.stats()
    assert (s.hits, s.misses, s.evictions) == (0, 3, 0)


def test_stats_snapshot_subtraction_meters_a_window():
    blocks = _blocks(k=5)
    with BlockExecutor(MemoryFetcher(blocks), prefetch=0, cache_blocks=8) as ex:
        ex.fetch(0)
        before = ex.stats()
        ex.fetch(0)  # hit
        ex.fetch(1)  # miss
        window = ex.stats() - before
    assert (window.hits, window.misses) == (1, 1)
    assert window.blocks_fetched == 1


def test_stats_under_prefetch_pipeline():
    blocks = _blocks(k=8)
    with BlockExecutor(MemoryFetcher(blocks), prefetch=3, cache_blocks=8) as ex:
        list(ex.map_blocks(None, [0, 1, 2, 3, 0, 1]))
        s = ex.stats()
    assert s.hits + s.misses == 6
    assert s.misses >= 4  # at least the four distinct blocks were fetched


def test_reset_stats():
    blocks = _blocks(k=3)
    with BlockExecutor(MemoryFetcher(blocks), prefetch=0) as ex:
        ex.fetch(0)
        ex.reset_stats()
        assert ex.stats() == rsp.ExecutorStats()


def test_stats_consistent_under_concurrent_hammering():
    """``stats()`` must be an atomic snapshot: with 8 threads fetching
    concurrently, every observed snapshot satisfies the conservation law
    ``accesses == hits + misses`` and counters never run backwards."""
    import threading

    blocks = _blocks(k=16)
    stop = threading.Event()
    bad: list[str] = []

    with BlockExecutor(MemoryFetcher(blocks), prefetch=0, cache_blocks=4) as ex:
        def hammer(seed: int) -> None:
            rng = np.random.default_rng(seed)
            while not stop.is_set():
                ex.fetch(int(rng.integers(0, 16)))

        def watch() -> None:
            prev = ex.stats()
            while not stop.is_set():
                s = ex.stats()
                total = s.hits + s.misses
                if s.blocks_fetched != s.misses:
                    bad.append(f"blocks_fetched {s.blocks_fetched} != misses {s.misses}")
                if s.hits < prev.hits or s.misses < prev.misses or total < (
                    prev.hits + prev.misses
                ):
                    bad.append(f"counters ran backwards: {prev} -> {s}")
                prev = s

        threads = [threading.Thread(target=hammer, args=(i,)) for i in range(8)]
        threads += [threading.Thread(target=watch) for _ in range(2)]
        for t in threads:
            t.start()
        import time as _time

        _time.sleep(0.5)
        stop.set()
        for t in threads:
            t.join()
        final = ex.stats()

    assert not bad, bad[:5]
    assert final.hits + final.misses > 0
    assert final.blocks_fetched == final.misses
