"""Launch-path tests: the multi-pod dry-run machinery end to end on one
small cell per mesh (subprocess: the dry-run needs its own 512-device jax
runtime), plus unit tests of the structural HLO analyzer."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_cell(args, timeout=2400):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        env=env, capture_output=True, text=True, timeout=timeout,
    )


@pytest.mark.slow
def test_dryrun_single_and_multi_pod_cell(tmp_path):
    for extra in ([], ["--multi-pod"]):
        proc = _run_cell(["--arch", "qwen2-0.5b", "--shape", "decode_32k",
                          "--out", str(tmp_path), *extra])
        assert proc.returncode == 0, proc.stderr[-3000:]
    single = json.load(open(tmp_path / "qwen2-0.5b_decode_32k_single.json"))
    multi = json.load(open(tmp_path / "qwen2-0.5b_decode_32k_multi.json"))
    for r in (single, multi):
        assert r["analysis"]["flops"] > 0
        assert r["memory"]["argument_size_in_bytes"] > 0
        # decode KV cache + params must fit a 16 GiB chip
        used = r["memory"]["argument_size_in_bytes"] + r["memory"]["temp_size_in_bytes"]
        assert used < 16 * 1024**3, f"{used/1e9:.1f} GB"
    # multi-pod shards the batch over 2x more DP ways -> fewer flops per chip
    assert multi["analysis"]["flops"] <= single["analysis"]["flops"] * 1.05


@pytest.mark.slow
def test_dryrun_rsp_partition_program(tmp_path):
    proc = _run_cell(["--arch", "rsp-partition", "--out", str(tmp_path)])
    assert proc.returncode == 0, proc.stderr[-3000:]
    r = json.load(open(tmp_path / "rsp-partition_single.json"))
    # pure data movement: no matmul flops; moved bytes at least read+write of
    # the per-device slab (1024 records x 4097 tokens x 4 B).  The absolute
    # count depends on the jax version's lowering, so anchor to the slab.
    slab = 1024 * 4097 * 4
    assert r["analysis"]["flops"] == 0
    assert r["analysis"]["bytes"] > 2 * slab


def test_hlo_analyzer_scales_loop_bodies():
    from repro.launch.roofline import analyze_hlo

    hlo = """\
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %init = (s32[], f32[8,8]) tuple(%a, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""
    a = analyze_hlo(hlo)
    # one 8x8x8 dot (1024 flops) x 5 trips
    assert a["flops"] == pytest.approx(2 * 8 * 8 * 8 * 5)


def test_hlo_analyzer_collectives_and_factors():
    from repro.launch.roofline import analyze_hlo, roofline_terms

    hlo = """\
HloModule test

ENTRY %main (a: f32[1024]) -> f32[1024] {
  %a = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%a), to_apply=%sum
  ROOT %ag = f32[1024]{0} all-gather(%ar), dimensions={0}
}

%sum (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %s = f32[] add(%x, %y)
}
"""
    a = analyze_hlo(hlo)
    assert a["collectives"]["all-reduce"]["bytes"] == 4096
    assert a["collectives"]["all-gather"]["bytes"] == 4096
    t = roofline_terms(a, chips=256)
    # wire = 2x all-reduce + 1x all-gather
    assert t["wire_bytes"] == pytest.approx(2 * 4096 + 4096)


def test_model_flops_sanity():
    from repro.configs import ARCHS, SHAPES
    from repro.launch.roofline import model_flops

    # dense train ~ 6 N D
    f = model_flops(ARCHS["llama3.2-1b"], SHAPES["train_4k"])
    assert 6e15 < f < 1.2e16
    # MoE active params ~3B of 30B -> flops closer to a 3B dense model
    f_moe = model_flops(ARCHS["qwen3-moe-30b-a3b"], SHAPES["train_4k"])
    f_dense30 = 6 * 30e9 * 256 * 4096
    assert f_moe < 0.25 * f_dense30
    # decode processes B tokens, not B*S
    f_dec = model_flops(ARCHS["llama3.2-1b"], SHAPES["decode_32k"])
    assert f_dec < f / 1000
