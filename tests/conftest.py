"""Shared test configuration.

Tier-1 tests must be deterministic: never let machine-local autotune
timings decide which kernel implementation a test exercises.  CI sets
``REPRO_AUTOTUNE=off`` explicitly; this default covers local runs too.
Tests that exercise the tuner itself override the variable via
``monkeypatch.setenv``.
"""

import os

os.environ.setdefault("REPRO_AUTOTUNE", "off")
