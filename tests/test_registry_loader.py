"""RSPStore (stored RSP) and RSPLoader (training pipeline) tests: atomic
write/read, checksums, block-level batching, exact resume."""

import numpy as np
import pytest

from repro.core import RSPSpec, RSPStore, two_stage_partition_np
from repro.data import BlockSource, PrefetchLoader, RSPLoader, make_higgs_like


@pytest.fixture()
def store(tmp_path):
    x, y = make_higgs_like(2048, num_features=4, seed=0)
    data = np.concatenate([x, y[:, None].astype(np.float32)], axis=1)
    spec = RSPSpec(num_records=2048, num_blocks=8, num_original_blocks=8, seed=3)
    blocks = two_stage_partition_np(data, spec)
    s = RSPStore(str(tmp_path / "rsp"))
    s.write_partition(blocks, spec)
    return s, blocks, spec


def test_store_roundtrip(store):
    s, blocks, spec = store
    assert s.num_blocks() == 8
    got = s.spec()
    assert got.num_records == spec.num_records and got.num_blocks == spec.num_blocks
    for k in range(8):
        np.testing.assert_array_equal(np.asarray(s.load_block(k, verify=True)), blocks[k])


def test_store_checksum_detects_corruption(store, tmp_path):
    s, blocks, _ = store
    path = s._block_path(2)
    arr = np.load(path)
    arr[0, 0] += 1.0
    np.save(path, arr)
    with pytest.raises(IOError):
        s.load_block(2, mmap=False, verify=True)


def test_loader_batches_cover_epoch(store):
    s, blocks, _ = store
    loader = RSPLoader(BlockSource(store=s), batch_size=128, seed=0)
    seen = [loader.next_batch() for _ in range(16)]  # 16*128 = 2048 = one epoch
    allb = np.concatenate(seen)
    flat = blocks.reshape(-1, blocks.shape[-1])
    # batch records are exactly the corpus records (multiset equality)
    assert allb.shape == flat.shape
    a = np.sort(allb.view(np.uint8).reshape(allb.shape[0], -1), axis=0)
    b = np.sort(flat.view(np.uint8).reshape(flat.shape[0], -1), axis=0)
    np.testing.assert_array_equal(a, b)


def test_loader_resume_exact(store):
    s, _, _ = store
    ref = RSPLoader(BlockSource(store=s), batch_size=64, seed=7)
    ref_batches = [ref.next_batch() for _ in range(10)]

    live = RSPLoader(BlockSource(store=s), batch_size=64, seed=7)
    for _ in range(4):
        live.next_batch()
    state = live.state_dict()

    resumed = RSPLoader(BlockSource(store=s), batch_size=64, seed=7)
    resumed.load_state_dict(state)
    for i in range(4, 10):
        np.testing.assert_array_equal(resumed.next_batch(), ref_batches[i])


def test_loader_in_memory_source():
    blocks = np.arange(4 * 10 * 2, dtype=np.float32).reshape(4, 10, 2)
    loader = RSPLoader(BlockSource(blocks=blocks), batch_size=5, seed=1)
    b = loader.next_batch()
    assert b.shape == (5, 2)


def test_prefetch_loader(store):
    s, _, _ = store
    inner_a = RSPLoader(BlockSource(store=s), batch_size=50, seed=3)
    inner_b = RSPLoader(BlockSource(store=s), batch_size=50, seed=3)
    pf = PrefetchLoader(inner_a, depth=2)
    try:
        got = [pf.next_batch() for _ in range(6)]
    finally:
        pf.close()
    want = [inner_b.next_batch() for _ in range(6)]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_loader_transform(store):
    s, _, _ = store
    loader = RSPLoader(
        BlockSource(store=s), batch_size=10, seed=0, transform=lambda b: b * 2.0
    )
    b1 = loader.next_batch()
    loader2 = RSPLoader(BlockSource(store=s), batch_size=10, seed=0)
    np.testing.assert_allclose(b1, loader2.next_batch() * 2.0)
