"""RSPStore (stored RSP) and RSPLoader (training pipeline) tests: atomic
write/read, checksums, block-level batching, exact resume."""

import numpy as np
import pytest

from repro.core import RSPSpec, RSPStore, two_stage_partition_np
from repro.data import BlockSource, PrefetchLoader, RSPLoader, make_higgs_like


@pytest.fixture()
def store(tmp_path):
    x, y = make_higgs_like(2048, num_features=4, seed=0)
    data = np.concatenate([x, y[:, None].astype(np.float32)], axis=1)
    spec = RSPSpec(num_records=2048, num_blocks=8, num_original_blocks=8, seed=3)
    blocks = two_stage_partition_np(data, spec)
    s = RSPStore(str(tmp_path / "rsp"))
    s.write_partition(blocks, spec)
    return s, blocks, spec


def test_store_roundtrip(store):
    s, blocks, spec = store
    assert s.num_blocks() == 8
    got = s.spec()
    assert got.num_records == spec.num_records and got.num_blocks == spec.num_blocks
    for k in range(8):
        np.testing.assert_array_equal(np.asarray(s.load_block(k, verify=True)), blocks[k])


def test_write_sweeps_orphaned_writer_temps(store):
    """Regression: ``block_*.npy.tmp.npy`` temps from a crashed writer used
    to survive the stale-block sweep forever (the block-id parse raised and
    skipped them)."""
    import os

    s, blocks, spec = store
    orphan = os.path.join(s.root, "block_00002.npy.tmp.npy")
    with open(orphan, "wb") as f:
        f.write(b"half-written junk")
    stale = os.path.join(s.root, "block_00099.npy")
    np.save(stale, np.zeros((2, 2)))
    s.write_partition(blocks, spec)
    assert not os.path.exists(orphan)
    assert not os.path.exists(stale)
    # the real blocks are untouched and still verify
    for k in range(8):
        np.testing.assert_array_equal(np.asarray(s.load_block(k, verify=True)), blocks[k])


def test_partition_writer_offset_ranges_and_finalize(store, tmp_path):
    """RSPStore.create_writer: offset-range writes land at their destinations,
    checksums come from the finished files, manifest publishes last."""
    import os

    _, blocks, base = store
    spec = RSPSpec(num_records=base.num_records, num_blocks=base.num_blocks,
                   num_original_blocks=base.num_original_blocks,
                   record_shape=(blocks.shape[-1],), dtype=str(blocks.dtype),
                   seed=base.seed)
    root = str(tmp_path / "streamed")
    writer = RSPStore(root).create_writer(spec)
    # write each block in two interleaved halves, out of order
    n = spec.block_size
    evens, odds = np.arange(0, n, 2), np.arange(1, n, 2)
    for k in range(spec.num_blocks):
        writer.write_rows(k, odds, blocks[k][odds])
    assert not os.path.exists(os.path.join(root, "manifest.json"))  # not yet published
    for k in range(spec.num_blocks):
        writer.write_rows(k, evens, blocks[k][evens])
    out = writer.finalize(meta={"backend": "np_stream"})
    assert out.num_blocks() == spec.num_blocks
    for k in range(spec.num_blocks):
        np.testing.assert_array_equal(
            np.asarray(out.load_block(k, verify=True)), blocks[k]
        )
    assert [f for f in os.listdir(root) if f.endswith(".tmp.npy")] == []
    with pytest.raises(RuntimeError):
        writer.finalize()  # double-finalize is an error


def test_partition_writer_crash_mid_swap_leaves_no_stale_manifest(store, tmp_path, monkeypatch):
    """Regression: finalize over a previously published store retracts the
    old manifest BEFORE renaming new blocks over its files -- a crash
    mid-swap must leave readers a clean absence, never a live manifest
    describing a mixture of old and new blocks."""
    import os

    _, blocks, base = store
    spec = RSPSpec(num_records=base.num_records, num_blocks=base.num_blocks,
                   num_original_blocks=base.num_original_blocks,
                   record_shape=(blocks.shape[-1],), dtype=str(blocks.dtype),
                   seed=base.seed)
    root = str(tmp_path / "streamed")
    s = RSPStore(root)
    s.write_partition(blocks, spec)

    writer = s.create_writer(spec)
    for k in range(spec.num_blocks):
        writer.write_rows(k, np.arange(spec.block_size), blocks[k][::-1])
    monkeypatch.setattr(
        RSPStore, "_sweep_stale",
        lambda self, keep: (_ for _ in ()).throw(OSError("crash mid-swap")),
    )
    with pytest.raises(OSError, match="mid-swap"):
        writer.finalize()
    assert not os.path.exists(os.path.join(root, "manifest.json"))
    monkeypatch.undo()
    # recovery: a fresh ingest into the same root publishes cleanly
    writer2 = s.create_writer(spec)
    for k in range(spec.num_blocks):
        writer2.write_rows(k, np.arange(spec.block_size), blocks[k])
    out = writer2.finalize()
    for k in range(spec.num_blocks):
        np.testing.assert_array_equal(np.asarray(out.load_block(k, verify=True)), blocks[k])


def test_partition_writer_abort_leaves_previous_publish_intact(store, tmp_path):
    import os

    _, blocks, base = store
    spec = RSPSpec(num_records=base.num_records, num_blocks=base.num_blocks,
                   num_original_blocks=base.num_original_blocks,
                   record_shape=(blocks.shape[-1],), dtype=str(blocks.dtype),
                   seed=base.seed)
    root = str(tmp_path / "streamed")
    s = RSPStore(root)
    s.write_partition(blocks, spec)
    writer = s.create_writer(spec)
    writer.write_rows(0, np.arange(4), np.zeros((4, blocks.shape[-1]), np.float32))
    writer.abort()
    assert [f for f in os.listdir(root) if f.endswith(".tmp.npy")] == []
    for k in range(spec.num_blocks):  # published blocks untouched
        np.testing.assert_array_equal(np.asarray(s.load_block(k, verify=True)), blocks[k])


def test_store_checksum_detects_corruption(store, tmp_path):
    s, blocks, _ = store
    path = s._block_path(2)
    arr = np.load(path)
    arr[0, 0] += 1.0
    np.save(path, arr)
    with pytest.raises(IOError):
        s.load_block(2, mmap=False, verify=True)


def test_loader_batches_cover_epoch(store):
    s, blocks, _ = store
    loader = RSPLoader(BlockSource(store=s), batch_size=128, seed=0)
    seen = [loader.next_batch() for _ in range(16)]  # 16*128 = 2048 = one epoch
    allb = np.concatenate(seen)
    flat = blocks.reshape(-1, blocks.shape[-1])
    # batch records are exactly the corpus records (multiset equality)
    assert allb.shape == flat.shape
    a = np.sort(allb.view(np.uint8).reshape(allb.shape[0], -1), axis=0)
    b = np.sort(flat.view(np.uint8).reshape(flat.shape[0], -1), axis=0)
    np.testing.assert_array_equal(a, b)


def test_loader_resume_exact(store):
    s, _, _ = store
    ref = RSPLoader(BlockSource(store=s), batch_size=64, seed=7)
    ref_batches = [ref.next_batch() for _ in range(10)]

    live = RSPLoader(BlockSource(store=s), batch_size=64, seed=7)
    for _ in range(4):
        live.next_batch()
    state = live.state_dict()

    resumed = RSPLoader(BlockSource(store=s), batch_size=64, seed=7)
    resumed.load_state_dict(state)
    for i in range(4, 10):
        np.testing.assert_array_equal(resumed.next_batch(), ref_batches[i])


def test_loader_in_memory_source():
    blocks = np.arange(4 * 10 * 2, dtype=np.float32).reshape(4, 10, 2)
    loader = RSPLoader(BlockSource(blocks=blocks), batch_size=5, seed=1)
    b = loader.next_batch()
    assert b.shape == (5, 2)


def test_prefetch_loader(store):
    s, _, _ = store
    inner_a = RSPLoader(BlockSource(store=s), batch_size=50, seed=3)
    inner_b = RSPLoader(BlockSource(store=s), batch_size=50, seed=3)
    pf = PrefetchLoader(inner_a, depth=2)
    try:
        got = [pf.next_batch() for _ in range(6)]
    finally:
        pf.close()
    want = [inner_b.next_batch() for _ in range(6)]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_loader_transform(store):
    s, _, _ = store
    loader = RSPLoader(
        BlockSource(store=s), batch_size=10, seed=0, transform=lambda b: b * 2.0
    )
    b1 = loader.next_batch()
    loader2 = RSPLoader(BlockSource(store=s), batch_size=10, seed=0)
    np.testing.assert_allclose(b1, loader2.next_batch() * 2.0)


def test_loader_resume_across_epoch_boundary(store):
    # 2048 records, batch 192 -> the epoch boundary falls inside batch 11;
    # checkpoint right before it and verify exact-batch equivalence after.
    s, _, _ = store
    ref = RSPLoader(BlockSource(store=s), batch_size=192, seed=11)
    ref_batches = [ref.next_batch() for _ in range(16)]

    live = RSPLoader(BlockSource(store=s), batch_size=192, seed=11)
    for _ in range(10):
        live.next_batch()
    state = live.state_dict()
    assert state["pool"]  # open-pool entries ride along in the checkpoint

    resumed = RSPLoader(BlockSource(store=s), batch_size=192, seed=11)
    resumed.load_state_dict(state)
    for i in range(10, 16):
        np.testing.assert_array_equal(resumed.next_batch(), ref_batches[i])


def test_loader_resume_is_pool_bounded(store, monkeypatch):
    # Resume must reload only the open-pool blocks, not replay the history.
    s, _, _ = store
    live = RSPLoader(BlockSource(store=s), batch_size=64, seed=3, prefetch=0)
    for _ in range(12):
        live.next_batch()
    state = live.state_dict()

    loads: list[int] = []
    orig = BlockSource.load

    def spying(self, block_id):
        loads.append(block_id)
        return orig(self, block_id)

    monkeypatch.setattr(BlockSource, "load", spying)
    resumed = RSPLoader(BlockSource(store=s), batch_size=64, seed=3, prefetch=0)
    resumed.load_state_dict(state)
    assert sorted(loads) == sorted(e["block_id"] for e in state["pool"])


def test_loader_resume_self_contained_seed(store):
    # the checkpoint carries the permutation seed: a loader constructed with
    # a different seed still resumes the original stream exactly
    s, _, _ = store
    ref = RSPLoader(BlockSource(store=s), batch_size=64, seed=7)
    ref_batches = [ref.next_batch() for _ in range(10)]
    live = RSPLoader(BlockSource(store=s), batch_size=64, seed=7)
    for _ in range(4):
        live.next_batch()
    state = live.state_dict()

    resumed = RSPLoader(BlockSource(store=s), batch_size=64, seed=0)  # wrong seed
    resumed.load_state_dict(state)
    for i in range(4, 10):
        np.testing.assert_array_equal(resumed.next_batch(), ref_batches[i])


def test_loader_legacy_state_replays(store):
    # v1 checkpoints (sampler seed + consumed count, no pool) still resume
    s, _, _ = store
    ref = RSPLoader(BlockSource(store=s), batch_size=64, seed=7)
    ref_batches = [ref.next_batch() for _ in range(8)]
    legacy = {"sampler": {"seed": 7, "epoch": 0, "cursor": 0}, "consumed_batches": 5}
    resumed = RSPLoader(BlockSource(store=s), batch_size=64, seed=7)
    resumed.load_state_dict(legacy)
    for i in range(5, 8):
        np.testing.assert_array_equal(resumed.next_batch(), ref_batches[i])


def test_loader_worker_exception_propagates(store):
    s, _, _ = store
    calls = {"n": 0}

    class FlakySource(BlockSource):
        def load(self, block_id):
            calls["n"] += 1
            if calls["n"] > 2:
                raise RuntimeError("store went away")
            return super().load(block_id)

    loader = RSPLoader(FlakySource(store=s), batch_size=64, seed=0, prefetch=2)
    with pytest.raises(RuntimeError, match="store went away"):
        for _ in range(64):
            loader.next_batch()
    loader.close()


def test_prefetch_loader_exception_propagates(store):
    # regression: a worker exception used to be swallowed, leaving
    # next_batch() blocked forever
    s, _, _ = store
    calls = {"n": 0}

    class FlakySource(BlockSource):
        def load(self, block_id):
            calls["n"] += 1
            if calls["n"] > 2:
                raise RuntimeError("worker died")
            return super().load(block_id)

    pf = PrefetchLoader(RSPLoader(FlakySource(store=s), batch_size=64, seed=0), depth=2)
    try:
        with pytest.raises(RuntimeError, match="worker died"):
            for _ in range(64):
                pf.next_batch()
    finally:
        pf.close()


def test_prefetch_loader_close_releases_inner_loader(store):
    s, _, _ = store
    inner = RSPLoader(BlockSource(store=s), batch_size=50, seed=3, prefetch=2)
    pf = PrefetchLoader(inner, depth=2)
    pf.next_batch()
    pf.close()
    assert inner._executor._pool is None  # engine workers released
    assert not inner._pool  # no in-flight block fetches left behind


def test_loader_policy_stream_and_resume(store):
    s, _, _ = store
    ref = RSPLoader(BlockSource(store=s), batch_size=64, seed=5, policy="weighted")
    ref_batches = [ref.next_batch() for _ in range(8)]
    assert all(b.shape == (64, 5) for b in ref_batches)

    live = RSPLoader(BlockSource(store=s), batch_size=64, seed=5, policy="weighted")
    for _ in range(3):
        live.next_batch()
    state = live.state_dict()
    assert state["policy"]["kind"] == "weighted"
    resumed = RSPLoader(BlockSource(store=s), batch_size=64, seed=5, policy="weighted")
    resumed.load_state_dict(state)
    for i in range(3, 8):
        np.testing.assert_array_equal(resumed.next_batch(), ref_batches[i])

    mismatched = RSPLoader(BlockSource(store=s), batch_size=64, seed=5)
    with pytest.raises(ValueError, match="policy"):
        mismatched.load_state_dict(state)

    # legacy (v1) states are uniform-only: no silent policy downgrade
    legacy = {"sampler": {"seed": 5, "epoch": 0, "cursor": 0}, "consumed_batches": 1}
    fresh = RSPLoader(BlockSource(store=s), batch_size=64, seed=5, policy="weighted")
    with pytest.raises(ValueError, match="uniform-only"):
        fresh.load_state_dict(legacy)
