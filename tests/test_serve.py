"""Concurrent approximate-query serving: QueryService + admission + scheduler.

The serving layer's contract under contention:

* no deadlock: N threads submitting mixed sketch-only / progressive queries
  over one store-backed dataset all complete;
* every served result equals its single-threaded answer (scheduling order
  must not leak into estimates -- per-query seeds are derived from
  ``(service seed, query id)``);
* per-query ``CallerStats`` sum exactly to the shared executor's totals;
* cancellation releases queued work (admission slots and prefetch futures);
* deadlines produce anytime results instead of failures.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np
import pytest

from repro import rsp
from repro.rsp.engine import BlockExecutor, CallerStats, MemoryFetcher
from repro.rsp.query import QueryExecutor, as_query, derive_seed
from repro.serve import (
    AdmissionController,
    AdmissionRejected,
    QueryService,
    StepScheduler,
)

K, BLOCK, F = 24, 384, 3


@pytest.fixture(scope="module")
def stored_ds(tmp_path_factory):
    rng = np.random.default_rng(0)
    data = rng.normal(5, 1, size=(K * BLOCK, F)).astype(np.float32)
    ds = rsp.partition(data, blocks=K, seed=1)
    path = str(tmp_path_factory.mktemp("serve") / "corpus.rsp")
    ds.save(path)
    ds.close()
    return path, data


def _open(path, **kw):
    kw.setdefault("cache_blocks", K)
    return rsp.open(path, **kw)


def _hog(svc, **kw):
    """A progressive query that can neither converge nor exhaust within the
    test's lifetime: PPS-with-replacement selection (no epoch bound) chasing
    an unreachable target.  It holds its admission slots until cancelled."""
    return svc.submit(
        "mean", use_sketches=False, target_rel_err=1e-12,
        policy="weighted", max_blocks=10**7, **kw,
    )


# ---------------------------------------------------------------------------
# Engine: per-caller stats + single-flight under concurrency
# ---------------------------------------------------------------------------

def test_caller_stats_sum_to_executor_total_under_threads():
    blocks = np.arange(16 * 8 * 2, dtype=np.float32).reshape(16, 8, 2)
    with BlockExecutor(MemoryFetcher(blocks), prefetch=2, cache_blocks=6) as ex:
        counters = [CallerStats() for _ in range(8)]

        def consume(c):
            for _ in ex.map_blocks(None, [1, 3, 5, 7, 9, 11], counter=c):
                pass

        threads = [threading.Thread(target=consume, args=(c,)) for c in counters]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        total = ex.stats()
    per = sum((c.stats() for c in counters), rsp.ExecutorStats())
    assert per.hits == total.hits and per.misses == total.misses
    assert per.accesses == 8 * 6


def test_single_flight_dedups_concurrent_fetches_of_one_block():
    calls = []
    gate = threading.Event()

    class SlowFetcher:
        num_blocks = 4

        def fetch(self, block_id):
            calls.append(block_id)
            gate.wait(5)
            return np.full((4, 2), block_id, dtype=np.float32)

    with BlockExecutor(SlowFetcher(), prefetch=0, cache_blocks=4) as ex:
        out = []
        threads = [
            threading.Thread(target=lambda: out.append(ex.fetch(2)))
            for _ in range(6)
        ]
        for t in threads:
            t.start()
        time.sleep(0.1)  # let every thread reach the fetch
        gate.set()
        for t in threads:
            t.join(timeout=30)
        s = ex.stats()
    assert len(calls) == 1, "concurrent callers must share one underlying fetch"
    assert s.misses == 1 and s.hits == 5
    assert all(np.array_equal(o, out[0]) for o in out)


def test_single_flight_leader_failure_lets_waiters_retry():
    attempts = []

    class FlakyFetcher:
        num_blocks = 2

        def fetch(self, block_id):
            attempts.append(block_id)
            if len(attempts) == 1:
                raise OSError("transient")
            return np.zeros((2, 2), dtype=np.float32)

    with BlockExecutor(FlakyFetcher(), prefetch=0, cache_blocks=2) as ex:
        with pytest.raises(OSError):
            ex.fetch(0)
        assert np.array_equal(ex.fetch(0), np.zeros((2, 2)))


# ---------------------------------------------------------------------------
# Admission controller
# ---------------------------------------------------------------------------

def test_admission_admit_queue_reject_and_promotion():
    ac = AdmissionController(4, max_queue=1)
    assert ac.try_admit("a", 3) == "admit"
    assert ac.try_admit("b", 3) == "queue"       # 3+3 > 4
    assert ac.try_admit("c", 1) == "reject"      # queue full
    snap = ac.snapshot()
    assert (snap.in_flight, snap.queued, snap.rejected_total) == (3, 1, 1)
    assert ac.release(3) == ["b"]                # freed -> b admitted
    assert ac.snapshot().in_flight == 3
    assert ac.release(3) == []


def test_admission_oversized_cost_clamps_to_capacity():
    ac = AdmissionController(4)
    assert ac.try_admit("wide", 100) == "admit"  # clamped, runs alone
    assert ac.try_admit("next", 1) == "queue"
    assert ac.release(100) == ["next"]


def test_admission_drop_removes_queued_item():
    ac = AdmissionController(1, max_queue=5)
    ac.try_admit("a", 1)
    ac.try_admit("b", 1)
    assert ac.drop("b") is True
    assert ac.drop("b") is False
    assert ac.release(1) == []


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

class _Stall:
    """Pins the (single) worker until released, so later submissions pile up
    in the heap and their pop order is deterministic."""

    deadline = -1.0  # sorts before every real task

    def __init__(self):
        self.gate = threading.Event()


def _wait_idle(sched, timeout=10.0):
    end = time.monotonic() + timeout
    while not sched.idle() and time.monotonic() < end:
        time.sleep(0.01)


def test_scheduler_round_robin_interleaves_tenants():
    trace = []

    class Task:
        deadline = None

        def __init__(self, name, steps):
            self.name, self.left = name, steps

    def step(t):
        if isinstance(t, _Stall):
            t.gate.wait(5)
            return False
        trace.append(t.name)
        t.left -= 1
        return t.left > 0

    sched = StepScheduler(step, workers=1)
    stall = _Stall()
    sched.submit(stall)
    sched.submit(Task("heavy", 6))
    sched.submit(Task("light", 2))
    stall.gate.set()
    _wait_idle(sched)
    sched.close()
    # equal-urgency tenants alternate one step at a time: the light tenant
    # finishes within its first rounds instead of waiting out the heavy one
    assert trace[:4] == ["heavy", "light", "heavy", "light"]
    assert trace.count("light") == 2 and trace.count("heavy") == 6


def test_scheduler_prefers_earliest_deadline():
    trace = []

    class Task:
        def __init__(self, name, deadline):
            self.name, self.deadline = name, deadline

    def step(t):
        if isinstance(t, _Stall):
            t.gate.wait(5)
            return False
        trace.append(t.name)
        return False

    sched = StepScheduler(step, workers=1)
    stall = _Stall()
    sched.submit(stall)
    now = time.monotonic()
    sched.submit(Task("late", now + 60))
    sched.submit(Task("none", None))
    sched.submit(Task("soon", now + 1))
    stall.gate.set()
    _wait_idle(sched)
    sched.close()
    assert trace == ["soon", "late", "none"]


# ---------------------------------------------------------------------------
# QueryService: concurrent serving
# ---------------------------------------------------------------------------

def test_concurrent_mixed_queries_match_single_threaded_answers(stored_ds):
    """N submitter threads, mixed sketch-only + progressive queries; no
    deadlock, every result identical to running the same seeded query alone,
    and per-query stats sum to the shared executor's totals."""
    path, data = stored_ds
    ds = _open(path)
    specs = []
    for i in range(24):
        if i % 4 == 0:
            specs.append((["mean", "var", "count"], {}))
        elif i % 4 == 1:
            specs.append(("median", dict(max_blocks=6, use_sketches=False)))
        elif i % 4 == 2:
            specs.append(("mean", dict(target_rel_err=0.01, use_sketches=False)))
        else:
            specs.append(("p90", dict(target_rel_err=0.05, use_sketches=False)))

    service_seed = 11
    stats_before = ds.executor.stats()
    tickets: list = [None] * len(specs)
    with QueryService(ds, capacity=8, workers=3, seed=service_seed) as svc:

        def submitter(lo, hi):
            for i in range(lo, hi):
                agg, kw = specs[i]
                tickets[i] = (i, svc.submit(agg, **kw))

        threads = [
            threading.Thread(target=submitter, args=(j * 6, (j + 1) * 6))
            for j in range(4)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        results = {i: svc.result(t, timeout=60) for i, t in tickets}
        per_query = [t.result.executor_stats for _, t in tickets]
    stats_after = ds.executor.stats()

    # per-query counters sum exactly to the executor's window
    total = sum(per_query, rsp.ExecutorStats())
    window = stats_after - stats_before
    assert (total.hits, total.misses) == (window.hits, window.misses)

    # every answer equals its single-threaded run with the same derived seed
    solo_ds = _open(path)
    for i, t in tickets:
        agg, kw = specs[i]
        q = dataclasses.replace(
            as_query(agg, **kw), seed=derive_seed(service_seed, t.id)
        )
        solo = QueryExecutor(solo_ds, q).run()
        served = results[i]
        assert served.blocks_read == solo.blocks_read
        assert served.converged == solo.converged
        for a, b in zip(served.aggregates, solo.aggregates):
            np.testing.assert_array_equal(
                np.asarray(a.estimate), np.asarray(b.estimate)
            )
            if a.ci_lo is not None:
                np.testing.assert_array_equal(
                    np.asarray(a.ci_lo), np.asarray(b.ci_lo)
                )
    solo_ds.close()
    ds.close()


def test_sketch_only_queries_bypass_admission_with_zero_io(stored_ds):
    path, data = stored_ds
    ds = _open(path)
    # saturate the service so progressive work is provably in the way
    with QueryService(ds, capacity=1, workers=1, seed=3) as svc:
        slow = _hog(svc)
        fast = [svc.submit(["mean", "count"]) for _ in range(10)]
        for t in fast:
            assert t.done and t.outcome == "sketch"
            assert t.result.executor_stats.blocks_fetched == 0
            assert t.result.from_sketches
        np.testing.assert_allclose(
            np.asarray(fast[0].result["mean"].estimate),
            data.astype(np.float64).mean(0),
            rtol=1e-5, atol=1e-5,
        )
        svc.cancel(slow)
    ds.close()


def test_deadline_returns_anytime_result_not_failure(stored_ds):
    path, data = stored_ds
    ds = _open(path)
    truth = data.astype(np.float64).mean(0)
    with QueryService(ds, capacity=8, workers=2, seed=5) as svc:
        # unreachable target -> can only finish via the deadline
        t = _hog(svc, deadline_ms=300, confidence=0.999)
        res = svc.result(t, timeout=30)
        assert t.outcome == "deadline"
        assert not res.converged
        assert res.blocks_read >= 1
        a = res["mean"]
        assert np.all(np.asarray(a.ci_lo) <= truth)
        assert np.all(truth <= np.asarray(a.ci_hi))
        # latency respected the budget (generous slack for slow CI hosts)
        assert t.latency_ms <= 300 + 250
    ds.close()


def test_deadline_fires_even_while_queued_for_admission(stored_ds):
    path, _ = stored_ds
    ds = _open(path)
    with QueryService(ds, capacity=1, workers=1, seed=5) as svc:
        hog = _hog(svc)
        queued = svc.submit(
            "median", use_sketches=False, deadline_ms=200, target_rel_err=0.01
        )
        res = svc.result(queued, timeout=30)
        assert queued.outcome == "deadline"
        assert res.blocks_read == 0  # never admitted: the empty anytime answer
        assert np.isnan(np.asarray(res["p50"].estimate)).all()
        assert res["p50"].ci_hi == np.inf
        svc.cancel(hog)
    ds.close()


def test_admission_rejects_when_saturated_and_queue_full(stored_ds):
    path, _ = stored_ds
    ds = _open(path)
    with QueryService(ds, capacity=1, max_queue=1, workers=1, seed=2) as svc:
        a = _hog(svc)
        b = _hog(svc)
        with pytest.raises(AdmissionRejected):
            svc.submit("median", use_sketches=False)
        rejected = svc.submit("median", use_sketches=False, on_reject="ticket")
        assert rejected.outcome == "rejected" and rejected.status == "rejected"
        with pytest.raises(AdmissionRejected):
            svc.result(rejected)
        m = svc.metrics()
        assert m.rejected == 2 and m.admission.rejected_total == 2
        svc.cancel(a)
        svc.cancel(b)
    ds.close()


def test_cancel_releases_admission_and_unblocks_queued_queries(stored_ds):
    path, _ = stored_ds
    ds = _open(path)
    with QueryService(ds, capacity=1, workers=1, seed=9) as svc:
        hog = _hog(svc)
        queued = svc.submit("mean", use_sketches=False, target_rel_err=0.02)
        assert svc.cancel(hog) is True
        assert svc.cancel(hog) is False  # idempotent
        assert hog.outcome == "cancelled"
        # the queued query must now be admitted and run to convergence
        res = svc.result(queued, timeout=60)
        assert queued.outcome in ("converged", "exhausted")
        assert res.blocks_read >= 2
        # the cancelled hog still reports an honest anytime estimate
        assert hog.result is not None
    ds.close()


def test_close_cancels_outstanding_queries(stored_ds):
    path, _ = stored_ds
    ds = _open(path)
    svc = QueryService(ds, capacity=2, workers=1, seed=4)
    tickets = [_hog(svc) for _ in range(6)]
    svc.close()
    for t in tickets:
        assert t.done
        assert t.outcome == "cancelled"
    with pytest.raises(RuntimeError):
        svc.submit("mean", use_sketches=False)
    ds.close()


def test_service_metrics_account_for_every_submission(stored_ds):
    path, _ = stored_ds
    ds = _open(path)
    with QueryService(ds, capacity=8, workers=2, seed=6) as svc:
        tickets = [svc.submit(["mean", "count"]) for _ in range(5)]
        tickets += [
            svc.submit("median", max_blocks=4, use_sketches=False)
            for _ in range(5)
        ]
        for t in tickets:
            svc.result(t, timeout=60)
        m = svc.metrics()
    assert m.submitted == 10
    assert m.completed == 10
    assert m.sketch_answers == 5
    assert m.qps > 0
    assert m.latency_p50_ms <= m.latency_p99_ms
    # 5 progressive queries x 4 blocks each; fetches <= 20, the shared cache
    # may turn overlapping picks into hits but at least one scan is cold
    assert 4 <= m.blocks_fetched <= 20
    assert m.blocks_per_query == pytest.approx(m.blocks_fetched / 10)
    ds.close()


def test_derived_seeds_are_schedule_invariant(stored_ds):
    """Submitting the same queries in a different interleaving must produce
    bit-identical estimates (seeds come from stable ids, never from
    scheduling order)."""
    path, _ = stored_ds

    def run(order):
        ds = _open(path)
        with QueryService(ds, capacity=4, workers=3, seed=42) as svc:
            tickets = {}
            for i in order:
                tickets[i] = svc.submit(
                    "p75", max_blocks=5, use_sketches=False,
                    # pin seeds from the logical index: submission order (and
                    # hence the auto-derived qid) differs between the two runs
                    seed=derive_seed(42, i),
                )
            out = {i: svc.result(t, timeout=60) for i, t in tickets.items()}
        ds.close()
        return out

    a = run(list(range(8)))
    b = run(list(reversed(range(8))))
    for i in range(8):
        np.testing.assert_array_equal(
            np.asarray(a[i]["p75"].estimate), np.asarray(b[i]["p75"].estimate)
        )
