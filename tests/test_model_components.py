"""Component-level oracles: chunked-flash vs naive attention, chunked SSD vs
step recurrence, MoE dispatch vs dense gather, RWKV scan invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    AttentionConfig,
    _reference_attention,
    attention_apply,
    attention_specs,
    flash_attention_jnp,
    init_cache,
)
from repro.models.common import init_params
from repro.models.mamba2 import Mamba2Config, ssd_chunked, ssd_reference
from repro.models.moe import MoEConfig, moe_apply, moe_ref, moe_specs
from repro.models.rwkv6 import wkv6_scan


# ---------------------------------------------------------------------------
# flash attention (jnp) vs naive reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,Hkv,G,S,D,kb", [(2, 2, 2, 32, 16, 8), (1, 1, 4, 33, 8, 16), (2, 4, 1, 64, 32, 64)])
def test_flash_matches_reference(causal, B, Hkv, G, S, D, kb):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, Hkv, G, S, D), jnp.float32)
    k = jax.random.normal(k2, (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(k3, (B, Hkv, S, D), jnp.float32)
    pos = jnp.arange(S)
    got = flash_attention_jnp(q, k, v, q_positions=pos, kv_positions=pos, causal=causal, k_block=kb)
    want = _reference_attention(q, k, v, q_positions=pos, kv_positions=pos, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_handles_nondivisible_kv():
    # Skv = 40 with k_block 16 -> padding path
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 2, 40, 8), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 40, 8), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 40, 8), jnp.float32)
    pos = jnp.arange(40)
    got = flash_attention_jnp(q, k, v, q_positions=pos, kv_positions=pos, causal=True, k_block=16)
    want = _reference_attention(q, k, v, q_positions=pos, kv_positions=pos, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_decode_matches_prefill_tail():
    """Prefill S tokens == prefill S-1 then decode 1, for the same params."""
    cfg = AttentionConfig(d_model=32, num_heads=4, num_kv_heads=2, head_dim=8, k_block=8)
    params = init_params(attention_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32), jnp.float32)

    full, _ = attention_apply(params, x, cfg, positions=jnp.arange(12))

    cache = init_cache(cfg, 2, 16, dtype=jnp.float32)
    _, cache = attention_apply(params, x[:, :11], cfg, cache=cache)
    last, _ = attention_apply(params, x[:, 11:], cfg, cache=cache)
    np.testing.assert_allclose(
        np.asarray(last[:, 0]), np.asarray(full[:, 11]), rtol=5e-2, atol=5e-2
    )


# ---------------------------------------------------------------------------
# Mamba2 SSD: chunked == recurrence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,L,H,P,N,chunk", [(2, 32, 2, 8, 4, 8), (1, 64, 4, 16, 16, 16), (2, 16, 1, 4, 8, 16)])
def test_ssd_chunked_matches_reference(B, L, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    xbar = jax.random.normal(ks[0], (B, L, H, P), jnp.float32)
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))  # <= 0
    Bm = jax.random.normal(ks[2], (B, L, N), jnp.float32)
    Cm = jax.random.normal(ks[3], (B, L, N), jnp.float32)
    y1, h1 = ssd_chunked(xbar, dA, Bm, Cm, chunk=chunk)
    y2, h2 = ssd_reference(xbar, dA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4, atol=1e-4)


def test_ssd_respects_initial_state():
    B, L, H, P, N = 1, 16, 2, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    xbar = jax.random.normal(ks[0], (B, L, H, P))
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    Bm = jax.random.normal(ks[2], (B, L, N))
    Cm = jax.random.normal(ks[3], (B, L, N))
    h0 = jax.random.normal(ks[4], (B, H, P, N))
    y1, hf1 = ssd_chunked(xbar, dA, Bm, Cm, chunk=8, h0=h0)
    y2, hf2 = ssd_reference(xbar, dA, Bm, Cm, h0=h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf1), np.asarray(hf2), rtol=1e-4, atol=1e-4)


def test_ssd_chunked_split_equals_whole():
    """Running two half-sequences with state carry == one full sequence."""
    B, L, H, P, N = 1, 32, 2, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    xbar = jax.random.normal(ks[0], (B, L, H, P))
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    Bm = jax.random.normal(ks[2], (B, L, N))
    Cm = jax.random.normal(ks[3], (B, L, N))
    y_full, h_full = ssd_chunked(xbar, dA, Bm, Cm, chunk=8)
    y_a, h_a = ssd_chunked(xbar[:, :16], dA[:, :16], Bm[:, :16], Cm[:, :16], chunk=8)
    y_b, h_b = ssd_chunked(xbar[:, 16:], dA[:, 16:], Bm[:, 16:], Cm[:, 16:], chunk=8, h0=h_a)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y_a, y_b], 1)), np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_b), np.asarray(h_full), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# MoE: capacity dispatch vs dense oracle
# ---------------------------------------------------------------------------

def test_moe_matches_dense_oracle_when_capacity_ample():
    cfg = MoEConfig(d_model=16, d_ff=32, num_experts=4, top_k=2, capacity_factor=4.0)
    params = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16), jnp.float32)
    got, aux = moe_apply(params, x, cfg, moe_groups=1, compute_dtype=jnp.float32)
    want = moe_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
    assert np.isfinite(float(aux))


def test_moe_group_invariance():
    """Dispatch is per-group; with ample capacity the result is group-count
    independent (groups only change which tokens share capacity)."""
    cfg = MoEConfig(d_model=8, d_ff=16, num_experts=4, top_k=2, capacity_factor=8.0)
    params = init_params(moe_specs(cfg), jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 8, 8), jnp.float32)
    y1, _ = moe_apply(params, x, cfg, moe_groups=1, compute_dtype=jnp.float32)
    y2, _ = moe_apply(params, x, cfg, moe_groups=4, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 some assignments must be dropped: output
    differs from the dense oracle but stays finite."""
    cfg = MoEConfig(d_model=8, d_ff=16, num_experts=2, top_k=2, capacity_factor=0.25)
    params = init_params(moe_specs(cfg), jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 16, 8), jnp.float32)
    got, _ = moe_apply(params, x, cfg, moe_groups=1, compute_dtype=jnp.float32)
    assert np.all(np.isfinite(np.asarray(got)))
    want = moe_ref(params, x, cfg)
    assert not np.allclose(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# RWKV6 wkv scan
# ---------------------------------------------------------------------------

def test_wkv6_scan_state_carry():
    B, L, H, C = 1, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    r = jax.random.normal(ks[0], (B, L, H, C))
    k = jax.random.normal(ks[1], (B, L, H, C))
    v = jax.random.normal(ks[2], (B, L, H, C))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, L, H, C)))  # (0,1)
    u = jnp.ones((H, C)) * 0.5
    y_full, h_full = wkv6_scan(r, k, v, w, u)
    y_a, h_a = wkv6_scan(r[:, :8], k[:, :8], v[:, :8], w[:, :8], u)
    y_b, h_b = wkv6_scan(r[:, 8:], k[:, 8:], v[:, 8:], w[:, 8:], u, h0=h_a)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y_a, y_b], 1)), np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_b), np.asarray(h_full), rtol=1e-5, atol=1e-5)


def test_wkv6_u_bonus_first_token():
    """First output token = r . (u * k v^T): pure bonus term."""
    B, L, H, C = 1, 1, 1, 4
    r = jnp.ones((B, L, H, C))
    k = jnp.full((B, L, H, C), 2.0)
    v = jnp.full((B, L, H, C), 3.0)
    w = jnp.full((B, L, H, C), 0.5)
    u = jnp.full((H, C), 0.25)
    y, h = wkv6_scan(r, k, v, w, u)
    # y = sum_c r_c * u_c * k_c * v_v ... outer product: y_v = sum_c r_c u_c k_c v_v
    want = (1.0 * 0.25 * 2.0) * 4 * 3.0
    np.testing.assert_allclose(np.asarray(y)[0, 0, 0], np.full(C, want), rtol=1e-6)
