"""Distribution substrate tests: sharding rules, ZeRO specs, gradient
compression, elastic re-sharding, straggler scheduling.  Multi-device cases
run through the harness with a forced host device count."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from distributed_harness import assert_ok, run_forced_devices
from repro.distributed.compression import (
    compression_ratio,
    error_feedback_compress,
    init_residual,
    quantize_roundtrip,
)
from repro.distributed.straggler import simulate
from repro.models.common import ParamSpec


# ---------------------------------------------------------------------------
# sharding rules (pure logic; no devices needed)
# ---------------------------------------------------------------------------

def _rules(num_kv=8, tp=1):
    from repro.distributed.sharding import default_rules

    devs = np.array(jax.devices() * max(tp, 1)).reshape(1, tp) if tp > 1 else np.array(
        jax.devices()[:1]
    ).reshape(1, 1)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    return default_rules(mesh, num_kv_heads=num_kv)


def test_rules_kv_sharding_threshold():
    # kv heads shard over 'model' only when divisible by the TP degree
    assert _rules(8, tp=4).rules["kv_heads"] == "model"
    assert _rules(8, tp=4).rules["heads_inner"] is None
    assert _rules(1, tp=4).rules["kv_heads"] is None
    assert _rules(1, tp=4).rules["heads_inner"] == "model"
    assert _rules(6, tp=4).rules["kv_heads"] is None  # 6 % 4 != 0


def test_spec_mapping():
    r = _rules()
    assert r.spec_for(("embed", "ff")) == P(None, "model")
    assert r.spec_for(("layers", "embed", "heads")) == P(None, None, "model")
    assert r.spec_for(("vocab", "embed")) == P("model", None)


def test_zero_shard_picks_largest_replicated_dim():
    from repro.distributed.sharding import default_rules, zero_shard_spec

    mesh = jax.sharding.Mesh(np.array(jax.devices() * 4).reshape(4, 1), ("data", "model"))
    r = default_rules(mesh)
    # [layers=8, d=64, ff->model]: ZeRO should shard d (=64, divisible by 4)
    spec = ParamSpec((8, 64, 128), ("layers", "embed", "ff"))
    assert zero_shard_spec(spec, r) == P("data", None, "model") or zero_shard_spec(spec, r) == P(
        None, "data", "model"
    )
    # all dims too small / already sharded -> unchanged
    spec2 = ParamSpec((3,), ("embed",))
    assert zero_shard_spec(spec2, r) == P(None)


def test_constrain_noop_without_context():
    from repro.distributed.sharding import constrain

    x = jnp.ones((2, 3))
    y = constrain(x, ("batch", None))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 5.0
    y = quantize_roundtrip(x)
    err = jnp.abs(x - y).max()
    assert float(err) <= float(jnp.abs(x).max()) / 127.0 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """With error feedback, the *accumulated* compressed signal tracks the
    accumulated true signal (residual stays bounded)."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (512,))}
    r = init_residual(g)
    total_true = jnp.zeros((512,))
    total_sent = jnp.zeros((512,))
    for i in range(20):
        gi = {"w": jax.random.normal(jax.random.PRNGKey(i + 2), (512,))}
        comp, r = error_feedback_compress(gi, r)
        total_true += gi["w"]
        total_sent += comp["w"]
    drift = jnp.abs(total_true - total_sent).max()
    assert float(drift) <= float(jnp.abs(total_true).max()) / 100.0 + 0.1


def test_compression_ratio():
    assert compression_ratio(jnp.float32) < 0.26
    assert compression_ratio(jnp.bfloat16) < 0.52


# ---------------------------------------------------------------------------
# straggler scheduling
# ---------------------------------------------------------------------------

def test_straggler_work_stealing_beats_static():
    speeds = [1.0, 1.0, 1.0, 0.1]  # one 10x straggler
    static = simulate(64, speeds, steal=False)
    dynamic = simulate(64, speeds, steal=True)
    assert dynamic["makespan"] < static["makespan"] * 0.5
    done = sorted(b for bs in dynamic["per_host_blocks"].values() for b in bs)
    assert done == list(range(64))  # every block exactly once


def test_straggler_balanced_hosts_no_pathology():
    speeds = [1.0] * 4
    dyn = simulate(32, speeds, steal=True)
    static = simulate(32, speeds, steal=False)
    assert dyn["makespan"] <= static["makespan"] * 1.26


def test_straggler_host_failure_completes_every_block_once():
    # kill the fastest host early: its leases (incl. the in-flight block)
    # requeue and the survivors drain them -- nothing dropped, no duplicates
    out = simulate(40, [4.0, 1.0, 1.0], fail_at={0: 2.0})
    assert out["dead_hosts"] == [0]
    assert out["completed"] == 40
    done = [b for bs in out["per_host_blocks"].values() for b in bs]
    assert sorted(done) == list(range(40))
    healthy = simulate(40, [4.0, 1.0, 1.0])
    assert out["makespan"] >= healthy["makespan"]  # losing a host has a cost


def test_straggler_all_hosts_dead_reports_shortfall():
    out = simulate(40, [1.0, 1.0], fail_at={0: 0.5, 1: 0.5})
    assert out["dead_hosts"] == [0, 1]
    assert out["completed"] < 40  # honest: blocks were lost, not hidden


# ---------------------------------------------------------------------------
# multi-device: compressed psum + elastic restore (subprocess, 8 devices)
# ---------------------------------------------------------------------------

MULTI_DEV_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# --- compressed psum over a 'pod' axis -----------------------------------
from repro.distributed.compression import compressed_psum
try:
    shard_map = jax.shard_map
except AttributeError:  # jax < 0.6: shard_map still lives in experimental
    from jax.experimental.shard_map import shard_map

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
x = jax.random.normal(jax.random.PRNGKey(0), (2, 256))

def f(x):
    return compressed_psum(x, "pod")

y = jax.jit(shard_map(f, mesh=mesh, in_specs=P("pod", None), out_specs=P("pod", None)))(x)
want = jnp.broadcast_to(x.mean(axis=0, keepdims=True), x.shape)
err = float(jnp.abs(np.asarray(y) - want).max())
rel = err / float(jnp.abs(want).max())
assert rel < 0.02, f"compressed psum error {rel}"

# --- elastic: checkpoint on 8-dev mesh, restore on 2-dev mesh -------------
import tempfile
from repro.checkpoint import store as ckpt
from repro.configs import smoke_config
from repro.distributed.sharding import default_rules
from repro.distributed.elastic import restore_for_mesh, state_shardings
from repro.train.loop import init_state

cfg = smoke_config("llama3.2-1b")
state = init_state(cfg, seed=0)
d = tempfile.mkdtemp()
ckpt.save(d, 3, state, extra={})

mesh_big = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
rules_big = default_rules(mesh_big, num_kv_heads=cfg.num_kv_heads)
like = jax.eval_shape(lambda: init_state(cfg, 0))
restored, _ = restore_for_mesh(d, 3, cfg, rules_big, like={"params": like["params"], "opt": like["opt"]})
# leaves actually sharded over the mesh
leaf = restored["opt"]["master"]["layers"]["mlp"]["gate"]["w"]
assert len(leaf.sharding.device_set) > 1, leaf.sharding
np.testing.assert_allclose(
    np.asarray(leaf), np.asarray(state["opt"]["master"]["layers"]["mlp"]["gate"]["w"]), rtol=1e-6
)

# smaller mesh restore
devs = np.array(jax.devices()[:2]).reshape(1, 2)
mesh_small = Mesh(devs, ("data", "model"))
rules_small = default_rules(mesh_small, num_kv_heads=cfg.num_kv_heads)
restored2, _ = restore_for_mesh(d, 3, cfg, rules_small, like={"params": like["params"], "opt": like["opt"]})
leaf2 = restored2["params"]["layers"]["mlp"]["gate"]["w"]
np.testing.assert_allclose(np.asarray(leaf2, np.float32), np.asarray(state["params"]["layers"]["mlp"]["gate"]["w"], np.float32))
print("MULTIDEV_OK")
"""


@pytest.mark.slow
def test_multi_device_substrate():
    assert_ok(
        run_forced_devices(MULTI_DEV_SCRIPT, devices=8, timeout=900),
        marker="MULTIDEV_OK",
    )
