"""Beyond-paper perf paths must be numerically equivalent to the
paper-faithful baselines (EXPERIMENTS.md §Perf): flat-head flash (+ custom
VJP), seq-chunked CE, MoE sort/slot dispatch."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import api
from repro.models.attention import (
    _reference_attention,
    flash_attention_flat,
    flash_flat_cvjp,
)
from repro.models.common import (
    init_params,
    seq_chunked_cross_entropy,
    softmax_cross_entropy,
)
from repro.models.moe import MoEConfig, moe_apply, moe_specs, _sorted_positions


# ---------------------------------------------------------------------------
# flat flash + custom VJP
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
def test_flash_flat_matches_reference(causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, H, S, D = 2, 4, 64, 16
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    pos = jnp.arange(S)
    got = flash_attention_flat(q, k, v, q_positions=pos, kv_positions=pos, causal=causal, k_block=16)
    want = _reference_attention(
        q[:, :, None], k, v, q_positions=pos, kv_positions=pos, causal=causal
    )[:, :, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_cvjp_grads_match_autodiff(causal):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, H, S, D = 1, 2, 32, 8
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    pos = jnp.arange(S)

    def f_c(q, k, v):
        return (flash_flat_cvjp(q, k, v, causal, 8) ** 2).sum()

    def f_r(q, k, v):
        out = _reference_attention(q[:, :, None], k, v, q_positions=pos, kv_positions=pos, causal=causal)
        return (out[:, :, 0] ** 2).sum()

    gc = jax.grad(f_c, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gc, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# seq-chunked CE
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunks", [2, 4])
def test_seq_chunked_ce_matches_plain(chunks):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, d, V = 2, 16, 8, 32
    h = jax.random.normal(ks[0], (B, S, d))
    table = jax.random.normal(ks[1], (V, d)) * 0.1
    labels = jax.random.randint(ks[2], (B, S), 0, V)
    plain = softmax_cross_entropy(jnp.einsum("bsd,vd->bsv", h, table), labels)
    chunked = seq_chunked_cross_entropy(h, table, labels, chunks=chunks, compute_dtype=jnp.float32)
    np.testing.assert_allclose(float(chunked), float(plain), rtol=1e-5)

    g1 = jax.grad(lambda t: softmax_cross_entropy(jnp.einsum("bsd,vd->bsv", h, t), labels))(table)
    g2 = jax.grad(
        lambda t: seq_chunked_cross_entropy(h, t, labels, chunks=chunks, compute_dtype=jnp.float32)
    )(table)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-6)


def test_seq_chunked_ce_nondivisible_falls_back():
    h = jnp.zeros((1, 7, 4))
    table = jnp.zeros((8, 4))
    labels = jnp.zeros((1, 7), jnp.int32)
    out = seq_chunked_cross_entropy(h, table, labels, chunks=3, compute_dtype=jnp.float32)
    assert np.isfinite(float(out))


# ---------------------------------------------------------------------------
# full-model equivalence: optimized flags vs baseline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen3-moe-30b-a3b", "zamba2-7b"])
def test_optimized_flags_preserve_loss_and_grads(arch):
    cfg0 = smoke_config(arch)
    cfg1 = dataclasses.replace(
        cfg0, flat_attention=True, loss_seq_chunks=4, moe_sort_dispatch=True, k_block=8
    )
    params = init_params(api.model_specs(cfg0), jax.random.PRNGKey(0))
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg0.vocab_size, (2, 17), np.int32)
    )
    l0, _ = jax.jit(api.make_loss_fn(cfg0))(params, {"tokens": tokens})
    l1, _ = jax.jit(api.make_loss_fn(cfg1))(params, {"tokens": tokens})
    assert abs(float(l0) - float(l1)) < 2e-3
    g0 = jax.grad(lambda p: api.make_loss_fn(cfg0)(p, {"tokens": tokens})[0])(params)
    g1 = jax.grad(lambda p: api.make_loss_fn(cfg1)(p, {"tokens": tokens})[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=0.1, atol=5e-3
        )


# ---------------------------------------------------------------------------
# MoE dispatch variants
# ---------------------------------------------------------------------------

def test_sorted_positions_match_onehot():
    e = jax.random.randint(jax.random.PRNGKey(3), (3, 64), 0, 8)
    pos_sort = _sorted_positions(e, 8)
    onehot = jax.nn.one_hot(e, 8, dtype=jnp.int32)
    pos_ref = jnp.take_along_axis(jnp.cumsum(onehot, axis=1) - 1, e[..., None], axis=-1)[..., 0]
    np.testing.assert_array_equal(np.asarray(pos_sort), np.asarray(pos_ref))


@pytest.mark.parametrize("cf", [0.5, 1.0, 4.0])
def test_slot_gather_dispatch_matches_baseline(cf):
    cfg_a = MoEConfig(d_model=16, d_ff=32, num_experts=4, top_k=2, capacity_factor=cf)
    cfg_b = dataclasses.replace(cfg_a, sort_dispatch=True)
    params = init_params(moe_specs(cfg_a), jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, 16), jnp.float32)
    ya, _ = moe_apply(params, x, cfg_a, compute_dtype=jnp.float32)
    yb, _ = moe_apply(params, x, cfg_b, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), rtol=1e-5, atol=1e-6)
    ga = jax.grad(lambda p: moe_apply(p, x, cfg_a, compute_dtype=jnp.float32)[0].sum())(params)
    gb = jax.grad(lambda p: moe_apply(p, x, cfg_b, compute_dtype=jnp.float32)[0].sum())(params)
    for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
