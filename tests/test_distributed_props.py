"""Property tests for distributed RSP queries (guarded on hypothesis).

Two invariants, explored over random corpora, ownership maps, and
straggler kill schedules:

* a distributed progressive query is bit-identical to the single-host
  answer with the same seed (HT/Hájek weights, CIs, stopping point), no
  matter how many hosts run it or which one dies mid-query;
* the lease scheduler's event simulation never double-processes or drops
  a block as long as one host survives.
"""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.distributed import LocalTransport  # noqa: E402
from repro.distributed.straggler import simulate  # noqa: E402

from test_distributed_query import _distributed_sigs, _make_ds, _sig  # noqa: E402

_DS_CACHE: dict = {}


def _cached_ds(data_seed):
    if data_seed not in _DS_CACHE:
        _DS_CACHE[data_seed] = _make_ds(n=2048, blocks=8, seed=3, data_seed=data_seed)
    return _DS_CACHE[data_seed]


@settings(max_examples=8, deadline=None, suppress_health_check=list(HealthCheck))
@given(
    data_seed=st.integers(0, 3),
    query_seed=st.integers(0, 1000),
    num_hosts=st.integers(1, 4),
    policy=st.sampled_from(["uniform", "weighted"]),
    kill=st.one_of(st.none(), st.tuples(st.integers(0, 3), st.integers(0, 3))),
)
def test_property_distributed_equals_single_host(
    data_seed, query_seed, num_hosts, policy, kill
):
    ds = _cached_ds(data_seed)
    q = dict(aggregates=["mean"], target_rel_err=0.05, seed=query_seed,
             policy=policy, where="c2 > 0.5", max_blocks=8)
    ref = _sig(ds.query(**q))
    transports = LocalTransport.group(num_hosts)
    killed = None
    if kill is not None and num_hosts > 1:
        killed = kill[0] % num_hosts
        transports[killed].kill_after_puts(kill[1])
    results = _distributed_sigs(ds, transports, q)
    for h, r in enumerate(results):
        if h == killed:
            continue  # may be None (died) -- only survivors have a contract
        assert r is not None and r[0] == ref


@settings(max_examples=50, deadline=None)
@given(
    num_blocks=st.integers(1, 48),
    speeds=st.lists(st.floats(0.05, 8.0), min_size=1, max_size=6),
    lease_window=st.integers(1, 4),
    fails=st.dictionaries(st.integers(0, 5), st.floats(0.0, 20.0), max_size=5),
)
def test_property_simulate_never_drops_or_duplicates(
    num_blocks, speeds, lease_window, fails
):
    fails = {h: t for h, t in fails.items() if h < len(speeds)}
    if len(fails) == len(speeds):
        fails.popitem()  # keep one survivor
    out = simulate(num_blocks, speeds, lease_window=lease_window, fail_at=fails)
    done = [b for bs in out["per_host_blocks"].values() for b in bs]
    assert len(done) == len(set(done)), "a block was processed twice"
    assert sorted(done) == list(range(num_blocks)), "a block was dropped"
    assert out["completed"] == num_blocks
    for h in out["dead_hosts"]:
        assert h in fails
