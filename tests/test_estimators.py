"""Block-level estimation (Sec. 8): exactness of the streaming combine and
convergence of block-level estimates to full-data statistics (Figs. 3/4)."""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tests skip below; the rest of the module runs
    HAVE_HYPOTHESIS = False

from repro.core import (
    BlockLevelEstimator,
    RSPSpec,
    batched_block_moments,
    block_histogram,
    block_moments,
    combine_moments,
    quantile_from_histogram,
    two_stage_partition_np,
)


def test_combine_is_exact():
    rng = np.random.default_rng(0)
    a = rng.normal(2.0, 3.0, size=(500, 4)).astype(np.float32)
    b = rng.normal(-1.0, 0.5, size=(300, 4)).astype(np.float32)
    combined = combine_moments(block_moments(jnp.asarray(a)), block_moments(jnp.asarray(b)))
    full = np.concatenate([a, b])
    np.testing.assert_allclose(combined.mean, full.mean(0), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(combined.std, full.std(0, ddof=1), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(combined.min, full.min(0))
    np.testing.assert_allclose(combined.max, full.max(0))


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        n1=st.integers(2, 400),
        n2=st.integers(2, 400),
        scale=st.floats(0.1, 100.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_combine_property(n1, n2, scale, seed):
        rng = np.random.default_rng(seed)
        a = (rng.normal(size=(n1, 3)) * scale).astype(np.float32)
        b = (rng.normal(size=(n2, 3)) * scale).astype(np.float32)
        combined = combine_moments(block_moments(jnp.asarray(a)), block_moments(jnp.asarray(b)))
        full = np.concatenate([a, b])
        np.testing.assert_allclose(combined.mean, full.mean(0), rtol=1e-3, atol=1e-3 * scale)
        np.testing.assert_allclose(combined.std, full.std(0, ddof=1), rtol=1e-2, atol=1e-3 * scale)

else:

    def test_combine_property():
        pytest.importorskip("hypothesis")


def test_block_level_estimation_converges():
    """Fig 3/4: estimates from few blocks are close; adding blocks converges
    towards the full-data value."""
    rng = np.random.default_rng(3)
    data = rng.normal(1.5, 2.0, size=(20000, 4)).astype(np.float32)
    spec = RSPSpec(num_records=20000, num_blocks=50, num_original_blocks=50, seed=7)
    blocks = two_stage_partition_np(data, spec)

    est = BlockLevelEstimator()
    errors = []
    for k in range(10):
        est.update(jnp.asarray(blocks[k]))
        errors.append(float(np.max(np.abs(est.stats.mean - data.mean(0)))))
    # error with 1 block already small (block n=400, se ~ 2/sqrt(400) = 0.1)
    assert errors[0] < 0.5
    # 10-block estimate much tighter
    assert errors[-1] < 0.08
    np.testing.assert_allclose(est.stats.std, data.std(0, ddof=1), rtol=0.05)


def test_estimator_exact_after_all_blocks():
    rng = np.random.default_rng(5)
    data = rng.normal(size=(4096, 3)).astype(np.float32)
    spec = RSPSpec(num_records=4096, num_blocks=8, num_original_blocks=8, seed=1)
    blocks = two_stage_partition_np(data, spec)
    est = BlockLevelEstimator()
    for k in range(8):
        est.update(jnp.asarray(blocks[k]))
    # having consumed the whole partition, estimate == full-data statistic
    np.testing.assert_allclose(est.stats.mean, data.mean(0), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(est.stats.std, data.std(0, ddof=1), rtol=1e-4, atol=1e-5)
    assert est.stats.count == 4096


def test_convergence_plateau_detection():
    rng = np.random.default_rng(6)
    data = rng.normal(10.0, 1.0, size=(19200, 2)).astype(np.float32)
    spec = RSPSpec(num_records=19200, num_blocks=40, num_original_blocks=40, seed=2)
    blocks = two_stage_partition_np(data, spec)
    est = BlockLevelEstimator()
    converged_at = None
    for k in range(40):
        est.update(jnp.asarray(blocks[k]))
        if est.converged(rel_tol=1e-3):
            converged_at = k
            break
    assert converged_at is not None and converged_at < 39  # stops early


def test_batched_block_moments_matches_loop():
    rng = np.random.default_rng(8)
    blocks = rng.normal(size=(6, 100, 5)).astype(np.float32)
    means, stds = batched_block_moments(jnp.asarray(blocks))
    np.testing.assert_allclose(np.asarray(means), blocks.mean(1), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(stds), blocks.std(1, ddof=1), rtol=1e-4, atol=1e-6)


def test_histogram_quantiles():
    rng = np.random.default_rng(9)
    data = rng.normal(size=(50000, 2)).astype(np.float32)
    h = block_histogram(data[:25000], bins=256, lo=-6, hi=6)
    h += block_histogram(data[25000:], bins=256, lo=-6, hi=6)
    q = quantile_from_histogram(h, [0.25, 0.5, 0.75], lo=-6, hi=6)
    truth = np.quantile(data, [0.25, 0.5, 0.75], axis=0).T
    np.testing.assert_allclose(q, truth, atol=0.08)


def test_block_histogram_clips_out_of_range_mass():
    """Mass beyond [lo, hi] lands in the edge bins instead of vanishing --
    the histogram always sums to the record count per feature."""
    rng = np.random.default_rng(10)
    x = rng.normal(0.0, 4.0, size=(5000, 3))
    h = block_histogram(x, bins=32, lo=-1.0, hi=1.0)
    np.testing.assert_array_equal(h.sum(axis=1), [5000, 5000, 5000])
    # clipped tails are in the edge bins
    assert h[0, 0] >= (x[:, 0] < -1.0).sum()
    assert h[0, -1] >= (x[:, 0] > 1.0).sum()


def test_block_histogram_matches_numpy_within_range():
    rng = np.random.default_rng(11)
    x = rng.uniform(-2.9, 2.9, size=(4000, 2))
    h = block_histogram(x, bins=64, lo=-3.0, hi=3.0)
    for j in range(2):
        want, _ = np.histogram(x[:, j], bins=np.linspace(-3, 3, 65))
        np.testing.assert_array_equal(h[j], want)


def test_quantile_interpolates_within_bin():
    """On uniform-in-bin data the interpolated quantile is near-exact; the
    old upper-edge snap was off by up to a full bin width."""
    rng = np.random.default_rng(12)
    u = rng.uniform(0.0, 1.0, size=(200_000, 1))
    h = block_histogram(u, bins=50, lo=0.0, hi=1.0)
    q = quantile_from_histogram(h, [0.25, 0.5, 0.9], lo=0.0, hi=1.0)
    np.testing.assert_allclose(q[0], [0.25, 0.5, 0.9], atol=2.5e-3)
    # strictly better than half the old snap bias (bin width = 0.02)
    assert np.abs(q[0] - [0.25, 0.5, 0.9]).max() < 0.01


def test_quantile_from_histogram_per_feature_grids():
    rng = np.random.default_rng(13)
    x = np.stack([rng.normal(0, 1, 50_000), rng.normal(10, 5, 50_000)], axis=1)
    lo, hi = x.min(0), x.max(0)
    h = block_histogram(x, bins=256, lo=lo, hi=hi)
    q = quantile_from_histogram(h, [0.5], lo=lo, hi=hi)[:, 0]
    np.testing.assert_allclose(q, np.median(x, axis=0), atol=0.12)
