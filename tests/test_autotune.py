"""Shared tile autotuner: off-mode determinism, measured selection,
persistence round-trips, and interpret-mode exclusion."""

import json
import os

import numpy as np
import pytest

from repro.kernels import autotune
from repro.kernels.autotune import Autotuner, Candidate


def _times(table):
    """A measure() stub returning fixed seconds per candidate label, and a
    call log so tests can assert what was (not) measured."""
    calls = []

    def measure(c):
        calls.append(c.label)
        return table[c.label]

    return measure, calls


@pytest.fixture
def tuner(tmp_path):
    return Autotuner(path=str(tmp_path / "autotune.json"))


CANDS = [Candidate("np", 8192), Candidate("np", 32768), Candidate("ref")]
DEFAULT = Candidate("np", 16384)


def test_off_mode_returns_default_without_measuring(tuner, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")
    assert not autotune.enabled()
    measure, calls = _times({})
    got = tuner.choose("k", "r1024xf8:float32", CANDS, measure, default=DEFAULT)
    assert got == DEFAULT
    assert calls == [] and tuner.measurements == 0
    assert not os.path.exists(tuner._file())  # touches no files


@pytest.mark.parametrize("value", ["0", "false", "no", "OFF"])
def test_off_spellings(value, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", value)
    assert not autotune.enabled()


def test_measured_winner_and_cache_hit(tuner, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "on")
    measure, calls = _times({"np:8192": 3e-3, "np:32768": 1e-3, "ref": 2e-3})
    got = tuner.choose("k", "key", CANDS, measure, default=DEFAULT, repeats=2)
    assert got == Candidate("np", 32768)
    assert tuner.measurements == 1
    assert calls.count("np:8192") == 2  # best-of-repeats per candidate

    # second call: cached winner, measure never invoked again
    calls.clear()
    again = tuner.choose("k", "key", CANDS, measure, default=DEFAULT)
    assert again == Candidate("np", 32768)
    assert calls == [] and tuner.measurements == 1


def test_persistence_round_trip(tuner, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "on")
    measure, _ = _times({"np:8192": 2e-3, "np:32768": 1e-3, "ref": 3e-3})
    tuner.choose("k", "key", CANDS, measure, default=DEFAULT)

    with open(tuner._file()) as f:
        disk = json.load(f)
    (rec,) = disk.values()
    assert rec["impl"] == "np" and rec["tile_rows"] == 32768
    assert not rec["fallback"]
    assert rec["measured_us"]["np:32768"] == pytest.approx(1e3)

    # a fresh process (new Autotuner on the same path) reuses the winner
    fresh = Autotuner(path=tuner._file())
    measure2, calls2 = _times({})
    got = fresh.choose("k", "key", CANDS, measure2, default=DEFAULT)
    assert got == Candidate("np", 32768)
    assert calls2 == [] and fresh.measurements == 0
    assert fresh.lookup("k", "key") == Candidate("np", 32768)
    assert fresh.lookup("k", "other") is None


def test_interpret_mode_candidates_never_win(tuner, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "on")
    cands = [Candidate("pallas", t, interpreted=True) for t in (128, 256)]
    cands.append(Candidate("np", 8192))
    # the interpreter "wins" the raw timing -- it must still be excluded
    measure, calls = _times({"pallas:128": 1e-6, "pallas:256": 1e-6, "np:8192": 1e-3})
    got = tuner.choose("k", "key", cands, measure, default=DEFAULT)
    assert got == Candidate("np", 8192)
    assert all(not c.startswith("pallas") for c in calls)
    with open(tuner._file()) as f:
        (rec,) = json.load(f).values()
    assert "pallas:128 (interpret)" in rec["excluded"]


def test_all_excluded_falls_back_to_default(tuner, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "on")
    cands = [Candidate("pallas", 128, interpreted=True)]
    measure, calls = _times({})
    got = tuner.choose("k", "key", cands, measure, default=DEFAULT)
    assert got == DEFAULT and calls == []
    with open(tuner._file()) as f:
        (rec,) = json.load(f).values()
    assert rec["fallback"] and rec["us"] is None


def test_failing_candidate_is_disqualified(tuner, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "on")

    def measure(c):
        if c.impl == "ref":
            raise RuntimeError("boom")
        return 1e-3

    got = tuner.choose("k", "key", CANDS, measure, default=DEFAULT)
    assert got.impl == "np"
    with open(tuner._file()) as f:
        (rec,) = json.load(f).values()
    assert "ref (error)" in rec["excluded"]


def test_clear_forgets_disk_and_memory(tuner, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "on")
    measure, _ = _times({"np:8192": 1e-3, "np:32768": 2e-3, "ref": 3e-3})
    tuner.choose("k", "key", CANDS, measure, default=DEFAULT)
    assert os.path.exists(tuner._file())
    tuner.clear()
    assert not os.path.exists(tuner._file())
    assert tuner.lookup("k", "key") is None


def test_cache_path_env_override(tmp_path, monkeypatch):
    target = str(tmp_path / "elsewhere.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", target)
    assert autotune.cache_path() == target
    monkeypatch.delenv("REPRO_AUTOTUNE_CACHE")
    assert autotune.cache_path().endswith(os.path.join("results", "bench", "autotune.json"))


def test_shape_key_buckets_rows():
    # rows bucket to the next power of two; features/dtype exact
    assert autotune.shape_key(600, 8) == autotune.shape_key(1024, 8) == "r1024xf8:float32"
    assert autotune.shape_key(1025, 8) == "r2048xf8:float32"
    assert autotune.shape_key(1024, 9) != autotune.shape_key(1024, 8)
    assert autotune.shape_key(1024, 8, "float64") != autotune.shape_key(1024, 8)


def test_candidate_labels():
    assert Candidate("ref").label == "ref"
    assert Candidate("np", 8192).label == "np:8192"


def test_auto_paths_deterministic_with_tuning_off(monkeypatch):
    """conftest pins REPRO_AUTOTUNE=off: impl="auto" entry points must not
    run measurements (tier-1 never depends on machine-local timings)."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")
    from repro.kernels.block_sketch import block_sketch
    from repro.kernels.plan import QueryPlan, plan_sketch
    from repro.kernels.rsp_shuffle import ops as rs_ops

    before = autotune.get_tuner().measurements
    x = np.random.default_rng(0).normal(size=(512, 4)).astype(np.float32)

    a = block_sketch(x, bins=8, lo=-4.0, hi=4.0, impl="auto")
    b = block_sketch(x, bins=8, lo=-4.0, hi=4.0, impl="ref")
    np.testing.assert_allclose(a.mean, b.mean, rtol=1e-5, atol=1e-6)

    plan = QueryPlan(predicates="c0 > 0.0")
    r = plan_sketch(x, plan, impl="auto")
    np.testing.assert_allclose(
        r.sketches[0].mean, plan_sketch(x, plan, impl="ref").sketches[0].mean,
        rtol=1e-5, atol=1e-5,
    )

    import jax

    key = jax.random.PRNGKey(0)
    s1 = np.asarray(rs_ops.rsp_randomize_block(x, key))
    s2 = np.asarray(rs_ops.rsp_randomize_block(x, key))
    np.testing.assert_array_equal(s1, s2)  # tile default is pinned

    assert autotune.get_tuner().measurements == before
