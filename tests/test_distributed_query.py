"""Distributed RSP query tests over the in-process ``LocalTransport`` mesh.

The contract under test: a distributed progressive query is *bit-identical*
to the single-host answer with the same seed.  Every host derives the same
block-selection sequence, per-block payloads are pure functions of (block
bytes, query shape), and all hosts fold decoded payloads in canonical
position order through the same streaming estimator -- so estimates, CI
endpoints, blocks_read, and convergence all match exactly, regardless of
which host computed which block, or whether a host died mid-query
(Theorem 1: re-assigning exchangeable blocks is statistically free).
"""

import json
import types

import numpy as np
import pytest

from repro.distributed import (
    BlockOwnership,
    LocalTransport,
    load_ownership,
    run_local_hosts,
    save_ownership,
)
from repro.distributed.elastic import open_or_deal, rebalance_join, redeal_departed
from repro.rsp.dataset import RSPDataset
from repro.rsp.engine import ScopedFetcher, as_fetcher


def _make_ds(n=4096, blocks=16, seed=3, data_seed=7):
    rng = np.random.default_rng(data_seed)
    data = rng.normal(size=(n, 4)).astype(np.float32)
    data[:, 2] = rng.gamma(2.0, 1.0, size=n).astype(np.float32)
    return RSPDataset.partition(data, blocks, seed=seed)


def _sig(r):
    """Canonical bit-exact signature of a QueryResult."""
    return json.dumps(
        {
            "est": {a.name: np.asarray(a.estimate).ravel().tolist() for a in r.aggregates},
            "lo": {
                a.name: None if a.ci_lo is None else np.asarray(a.ci_lo).ravel().tolist()
                for a in r.aggregates
            },
            "hi": {
                a.name: None if a.ci_hi is None else np.asarray(a.ci_hi).ravel().tolist()
                for a in r.aggregates
            },
            "blocks_read": r.blocks_read,
            "converged": r.converged,
            "selectivity": r.selectivity,
        },
        sort_keys=True,
    )


QUERY = dict(
    aggregates=["mean", "p95"],
    target_rel_err=0.04,
    seed=11,
    policy="weighted",
    where="c2 > 0.5",
    max_blocks=16,
)


def _distributed_sigs(ds, transports, query_kwargs, **dds_kwargs):
    def run(t):
        dds = ds.distribute(t, straggler_grace=2.0, poll_interval=0.01, **dds_kwargs)
        res = dds.query(**query_kwargs)
        return _sig(res), dds.ownership

    return run_local_hosts(transports, run)


# ---------------------------------------------------------------------------
# bit-identity
# ---------------------------------------------------------------------------

def test_distributed_matches_single_host_bitwise():
    ds = _make_ds()
    ref = _sig(ds.query(**QUERY))
    results = _distributed_sigs(ds, LocalTransport.group(3), QUERY)
    assert len(results) == 3
    for sig, _own in results:
        assert sig == ref


def test_early_convergence_stops_at_same_block_everywhere():
    ds = _make_ds(n=8192, blocks=32)
    q = dict(QUERY, aggregates=["mean"], target_rel_err=0.2, max_blocks=32,
             columns=[2])
    ref = ds.query(**q)
    assert ref.converged and ref.blocks_read < 32  # must actually stop early
    results = _distributed_sigs(ds, LocalTransport.group(4), q)
    for sig, _own in results:
        assert sig == _sig(ref)


def test_uniform_policy_and_grouped_quantiles_match():
    ds = _make_ds()
    q = dict(aggregates=["mean", "p50"], policy="uniform", seed=5, max_blocks=16,
             target_rel_err=0.01, where="c0 > 0.0")
    ref = _sig(ds.query(**q))
    for sig, _own in _distributed_sigs(ds, LocalTransport.group(2), q):
        assert sig == ref


# ---------------------------------------------------------------------------
# straggler death mid-query
# ---------------------------------------------------------------------------

def test_killed_host_changes_no_estimate():
    ds = _make_ds(n=8192, blocks=32)
    q = dict(QUERY, max_blocks=32)
    ref = _sig(ds.query(**q))
    transports = LocalTransport.group(4)
    transports[3].kill_after_puts(2)  # dies after publishing 2 payloads
    results = _distributed_sigs(ds, transports, q)
    survivors = [r for r in results if r is not None]
    assert len(survivors) == 3  # host 3 died via HostKilledError
    for sig, own in survivors:
        assert sig == ref  # estimates, CIs, stopping point: all unchanged
        assert sorted(own.hosts()) == [0, 1, 2]  # dead host re-dealt away
        assert own.epoch == 1


def test_killed_host_blocks_are_redealt_to_survivors():
    ds = _make_ds()
    transports = LocalTransport.group(2)
    transports[1].kill_after_puts(0)  # dies before publishing anything
    ref = _sig(ds.query(**QUERY))
    results = _distributed_sigs(ds, transports, QUERY)
    assert results[1] is None
    sig, own = results[0]
    assert sig == ref
    assert sorted(own.blocks_of(0)) == list(range(ds.num_blocks))


# ---------------------------------------------------------------------------
# serve: QueryService over a DistributedDataset
# ---------------------------------------------------------------------------

def test_query_service_over_distributed_mesh():
    ds = _make_ds()
    ref = _sig(ds.query(**QUERY))

    def run(t):
        dds = ds.distribute(t, straggler_grace=2.0, poll_interval=0.01)
        with dds.serve(workers=1) as svc:
            # explicit seed: every host's service derives the same namespace
            ticket = svc.submit(**QUERY)
            return _sig(svc.result(ticket, timeout=60.0))

    for sig in run_local_hosts(LocalTransport.group(2), run):
        assert sig == ref


# ---------------------------------------------------------------------------
# scope enforcement
# ---------------------------------------------------------------------------

def test_scoped_fetcher_denies_unowned_blocks():
    ds = _make_ds()
    scoped = ScopedFetcher(as_fetcher(ds._make_fetcher()), [0, 1, 2])
    assert scoped.fetch(1) is not None
    with pytest.raises(PermissionError):
        scoped.fetch(3)
    scoped.allow([3])  # a stolen lease widens the scope
    assert scoped.fetch(3) is not None
    scoped.replace([5])  # a re-deal resets it
    with pytest.raises(PermissionError):
        scoped.fetch(0)
    assert scoped.fetch(5) is not None


def test_distributed_dataset_requires_summaries():
    rng = np.random.default_rng(0)
    data = rng.normal(size=(1024, 2)).astype(np.float32)
    ds = RSPDataset.partition(data, 4, summaries=False)
    with pytest.raises(ValueError, match="summaries"):
        ds.distribute(LocalTransport.group(1)[0])


# ---------------------------------------------------------------------------
# elastic churn: leave, join, persisted deals
# ---------------------------------------------------------------------------

def test_redeal_departed_covers_all_blocks():
    own = BlockOwnership.deal(32, 4, seed=1)
    new = redeal_departed(own, [2])
    assert sorted(new.hosts()) == [0, 1, 3]
    covered = sorted(b for h in new.hosts() for b in new.blocks_of(h))
    assert covered == list(range(32))
    assert new.epoch == own.epoch + 1


def test_join_rebalance_roundtrips_through_store(tmp_path):
    store = types.SimpleNamespace(root=str(tmp_path))
    own = open_or_deal(store, 32, 2, seed=5)
    assert load_ownership(store) == own
    grown = rebalance_join(own, 3, store=store)
    assert grown.num_hosts == 3
    assert load_ownership(store) == grown
    # matching reopen returns the persisted deal, mismatch deals fresh
    assert open_or_deal(store, 32, 3) == grown
    fresh = open_or_deal(store, 32, 4)
    assert fresh.num_hosts == 4 and load_ownership(store) == fresh


def test_ownership_save_load_roundtrip(tmp_path):
    store = types.SimpleNamespace(root=str(tmp_path))
    own = BlockOwnership.deal(16, 3, seed=9).redeal([1])
    save_ownership(store, own)
    assert load_ownership(store) == own


def test_elastic_join_after_query(tmp_path):
    ds = _make_ds()
    t = LocalTransport.group(1)[0]
    dds = ds.distribute(t)
    assert sorted(dds.owned_blocks) == list(range(16))
    own = dds.rebalance(3)  # two hosts joined
    assert own.num_hosts == 3
    assert sorted(dds.owned_blocks) == sorted(own.blocks_of(0))
    store = types.SimpleNamespace(root=str(tmp_path))
    save_ownership(store, own)
    assert load_ownership(store) == own


