"""Plan-compiled fused query kernels: predicate parsing, three-way parity
(ref / fused numpy / jax / Pallas-interpret), the plan-keyed compile cache,
and a property test against plain boolean-mask numpy aggregates."""

import numpy as np
import pytest

from repro.kernels.plan import (
    Predicate,
    QueryPlan,
    as_predicates,
    parse_predicate,
    plan_sketch,
    plan_sketch_ref,
)
from repro.kernels.plan import ops as plan_ops

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Predicate / plan construction
# ---------------------------------------------------------------------------


def test_parse_predicate_forms():
    p = parse_predicate("c3 > 0.5")
    assert p == Predicate(3, "gt", 0.5)
    assert parse_predicate("col2 <= -1e-2") == Predicate(2, "le", -0.01)
    assert parse_predicate("0 != 4") == Predicate(0, "ne", 4.0)
    assert parse_predicate((1, "<", 2.0)) == Predicate(1, "lt", 2.0)
    assert parse_predicate(p) is p
    with pytest.raises(ValueError):
        parse_predicate("c3 ~ 0.5")
    with pytest.raises(TypeError):
        parse_predicate(7)


def test_predicate_symbol_normalization():
    # symbols and names are the same predicate -- and the same cache key
    assert Predicate(0, ">", 1.0) == Predicate(0, "gt", 1.0)
    assert str(Predicate(2, "le", 0.25)) == "c2 <= 0.25"
    with pytest.raises(ValueError):
        Predicate(0, "gtt", 1.0)
    with pytest.raises(ValueError):
        Predicate(-1, "gt", 1.0)


def test_as_predicates_shapes():
    assert as_predicates(None) == ()
    assert as_predicates("c0 > 1") == (Predicate(0, "gt", 1.0),)
    assert as_predicates((0, ">", 1.0)) == (Predicate(0, "gt", 1.0),)
    two = as_predicates(["c0 > 1", (2, "<", 3.0)])
    assert two == (Predicate(0, "gt", 1.0), Predicate(2, "lt", 3.0))


def test_plan_validation():
    with pytest.raises(ValueError):
        QueryPlan(columns=())
    with pytest.raises(ValueError):
        QueryPlan(num_classes=3)  # num_classes without group_by
    plan = QueryPlan(columns=(0, -1))
    assert plan.resolve_columns(4) == (0, 3)
    assert not plan.filtered
    assert QueryPlan(predicates="c0 > 1").filtered


# ---------------------------------------------------------------------------
# Three-way parity: every impl must agree with the two-pass reference
# ---------------------------------------------------------------------------


def _data(n=4000, f=6, classes=0, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(1.5, 2.0, size=(n, f)).astype(np.float32)
    if classes:
        x[:, f - 1] = rng.integers(0, classes, size=n)
    return x


PLANS = {
    "filter": (QueryPlan(predicates="c0 > 1.0"), 0),
    "conjunction": (QueryPlan(predicates=["c0 > 1.0", "c2 < 2.5"]), 0),
    "empty_selection": (QueryPlan(predicates="c0 > 1e9"), 0),
    "all_pass": (QueryPlan(predicates="c0 > -1e9"), 0),
    "projection": (QueryPlan(columns=(0, 2, 4)), 0),
    "filter_project": (QueryPlan(predicates="c1 < 2.0", columns=(3, 1)), 0),
    "grouped_filter": (
        QueryPlan(predicates="c0 > 1.0", columns=(0, 1, 2), group_by=5, num_classes=3),
        3,
    ),
}


def _assert_matches(res, ref, *, hist_exact=False):
    """Moment parity at 1e-5 (the acceptance bar); histograms must agree on
    mass always and bin-for-bin when hist_exact (same f32 binning path)."""
    assert res.rows_total == ref.rows_total
    assert res.rows_selected == ref.rows_selected
    assert res.selectivity == pytest.approx(ref.selectivity)
    assert len(res.sketches) == len(ref.sketches)
    for got, want in zip(res.sketches, ref.sketches):
        assert got.count == want.count
        if want.count == 0:
            assert np.all(np.isinf(got.min)) and np.all(np.isinf(got.max))
            continue
        np.testing.assert_allclose(got.mean, want.mean, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(got.min, want.min, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(got.max, want.max, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(got.m2, want.m2, rtol=1e-4, atol=1e-3)
        if want.hist is not None:
            assert got.hist is not None
            # bin-edge caveat: f32 vs f64 binning may shift edge values one
            # bin, but never changes per-feature mass
            np.testing.assert_array_equal(got.hist.sum(-1), want.hist.sum(-1))
            if hist_exact:
                np.testing.assert_array_equal(got.hist, want.hist)


@pytest.mark.parametrize("impl", ["np", "jax", "pallas"])
@pytest.mark.parametrize("name", sorted(PLANS))
def test_plan_parity_with_hist(impl, name):
    plan, classes = PLANS[name]
    x = _data(classes=classes)
    kw = dict(bins=16, lo=-8.0, hi=12.0)
    ref = plan_sketch(x, plan, impl="ref", **kw)
    res = plan_sketch(x, plan, impl=impl, tile_rows=512, **kw)
    _assert_matches(res, ref)
    # the fused paths share one f32 binning rule -- exact hist agreement
    base = plan_sketch(x, plan, impl="np", tile_rows=512, **kw)
    _assert_matches(res, base, hist_exact=True)


@pytest.mark.parametrize("impl", ["np", "jax", "pallas"])
def test_plan_parity_no_hist(impl):
    # bins=0 skips histograms (pallas falls back to the jit path)
    plan = QueryPlan(predicates=["c0 > 1.0", "c3 >= 0.0"])
    x = _data()
    ref = plan_sketch(x, plan, impl="ref")
    _assert_matches(plan_sketch(x, plan, impl=impl, tile_rows=256), ref)


def test_plan_ragged_tiles():
    # n not divisible by the tile: the tail tile and Pallas padding must not
    # leak phantom rows into any aggregate
    plan = QueryPlan(predicates="c1 > 1.5")
    x = _data(n=3001)
    ref = plan_sketch(x, plan, impl="ref", bins=8, lo=-8.0, hi=12.0)
    for impl in ("np", "jax", "pallas"):
        res = plan_sketch(x, plan, impl=impl, tile_rows=128, bins=8, lo=-8.0, hi=12.0)
        _assert_matches(res, ref)


def test_plan_sketch_ref_is_mask_then_sketch():
    plan = QueryPlan(predicates="c0 > 1.0", columns=(2,))
    x = _data(n=500)
    res = plan_sketch_ref(x, plan)
    sel = x[x[:, 0] > np.float32(1.0)][:, 2]
    assert res.rows_selected == sel.shape[0]
    np.testing.assert_allclose(res.sketches[0].mean, [sel.astype(np.float64).mean()], rtol=1e-6)


def test_auto_impl_matches_ref():
    # REPRO_AUTOTUNE=off (conftest): auto pins the deterministic default
    plan = QueryPlan(predicates="c2 < 2.0")
    x = _data(n=2000)
    ref = plan_sketch(x, plan, impl="ref", bins=8, lo=-8.0, hi=12.0)
    _assert_matches(plan_sketch(x, plan, bins=8, lo=-8.0, hi=12.0), ref)


def test_unknown_impl_rejected():
    with pytest.raises(ValueError):
        plan_sketch(_data(n=10), QueryPlan(), impl="cuda")


# ---------------------------------------------------------------------------
# Plan-keyed compile cache
# ---------------------------------------------------------------------------


def test_compile_cache_hits_and_misses():
    plan_ops.cache_clear()
    plan = QueryPlan(predicates="c0 > 0.5")
    fn = plan_ops.compile_plan(plan, num_features=4, bins=8, impl="np")
    assert plan_ops.cache_info() == {"hits": 0, "misses": 1, "size": 1}

    # identical plan (fresh object, symbol spelling) -> cache hit, same fn
    again = plan_ops.compile_plan(
        QueryPlan(predicates=(Predicate(0, ">", 0.5),)),
        num_features=4, bins=8, impl="np",
    )
    assert again is fn
    assert plan_ops.cache_info()["hits"] == 1

    # changing the predicate value recompiles
    plan_ops.compile_plan(
        QueryPlan(predicates="c0 > 0.25"), num_features=4, bins=8, impl="np"
    )
    # ...as does any other key component (shape, bins, impl, tile)
    plan_ops.compile_plan(plan, num_features=5, bins=8, impl="np")
    plan_ops.compile_plan(plan, num_features=4, bins=16, impl="np")
    plan_ops.compile_plan(plan, num_features=4, bins=8, impl="ref")
    plan_ops.compile_plan(plan, num_features=4, bins=8, impl="np", tile_rows=8192)
    info = plan_ops.cache_info()
    assert info["misses"] == 6 and info["size"] == 6


def test_plan_key_identity():
    a = QueryPlan(predicates="c0 > 0.5", columns=(1, 2))
    b = QueryPlan(predicates=(0, ">", 0.5), columns=[1, 2])
    assert a.key() == b.key()
    assert a.key() != QueryPlan(predicates="c0 > 0.6", columns=(1, 2)).key()
    assert a.key() != QueryPlan(predicates="c0 >= 0.5", columns=(1, 2)).key()
    assert a.key() != QueryPlan(predicates="c0 > 0.5").key()


def test_compile_plan_rejects_auto():
    with pytest.raises(ValueError):
        plan_ops.compile_plan(QueryPlan(), num_features=3, impl="auto")


# ---------------------------------------------------------------------------
# Property test: fused filtered aggregates == boolean-mask numpy aggregates
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        col=st.integers(0, 2),
        op=st.sampled_from(["lt", "le", "gt", "ge"]),
        thresh=st.floats(-2.0, 2.0, allow_nan=False),
        impl=st.sampled_from(["np", "jax"]),
    )
    def test_fused_filter_matches_boolean_mask(seed, col, op, thresh, impl):
        rng = np.random.default_rng(seed)
        x = rng.normal(0.0, 1.0, size=(257, 3)).astype(np.float32)
        plan = QueryPlan(predicates=(Predicate(col, op, thresh),))
        res = plan_sketch(x, plan, impl=impl, tile_rows=64)
        sel = x[plan.mask(x)].astype(np.float64)
        assert res.rows_total == 257
        assert res.rows_selected == sel.shape[0]
        sk = res.sketches[0]
        assert sk.count == sel.shape[0]
        if sel.shape[0] == 0:
            return
        np.testing.assert_allclose(sk.mean, sel.mean(0), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(sk.min, sel.min(0), rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(sk.max, sel.max(0), rtol=1e-6, atol=1e-7)
        m2 = ((sel - sel.mean(0)) ** 2).sum(0)
        np.testing.assert_allclose(sk.m2, m2, rtol=1e-3, atol=1e-3)
