"""Progressive query subsystem: sketch fast path (zero block reads), anytime
CI calibration across blocks, early stopping at target_rel_err, grouped
aggregates, and the bootstrap quantile intervals."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tests skip below; the rest of the module runs
    HAVE_HYPOTHESIS = False

from repro import rsp
from repro.rsp.query import (
    Aggregate,
    Query,
    as_query,
    norm_ppf,
    parse_aggregate,
    t_ppf,
)


@pytest.fixture(scope="module")
def labelled_ds():
    rng = np.random.default_rng(0)
    n, k = 24000, 40
    x = rng.normal(1.5, 2.0, size=(n, 3)).astype(np.float32)
    y = rng.integers(0, 2, size=(n, 1)).astype(np.float32)
    data = np.concatenate([x, y], axis=1)
    return rsp.partition(data, blocks=k, seed=7, num_classes=2), data


@pytest.fixture(scope="module")
def plain_ds():
    rng = np.random.default_rng(42)
    data = rng.normal(1.5, 2.0, size=(20000, 3)).astype(np.float32)
    return rsp.partition(data, blocks=50, seed=3), data


# ---------------------------------------------------------------------------
# Declaration / parsing
# ---------------------------------------------------------------------------

def test_parse_aggregates():
    assert parse_aggregate("mean").kind == "mean"
    assert parse_aggregate("median").q == 0.5
    assert parse_aggregate("p95").q == 0.95
    assert parse_aggregate("p99.9").q == pytest.approx(0.999)
    assert parse_aggregate(Aggregate("var")).kind == "var"
    with pytest.raises(ValueError):
        parse_aggregate("p101x")
    with pytest.raises(ValueError):
        Aggregate("quantile")  # missing q
    with pytest.raises(ValueError):
        Aggregate("mean", q=0.5)  # q on a non-quantile
    with pytest.raises(ValueError):
        Aggregate("wat")


def test_as_query_validation():
    q = as_query(["mean", "p95"], target_rel_err=0.01)
    assert len(q.aggregates) == 2 and q.aggregates[1].q == 0.95
    with pytest.raises(ValueError):
        as_query("mean", target_rel_err=-1.0)
    with pytest.raises(ValueError):
        as_query("mean", min_blocks=1)
    with pytest.raises(ValueError):
        as_query(Query(aggregates=(Aggregate("mean"),)), max_blocks=5)


def test_t_and_norm_quantiles():
    # exact low-df values and monotone approach to the normal quantile
    assert t_ppf(0.975, 1) == pytest.approx(12.7062, rel=1e-4)
    assert t_ppf(0.975, 2) == pytest.approx(4.30265, rel=1e-4)
    assert t_ppf(0.975, 9) == pytest.approx(2.26216, rel=5e-3)
    assert t_ppf(0.975, 200) == pytest.approx(1.97190, rel=1e-3)  # scipy value
    assert t_ppf(0.975, 10_000) == pytest.approx(norm_ppf(0.975), rel=1e-3)
    assert norm_ppf(0.975) == pytest.approx(1.95996, rel=1e-5)
    assert norm_ppf(0.5) == pytest.approx(0.0, abs=1e-12)
    scipy_stats = pytest.importorskip("scipy.stats")
    for df in (3, 5, 12, 40):
        assert t_ppf(0.975, df) == pytest.approx(scipy_stats.t.ppf(0.975, df), rel=1.5e-2)


# ---------------------------------------------------------------------------
# Sketch fast path: zero block reads
# ---------------------------------------------------------------------------

def test_sketch_only_query_reads_zero_blocks(labelled_ds):
    ds, data = labelled_ds
    res = ds.query(["mean", "var", "sum", "count"])
    assert res.from_sketches and res.converged
    assert res.blocks_read == 0
    assert res.executor_stats.blocks_fetched == 0
    full = data.astype(np.float64)
    np.testing.assert_allclose(res["mean"].estimate, full.mean(0), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(res["var"].estimate, full.var(0, ddof=1), rtol=1e-4)
    np.testing.assert_allclose(res["sum"].estimate, full.sum(0), rtol=1e-5)
    assert res["count"].estimate == data.shape[0]
    assert res.max_rel_err == 0.0  # exact: all K sketches combined


def test_sketch_only_grouped_count(labelled_ds):
    ds, data = labelled_ds
    res = ds.query(Aggregate("count", by_label=True))
    assert res.from_sketches and res.blocks_read == 0
    truth = np.bincount(data[:, -1].astype(np.int64), minlength=2)
    np.testing.assert_allclose(res["count/label"].estimate, truth)


def test_quantile_sketch_only_within_kll_bound(labelled_ds):
    """v2 suites answer ungrouped unfiltered quantiles with zero block
    reads, and the estimate lands within the KLL additive rank bound."""
    ds, data = labelled_ds
    full = np.asarray(data, dtype=np.float64)
    eps = ds.summaries[0].get("kll").rank_error_bound()
    for spec, q in (("median", 0.5), ("p95", 0.95)):
        res = ds.query(spec)
        assert res.from_sketches and res.blocks_read == 0
        name = "p50" if spec == "median" else "p95"
        est = np.asarray(res[name].estimate)
        lo = np.quantile(full, max(q - eps, 0.0), axis=0)
        hi = np.quantile(full, min(q + eps, 1.0), axis=0)
        assert np.all(est >= lo - 1e-9) and np.all(est <= hi + 1e-9)
        # honest interval: quantile sketch answers are not exact
        assert res[name].rel_err is not None and res[name].rel_err > 0.0


def test_distinct_sketch_only_within_kmv_bound(labelled_ds):
    ds, data = labelled_ds
    res = ds.query("distinct")
    assert res.from_sketches and res.blocks_read == 0
    est = np.asarray(res["distinct"].estimate)
    full = np.asarray(data, dtype=np.float64)
    truth = np.array([np.unique(full[:, j]).size for j in range(full.shape[1])])
    bound = ds.summaries[0].get("distinct").relative_error_bound()
    # exact below k (the label column), within ~4 sigma above it
    assert np.all(np.abs(est - truth) <= np.maximum(4.0 * bound * truth, 1.0))


def test_quantile_auto_falls_back_on_tight_target(labelled_ds):
    """auto mode streams blocks when the KLL bound cannot meet the target;
    use_sketches=True returns the bound-limited sketch answer instead."""
    ds, _ = labelled_ds
    res = ds.query("median", target_rel_err=1e-7, max_blocks=5)
    assert not res.from_sketches and res.blocks_read > 0
    forced = ds.query("median", use_sketches=True, target_rel_err=1e-7)
    assert forced.from_sketches and not forced.converged


def test_grouped_quantile_needs_blocks(labelled_ds):
    ds, _ = labelled_ds
    res = ds.query(Aggregate("quantile", q=0.5, by_label=True), max_blocks=5)
    assert not res.from_sketches and res.blocks_read > 0
    with pytest.raises(ValueError):
        ds.query(
            Aggregate("quantile", q=0.5, by_label=True),
            use_sketches=True,
            max_blocks=5,
        )


def test_use_sketches_false_streams(labelled_ds):
    ds, data = labelled_ds
    res = ds.query("mean", use_sketches=False, max_blocks=6)
    assert not res.from_sketches
    assert res.blocks_read == 6
    assert res.executor_stats.hits + res.executor_stats.misses >= 6


# ---------------------------------------------------------------------------
# CI calibration and early stopping (the paper's "few blocks" loop)
# ---------------------------------------------------------------------------

def test_mean_ci_coverage(plain_ds):
    """A 95% CI from g=10 of K=50 blocks must cover the corpus mean in >=90%
    of seeded trials (nominal coverage ~95%; the margin absorbs noise)."""
    ds, data = plain_ds
    truth = data.astype(np.float64).mean(0)[0]
    trials, covered = 80, 0
    for s in range(trials):
        res = ds.query("mean", max_blocks=10, use_sketches=False, seed=s)
        a = res["mean"]
        assert res.blocks_read == 10
        covered += bool(a.ci_lo[0] <= truth <= a.ci_hi[0])
    assert covered / trials >= 0.90, f"coverage {covered}/{trials}"


def test_target_rel_err_stops_early_and_respects_max_blocks(plain_ds):
    ds, _ = plain_ds
    # generous target -> stops well before max_blocks
    res = ds.query("mean", target_rel_err=0.05, max_blocks=40, use_sketches=False)
    assert res.converged
    assert 2 <= res.blocks_read < 40
    # impossible target -> reads exactly max_blocks, not more, not converged
    res = ds.query("mean", target_rel_err=1e-7, max_blocks=12, use_sketches=False)
    assert not res.converged
    assert res.blocks_read == 12


def test_stream_emits_anytime_results_with_narrowing_ci(plain_ds):
    ds, _ = plain_ds
    widths, reads = [], []
    for res in ds.query_stream("mean", max_blocks=15, use_sketches=False, seed=1):
        a = res["mean"]
        assert np.all(a.ci_lo <= a.estimate) and np.all(a.estimate <= a.ci_hi)
        widths.append(float(np.max(a.ci_hi - a.ci_lo)))
        reads.append(res.blocks_read)
    assert reads == list(range(1, 16))
    assert widths[0] == np.inf  # one block: no spread estimate yet
    assert widths[-1] < widths[1]  # intervals narrow as blocks accumulate


def test_executor_stats_meter_the_query(plain_ds):
    ds, _ = plain_ds
    res = ds.query("mean", max_blocks=8, use_sketches=False, seed=2)
    stats = res.executor_stats
    assert stats.hits + stats.misses >= res.blocks_read
    # a second identical query hits the LRU cache for the overlapping blocks
    res2 = ds.query("mean", max_blocks=8, use_sketches=False, seed=2)
    assert res2.executor_stats.hits > 0


# ---------------------------------------------------------------------------
# Quantiles: merged histograms + bootstrap intervals
# ---------------------------------------------------------------------------

def test_quantile_estimate_and_ci(plain_ds):
    ds, data = plain_ds
    full = data.astype(np.float64)
    res = ds.query(["median", "p95"], max_blocks=20, use_sketches=False, seed=3)
    med, p95 = res["p50"], res["p95"]
    np.testing.assert_allclose(med.estimate, np.median(full, axis=0), atol=0.06)
    np.testing.assert_allclose(p95.estimate, np.quantile(full, 0.95, axis=0), atol=0.12)
    assert np.all(med.ci_lo <= med.estimate) and np.all(med.estimate <= med.ci_hi)
    # bootstrap CI should cover the corpus median here
    truth = np.median(full, axis=0)
    assert np.all(med.ci_lo <= truth) and np.all(truth <= med.ci_hi)


def test_quantile_stops_early_at_loose_target(plain_ds):
    ds, _ = plain_ds
    res = ds.query("median", target_rel_err=0.05, max_blocks=40, use_sketches=False)
    assert res.converged and res.blocks_read < 40


def test_histogram_aggregate_scales_to_corpus(plain_ds):
    ds, data = plain_ds
    res = ds.query("histogram", max_blocks=10, use_sketches=False, bins=32)
    h = res["histogram"]
    assert h.rel_err is None and h.ci_lo is None
    est = np.asarray(h.estimate)
    assert est.shape == (3, 32)
    # total scaled mass ~ corpus record count per feature
    np.testing.assert_allclose(est.sum(axis=1), data.shape[0], rtol=0.15)


# ---------------------------------------------------------------------------
# Grouped aggregates
# ---------------------------------------------------------------------------

def test_grouped_mean_and_quantile(labelled_ds):
    ds, data = labelled_ds
    full = data.astype(np.float64)
    labels = full[:, -1].astype(np.int64)
    res = ds.query(
        [
            Aggregate("mean", feature=0, by_label=True),
            Aggregate("quantile", q=0.95, feature=0, by_label=True),
        ],
        max_blocks=25,
        use_sketches=False,
        seed=5,
    )
    gm = res["mean[0]/label"]
    gq = res["p95[0]/label"]
    assert gm.estimate.shape == (2,) and gq.estimate.shape == (2,)
    for c in (0, 1):
        cls = full[labels == c, 0]
        assert abs(gm.estimate[c] - cls.mean()) < 0.1
        assert abs(gq.estimate[c] - np.quantile(cls, 0.95)) < 0.25
        assert gm.ci_lo[c] <= gm.estimate[c] <= gm.ci_hi[c]


def test_histogram_feature_selection(plain_ds):
    ds, _ = plain_ds
    res = ds.query(
        Aggregate("histogram", feature=0), max_blocks=4, bins=8, use_sketches=False
    )
    assert np.asarray(res["histogram[0]"].estimate).shape == (8,)


def test_grouped_count_shape_matches_sketch_path(labelled_ds):
    """Streamed and sketch-answered grouped counts must agree in shape [C]."""
    ds, _ = labelled_ds
    a = ds.query(Aggregate("count", by_label=True))
    b = ds.query(Aggregate("count", by_label=True), use_sketches=False, max_blocks=4)
    assert a["count/label"].estimate.shape == (2,)
    assert b["count/label"].estimate.shape == (2,)


def test_forced_sketch_path_meters_summary_computation():
    """use_sketches=True on a sketch-less dataset computes the sketches via a
    full-corpus pass; the result's executor_stats must show it."""
    rng = np.random.default_rng(2)
    data = rng.normal(5, 1, size=(6400, 3)).astype(np.float32)
    ds = rsp.partition(data, blocks=16, seed=1, summaries=False)
    res = ds.query("mean", use_sketches=True)
    assert res.from_sketches
    assert res.executor_stats.blocks_fetched >= 16


def test_weighted_policy_summary_scan_is_metered():
    """Building weighted-policy probabilities on a sketch-less dataset reads
    every block; that pass belongs in the query's I/O count."""
    rng = np.random.default_rng(3)
    data = rng.normal(5, 1, size=(6400, 3)).astype(np.float32)
    ds = rsp.partition(data, blocks=16, seed=1, summaries=False)
    res = ds.query("median", policy="weighted", max_blocks=3, use_sketches=False)
    assert res.executor_stats.blocks_fetched >= 16


def test_grouped_requires_num_classes(plain_ds):
    ds, _ = plain_ds
    with pytest.raises(ValueError, match="num_classes"):
        ds.query(Aggregate("mean", by_label=True), max_blocks=4)


# ---------------------------------------------------------------------------
# Policies and storage round-trip
# ---------------------------------------------------------------------------

def test_weighted_policy_query(plain_ds):
    ds, data = plain_ds
    res = ds.query(
        "mean", policy="weighted", max_blocks=25, use_sketches=False, seed=4
    )
    truth = data.astype(np.float64).mean(0)
    assert np.abs(res["mean"].estimate - truth).max() < 0.25
    assert np.all(res["mean"].ci_lo <= res["mean"].estimate)


def test_weighted_policy_quantile_is_ht_weighted(plain_ds):
    """Under PPS selection the merged histogram must be HT-expanded; the
    resulting quantile stays close to the truth."""
    ds, data = plain_ds
    res = ds.query("median", policy="weighted", max_blocks=25, use_sketches=False, seed=6)
    truth = np.median(data.astype(np.float64), axis=0)
    assert np.abs(res["p50"].estimate - truth).max() < 0.2


def test_weighted_policy_var_is_ht_unbiased():
    """Variance under PPS selection must divide the selection bias back out
    (HT expansion of the corpus sum of squares); the raw fold over the
    oversampled high-dispersion blocks is several times too large."""
    rng = np.random.default_rng(0)
    skewed = np.sort(rng.lognormal(mean=1.0, sigma=1.2, size=64 * 512))
    chunked = rsp.RSPDataset(
        rsp.RSPSpec(num_records=64 * 512, num_blocks=64, num_original_blocks=1,
                    record_shape=(1,)),
        blocks=skewed.reshape(64, 512, 1).astype(np.float32),
    )
    truth = skewed.var(ddof=1)
    ests = [
        float(np.asarray(
            chunked.query("var", policy="weighted", max_blocks=8,
                          use_sketches=False, seed=s)["var"].estimate
        ))
        for s in range(20)
    ]
    ratio = np.mean(ests) / truth
    assert 0.5 < ratio < 1.7, f"HT var off by {ratio:.2f}x"


def test_summaryless_quantile_query_reports_grid_scan_io():
    """Deriving the histogram grid on a sketch-less dataset reads blocks;
    that pass must show up in the query's executor_stats."""
    rng = np.random.default_rng(1)
    data = rng.normal(5, 1, size=(8000, 4)).astype(np.float32)
    ds = rsp.partition(data, blocks=20, seed=1, summaries=False)
    res = ds.query("median", max_blocks=3)
    assert res.blocks_read == 3
    assert res.executor_stats.blocks_fetched >= 20  # grid scan counted


def test_run_without_target_matches_final_stream_result(plain_ds):
    """run() skips intermediate result materialization when no stopping rule
    can fire -- but the final answer must equal the anytime stream's last."""
    ds, _ = plain_ds
    final = ds.query("median", max_blocks=8, use_sketches=False, seed=9)
    last = list(ds.query_stream("median", max_blocks=8, use_sketches=False, seed=9))[-1]
    assert final.blocks_read == last.blocks_read == 8
    np.testing.assert_allclose(final["p50"].estimate, last["p50"].estimate)
    np.testing.assert_allclose(final["p50"].ci_lo, last["p50"].ci_lo)


def test_query_on_stored_dataset(tmp_path, labelled_ds):
    ds, data = labelled_ds
    ds.save(str(tmp_path / "q.rsp"))
    opened = rsp.open(str(tmp_path / "q.rsp"))
    # sketches come from the manifest: still zero block reads
    res = opened.query("mean")
    assert res.from_sketches and res.executor_stats.blocks_fetched == 0
    np.testing.assert_allclose(
        res["mean"].estimate, data.astype(np.float64).mean(0), rtol=1e-5, atol=1e-5
    )
    # a quantile query actually fetches from the store
    res = opened.query("median", max_blocks=5, use_sketches=False)
    assert res.executor_stats.blocks_fetched > 0


# ---------------------------------------------------------------------------
# Property test (guarded like the others)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        max_blocks=st.integers(2, 20),
        target=st.one_of(st.none(), st.floats(1e-4, 0.5)),
        seed=st.integers(0, 1000),
    )
    def test_query_invariants(max_blocks, target, seed):
        rng = np.random.default_rng(11)
        data = rng.normal(3.0, 1.0, size=(4000, 2)).astype(np.float32)
        ds = rsp.partition(data, blocks=20, seed=1)
        res = ds.query(
            "mean",
            target_rel_err=target,
            max_blocks=max_blocks,
            min_blocks=2,
            use_sketches=False,
            seed=seed,
        )
        assert 1 <= res.blocks_read <= max_blocks
        a = res["mean"]
        assert np.all(a.ci_lo <= a.estimate) and np.all(a.estimate <= a.ci_hi)
        if res.converged:
            assert res.max_rel_err <= target

else:

    def test_query_invariants():
        pytest.importorskip("hypothesis")
