"""Pure-jnp oracle for the SSD kernel: the exact step recurrence."""

from repro.models.mamba2 import ssd_reference as ssd_ref  # noqa: F401

ssd_reference = ssd_ref
