"""Pallas TPU kernel for the Mamba2 SSD inner loop.

Grid: (B, num_head_tiles, num_chunks); the chunk dimension is innermost and
sequential, so the inter-chunk SSM state [Ht, P, N] lives in VMEM scratch and
never round-trips to HBM -- that is the whole point versus the XLA scan,
whose per-chunk state traffic is HBM-bound.

Per grid step the kernel computes, entirely in VMEM:
  intra-chunk:  (C B^T  .  exp(cum_i - cum_j) mask)  @  xbar      (MXU)
  inter-chunk:  C @ (exp(cum_i) * h_state)                        (MXU)
  state update: h = exp(cum_Q) h + sum_j exp(cum_Q - cum_j) B_j xbar_j^T

All decay exponents are non-positive: numerically safe at any chunk size.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dA_ref, B_ref, C_ref, y_ref, hout_ref, h_scr, *, nc: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # [Q, Ht, P]
    dA = dA_ref[0].astype(jnp.float32)        # [Q, Ht]
    Bm = B_ref[0].astype(jnp.float32)         # [Q, N]
    Cm = C_ref[0].astype(jnp.float32)         # [Q, N]
    Q, Ht, P = x.shape
    N = Bm.shape[-1]

    cum = jnp.cumsum(dA, axis=0)              # [Q, Ht]
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)       # [Q, Q]
    rel = cum[:, None, :] - cum[None, :, :]                            # [Q, Q, Ht]
    causal = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (Q, Q), 1
    )
    M = jnp.where(causal[:, :, None], jnp.exp(rel), 0.0)               # [Q, Q, Ht]
    scores = CB[:, :, None] * M                                        # [Q, Q, Ht]
    # y_diag[q, h, p] = sum_k scores[q, k, h] * x[k, h, p]
    y_diag = jnp.einsum("qkh,khp->qhp", scores, x)

    # inter-chunk: y_off[q, h, p] = exp(cum[q, h]) * sum_n C[q, n] h_scr[h, p, n]
    h_prev = h_scr[...]                                                # [Ht, P, N]
    y_off = jnp.einsum("qn,hpn->qhp", Cm, h_prev) * jnp.exp(cum)[:, :, None]

    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update
    decay_to_end = jnp.exp(cum[-1][None, :] - cum)                     # [Q, Ht]
    # S_c[h, p, n] = sum_k decay[k, h] * x[k, h, p] * B[k, n]
    S_c = jnp.einsum("kh,khp,kn->hpn", decay_to_end, x, Bm)
    h_scr[...] = h_prev * jnp.exp(cum[-1])[:, None, None] + S_c

    @pl.when(c == nc - 1)
    def _write_state():
        hout_ref[0] = h_scr[...]


def ssd_pallas(
    xbar: jax.Array,   # [B, L, H, P] fp32
    dA: jax.Array,     # [B, L, H]    fp32 (<= 0)
    Bm: jax.Array,     # [B, L, N]    fp32
    Cm: jax.Array,     # [B, L, N]    fp32
    *,
    chunk: int = 128,
    head_tile: int = 8,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    B, L, H, P = xbar.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    if L % Q:
        raise ValueError(f"L={L} must be divisible by chunk={Q}")
    Ht = min(head_tile, H)
    if H % Ht:
        raise ValueError(f"H={H} must be divisible by head_tile={Ht}")
    nc, nh = L // Q, H // Ht

    kernel = functools.partial(_ssd_kernel, nc=nc)
    y, hfinal = pl.pallas_call(
        kernel,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, Q, Ht, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, Ht), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, Ht, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Ht, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Ht, P, N), jnp.float32)],
        interpret=interpret,
    )(xbar, dA, Bm, Cm)
    return y, hfinal
