"""jit'd wrapper for the Mamba2 SSD Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba2_ssd.kernel import ssd_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "head_tile", "interpret"))
def ssd(
    xbar: jax.Array,
    dA: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    *,
    chunk: int = 128,
    head_tile: int = 8,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    B, L, H, P = xbar.shape
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    ht = head_tile
    while H % ht:
        ht //= 2
    y, h = ssd_pallas(
        xbar.astype(jnp.float32),
        dA.astype(jnp.float32),
        Bm.astype(jnp.float32),
        Cm.astype(jnp.float32),
        chunk=Q,
        head_tile=max(ht, 1),
        interpret=interpret,
    )
    return y[:, :L], h
