"""Pure-jnp oracle: the exact WKV6 step recurrence (from the model path)."""

from repro.models.rwkv6 import wkv6_scan  # noqa: F401
