"""jit'd wrapper for the RWKV6 WKV Pallas kernel.

Takes multiplicative decay ``w`` in (0, 1) (the model-side convention) and
converts to log space for the kernel.  Pads T to a chunk multiple with
identity steps (log w = 0, k = 0: state untouched, outputs sliced off).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_wkv.kernel import wkv6_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(
    r: jax.Array,      # [B, T, H, C]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,      # decay in (0, 1)
    u: jax.Array,      # [H, C]
    *,
    chunk: int = 16,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    B, T, H, C = r.shape
    Q = min(chunk, T)
    pad = (-T) % Q
    logw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38))
    if pad:
        zeros = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zeros(r), zeros(k), zeros(v)
        logw = zeros(logw)  # log w = 0 -> decay 1 -> state untouched
    y, h = wkv6_pallas(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        logw, u.astype(jnp.float32), chunk=Q, interpret=interpret,
    )
    return y[:, :T], h
