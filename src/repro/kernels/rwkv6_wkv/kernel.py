"""Pallas TPU kernel for the RWKV6 WKV recurrence (chunked, VMEM-resident
state).

Grid: (B, H, num_time_chunks); time is innermost and sequential so the
[C, V] state matrix stays in VMEM scratch across chunks.  Within a chunk of
Q steps the data-dependent per-channel decay makes the usual r~/k~
factorization unstable (one side is exp of a positive cumsum), so the kernel
materializes the pairwise per-channel decay tensor [Q, Q, C] -- affordable
*only* at kernel tile sizes (Q=16/32), which is exactly why this is a kernel
and the jnp model path is a plain scan.

All exponents are non-positive => stable at fp32 for any decay strength.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, logw_ref, u_ref, y_ref, hout_ref, h_scr, *, nt: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    r = r_ref[0, 0].astype(jnp.float32)       # [Q, C]
    k = k_ref[0, 0].astype(jnp.float32)       # [Q, C]
    v = v_ref[0, 0].astype(jnp.float32)       # [Q, V]
    lw = logw_ref[0, 0].astype(jnp.float32)   # [Q, C]  log decay, <= 0
    u = u_ref[0].astype(jnp.float32)          # [C]
    Q, C = r.shape

    cw = jnp.cumsum(lw, axis=0)               # [Q, C] inclusive
    h_prev = h_scr[...]                       # [C, V]

    # cross-chunk: y_t += (r_t * exp(cw_{t-1})) @ h_prev
    cw_prev = cw - lw                          # exclusive cumsum (cw_{t-1})
    r_dec = r * jnp.exp(cw_prev)               # exponents <= 0
    y = jax.lax.dot_general(r_dec, h_prev, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # [Q, V]

    # intra-chunk, j < t: A[t, j] = sum_c r[t,c] k[j,c] exp(cw_{t-1,c} - cw_{j,c})
    rel = cw_prev[:, None, :] - cw[None, :, :]                        # [Q, Q, C]
    strict = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) > jax.lax.broadcasted_iota(
        jnp.int32, (Q, Q), 1
    )
    E = jnp.where(strict[:, :, None], jnp.exp(rel), 0.0)              # [Q, Q, C]
    A = jnp.einsum("tc,jc,tjc->tj", r, k, E)
    y = y + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # diagonal bonus: y_t += (sum_c r[t,c] u[c] k[t,c]) * v_t
    bonus = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)       # [Q, 1]
    y = y + bonus * v

    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: h = exp(cw_Q) h_prev + sum_j (k_j exp(cw_Q - cw_j)) v_j^T
    k_dec = k * jnp.exp(cw[-1][None, :] - cw)                          # <= 0 exps
    h_new = h_prev * jnp.exp(cw[-1])[:, None] + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    h_scr[...] = h_new

    @pl.when(t == nt - 1)
    def _write_state():
        hout_ref[0, 0] = h_scr[...]


def wkv6_pallas(
    r: jax.Array,      # [B, T, H, C] fp32
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,   # [B, T, H, C] log decay (<= 0)
    u: jax.Array,      # [H, C]
    *,
    chunk: int = 16,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    B, T, H, C = r.shape
    Q = min(chunk, T)
    if T % Q:
        raise ValueError(f"T={T} must be divisible by chunk={Q}")
    nt = T // Q

    def reorder(a):  # [B, T, H, C] -> [B, H, T, C]
        return jnp.moveaxis(a, 2, 1)

    kernel = functools.partial(_wkv_kernel, nt=nt)
    y, hfinal = pl.pallas_call(
        kernel,
        grid=(B, H, nt),
        in_specs=[
            pl.BlockSpec((1, 1, Q, C), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, Q, C), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, Q, C), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, Q, C), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, C), lambda b, h, t: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, C), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, C, C), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, C), jnp.float32),
            jax.ShapeDtypeStruct((B, H, C, C), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((C, C), jnp.float32)],
        interpret=interpret,
    )(reorder(r), reorder(k), reorder(v), reorder(logw), u)
    return jnp.moveaxis(y, 1, 2), hfinal
