"""Oracle for the fused plan kernels: mask, project, then sketch with the
plain-numpy :func:`~repro.kernels.block_sketch.ref.block_sketch_ref`.

This is the *two-pass* baseline the fused kernels are benchmarked against
(materialize the boolean mask, copy the surviving rows, then sketch them
per group) and the parity reference the fused results must match to 1e-5
on moments.  Histograms carry the repo's standing bin-edge caveat: values
lying exactly on a bin edge may land in adjacent bins between the f32
fused paths and this f64 reference.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.kernels.block_sketch.ref import BlockSketch, _grid, block_sketch_ref
from repro.kernels.plan.plan import QueryPlan


def empty_sketch(
    num_features: int,
    bins: int = 0,
    lo: np.ndarray | None = None,
    hi: np.ndarray | None = None,
) -> BlockSketch:
    """The identity element: a sketch of zero rows (inf/-inf extrema, zero
    histogram) that merges as a no-op."""
    f = int(num_features)
    return BlockSketch(
        count=0.0,
        mean=np.zeros(f),
        m2=np.zeros(f),
        min=np.full(f, np.inf),
        max=np.full(f, -np.inf),
        hist=np.zeros((f, bins), np.int64) if bins > 0 else None,
        lo=lo,
        hi=hi,
    )


@dataclasses.dataclass
class PlanResult:
    """Outcome of one fused block pass: how many rows the block held, how
    many survived the predicates, and one sketch per plan group (length 1
    ungrouped, ``num_classes`` grouped) over the *projected* features of
    the surviving rows."""

    rows_total: int
    rows_selected: int
    sketches: list[BlockSketch]

    @property
    def selectivity(self) -> float:
        return self.rows_selected / max(self.rows_total, 1)


def plan_sketch_ref(
    block,
    plan: QueryPlan,
    *,
    bins: int = 0,
    lo=0.0,
    hi=1.0,
) -> PlanResult:
    """Reference execution of ``plan`` over one block: float32 predicate
    mask -> row materialization -> per-group f64 ``block_sketch_ref`` on the
    projected columns."""
    x = np.asarray(block, dtype=np.float32).reshape(np.shape(block)[0], -1)
    n, f = x.shape
    cols = list(plan.resolve_columns(f))
    fp = len(cols)
    glo = ghi = None
    if bins > 0:
        glo, ghi = _grid(lo, hi, fp)
    sel = x[plan.mask(x)] if plan.predicates else x
    kw = dict(bins=bins) if bins == 0 else dict(bins=bins, lo=glo, hi=ghi)

    def sketch(rows: np.ndarray) -> BlockSketch:
        if rows.shape[0] == 0:
            return empty_sketch(fp, bins, glo, ghi)
        return block_sketch_ref(rows[:, cols], **kw)

    if plan.group_by is None:
        sketches = [sketch(sel)]
    else:
        labels = sel[:, plan.group_by % f].astype(np.int64)
        sketches = [sketch(sel[labels == g]) for g in range(plan.num_classes)]
    return PlanResult(
        rows_total=int(n), rows_selected=int(sel.shape[0]), sketches=sketches
    )
