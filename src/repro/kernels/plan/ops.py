"""Plan-compiled fused query kernels: compile cache + impl dispatcher.

``plan_sketch(block, plan, ...)`` runs one :class:`~repro.kernels.plan.plan.
QueryPlan` (predicates + projection + optional group-by) over one block in a
single data pass and returns a :class:`~repro.kernels.plan.ref.PlanResult`.
Four equivalent implementations (1e-5 moment parity; histograms carry the
standing bin-edge caveat):

* ``impl="ref"``    -- mask-then-sketch numpy oracle (two passes; the
  baseline the fused paths are benchmarked against).
* ``impl="np"``     -- cache-blocked fused numpy: each row tile is masked,
  projected, moment-folded (f64 accumulators) and histogrammed while hot in
  cache; the fastest CPU path.
* ``impl="jax"``    -- one jit'd fused pass (masked reductions + scatter
  histogram); the accelerator path.
* ``impl="pallas"`` -- the row-tiled TPU kernel (``plan.kernel``): rows
  failing a predicate are masked inside the same VMEM pass as the Chan
  moment fold and histogram scatter.

Kernels are **compiled per plan**: :func:`compile_plan` closes over the
plan's predicates/columns/groups as constants and memoizes on
``(plan.key(), features, bins, impl, tile)`` -- re-running a plan hits the
cache, changing any predicate misses.  ``impl="auto"`` consults the shared
measured autotuner (:mod:`repro.kernels.autotune`) for the winning
(impl, tile) on this machine; with ``REPRO_AUTOTUNE=off`` it pins the
deterministic default (fused numpy @ ``16384`` rows on CPU, jax on
accelerators).
"""

from __future__ import annotations

import threading
import time
from typing import Callable

import numpy as np

from repro import obs
from repro.kernels import autotune
from repro.kernels.autotune import Candidate
from repro.kernels.block_sketch.ops import _inv_width
from repro.kernels.block_sketch.ref import BlockSketch, _grid
from repro.kernels.plan.plan import QueryPlan
from repro.kernels.plan.ref import PlanResult, plan_sketch_ref

IMPLS = ("auto", "ref", "np", "jax", "pallas")

NP_TILES = (8192, 16384, 32768, 65536)
PALLAS_TILES = (128, 256, 512, 1024)
DEFAULT_NP_TILE = 16384  # the pinned REPRO_AUTOTUNE=off choice on CPU

_CACHE: dict[tuple, Callable] = {}
_CACHE_LOCK = threading.Lock()
_HITS = 0
_MISSES = 0


def cache_info() -> dict:
    """Compile-cache counters: ``hits`` / ``misses`` / ``size``."""
    with _CACHE_LOCK:
        return {"hits": _HITS, "misses": _MISSES, "size": len(_CACHE)}


def cache_clear() -> None:
    global _HITS, _MISSES
    with _CACHE_LOCK:
        _CACHE.clear()
        _HITS = _MISSES = 0


# ---------------------------------------------------------------------------
# Implementations (each factory returns run(x32, glo, ghi) -> PlanResult)
# ---------------------------------------------------------------------------

def _result(plan, fp, bins, glo, ghi, *, nsel, n, cnt, mean, m2, mn, mx, hist):
    """Assemble numpy per-group stats into a PlanResult."""
    sketches = []
    for g in range(plan.groups):
        sketches.append(
            BlockSketch(
                count=float(cnt[g]),
                mean=np.asarray(mean[g], np.float64),
                m2=np.maximum(np.asarray(m2[g], np.float64), 0.0),
                min=np.asarray(mn[g], np.float64),
                max=np.asarray(mx[g], np.float64),
                hist=None if bins == 0 else np.asarray(hist[g], np.int64),
                lo=glo,
                hi=ghi,
            )
        )
    return PlanResult(rows_total=int(n), rows_selected=int(nsel), sketches=sketches)


def _build_ref(plan, f, bins):
    def run(x, glo, ghi):
        lo = 0.0 if glo is None else glo
        hi = 1.0 if ghi is None else ghi
        return plan_sketch_ref(x, plan, bins=bins, lo=lo, hi=hi)

    return run


_MINMAX_CHUNK = 32


def _minmax_into(a: np.ndarray, mn: np.ndarray, mx: np.ndarray) -> None:
    """Fold columnwise min/max of contiguous ``a`` [k, F] into ``mn``/``mx``.

    numpy's axis-0 reduction over a narrow [k, F] array runs near scalar
    speed; reshaping ``_MINMAX_CHUNK`` rows into one wide row first makes
    the inner reduction SIMD-wide (~12x on 8-feature blocks)."""
    k, f = a.shape
    body = (k // _MINMAX_CHUNK) * _MINMAX_CHUNK
    if body:
        wide = a[:body].reshape(-1, _MINMAX_CHUNK * f)
        np.minimum(mn, wide.min(0).reshape(_MINMAX_CHUNK, f).min(0), out=mn)
        np.maximum(mx, wide.max(0).reshape(_MINMAX_CHUNK, f).max(0), out=mx)
    if body < k:
        np.minimum(mn, a[body:].min(0), out=mn)
        np.maximum(mx, a[body:].max(0), out=mx)


def _build_np(plan, f, bins, tile_rows):
    """Cache-blocked fused numpy path.  Per row tile: predicate mask ->
    ``take`` the survivors -> float32 moment/extrema/histogram work while
    the tile is cache-resident, folded into float64 accumulators across
    tiles (one pass over the block, versus the baseline's mask pass + f64
    per-group sketch passes)."""
    cols = plan.resolve_columns(f)
    project = cols != tuple(range(f))
    cols_arr = np.asarray(cols, np.intp)
    fp = len(cols)
    G = plan.groups
    gcol = None if plan.group_by is None else plan.group_by % f
    preds = plan.predicates
    offs32 = np.arange(fp, dtype=np.int32) * bins

    def run(x, glo, ghi):
        n = x.shape[0]
        cnt = np.zeros(G)
        s = np.zeros((G, fp))
        ss = np.zeros((G, fp))
        mn = np.full((G, fp), np.inf, np.float32)
        mx = np.full((G, fp), -np.inf, np.float32)
        hist = np.zeros(G * fp * bins, np.int64) if bins else None
        if bins:
            lo32 = glo.astype(np.float32)
            invw32 = _inv_width(glo, ghi, bins).astype(np.float32)
        nsel = 0
        for start in range(0, n, tile_rows):
            t = x[start : start + tile_rows]
            if preds:
                m = preds[0].mask(t)
                for p in preds[1:]:
                    m &= p.mask(t)
                idxs = np.flatnonzero(m)
                if idxs.shape[0] == 0:
                    continue
                sel = np.take(t, idxs, axis=0)
            else:
                sel = np.ascontiguousarray(t)
            nsel += sel.shape[0]
            if gcol is not None:
                lab = sel[:, gcol].astype(np.int32)
                ok = (lab >= 0) & (lab < G)
                if not ok.all():
                    sel = sel[ok]
                    lab = lab[ok]
                    if sel.shape[0] == 0:
                        continue
            selp = np.take(sel, cols_arr, axis=1) if project else sel
            sq = selp * selp
            if G == 1:
                cnt[0] += selp.shape[0]
                s[0] += selp.sum(0)   # f32 pairwise per tile, f64 across tiles
                ss[0] += sq.sum(0)
                _minmax_into(selp, mn[0], mx[0])
            else:
                for g in range(G):
                    gi = np.flatnonzero(lab == g)
                    if gi.shape[0] == 0:
                        continue
                    sub = np.take(selp, gi, axis=0)
                    cnt[g] += sub.shape[0]
                    s[g] += sub.sum(0)
                    ss[g] += np.take(sq, gi, axis=0).sum(0)
                    _minmax_into(sub, mn[g], mx[g])
            if bins:
                w = selp - lo32
                w *= invw32
                idx = w.astype(np.int32)  # truncation == floor: clip handles < 0
                np.clip(idx, 0, bins - 1, out=idx)
                idx += offs32
                if G > 1:
                    idx += (lab * np.int32(fp * bins))[:, None]
                hist += np.bincount(idx.ravel(), minlength=G * fp * bins)
        mean = s / np.maximum(cnt, 1.0)[:, None]
        m2 = np.maximum(ss - cnt[:, None] * mean**2, 0.0)
        return _result(
            plan, fp, bins, glo, ghi, nsel=nsel, n=n, cnt=cnt, mean=mean, m2=m2,
            mn=mn, mx=mx, hist=None if bins == 0 else hist.reshape(G, fp, bins),
        )

    return run


def _build_jax(plan, f, bins):
    import jax
    import jax.numpy as jnp

    from repro.kernels.plan.kernel import _JNP_OPS

    cols = plan.resolve_columns(f)
    project = cols != tuple(range(f))
    cols_arr = np.asarray(cols, np.int32)
    fp = len(cols)
    G = plan.groups
    gcol = None if plan.group_by is None else plan.group_by % f

    @jax.jit
    def fused(x, lo, invw):
        x = x.astype(jnp.float32)
        m = jnp.ones((x.shape[0],), bool)
        for p in plan.predicates:
            m = jnp.logical_and(m, _JNP_OPS[p.op](x[:, p.column], jnp.float32(p.value)))
        nsel = m.astype(jnp.float32).sum()
        xp = x[:, cols_arr] if project else x
        lab = None if gcol is None else x[:, gcol].astype(jnp.int32)
        outs = []
        for g in range(G):
            mg = m if lab is None else jnp.logical_and(m, lab == g)
            w = mg.astype(jnp.float32)
            cnt = w.sum()
            safe = jnp.maximum(cnt, 1.0)
            mean = (w @ xp) / safe
            m2 = w @ jnp.square(xp - mean)
            mn = jnp.where(mg[:, None], xp, jnp.inf).min(axis=0)
            mx = jnp.where(mg[:, None], xp, -jnp.inf).max(axis=0)
            if bins:
                idx = jnp.clip(
                    jnp.floor((xp - lo) * invw).astype(jnp.int32), 0, bins - 1
                )
                flat = idx + jnp.arange(fp, dtype=jnp.int32) * bins
                hist = (
                    jnp.zeros((fp * bins,), jnp.float32)
                    .at[flat.ravel()]
                    .add(jnp.repeat(w, fp))
                    .reshape(fp, bins)
                )
            else:
                hist = jnp.zeros((fp, 0), jnp.float32)
            outs.append((cnt, mean, m2, mn, mx, hist))
        cnts, means, m2s, mns, mxs, hists = (jnp.stack(v) for v in zip(*outs))
        return nsel, cnts, means, m2s, mns, mxs, hists

    def run(x, glo, ghi):
        import jax.numpy as jnp

        lo = np.zeros(fp) if glo is None else glo
        invw = np.zeros(fp) if bins == 0 else _inv_width(glo, ghi, bins)
        nsel, cnt, mean, m2, mn, mx, hist = fused(
            jnp.asarray(x), jnp.asarray(lo, jnp.float32), jnp.asarray(invw, jnp.float32)
        )
        return _result(
            plan, fp, bins, glo, ghi, nsel=float(nsel), n=x.shape[0],
            cnt=np.asarray(cnt, np.float64), mean=np.asarray(mean, np.float64),
            m2=np.asarray(m2, np.float64), mn=np.asarray(mn, np.float64),
            mx=np.asarray(mx, np.float64),
            hist=None if bins == 0 else np.rint(np.asarray(hist)).astype(np.int64),
        )

    return run


def _build_pallas(plan, f, bins, tile_rows, interpret):
    import jax.numpy as jnp

    from repro.kernels.plan.kernel import plan_sketch_pallas

    cols = plan.resolve_columns(f)
    fp = len(cols)
    G = plan.groups

    def run(x, glo, ghi):
        stats, hist, nsel = plan_sketch_pallas(
            jnp.asarray(x),
            jnp.asarray(glo),
            jnp.asarray(_inv_width(glo, ghi, bins)),
            plan=plan,
            bins=bins,
            tile_rows=tile_rows,
            interpret=interpret,
        )
        stats = np.asarray(stats, np.float64).reshape(G, 5, fp)
        hist = np.rint(np.asarray(hist, np.float64)).astype(np.int64)
        return _result(
            plan, fp, bins, glo, ghi, nsel=float(np.asarray(nsel)[0, 0]),
            n=x.shape[0], cnt=stats[:, 0, 0], mean=stats[:, 1], m2=stats[:, 2],
            mn=stats[:, 3], mx=stats[:, 4], hist=hist.reshape(G, fp, bins),
        )

    return run


def compile_plan(
    plan: QueryPlan,
    *,
    num_features: int,
    bins: int = 0,
    impl: str = "np",
    tile_rows: int | None = None,
    interpret: bool = True,
) -> Callable:
    """The compiled executor ``run(x32, glo, ghi) -> PlanResult`` for
    ``plan`` at this shape, memoized on ``(plan.key(), features, bins,
    impl, tile)`` -- the plan-keyed compile cache."""
    global _HITS, _MISSES
    if impl not in IMPLS or impl == "auto":
        raise ValueError(f"compile_plan impl must be concrete, got {impl!r}")
    if impl in ("np", "pallas") and tile_rows is None:
        tile_rows = DEFAULT_NP_TILE if impl == "np" else PALLAS_TILES[0]
    key = (plan.key(), int(num_features), int(bins), impl, tile_rows, bool(interpret))
    telemetry = obs.enabled()
    with _CACHE_LOCK:
        fn = _CACHE.get(key)
        if fn is not None:
            _HITS += 1
            if telemetry:
                obs.get_registry().counter(
                    "rsp_plan_compile_total", "plan-cache lookups", outcome="hit"
                ).inc()
            return fn
    t0 = time.perf_counter()
    if impl == "ref":
        fn = _build_ref(plan, num_features, bins)
    elif impl == "np":
        fn = _build_np(plan, num_features, bins, tile_rows)
    elif impl == "jax":
        fn = _build_jax(plan, num_features, bins)
    else:
        fn = _build_pallas(plan, num_features, bins, tile_rows, interpret)
    if telemetry:
        reg = obs.get_registry()
        reg.counter("rsp_plan_compile_total", "plan-cache lookups", outcome="miss").inc()
        reg.histogram(
            "rsp_plan_compile_seconds", "executor build time on a cache miss",
            impl=impl,
        ).observe(time.perf_counter() - t0)
    with _CACHE_LOCK:
        fn = _CACHE.setdefault(key, fn)
        _MISSES += 1
    return fn


# ---------------------------------------------------------------------------
# Autotuned dispatch
# ---------------------------------------------------------------------------

def _default_candidate() -> Candidate:
    import jax

    if jax.default_backend() == "cpu":
        return Candidate("np", DEFAULT_NP_TILE)
    return Candidate("jax")


def _auto_config(plan, x, glo, ghi, *, bins, interpret) -> Candidate:
    import jax

    n, f = x.shape
    dev = jax.default_backend()
    on_tpu = dev == "tpu"
    cands = [Candidate("np", t) for t in NP_TILES]
    cands.append(Candidate("ref"))
    if dev != "cpu":
        cands.append(Candidate("jax"))
    if bins >= 1:
        # off-TPU these run the Pallas interpreter; flagged so the tuner
        # never crowns a config from interpret-mode timings
        cands += [
            Candidate("pallas", t, interpreted=not on_tpu) for t in PALLAS_TILES
        ]
    key = autotune.shape_key(n, f) + f"|g{plan.groups}p{len(plan.predicates)}c{len(plan.resolve_columns(f))}b{bins}"

    def measure(c: Candidate) -> float:
        fn = compile_plan(
            plan, num_features=f, bins=bins, impl=c.impl, tile_rows=c.tile_rows,
            interpret=interpret and not on_tpu,
        )
        fn(x, glo, ghi)  # warm (jit compile / first-touch) outside the timer
        t0 = time.perf_counter()
        fn(x, glo, ghi)
        return time.perf_counter() - t0

    return autotune.choose(
        "plan_sketch", key, cands, measure, default=_default_candidate()
    )


def plan_sketch(
    block,
    plan: QueryPlan,
    *,
    bins: int = 0,
    lo=0.0,
    hi=1.0,
    impl: str = "auto",
    tile_rows: int | None = None,
    interpret: bool = True,
) -> PlanResult:
    """Execute ``plan`` over one block (any ``[n, ...]`` shape; features
    flatten) in a single fused pass.

    ``bins=0`` skips histograms (``impl="pallas"`` then falls back to the
    jit path, as its kernel always histograms).  ``lo`` / ``hi`` are
    scalars or arrays over the *projected* features.  ``impl="auto"``
    routes through the measured autotuner; an explicit ``tile_rows`` pins
    the tile for the tiled impls.
    """
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r} (one of {IMPLS})")
    x = np.asarray(block, dtype=np.float32).reshape(np.shape(block)[0], -1)
    n, f = x.shape
    fp = len(plan.resolve_columns(f))
    glo = ghi = None
    if bins > 0:
        glo, ghi = _grid(lo, hi, fp)
    if impl == "pallas" and bins == 0:
        impl = "jax"
    if impl == "auto":
        cfg = _auto_config(plan, x, glo, ghi, bins=bins, interpret=interpret)
        impl = cfg.impl
        if tile_rows is None:
            tile_rows = cfg.tile_rows
        if impl == "pallas" and bins == 0:
            impl = "jax"
    fn = compile_plan(
        plan, num_features=f, bins=bins, impl=impl, tile_rows=tile_rows,
        interpret=interpret,
    )
    return fn(x, glo, ghi)
