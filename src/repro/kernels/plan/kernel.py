"""Pallas TPU kernel for plan-compiled fused query execution.

One grid pass over the row tiles of a ``[n, F]`` block does, per tile,
entirely in VMEM:

1. **Predicate mask** -- the plan's conjunctive column comparisons, with
   tile-padding rows masked out alongside the failing rows;
2. **Projection** -- a static one-hot matmul ``x @ P`` onto the plan's
   columns (MXU-friendly; identity plans skip it);
3. **Grouped Chan moments** -- per plan group, masked (count, mean, M2,
   min, max) folded across tiles with the parallel combine;
4. **Histogram scatter** -- the same one-hot-vs-iota trick as
   ``block_sketch.kernel``, weighted by the mask so rejected rows add zero
   mass.

Rows that fail a predicate never leave the tile: there is no second
"apply the mask" pass over HBM, which is the whole point versus the
mask-then-sketch baseline in ``plan.ref``.

Outputs (2D, TPU-friendly):

* ``stats [G * 5, Fp]`` -- per group g, rows ``5g..5g+4`` are (count,
  mean, M2, min, max) over the selected rows of that group;
* ``hist  [G * Fp, B]`` -- per-group per-feature bin counts;
* ``nsel  [1, 1]``      -- total selected rows (all groups, including rows
  whose group label falls outside ``[0, G)``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.moments import chan_merge
from repro.kernels.plan.plan import QueryPlan

_JNP_OPS = {
    "lt": jnp.less,
    "le": jnp.less_equal,
    "gt": jnp.greater,
    "ge": jnp.greater_equal,
    "eq": jnp.equal,
    "ne": jnp.not_equal,
}


def _plan_kernel(
    *refs, plan: QueryPlan, project: bool, valid_rows, tile_rows, bins,
):
    if project:
        x_ref, lo_ref, invw_ref, proj_ref, stats_ref, hist_ref, nsel_ref = refs
    else:
        x_ref, lo_ref, invw_ref, stats_ref, hist_ref, nsel_ref = refs
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                        # [T, F]
    t, f = x.shape
    row = jax.lax.broadcasted_iota(jnp.int32, (t, 1), 0) + i * tile_rows
    mask = row < valid_rows                                   # [T, 1]
    for p in plan.predicates:
        mask = jnp.logical_and(
            mask, _JNP_OPS[p.op](x[:, p.column : p.column + 1], jnp.float32(p.value))
        )
    nsel_t = jnp.sum(mask.astype(jnp.float32))

    xp = x @ proj_ref[...] if project else x                  # [T, Fp]
    fp = xp.shape[1]
    if plan.group_by is not None:
        lab = x[:, plan.group_by : plan.group_by + 1]         # [T, 1] float labels

    groups = []
    for g in range(plan.groups):
        mg = mask
        if plan.group_by is not None:
            mg = jnp.logical_and(mask, lab == jnp.float32(g))
        cnt = jnp.sum(mg.astype(jnp.float32))
        safe_cnt = jnp.maximum(cnt, 1.0)
        xz = jnp.where(mg, xp, 0.0)
        mean_t = xz.sum(axis=0) / safe_cnt                    # [Fp]
        m2_t = jnp.where(mg, (xp - mean_t) ** 2, 0.0).sum(axis=0)
        min_t = jnp.where(mg, xp, jnp.inf).min(axis=0)
        max_t = jnp.where(mg, xp, -jnp.inf).max(axis=0)
        idx = jnp.clip(
            jnp.floor((xp - lo_ref[0]) * invw_ref[0]).astype(jnp.int32), 0, bins - 1
        )                                                     # [T, Fp]
        onehot = idx[:, :, None] == jax.lax.broadcasted_iota(
            jnp.int32, (t, fp, bins), 2
        )
        onehot = jnp.logical_and(onehot, mg[:, :, None])
        hist_t = onehot.astype(jnp.float32).sum(axis=0)       # [Fp, B]
        groups.append((cnt, mean_t, m2_t, min_t, max_t, hist_t))

    @pl.when(i == 0)
    def _init():
        nsel_ref[0, 0] = nsel_t
        for g, (cnt, mean_t, m2_t, min_t, max_t, hist_t) in enumerate(groups):
            stats_ref[5 * g + 0, :] = jnp.full((fp,), cnt, jnp.float32)
            stats_ref[5 * g + 1, :] = mean_t
            stats_ref[5 * g + 2, :] = m2_t
            stats_ref[5 * g + 3, :] = min_t
            stats_ref[5 * g + 4, :] = max_t
            hist_ref[fp * g : fp * (g + 1), :] = hist_t

    @pl.when(i > 0)
    def _fold():
        nsel_ref[0, 0] = nsel_ref[0, 0] + nsel_t
        for g, (cnt, mean_t, m2_t, min_t, max_t, hist_t) in enumerate(groups):
            # shared Chan combine (repro.core.moments), traced with xp=jnp
            n, mean, m2 = chan_merge(
                stats_ref[5 * g + 0, :],
                stats_ref[5 * g + 1, :],
                stats_ref[5 * g + 2, :],
                cnt, mean_t, m2_t,
                xp=jnp,
            )
            stats_ref[5 * g + 0, :] = n
            stats_ref[5 * g + 1, :] = mean
            stats_ref[5 * g + 2, :] = m2
            stats_ref[5 * g + 3, :] = jnp.minimum(stats_ref[5 * g + 3, :], min_t)
            stats_ref[5 * g + 4, :] = jnp.maximum(stats_ref[5 * g + 4, :], max_t)
            hist_ref[fp * g : fp * (g + 1), :] = (
                hist_ref[fp * g : fp * (g + 1), :] + hist_t
            )


def plan_sketch_pallas(
    x: jax.Array,          # [n, F]
    lo: jax.Array,         # [Fp] projected-grid lower edges
    inv_width: jax.Array,  # [Fp] 1 / bin width (0 for constant features)
    *,
    plan: QueryPlan,
    bins: int,
    tile_rows: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Run the fused plan kernel; returns ``(stats [G*5, Fp],
    hist [G*Fp, bins], nsel [1, 1])``.  ``n`` need not divide
    ``tile_rows``; padded rows are masked like failing predicate rows."""
    if x.ndim != 2:
        raise ValueError(f"block must be [n, F], got shape {x.shape}")
    if bins < 1:
        raise ValueError("the fused plan kernel needs bins >= 1")
    n, f = x.shape
    cols = plan.resolve_columns(f)
    proj = None
    if cols != tuple(range(f)):
        proj = np.zeros((f, len(cols)), np.float32)
        proj[list(cols), np.arange(len(cols))] = 1.0
    fp = len(cols)
    g = plan.groups
    n_tiles = max(1, -(-n // tile_rows))
    pad = n_tiles * tile_rows - n
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))

    kernel = functools.partial(
        _plan_kernel, plan=plan, project=proj is not None, valid_rows=n,
        tile_rows=tile_rows, bins=bins,
    )
    in_specs = [
        pl.BlockSpec((tile_rows, f), lambda i: (i, 0)),
        pl.BlockSpec((1, fp), lambda i: (0, 0)),
        pl.BlockSpec((1, fp), lambda i: (0, 0)),
    ]
    inputs = [
        x.astype(jnp.float32),
        lo.reshape(1, fp).astype(jnp.float32),
        inv_width.reshape(1, fp).astype(jnp.float32),
    ]
    if proj is not None:
        in_specs.append(pl.BlockSpec((f, fp), lambda i: (0, 0)))
        inputs.append(jnp.asarray(proj))
    stats, hist, nsel = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((5 * g, fp), lambda i: (0, 0)),
            pl.BlockSpec((fp * g, bins), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((5 * g, fp), jnp.float32),
            jax.ShapeDtypeStruct((fp * g, bins), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs)
    return stats, hist, nsel
