"""Plan-compiled fused query kernels: predicate filter + column projection
+ grouped Chan-moment/histogram sketch in one data pass, compiled per
:class:`QueryPlan` and dispatched through the shared tile autotuner."""

from repro.kernels.plan.ops import (
    IMPLS,
    cache_clear,
    cache_info,
    compile_plan,
    plan_sketch,
)
from repro.kernels.plan.plan import (
    Predicate,
    QueryPlan,
    as_predicates,
    parse_predicate,
)
from repro.kernels.plan.ref import PlanResult, empty_sketch, plan_sketch_ref

__all__ = [
    "IMPLS",
    "PlanResult",
    "Predicate",
    "QueryPlan",
    "as_predicates",
    "cache_clear",
    "cache_info",
    "compile_plan",
    "empty_sketch",
    "parse_predicate",
    "plan_sketch",
    "plan_sketch_ref",
]
