"""Query plans for the fused filter+project+sketch kernels.

A :class:`QueryPlan` is the static description the kernel templates compile
against: column predicates (conjunctive ``where``), a column projection,
and an optional group-by column.  Everything in the plan is baked into the
compiled kernel as constants -- the plan's :meth:`QueryPlan.key` is the
compile-cache key, so changing a predicate value recompiles while repeating
a plan hits the cache.

Predicate semantics are defined on the **float32 view** of the block (every
execution path -- numpy reference included -- evaluates predicates after an
``astype(float32)``), so a value that straddles the f32 rounding of the
threshold cannot flip between implementations.  Projections select columns
*after* filtering; ``group_by`` always indexes the original (pre-projection)
feature space, like ``RSPDataset.label_column`` does.
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

OPS = ("lt", "le", "gt", "ge", "eq", "ne")
_NUMPY_OPS = {
    "lt": np.less,
    "le": np.less_equal,
    "gt": np.greater,
    "ge": np.greater_equal,
    "eq": np.equal,
    "ne": np.not_equal,
}
_SYMBOLS = {"<": "lt", "<=": "le", ">": "gt", ">=": "ge", "==": "eq", "!=": "ne"}

_PRED_RE = re.compile(
    r"^\s*(?:c|col)?(\d+)\s*(<=|>=|==|!=|<|>)\s*([-+]?[0-9]*\.?[0-9]+(?:[eE][-+]?[0-9]+)?)\s*$"
)


@dataclasses.dataclass(frozen=True)
class Predicate:
    """One column comparison: ``column <op> value`` with ``op`` one of
    ``lt | le | gt | ge | eq | ne`` (symbols accepted and normalized)."""

    column: int
    op: str
    value: float

    def __post_init__(self):
        op = _SYMBOLS.get(self.op, self.op)
        if op not in OPS:
            raise ValueError(f"unknown predicate op {self.op!r} (one of {OPS} or symbols)")
        object.__setattr__(self, "op", op)
        object.__setattr__(self, "column", int(self.column))
        object.__setattr__(self, "value", float(self.value))
        if self.column < 0:
            raise ValueError("predicate column must be >= 0")

    def mask(self, x: np.ndarray) -> np.ndarray:
        """Boolean row mask over ``x`` [n, F] (float32 comparison)."""
        return _NUMPY_OPS[self.op](x[:, self.column], np.float32(self.value))

    def __str__(self) -> str:
        sym = {v: k for k, v in _SYMBOLS.items()}[self.op]
        return f"c{self.column} {sym} {self.value:g}"


def parse_predicate(spec) -> Predicate:
    """``"c3 > 0.5"`` / ``"0 <= 1e-2"`` / ``(3, ">", 0.5)`` /
    ``Predicate`` -> :class:`Predicate`."""
    if isinstance(spec, Predicate):
        return spec
    if isinstance(spec, str):
        m = _PRED_RE.match(spec)
        if not m:
            raise ValueError(
                f"cannot parse predicate {spec!r} (expected e.g. 'c3 > 0.5')"
            )
        return Predicate(int(m.group(1)), m.group(2), float(m.group(3)))
    if isinstance(spec, (tuple, list)) and len(spec) == 3:
        return Predicate(int(spec[0]), str(spec[1]), float(spec[2]))
    raise TypeError(f"cannot build a Predicate from {type(spec).__name__}")


def as_predicates(where) -> tuple[Predicate, ...]:
    """Normalize a ``where=`` argument -- ``None``, one predicate spec, or a
    sequence of them -- into a tuple of :class:`Predicate` (AND semantics)."""
    if where is None:
        return ()
    if isinstance(where, (str, Predicate)):
        return (parse_predicate(where),)
    if isinstance(where, (tuple, list)):
        if len(where) == 3 and isinstance(where[0], (int, np.integer)):
            return (parse_predicate(where),)
        return tuple(parse_predicate(p) for p in where)
    raise TypeError(f"cannot build predicates from {type(where).__name__}")


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """The static shape of one fused block pass.

    ``predicates`` AND together (empty = all rows).  ``columns`` projects
    the sketch onto those original-space columns (``None`` = all).
    ``group_by``/``num_classes`` produce one sketch per class from the
    ``group_by`` column of the *original* feature space; ungrouped plans
    leave ``group_by=None`` and ``num_classes=1``.
    """

    predicates: tuple[Predicate, ...] = ()
    columns: tuple[int, ...] | None = None
    group_by: int | None = None
    num_classes: int = 1

    def __post_init__(self):
        object.__setattr__(self, "predicates", as_predicates(self.predicates))
        if self.columns is not None:
            object.__setattr__(
                self, "columns", tuple(int(c) for c in self.columns)
            )
            if len(self.columns) == 0:
                raise ValueError("columns= must name at least one column")
        if self.group_by is None:
            if self.num_classes != 1:
                raise ValueError("num_classes needs group_by (or must be 1)")
        elif self.num_classes < 1:
            raise ValueError("grouped plans need num_classes >= 1")

    @property
    def groups(self) -> int:
        return self.num_classes if self.group_by is not None else 1

    @property
    def filtered(self) -> bool:
        return bool(self.predicates)

    def key(self) -> tuple:
        """Hashable identity for the compile cache: two plans with the same
        key compile to the same kernel."""
        return (
            tuple((p.column, p.op, p.value) for p in self.predicates),
            self.columns,
            self.group_by,
            self.num_classes,
        )

    def resolve_columns(self, num_features: int) -> tuple[int, ...]:
        """The projected column indices against an ``[n, F]`` block."""
        if self.columns is None:
            return tuple(range(num_features))
        cols = tuple(c % num_features for c in self.columns)
        return cols

    def mask(self, x: np.ndarray) -> np.ndarray:
        """AND of all predicate masks over float32 ``x`` [n, F]."""
        if not self.predicates:
            return np.ones(x.shape[0], dtype=bool)
        m = self.predicates[0].mask(x)
        for p in self.predicates[1:]:
            m &= p.mask(x)
        return m
