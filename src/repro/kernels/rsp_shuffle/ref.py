"""Oracle: the hierarchical permutation as a flat row gather."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def flat_indices(tile_perm: np.ndarray, intra_perm: np.ndarray, tile_rows: int) -> np.ndarray:
    """Expand (tile_perm, intra_perm) to the equivalent flat row gather."""
    n_tiles = tile_perm.shape[0]
    out = np.empty(n_tiles * tile_rows, dtype=np.int64)
    for i in range(n_tiles):
        src = tile_perm[i] * tile_rows
        out[i * tile_rows : (i + 1) * tile_rows] = src + intra_perm[i]
    return out


def rsp_shuffle_ref(x, tile_perm, intra_perm, *, tile_rows: int):
    idx = flat_indices(np.asarray(tile_perm), np.asarray(intra_perm), tile_rows)
    return jnp.asarray(np.asarray(x)[idx])
