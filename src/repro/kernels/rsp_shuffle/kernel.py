"""Pallas TPU kernel for RSP block randomization (the paper's partitioning
hot spot, Fig. 1).

TPU adaptation of Algorithm 1's record shuffle: a *hierarchical* permutation
  out_tile[i] = P_i  @  in_tile[tile_perm[i]]
where
  * ``tile_perm`` (scalar-prefetched) drives the BlockSpec index_map -- the
    delta-slice dealing between blocks becomes pure DMA scheduling; rows are
    moved HBM->VMEM tile-by-tile, never row-at-a-time (XLA's gather lowers
    row-at-a-time dynamic slices, which is what makes naive shuffles slow).
  * ``P_i`` is the intra-tile permutation applied as a one-hot matmul on the
    MXU (a [T, T] x [T, D] matmul per tile -- cheap, and avoids unsupported
    in-VMEM vector gathers).

The composition (tile dealing o intra-tile shuffle) is a bijection and is
exactly the structure Algorithm 1 needs: locally randomize, slice into
delta-chunks, deal chunks to output blocks (Lemma 1 applies at slice
granularity).  ``ref.py`` is the equivalent flat row-gather.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _shuffle_kernel(tile_perm_ref, intra_ref, x_ref, o_ref):
    del tile_perm_ref  # consumed by the index_map
    tile = x_ref[...]                       # [T, D] (gathered tile)
    perm = intra_ref[0]                     # [T] int32
    T = tile.shape[0]
    # one-hot permutation matrix on the MXU: onehot[r, c] = (c == perm[r])
    cols = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    onehot = (cols == perm[:, None]).astype(tile.dtype)
    o_ref[...] = jax.lax.dot_general(
        onehot, tile, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def rsp_shuffle_pallas(
    x: jax.Array,           # [R, D]   R = num_tiles * tile_rows
    tile_perm: jax.Array,   # [num_tiles] int32 -- source tile for output tile i
    intra_perm: jax.Array,  # [num_tiles, T] int32 -- row perm within each tile
    *,
    tile_rows: int,
    interpret: bool = True,
) -> jax.Array:
    R, D = x.shape
    if R % tile_rows:
        raise ValueError(f"rows {R} must be divisible by tile_rows {tile_rows}")
    n_tiles = R // tile_rows

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile_rows), lambda i, tp: (i, 0)),
            pl.BlockSpec((tile_rows, D), lambda i, tp: (tp[i], 0)),
        ],
        out_specs=pl.BlockSpec((tile_rows, D), lambda i, tp: (i, 0)),
        scratch_shapes=[],
    )
    return pl.pallas_call(
        _shuffle_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(tile_perm.astype(jnp.int32), intra_perm.astype(jnp.int32), x)
