"""jit'd wrapper: generate the hierarchical permutation from a PRNG key and
apply the kernel.  ``rsp_randomize_block`` is the on-device realization of
Algorithm 1's per-block randomize step."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rsp_shuffle.kernel import rsp_shuffle_pallas


def make_permutations(key: jax.Array, n_tiles: int, tile_rows: int):
    k1, k2 = jax.random.split(key)
    tile_perm = jax.random.permutation(k1, n_tiles).astype(jnp.int32)
    intra = jax.vmap(lambda k: jax.random.permutation(k, tile_rows))(
        jax.random.split(k2, n_tiles)
    ).astype(jnp.int32)
    return tile_perm, intra


@functools.partial(jax.jit, static_argnames=("tile_rows", "interpret"))
def rsp_randomize_block(
    x: jax.Array, key: jax.Array, *, tile_rows: int = 256, interpret: bool = True
) -> jax.Array:
    """Randomize one original block [R, D] on-device (hierarchical shuffle)."""
    R = x.shape[0]
    if R % tile_rows:
        raise ValueError(f"R={R} must be divisible by tile_rows={tile_rows}")
    tile_perm, intra = make_permutations(key, R // tile_rows, tile_rows)
    return rsp_shuffle_pallas(x, tile_perm, intra, tile_rows=tile_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile_rows", "interpret"))
def rsp_shuffle(
    x: jax.Array,
    tile_perm: jax.Array,
    intra_perm: jax.Array,
    *,
    tile_rows: int,
    interpret: bool = True,
) -> jax.Array:
    return rsp_shuffle_pallas(x, tile_perm, intra_perm, tile_rows=tile_rows, interpret=interpret)
