"""jit'd wrapper: generate the hierarchical permutation from a PRNG key and
apply the kernel.  ``rsp_randomize_block`` is the on-device realization of
Algorithm 1's per-block randomize step.

``tile_rows=None`` (the default) asks the shared autotuner for the fastest
tile among the divisors of ``R``; passing an explicit tile pins it -- the
partition backends do exactly that (``tile_rows=delta``) because the tile
*is* part of the permutation's definition there, and retuning would change
which rows land in which RSP block."""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.autotune import Candidate
from repro.kernels.rsp_shuffle.kernel import rsp_shuffle_pallas

SHUFFLE_TILES = (64, 128, 256, 512, 1024)
DEFAULT_SHUFFLE_TILE = 256


def make_permutations(key: jax.Array, n_tiles: int, tile_rows: int):
    k1, k2 = jax.random.split(key)
    tile_perm = jax.random.permutation(k1, n_tiles).astype(jnp.int32)
    intra = jax.vmap(lambda k: jax.random.permutation(k, tile_rows))(
        jax.random.split(k2, n_tiles)
    ).astype(jnp.int32)
    return tile_perm, intra


@functools.partial(jax.jit, static_argnames=("tile_rows", "interpret"))
def _randomize(x: jax.Array, key: jax.Array, *, tile_rows: int, interpret: bool) -> jax.Array:
    R = x.shape[0]
    if R % tile_rows:
        raise ValueError(f"R={R} must be divisible by tile_rows={tile_rows}")
    tile_perm, intra = make_permutations(key, R // tile_rows, tile_rows)
    return rsp_shuffle_pallas(x, tile_perm, intra, tile_rows=tile_rows, interpret=interpret)


def _auto_tile(x: jax.Array, *, interpret: bool) -> int:
    """Tuner-backed shuffle tile: fastest divisor of ``R`` at this shape.

    Off-TPU the kernel runs in Pallas interpret mode, so every candidate is
    flagged ``interpreted`` and the tuner falls back to the deterministic
    default instead of crowning a config from interpreter timings."""
    R, d = int(x.shape[0]), int(x.shape[1]) if x.ndim > 1 else 1
    valid = [t for t in SHUFFLE_TILES if R % t == 0]
    if not valid:
        raise ValueError(
            f"no tile in {SHUFFLE_TILES} divides R={R}; pass tile_rows explicitly"
        )
    default_tile = DEFAULT_SHUFFLE_TILE if R % DEFAULT_SHUFFLE_TILE == 0 else valid[-1]
    on_tpu = jax.default_backend() == "tpu"
    cands = [Candidate("pallas", t, interpreted=not on_tpu) for t in valid]

    def measure(c: Candidate) -> float:
        key = jax.random.PRNGKey(0)
        _randomize(x, key, tile_rows=c.tile_rows, interpret=interpret).block_until_ready()
        t0 = time.perf_counter()
        _randomize(x, key, tile_rows=c.tile_rows, interpret=interpret).block_until_ready()
        return time.perf_counter() - t0

    cfg = autotune.choose(
        "rsp_shuffle", autotune.shape_key(R, d), cands, measure,
        default=Candidate("pallas", default_tile),
    )
    return cfg.tile_rows if cfg.tile_rows in valid else default_tile


def rsp_randomize_block(
    x: jax.Array, key: jax.Array, *, tile_rows: int | None = None, interpret: bool = True
) -> jax.Array:
    """Randomize one original block [R, D] on-device (hierarchical shuffle).

    ``tile_rows=None`` autotunes over the divisors of ``R``; an explicit
    tile is honored verbatim (and is part of the shuffle's definition --
    two calls with different tiles produce different permutations)."""
    if tile_rows is None:
        tile_rows = _auto_tile(x, interpret=interpret)
    return _randomize(x, key, tile_rows=tile_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile_rows", "interpret"))
def rsp_shuffle(
    x: jax.Array,
    tile_perm: jax.Array,
    intra_perm: jax.Array,
    *,
    tile_rows: int,
    interpret: bool = True,
) -> jax.Array:
    return rsp_shuffle_pallas(x, tile_perm, intra_perm, tile_rows=tile_rows, interpret=interpret)
