"""Pallas TPU kernel for the fused per-block sketch (moments + histogram).

The query layer touches every record of a fetched block exactly once; doing
moments and the quantile histogram in *separate* passes doubles the HBM
traffic of the hot loop.  This kernel fuses them: the grid walks row tiles of
a ``[n, F]`` block, each step computes the tile's stable (mean, M2) moments,
extrema, and a per-feature fixed-grid histogram entirely in VMEM, then folds
them into the running outputs -- moments via the Chan parallel combine
(numerically stable across tiles), histogram by addition, extrema by
min/max.  One pass over HBM, two small resident outputs:

  * ``stats [5, F]``  -- rows (count, mean, M2, min, max)
  * ``hist  [F, B]``  -- per-feature bin counts (out-of-range mass clipped
    into the edge bins, so the histogram always sums to ``n``)

Rows past ``n`` (tile padding) are masked out of every reduction.  The bin
index is ``clip(floor((x - lo) * inv_width), 0, B-1)`` with per-feature
``lo`` / ``inv_width`` carried as ``[1, F]`` inputs; a constant feature
(``inv_width = 0``) lands all its mass in bin 0, matching ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.moments import chan_merge


def _sketch_kernel(
    x_ref, lo_ref, invw_ref, stats_ref, hist_ref, *, valid_rows, tile_rows, bins
):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                       # [T, F]
    t, f = x.shape
    row = jax.lax.broadcasted_iota(jnp.int32, (t, 1), 0) + i * tile_rows
    valid = row < valid_rows                                  # [T, 1]
    cnt = jnp.sum(valid.astype(jnp.float32))
    safe_cnt = jnp.maximum(cnt, 1.0)

    xz = jnp.where(valid, x, 0.0)
    mean_t = xz.sum(axis=0) / safe_cnt                        # [F]
    m2_t = jnp.where(valid, (x - mean_t) ** 2, 0.0).sum(axis=0)
    min_t = jnp.where(valid, x, jnp.inf).min(axis=0)
    max_t = jnp.where(valid, x, -jnp.inf).max(axis=0)

    idx = jnp.clip(
        jnp.floor((x - lo_ref[0]) * invw_ref[0]).astype(jnp.int32), 0, bins - 1
    )                                                         # [T, F]
    onehot = (idx[:, :, None] == jax.lax.broadcasted_iota(jnp.int32, (t, f, bins), 2))
    onehot = jnp.logical_and(onehot, valid[:, :, None])
    hist_t = onehot.astype(jnp.float32).sum(axis=0)           # [F, B]

    @pl.when(i == 0)
    def _init():
        stats_ref[0, :] = jnp.full((f,), cnt, jnp.float32)
        stats_ref[1, :] = mean_t
        stats_ref[2, :] = m2_t
        stats_ref[3, :] = min_t
        stats_ref[4, :] = max_t
        hist_ref[...] = hist_t

    @pl.when(i > 0)
    def _fold():
        # the one shared Chan combine (repro.core.moments), traced with xp=jnp
        n, mean, m2 = chan_merge(
            stats_ref[0, :], stats_ref[1, :], stats_ref[2, :],
            cnt, mean_t, m2_t,
            xp=jnp,
        )
        stats_ref[0, :] = n
        stats_ref[1, :] = mean
        stats_ref[2, :] = m2
        stats_ref[3, :] = jnp.minimum(stats_ref[3, :], min_t)
        stats_ref[4, :] = jnp.maximum(stats_ref[4, :], max_t)
        hist_ref[...] = hist_ref[...] + hist_t


def block_sketch_pallas(
    x: jax.Array,        # [n, F]
    lo: jax.Array,       # [F] per-feature grid lower edge
    inv_width: jax.Array,  # [F] 1 / bin_width (0 for constant features)
    *,
    bins: int,
    tile_rows: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Run the fused sketch kernel; returns ``(stats [5, F], hist [F, bins])``.

    ``n`` need not divide ``tile_rows`` -- the input is zero-padded to a tile
    multiple and padded rows are masked inside the kernel.
    """
    if x.ndim != 2:
        raise ValueError(f"block must be [n, F], got shape {x.shape}")
    if bins < 1:
        raise ValueError("the fused kernel needs bins >= 1")
    n, f = x.shape
    n_tiles = max(1, -(-n // tile_rows))
    pad = n_tiles * tile_rows - n
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))

    kernel = functools.partial(
        _sketch_kernel, valid_rows=n, tile_rows=tile_rows, bins=bins
    )
    stats, hist = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile_rows, f), lambda i: (i, 0)),
            pl.BlockSpec((1, f), lambda i: (0, 0)),
            pl.BlockSpec((1, f), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((5, f), lambda i: (0, 0)),
            pl.BlockSpec((f, bins), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((5, f), jnp.float32),
            jax.ShapeDtypeStruct((f, bins), jnp.float32),
        ],
        interpret=interpret,
    )(
        x.astype(jnp.float32),
        lo.reshape(1, f).astype(jnp.float32),
        inv_width.reshape(1, f).astype(jnp.float32),
    )
    return stats, hist
