"""jit'd wrappers and the impl dispatcher for the fused block sketch.

Three equivalent paths (``1e-5``-agreeing on the same block; the one caveat
is values lying *exactly on a bin edge* -- discrete/integer columns -- which
the float32 jax/pallas paths and the float64 ref path may assign to adjacent
bins, moving a downstream quantile by at most one bin width):

* ``impl="ref"``    -- plain numpy (float64), the oracle.
* ``impl="jax"``    -- one jit'd fused pass (scatter-add histogram); vmap'd
  batch variant for stacked blocks.
* ``impl="pallas"`` -- the tiled TPU kernel (interpret=True off-TPU), moments
  folded Chan-style across row tiles in VMEM.

``impl="auto"`` consults the shared measured autotuner
(:mod:`repro.kernels.autotune`): the first call at a shape benchmarks the
candidate (impl, tile) grid and persists the winner; with
``REPRO_AUTOTUNE=off`` it pins the deterministic default (numpy oracle on
CPU hosts -- XLA's scatter-add histogram lowers poorly there -- and the
jit'd jax path on accelerators).  All paths return the numpy
:class:`~repro.kernels.block_sketch.ref.BlockSketch`.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune
from repro.kernels.autotune import Candidate
from repro.kernels.block_sketch.kernel import block_sketch_pallas
from repro.kernels.block_sketch.ref import BlockSketch, _grid, block_sketch_ref

IMPLS = ("auto", "ref", "jax", "pallas")

PALLAS_TILES = (128, 256, 512, 1024)
DEFAULT_TILE = 128  # legacy hardcoded tile; now only the explicit-impl fallback


@functools.partial(jax.jit, static_argnames=("bins",))
def _sketch_jax(x: jax.Array, lo: jax.Array, inv_width: jax.Array, *, bins: int):
    """Fused one-pass sketch of ``x`` [n, F]; returns (mean, m2, min, max,
    hist) with ``hist`` empty when ``bins == 0``."""
    x = x.astype(jnp.float32)
    n, f = x.shape
    mean = x.mean(axis=0)
    m2 = ((x - mean) ** 2).sum(axis=0)
    mn = x.min(axis=0)
    mx = x.max(axis=0)
    if bins == 0:
        return mean, m2, mn, mx, jnp.zeros((f, 0), jnp.float32)
    idx = jnp.clip(jnp.floor((x - lo) * inv_width).astype(jnp.int32), 0, bins - 1)
    flat = idx + jnp.arange(f, dtype=jnp.int32) * bins
    hist = jnp.zeros((f * bins,), jnp.float32).at[flat.ravel()].add(1.0)
    return mean, m2, mn, mx, hist.reshape(f, bins)


@functools.partial(jax.jit, static_argnames=("bins",))
def batched_block_sketch(blocks: jax.Array, lo: jax.Array, inv_width: jax.Array, *, bins: int):
    """vmap'd fused sketch for stacked blocks [g, n, F] -> per-block sketches."""
    return jax.vmap(lambda b: _sketch_jax(b, lo, inv_width, bins=bins))(blocks)


def _inv_width(lo: np.ndarray, hi: np.ndarray, bins: int) -> np.ndarray:
    width = (hi - lo) / max(bins, 1)
    return np.where(width > 0, 1.0 / np.where(width > 0, width, 1.0), 0.0)


def _auto_config(block, *, bins, lo, hi, interpret) -> Candidate:
    """Tuner-backed (impl, tile) choice for this block's shape bucket."""
    dev = jax.default_backend()
    default = Candidate("ref") if dev == "cpu" else Candidate("jax")
    shape = np.shape(block)
    n = int(shape[0]) if shape else 0
    f = int(np.prod(shape[1:])) if len(shape) > 1 else 1
    cands = [Candidate("ref"), Candidate("jax")]
    if bins >= 1:
        on_tpu = dev == "tpu"
        # off-TPU the Pallas kernel runs interpreted; flagged so the tuner
        # never crowns a config from interpret-mode timings
        cands += [Candidate("pallas", t, interpreted=not on_tpu) for t in PALLAS_TILES]

    def measure(c: Candidate) -> float:
        run = lambda: block_sketch(  # noqa: E731
            block, bins=bins, lo=lo, hi=hi, impl=c.impl,
            tile_rows=c.tile_rows, interpret=interpret,
        )
        run()  # warm (jit compile / first-touch) outside the timer
        t0 = time.perf_counter()
        run()
        return time.perf_counter() - t0

    key = autotune.shape_key(n, f) + f"|b{bins}"
    return autotune.choose("block_sketch", key, cands, measure, default=default)


def block_sketch(
    block,
    *,
    bins: int = 0,
    lo=0.0,
    hi=1.0,
    impl: str = "auto",
    tile_rows: int | None = None,
    interpret: bool = True,
) -> BlockSketch:
    """Fused sketch of one block (any shape ``[n, ...]``; features flatten).

    ``bins=0`` skips the histogram (moments-only fast path; ref/jax only --
    the Pallas kernel always produces a histogram, so ``impl="pallas"`` needs
    ``bins >= 1``).  ``lo`` / ``hi`` are scalars or per-feature arrays.
    ``impl="auto"`` routes through the measured autotuner; an explicit
    ``tile_rows`` pins the Pallas tile.
    """
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r} (one of {IMPLS})")
    if impl == "auto":
        cfg = _auto_config(block, bins=bins, lo=lo, hi=hi, interpret=interpret)
        impl = cfg.impl
        if tile_rows is None:
            tile_rows = cfg.tile_rows
    if tile_rows is None:
        tile_rows = DEFAULT_TILE
    if impl == "ref":
        return block_sketch_ref(block, bins=bins, lo=lo, hi=hi)
    x = np.asarray(block, dtype=np.float32).reshape(np.shape(block)[0], -1)
    glo, ghi = _grid(lo, hi, x.shape[1])
    if impl == "pallas":
        if bins < 1:
            raise ValueError("impl='pallas' needs bins >= 1")
        stats, hist = block_sketch_pallas(
            jnp.asarray(x),
            jnp.asarray(glo),
            jnp.asarray(_inv_width(glo, ghi, bins)),
            bins=bins,
            tile_rows=tile_rows,
            interpret=interpret,
        )
        stats = np.asarray(stats, dtype=np.float64)
        return BlockSketch(
            count=float(stats[0, 0]),
            mean=stats[1],
            m2=stats[2],
            min=stats[3],
            max=stats[4],
            hist=np.asarray(np.rint(np.asarray(hist)), dtype=np.int64),
            lo=glo,
            hi=ghi,
        )
    mean, m2, mn, mx, hist = _sketch_jax(
        jnp.asarray(x),
        jnp.asarray(glo, dtype=jnp.float32),
        jnp.asarray(_inv_width(glo, ghi, bins), dtype=jnp.float32),
        bins=bins,
    )
    return BlockSketch(
        count=float(x.shape[0]),
        mean=np.asarray(mean, dtype=np.float64),
        m2=np.asarray(m2, dtype=np.float64),
        min=np.asarray(mn, dtype=np.float64),
        max=np.asarray(mx, dtype=np.float64),
        hist=None if bins == 0 else np.asarray(np.rint(np.asarray(hist)), np.int64),
        lo=None if bins == 0 else glo,
        hi=None if bins == 0 else ghi,
    )
