"""Oracle: the fused per-block sketch as plain numpy.

One conceptual pass over a block ``[n, ...]`` produces everything the query
layer needs from it: record count, per-feature mean / M2 / extrema
(Chan-combinable moments) and a per-feature fixed-grid histogram.  Mass
outside ``[lo, hi]`` is *clipped into the edge bins* -- the histogram always
sums to ``n`` per feature, so merged histograms stay consistent with the
merged counts (the silent-mass-drop bias the old ``block_histogram`` had).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class BlockSketch:
    """Combinable one-pass sketch of a single RSP block.

    ``hist`` is ``None`` when the sketch was computed with ``bins=0``
    (moments-only fast path).  ``lo`` / ``hi`` record the per-feature grid the
    histogram was computed on; sketches combine only on identical grids.
    """

    count: float
    mean: np.ndarray                  # [F]
    m2: np.ndarray                    # [F] sum of squared deviations
    min: np.ndarray                   # [F]
    max: np.ndarray                   # [F]
    hist: np.ndarray | None = None    # [F, bins] counts
    lo: np.ndarray | None = None      # [F] grid lower edges
    hi: np.ndarray | None = None      # [F] grid upper edges

    @property
    def variance(self) -> np.ndarray:
        return self.m2 / max(self.count - 1.0, 1.0)

    @property
    def sum(self) -> np.ndarray:
        return self.count * self.mean

    def to_dict(self) -> dict:
        """JSON-safe encoding, exact to the bit: Python's shortest-repr float
        serialization round-trips every finite float64, and the per-array
        dtype is carried so decoding restores identical arrays."""
        def arr(a):
            if a is None:
                return None
            a = np.asarray(a)
            return {"dtype": str(a.dtype), "shape": list(a.shape),
                    "data": a.ravel().tolist()}

        return {
            "count": float(self.count),
            "mean": arr(self.mean), "m2": arr(self.m2),
            "min": arr(self.min), "max": arr(self.max),
            "hist": arr(self.hist), "lo": arr(self.lo), "hi": arr(self.hi),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "BlockSketch":
        def arr(e):
            if e is None:
                return None
            return np.asarray(e["data"], dtype=np.dtype(e["dtype"])).reshape(e["shape"])

        return cls(
            count=float(d["count"]),
            mean=arr(d["mean"]), m2=arr(d["m2"]),
            min=arr(d["min"]), max=arr(d["max"]),
            hist=arr(d.get("hist")), lo=arr(d.get("lo")), hi=arr(d.get("hi")),
        )


def merge_sketches(a: BlockSketch, b: BlockSketch) -> BlockSketch:
    """Chan-style parallel combine of two sketches (histograms add); the
    moment algebra is the shared :func:`repro.core.moments.chan_merge`."""
    from repro.core.moments import chan_merge

    if a.count + b.count <= 0:
        return a
    hist = None
    if a.hist is not None and b.hist is not None:
        hist = a.hist + b.hist
    n, mean, m2 = chan_merge(a.count, a.mean, a.m2, b.count, b.mean, b.m2)
    return BlockSketch(
        count=n,
        mean=mean,
        m2=m2,
        min=np.minimum(a.min, b.min),
        max=np.maximum(a.max, b.max),
        hist=hist,
        lo=a.lo,
        hi=a.hi,
    )


def _grid(lo, hi, num_features: int) -> tuple[np.ndarray, np.ndarray]:
    lo = np.broadcast_to(np.asarray(lo, dtype=np.float64), (num_features,)).copy()
    hi = np.broadcast_to(np.asarray(hi, dtype=np.float64), (num_features,)).copy()
    return lo, hi


def grid_histogram(
    x: np.ndarray, lo: np.ndarray, hi: np.ndarray, bins: int
) -> np.ndarray:
    """Vectorized per-feature fixed-grid histogram of ``x`` [n, F] with
    out-of-range mass clipped into the edge bins."""
    n, f = x.shape
    width = (hi - lo) / bins
    safe = np.where(width > 0, width, 1.0)
    idx = np.clip(np.floor((x - lo) / safe).astype(np.int64), 0, bins - 1)
    flat = idx + np.arange(f, dtype=np.int64) * bins
    return np.bincount(flat.ravel(), minlength=f * bins).reshape(f, bins)


def block_sketch_ref(
    block: np.ndarray,
    *,
    bins: int = 0,
    lo=0.0,
    hi=1.0,
    dtype=np.float64,
) -> BlockSketch:
    """Reference fused sketch: moments + extrema (+ fixed-grid histogram when
    ``bins > 0``) of one block, flattened to ``[n, F]``."""
    x = np.asarray(block, dtype=dtype).reshape(np.shape(block)[0], -1)
    mean = x.mean(axis=0)
    m2 = ((x - mean) ** 2).sum(axis=0)
    sketch = BlockSketch(
        count=float(x.shape[0]),
        mean=mean,
        m2=m2,
        min=x.min(axis=0),
        max=x.max(axis=0),
    )
    if bins > 0:
        sketch.lo, sketch.hi = _grid(lo, hi, x.shape[1])
        sketch.hist = grid_histogram(x, sketch.lo, sketch.hi, bins)
    return sketch
