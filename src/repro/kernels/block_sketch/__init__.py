"""Fused per-block sketch kernel: one pass -> moments + extrema + histogram.

The query subsystem's per-block hot loop (``repro.rsp.query``) and the
partition-time summaries both reduce to this sketch; ``ops.block_sketch``
dispatches between the numpy oracle, the jit'd jax path, and the Pallas TPU
kernel (``ref.py`` / ``ops.py`` / ``kernel.py``).
"""

from repro.kernels.block_sketch.ops import (
    IMPLS,
    batched_block_sketch,
    block_sketch,
)
from repro.kernels.block_sketch.ref import (
    BlockSketch,
    block_sketch_ref,
    grid_histogram,
    merge_sketches,
)

__all__ = [
    "IMPLS",
    "BlockSketch",
    "batched_block_sketch",
    "block_sketch",
    "block_sketch_ref",
    "grid_histogram",
    "merge_sketches",
]
