"""``repro.kernels.autotune`` -- measure-based tile autotuner shared by the
RSP kernels.

Every tiled kernel in the repo used to hardcode its tile size
(``tile_rows=128`` and friends).  The right tile depends on the machine:
cache sizes on CPU hosts, VMEM pressure and grid occupancy on TPUs.  This
module replaces the constants with a tiny measured search:

* On the first ``impl="auto"`` call for a given ``(kernel, shape bucket,
  dtype, device)`` key, each candidate config is timed on the *actual*
  workload (best-of-``repeats``, so a noisy neighbour cannot crown a loser)
  and the fastest wins.
* The winner is persisted to ``results/bench/autotune.json`` (atomic
  rename), so later processes skip the measurement entirely.  Shapes are
  bucketed to the next power of two in rows -- one measurement covers the
  whole bucket.
* **Interpret-mode Pallas timings never decide.**  Off-TPU the Pallas
  kernels run under ``interpret=True``, which measures the interpreter,
  not the kernel; candidates flagged ``interpreted`` are excluded from
  selection (they would otherwise "lose" to numpy by 100x for reasons that
  vanish on real hardware).  If every candidate is excluded the pinned
  default wins and the record says so.
* ``REPRO_AUTOTUNE=off`` (or ``0`` / ``false``) disables measurement
  everywhere: ``choose`` returns the pinned default immediately and
  touches no files.  CI and the tier-1 tests run in this mode, so test
  outcomes never depend on machine-local timings.

Consumers: ``repro.kernels.plan`` (fused query-plan kernels),
``repro.kernels.block_sketch`` (``impl="auto"`` + Pallas tile), and
``repro.kernels.rsp_shuffle`` (``tile_rows=None``).
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Callable, Sequence

_ENV = "REPRO_AUTOTUNE"
_ENV_CACHE = "REPRO_AUTOTUNE_CACHE"
_OFF = ("off", "0", "false", "no")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One tunable configuration: an implementation name plus its tile size
    (``None`` when the impl is untiled).  ``interpreted=True`` marks a
    config whose measurement would time an interpreter (Pallas off-TPU);
    such candidates are never selected from measurements."""

    impl: str
    tile_rows: int | None = None
    interpreted: bool = False

    @property
    def label(self) -> str:
        return self.impl if self.tile_rows is None else f"{self.impl}:{self.tile_rows}"


def enabled() -> bool:
    """Whether measurement is allowed (``REPRO_AUTOTUNE`` not off)."""
    return os.environ.get(_ENV, "on").strip().lower() not in _OFF


def cache_path() -> str:
    """Where winners persist: ``$REPRO_AUTOTUNE_CACHE`` or the repo's
    ``results/bench/autotune.json``."""
    env = os.environ.get(_ENV_CACHE)
    if env:
        return env
    root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))))
    return os.path.join(root, "results", "bench", "autotune.json")


def shape_key(rows: int, features: int, dtype: str = "float32") -> str:
    """Bucket ``rows`` to the next power of two so one measurement covers
    nearby shapes; features and dtype are exact."""
    b = 1 << max(0, int(rows) - 1).bit_length()
    return f"r{b}xf{int(features)}:{dtype}"


def _device() -> str:
    import jax

    return jax.default_backend()


class Autotuner:
    """In-memory + on-disk cache of measured winners (see module docs)."""

    def __init__(self, path: str | None = None):
        self._path = path
        self._lock = threading.RLock()
        self._mem: dict[str, dict] = {}
        self._loaded = False
        self.measurements = 0  # total tuning runs this process (test hook)

    def _file(self) -> str:
        return self._path or cache_path()

    def _load(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        try:
            with open(self._file()) as f:
                disk = json.load(f)
            if isinstance(disk, dict):
                for k, v in disk.items():
                    self._mem.setdefault(k, v)
        except (OSError, ValueError):
            pass

    def _persist(self) -> None:
        path = self._file()
        try:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            disk: dict = {}
            try:
                with open(path) as f:
                    old = json.load(f)
                if isinstance(old, dict):
                    disk.update(old)
            except (OSError, ValueError):
                pass
            disk.update(self._mem)
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump(disk, f, indent=2, sort_keys=True)
                f.write("\n")
            os.replace(tmp, path)
        except OSError:
            pass  # tuning still works this process; it just won't persist

    def clear(self) -> None:
        """Forget every winner (memory and disk)."""
        with self._lock:
            self._mem.clear()
            self._loaded = False
            try:
                os.remove(self._file())
            except OSError:
                pass

    def lookup(self, kernel: str, key: str) -> Candidate | None:
        """The cached winner for ``(kernel, key, device)``, or None."""
        with self._lock:
            self._load()
            rec = self._mem.get(f"{kernel}|{key}|{_device()}")
        if not rec:
            return None
        return Candidate(impl=rec["impl"], tile_rows=rec.get("tile_rows"))

    def choose(
        self,
        kernel: str,
        key: str,
        candidates: Sequence[Candidate],
        measure: Callable[[Candidate], float],
        *,
        default: Candidate,
        repeats: int = 3,
    ) -> Candidate:
        """The winning :class:`Candidate` for ``(kernel, key, device)``.

        With tuning disabled returns ``default`` untouched.  Otherwise the
        cached winner is returned if present; else every non-``interpreted``
        candidate is timed ``repeats`` times via ``measure`` (which returns
        seconds for one run; exceptions disqualify the candidate), the
        best-of-N fastest wins, and the winner persists to
        :func:`cache_path`.  If no candidate is measurable the ``default``
        wins and the record notes the fallback.
        """
        if not enabled():
            return default
        cached = self.lookup(kernel, key)
        if cached is not None:
            return cached
        with self._lock:
            cached = self.lookup(kernel, key)
            if cached is not None:
                return cached
            t_tune = time.perf_counter()
            measured: dict[str, float] = {}
            excluded: list[str] = []
            best: Candidate | None = None
            best_t = float("inf")
            for c in candidates:
                if c.interpreted:
                    excluded.append(f"{c.label} (interpret)")
                    continue
                try:
                    t = min(measure(c) for _ in range(max(1, repeats)))
                except Exception:
                    excluded.append(f"{c.label} (error)")
                    continue
                measured[c.label] = t * 1e6
                if t < best_t:
                    best, best_t = c, t
            self.measurements += 1
            winner = best if best is not None else default
            from repro import obs  # deferred: keep this module import-light

            if obs.enabled():
                reg = obs.get_registry()
                reg.counter(
                    "rsp_autotune_runs_total", "tuning measurement runs",
                    kernel=kernel,
                ).inc()
                reg.histogram(
                    "rsp_autotune_measure_seconds",
                    "wall time spent timing candidates for one tuning run",
                    kernel=kernel,
                ).observe(time.perf_counter() - t_tune)
            rec = {
                "impl": winner.impl,
                "tile_rows": winner.tile_rows,
                "us": None if best is None else best_t * 1e6,
                "measured_us": measured,
                "excluded": excluded,
                "fallback": best is None,
            }
            self._mem[f"{kernel}|{key}|{_device()}"] = rec
            self._persist()
            return winner


_TUNER = Autotuner()


def get_tuner() -> Autotuner:
    return _TUNER


def choose(*args, **kwargs) -> Candidate:
    """Module-level convenience for :meth:`Autotuner.choose` on the shared
    process-wide tuner."""
    return _TUNER.choose(*args, **kwargs)


def clear() -> None:
    _TUNER.clear()
