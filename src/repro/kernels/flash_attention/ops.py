"""jit'd public wrapper for the flash attention kernel.

Accepts grouped-query layout [B, Hkv, G, S, D] (the model's native shape) or
flat [B, H, S, D]; pads head_dim to an MXU-friendly multiple of 128 and picks
block sizes that divide the sequence.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _pick_block(s: int, target: int) -> int:
    b = min(target, s)
    while s % b:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    grouped = q.ndim == 5
    if grouped:
        B, Hkv, G, S, D = q.shape
        qf = q.reshape(B, Hkv * G, S, D)
    else:
        B, H, S, D = q.shape
        qf = q

    # pad head_dim to a lane-aligned multiple (MXU likes 128)
    D = qf.shape[-1]
    scale = 1.0 / (D**0.5)
    pad_d = (-D) % 128 if D > 64 else (-D) % 64
    if pad_d:
        qf = jnp.pad(qf, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad_d)))

    bq = _pick_block(S, block_q)
    bk = _pick_block(S, block_k)
    out = flash_attention_pallas(
        qf, k, v, causal=causal, block_q=bq, block_k=bk, scale=scale, interpret=interpret
    )
    if pad_d:
        out = out[..., :D]
    if grouped:
        return out.reshape(B, Hkv, G, S, D)
    return out
