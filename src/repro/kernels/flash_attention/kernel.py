"""Pallas TPU flash attention (causal / bidirectional, GQA via index maps).

Grid: (B, H, num_q_blocks, num_kv_blocks); the kv dimension is innermost and
sequential, so the online-softmax running state (m, l, acc) lives in VMEM
scratch that persists across kv iterations.  K/V BlockSpecs map query head h
to kv head h // group_size, so grouped heads never materialize expanded K/V.
Causal block-skipping: kv blocks strictly above the diagonal are skipped
(`pl.when`), recovering the ~2x causal FLOP saving the jnp path wastes.

MXU alignment: block_q/block_k default to 128 and head_dim is padded by the
wrapper (ops.py) to a multiple of 128 if needed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, causal: bool, scale: float, block_q: int, block_k: int, nk: int,
):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: the last kv block that can contribute to q block i
    last_j = ((i + 1) * block_q - 1) // block_k if causal else nk - 1

    @pl.when(j <= last_j)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                     # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)                     # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                               # [bq, bk]
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(j == (nk - 1 if not causal else jnp.minimum(last_j, nk - 1)))
    def _write():
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...][:, None], 1e-30)
        ).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,        # [B, H, S, D]
    k: jax.Array,        # [B, Hkv, S, D]
    v: jax.Array,        # [B, Hkv, S, D]
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    scale: float | None = None,
    interpret: bool = True,
) -> jax.Array:
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    if S % block_q or S % block_k:
        raise ValueError(f"S={S} must be divisible by block sizes ({block_q}, {block_k})")
    nq, nk = S // block_q, S // block_k
    if scale is None:
        scale = 1.0 / (D**0.5)

    kernel = functools.partial(
        _fa_kernel, causal=causal, scale=scale, block_q=block_q, block_k=block_k, nk=nk
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # m: running max
            pltpu.VMEM((block_q,), jnp.float32),       # l: running sum
            pltpu.VMEM((block_q, D), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(q, k, v)
