"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array,        # [B, H, S, D]
    k: jax.Array,        # [B, Hkv, S, D]
    v: jax.Array,        # [B, Hkv, S, D]
    *,
    causal: bool = True,
) -> jax.Array:
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (D**0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
