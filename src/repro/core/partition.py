"""Two-stage RSP partitioning (Algorithm 1 of the paper) in three forms.

1. ``two_stage_partition_np``  -- faithful out-of-core-style numpy streaming
   implementation (the HDFS/Spark path of the paper, adapted to local files /
   arrays).  This is the *paper-faithful baseline* used by the Fig-1
   benchmark.
2. ``two_stage_partition_jax`` -- jit-able in-memory implementation: the two
   stages become (vmapped per-block permutation) + (transpose/reshape).
3. ``distributed_rsp_partition`` -- the TPU-native adaptation: Algorithm 1 as
   one ``shard_map`` program whose slice-and-recombine stage is a single
   ``jax.lax.all_to_all`` across the mesh.  Each device holds one original
   block; after the collective, device ``k`` holds RSP block ``k``.

All three produce the same statistical object: a partition ``T = {D_1..D_K}``
where each block is a random sample of ``D`` (Lemma 1).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import RSPSpec

try:
    _shard_map = jax.shard_map
except AttributeError:  # jax < 0.6: shard_map still lives in experimental
    from jax.experimental.shard_map import shard_map as _shard_map

Array = jax.Array


# ---------------------------------------------------------------------------
# Stage helpers
# ---------------------------------------------------------------------------

def _np_rng(seed: int, *stream: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, *stream]))


# ---------------------------------------------------------------------------
# 1. Paper-faithful numpy implementation (streaming-friendly)
# ---------------------------------------------------------------------------

def two_stage_partition_np(
    data: np.ndarray,
    spec: RSPSpec,
    *,
    permute_assignment: bool = True,
) -> np.ndarray:
    """Algorithm 1: returns an array of K RSP blocks, shape [K, n, ...].

    Stage 1 (chunking): ``data`` is viewed as P original blocks in storage
    order.  Stage 2 (randomization): each original block is permuted locally,
    sliced into K sub-blocks of ``delta`` records, and RSP block ``k`` is the
    concatenation of one sub-block drawn *without replacement* from each
    original block (``permute_assignment`` randomizes which sub-block each RSP
    block receives, matching the paper's "select one sub-block from D_i
    without replacement").
    """
    if data.shape[0] != spec.num_records:
        raise ValueError(f"data has {data.shape[0]} records, spec says {spec.num_records}")
    P, K = spec.num_original_blocks, spec.num_blocks
    if spec.num_records % (P * K) != 0:
        # RSPSpec validates this at construction; hand-built spec-like objects
        # get a clear message here instead of an opaque reshape error
        # (mirrors the jax path's divisibility check).
        raise ValueError(
            f"spec unsatisfiable: N={spec.num_records} must be divisible by"
            f" P*K={P * K} (P={P} original blocks x K={K} RSP blocks need"
            " uniform sub-blocks of delta = N/(P*K) records)"
        )
    delta = spec.slice_size
    tail = data.shape[1:]

    out = np.empty((K, spec.block_size, *tail), dtype=data.dtype)
    original = data.reshape(P, spec.original_block_size, *tail)
    for i in range(P):
        rng = _np_rng(spec.seed, 0, i)
        block = original[i][rng.permutation(spec.original_block_size)]
        sub = block.reshape(K, delta, *tail)
        if permute_assignment:
            assign = _np_rng(spec.seed, 1, i).permutation(K)
        else:
            assign = np.arange(K)
        # sub-block assign[k] of original block i -> slice i of RSP block k
        out[:, i * delta : (i + 1) * delta] = sub[assign]
    return out


# ---------------------------------------------------------------------------
# 2. jit-able single-device implementation
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_blocks", "num_original_blocks", "permute_assignment"))
def two_stage_partition_jax(
    data: Array,
    key: Array,
    *,
    num_blocks: int,
    num_original_blocks: int,
    permute_assignment: bool = True,
) -> Array:
    """Algorithm 1 in jnp.  Returns [K, n, ...].

    Stage 2's "permute each original block" is a vmapped
    ``jax.random.permutation``; slice+recombine is a transpose/reshape (the
    memory-movement pattern that ``distributed_rsp_partition`` turns into an
    all_to_all when blocks live on different devices).
    """
    N = data.shape[0]
    P, K = num_original_blocks, num_blocks
    tail = data.shape[1:]
    if N % (P * K) != 0:
        raise ValueError(f"N={N} must be divisible by P*K={P * K}")
    delta = N // (P * K)

    original = data.reshape(P, N // P, *tail)
    perm_keys = jax.random.split(jax.random.fold_in(key, 0), P)
    randomized = jax.vmap(lambda k, b: jax.random.permutation(k, b, axis=0))(
        perm_keys, original
    )
    # [P, K, delta, ...]
    sub = randomized.reshape(P, K, delta, *tail)
    if permute_assignment:
        assign_keys = jax.random.split(jax.random.fold_in(key, 1), P)
        assign = jax.vmap(lambda k: jax.random.permutation(k, K))(assign_keys)
        sub = jax.vmap(lambda s, a: s[a])(sub, assign)
    # recombine: RSP block k = concat over i of sub[i, k]  -> [K, P*delta, ...]
    return sub.transpose(1, 0, 2, *range(3, 3 + len(tail))).reshape(K, P * delta, *tail)


def randomize_dataset(data: Array, key: Array) -> Array:
    """Global randomization (for non-randomized sources; paper Sec. 2)."""
    return jax.random.permutation(key, data, axis=0)


# ---------------------------------------------------------------------------
# 3. Distributed shard_map + all_to_all implementation
# ---------------------------------------------------------------------------

def distributed_rsp_partition(
    data: Array,
    key: Array,
    mesh: jax.sharding.Mesh,
    *,
    axis: str = "data",
    permute_assignment: bool = True,
) -> Array:
    """Algorithm 1 as a collective program over one mesh axis.

    ``data`` is [N, ...] sharded (or shardable) over ``axis`` along dim 0 with
    D devices: device ``i`` holds original block ``i`` (P = D).  Each device
    permutes its shard locally, slices it into D sub-blocks, and a single
    ``all_to_all`` transposes (device, sub-block) so device ``k`` ends with
    RSP block ``k`` (K = D).  The HDFS shuffle-read/write of the paper is
    exactly this collective on the ICI mesh.
    """
    D = mesh.shape[axis]
    N = data.shape[0]
    if N % (D * D) != 0:
        raise ValueError(f"N={N} must be divisible by D^2={D * D} (P=K=D, delta=N/D^2)")
    tail = data.shape[1:]

    in_spec = jax.sharding.PartitionSpec(axis, *(None,) * len(tail))

    def local_fn(shard: Array, key: Array) -> Array:
        # shard: [N/D, ...] -- this device's original block.
        idx = jax.lax.axis_index(axis)
        k = jax.random.fold_in(key, idx)
        block = jax.random.permutation(jax.random.fold_in(k, 0), shard, axis=0)
        sub = block.reshape(D, N // (D * D), *tail)          # D sub-blocks
        if permute_assignment:
            assign = jax.random.permutation(jax.random.fold_in(k, 1), D)
            sub = sub[assign]
        # transpose (device, sub-block): after this, slot j holds the
        # sub-block destined for this device from device j.
        sub = jax.lax.all_to_all(sub[None], axis, split_axis=1, concat_axis=0)[:, 0]
        return sub.reshape(N // D, *tail)

    fn = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(in_spec, jax.sharding.PartitionSpec()),
        out_specs=in_spec,
    )
    out = fn(data, key)
    # [N, ...] where contiguous slabs of n = N/D records are the RSP blocks.
    return out.reshape(D, N // D, *tail)


# ---------------------------------------------------------------------------
# Validation helpers (Definition 2 / Definition 3 empirical checks)
# ---------------------------------------------------------------------------

def _lex_sorted_rows(x: np.ndarray) -> np.ndarray:
    """Rows of ``x`` as a byte matrix, sorted lexicographically as *whole
    rows* -- row (record) identity is preserved, unlike a column-wise sort."""
    x = np.asarray(x)
    n = x.shape[0] if x.ndim else 0
    feat = int(np.prod(x.shape[1:], dtype=np.int64))  # explicit: -1 breaks on n=0
    rows = np.ascontiguousarray(x.reshape(n, feat))
    b = rows.view(np.uint8).reshape(n, -1) if rows.size else rows.view(np.uint8)
    if b.shape[0] <= 1 or b.shape[1] == 0:
        return b  # nothing to sort (and lexsort needs >= 1 key column)
    return b[np.lexsort(b.T[::-1])]


def is_partition(blocks: np.ndarray, data: np.ndarray) -> bool:
    """Definition 2: blocks form a partition of ``data`` (as multisets of
    whole records).  Rows are compared as units: lexicographically sorting
    complete rows keeps record identity, where the per-column sort this
    replaces validated any pair with equal per-column byte multisets."""
    blocks = np.asarray(blocks)
    data = np.asarray(data)
    flat = blocks.reshape(-1, *blocks.shape[2:])
    if flat.shape != data.shape:
        return False
    return bool(np.array_equal(_lex_sorted_rows(flat), _lex_sorted_rows(data)))


def empirical_cdf(x: np.ndarray, thresholds: Sequence[float]) -> np.ndarray:
    """F(t) for each threshold -- used by Lemma-1 style unbiasedness tests."""
    x = np.asarray(x).reshape(-1)
    t = np.asarray(thresholds).reshape(-1, 1)
    return (x[None, :] <= t).mean(axis=1)
