"""The one Chan et al. parallel moment-combine.

Every layer that folds per-block moments -- ``core.estimators``, the
``block_sketch`` reference and Pallas kernels, and the ``rsp.sketch``
suite -- routes through :func:`chan_merge` so the algebra lives in exactly
one place.  The helper is array-namespace generic (``xp=np`` by default,
``xp=jax.numpy`` inside jitted/Pallas code) and operates on the raw
``(count, mean, m2)`` triple so callers can wrap the result in whatever
container they use.
"""

from __future__ import annotations

import numpy as np


def chan_merge(count_a, mean_a, m2_a, count_b, mean_b, m2_b, *, xp=np):
    """Combine two (count, mean, M2) moment triples exactly.

    Chan et al.'s parallel update: order-independent and numerically stable
    for the block-fold sizes used here.  Returns ``(count, mean, m2)``.
    ``xp`` selects the array namespace (``numpy`` or ``jax.numpy``) so the
    same expression serves host folds and traced kernel code; the
    ``maximum(n, 1)`` guard makes the empty+empty merge well-defined
    (returns zeros) instead of dividing by zero.
    """
    n = count_a + count_b
    safe_n = xp.maximum(n, 1.0)
    delta = mean_b - mean_a
    mean = mean_a + delta * (count_b / safe_n)
    m2 = m2_a + m2_b + delta * delta * (count_a * count_b / safe_n)
    return n, mean, m2
