"""repro.core -- the paper's contribution: the Random Sample Partition model.

Public API:
    RSPSpec, SamplerState, BlockDescriptor          (types)
    two_stage_partition_np / _jax, distributed_rsp_partition  (Algorithm 1)
    BlockSampler, deal_blocks, HostAssignment       (Definition 4)
    BlockLevelEstimator, block_moments, combine_moments       (Sec. 8)
    BaseLearner, make_logreg, make_mlp, Ensemble,
    asymptotic_ensemble_learn                       (Algorithm 2, Sec. 9)
    mmd2_rbf, hotelling_t2, ks_statistic            (Sec. 7)
    RSPStore                                        (stored RSP)
"""

from repro.core.types import BlockDescriptor, RSPSpec, SamplerState
from repro.core.partition import (
    distributed_rsp_partition,
    empirical_cdf,
    is_partition,
    randomize_dataset,
    two_stage_partition_jax,
    two_stage_partition_np,
)
from repro.core.sampler import BlockSampler, HostAssignment, deal_blocks
from repro.core.estimators import (
    BlockLevelEstimator,
    MomentStats,
    batched_block_moments,
    block_histogram,
    block_moments,
    combine_moments,
    quantile_from_histogram,
)
from repro.core.ensemble import (
    BaseLearner,
    Ensemble,
    EnsembleHistory,
    asymptotic_ensemble_learn,
    ensemble_vs_single_model,
    make_logreg,
    make_mlp,
    train_base_models_vmapped,
)
from repro.core.similarity import (
    hotelling_t2,
    ks_statistic,
    label_distribution,
    max_label_divergence,
    median_heuristic_gamma,
    mmd2_rbf,
    mmd_block_vs_data,
)
from repro.core.registry import RSPStore
from repro.core.monitor import DriftMonitor, DriftReport

__all__ = [k for k in dir() if not k.startswith("_")]
