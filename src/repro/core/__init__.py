"""repro.core -- the paper's contribution: the Random Sample Partition model.

This is the *low-level* layer.  New code should use the ``repro.rsp`` facade
(``rsp.partition(...) -> RSPDataset``), which wires these pieces into one
chainable pipeline and dispatches partitioning through a backend registry.
The free functions below remain supported as the stable substrate the facade
is built on, but direct wiring of them is a deprecation path: prefer

    repro.rsp.partition / RSPDataset        over  two_stage_partition_* +
                                                  RSPStore + BlockSampler glue
    RSPDataset.save / rsp.open              over  RSPStore.write_partition /
                                                  load_block
    RSPDataset.sample / .moments /          over  BlockSampler +
        .estimate / .ensemble / .similarity       BlockLevelEstimator +
                                                  asymptotic_ensemble_learn +
                                                  mmd/ks call sites

API map (paper reference in parentheses):

  types        RSPSpec, SamplerState, BlockDescriptor
  partition    two_stage_partition_np   -- streaming numpy (Algorithm 1)
               two_stage_partition_jax  -- jit in-memory (Algorithm 1)
               distributed_rsp_partition-- shard_map + all_to_all (Algorithm 1)
               randomize_dataset, is_partition, empirical_cdf (Defs. 2/3)
  sampling     BlockSampler, deal_blocks, HostAssignment (Definition 4)
               SamplingPolicy: UniformPolicy / WeightedPolicy /
               StratifiedPolicy, make_policy, sketch_dispersion
               (sketch-guided block selection + HT reweighting)
  estimation   BlockLevelEstimator, MomentStats, block_moments,
               combine_moments, batched_block_moments, block_histogram,
               quantile_from_histogram (Sec. 8); the declarative progressive
               query layer (anytime CIs, early stopping) is repro.rsp.query
  ensemble     BaseLearner, make_logreg, make_mlp, Ensemble,
               train_base_models_vmapped, asymptotic_ensemble_learn,
               ensemble_vs_single_model (Sec. 9, Algorithm 2)
  similarity   mmd2_rbf, mmd_block_vs_data, median_heuristic_gamma,
               hotelling_t2, ks_statistic, label_distribution,
               max_label_divergence (Sec. 7)
  storage      RSPStore (stored RSP; manifest cache + atomic block writes)
  monitoring   DriftMonitor, DriftReport
"""

from repro.core.types import BlockDescriptor, RSPSpec, SamplerState
from repro.core.partition import (
    distributed_rsp_partition,
    empirical_cdf,
    is_partition,
    randomize_dataset,
    two_stage_partition_jax,
    two_stage_partition_np,
)
from repro.core.sampler import (
    POLICIES,
    BlockSampler,
    HostAssignment,
    SamplingPolicy,
    StratifiedPolicy,
    UniformPolicy,
    WeightedPolicy,
    deal_blocks,
    make_policy,
    sketch_dispersion,
)
from repro.core.estimators import (
    BlockLevelEstimator,
    MomentStats,
    batched_block_moments,
    block_histogram,
    block_moments,
    combine_moments,
    quantile_from_histogram,
    streaming_estimate,
)
from repro.core.ensemble import (
    BaseLearner,
    Ensemble,
    EnsembleHistory,
    asymptotic_ensemble_learn,
    ensemble_vs_single_model,
    make_logreg,
    make_mlp,
    train_base_models_vmapped,
)
from repro.core.similarity import (
    hotelling_t2,
    ks_statistic,
    label_distribution,
    max_label_divergence,
    median_heuristic_gamma,
    mmd2_rbf,
    mmd_block_vs_data,
)
from repro.core.registry import RSPStore
from repro.core.monitor import DriftMonitor, DriftReport

__all__ = [k for k in dir() if not k.startswith("_")]
