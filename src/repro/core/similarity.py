"""Distribution-similarity measures between RSP blocks and the full data.

Implements the paper's Sec. 7 toolkit: MMD (Gretton et al. kernel two-sample
test), Hotelling's T-square test for mean differences, a 1-D two-sample KS
statistic, and categorical label-distribution comparison (Fig. 2a).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ---------------------------------------------------------------------------
# MMD^2 (unbiased, RBF kernel)
# ---------------------------------------------------------------------------

def _sq_dists(x: Array, y: Array) -> Array:
    xx = (x * x).sum(-1)[:, None]
    yy = (y * y).sum(-1)[None, :]
    return xx + yy - 2.0 * x @ y.T


@functools.partial(jax.jit, static_argnames=())
def mmd2_rbf(x: Array, y: Array, gamma: Array) -> Array:
    """Unbiased MMD^2 with k(a,b) = exp(-gamma * ||a-b||^2)."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    m, n = x.shape[0], y.shape[0]
    kxx = jnp.exp(-gamma * _sq_dists(x, x))
    kyy = jnp.exp(-gamma * _sq_dists(y, y))
    kxy = jnp.exp(-gamma * _sq_dists(x, y))
    sum_xx = (kxx.sum() - jnp.trace(kxx)) / (m * (m - 1))
    sum_yy = (kyy.sum() - jnp.trace(kyy)) / (n * (n - 1))
    return sum_xx + sum_yy - 2.0 * kxy.mean()


def median_heuristic_gamma(x: np.ndarray, max_points: int = 512) -> float:
    """gamma = 1 / (2 * median(||a-b||^2)) on a subsample."""
    x = np.asarray(x, dtype=np.float64)[:max_points]
    d = np.asarray(_sq_dists(jnp.asarray(x), jnp.asarray(x)))
    med = float(np.median(d[np.triu_indices_from(d, k=1)]))
    return 1.0 / max(2.0 * med, 1e-12)


def mmd_block_vs_data(
    block: np.ndarray, data: np.ndarray, *, max_points: int = 1024, seed: int = 0
) -> float:
    """MMD^2 between a block and a subsample of the full data set."""
    rng = np.random.default_rng(seed)
    b = np.asarray(block).reshape(block.shape[0], -1)
    d = np.asarray(data).reshape(data.shape[0], -1)
    b = b[rng.choice(b.shape[0], min(max_points, b.shape[0]), replace=False)]
    d = d[rng.choice(d.shape[0], min(max_points, d.shape[0]), replace=False)]
    gamma = median_heuristic_gamma(d)
    return float(mmd2_rbf(jnp.asarray(b), jnp.asarray(d), jnp.asarray(gamma)))


# ---------------------------------------------------------------------------
# Hotelling's T-square two-sample test
# ---------------------------------------------------------------------------

def hotelling_t2(x: np.ndarray, y: np.ndarray) -> tuple[float, float, float]:
    """Returns (t2, f_stat, p_value) for H0: mean(x) == mean(y)."""
    x = np.asarray(x, dtype=np.float64).reshape(x.shape[0], -1)
    y = np.asarray(y, dtype=np.float64).reshape(y.shape[0], -1)
    n1, n2 = x.shape[0], y.shape[0]
    p = x.shape[1]
    if n1 + n2 - 2 <= p:
        raise ValueError("need n1 + n2 - 2 > num_features for pooled covariance")
    d = x.mean(0) - y.mean(0)
    s_pooled = ((n1 - 1) * np.cov(x, rowvar=False) + (n2 - 1) * np.cov(y, rowvar=False)) / (
        n1 + n2 - 2
    )
    s_pooled = s_pooled + 1e-9 * np.eye(p)
    t2 = (n1 * n2) / (n1 + n2) * d @ np.linalg.solve(s_pooled, d)
    f_stat = t2 * (n1 + n2 - p - 1) / (p * (n1 + n2 - 2))
    dfn, dfd = p, n1 + n2 - p - 1
    # p-value from the regularized incomplete beta (F survival function).
    xbeta = dfd / (dfd + dfn * max(f_stat, 0.0))
    p_value = float(
        jax.scipy.special.betainc(jnp.asarray(dfd / 2.0), jnp.asarray(dfn / 2.0), jnp.asarray(xbeta))
    )
    return float(t2), float(f_stat), p_value


# ---------------------------------------------------------------------------
# 1-D two-sample Kolmogorov-Smirnov statistic
# ---------------------------------------------------------------------------

def ks_statistic(x: np.ndarray, y: np.ndarray) -> float:
    x = np.sort(np.asarray(x).reshape(-1))
    y = np.sort(np.asarray(y).reshape(-1))
    grid = np.concatenate([x, y])
    fx = np.searchsorted(x, grid, side="right") / x.size
    fy = np.searchsorted(y, grid, side="right") / y.size
    return float(np.max(np.abs(fx - fy)))


# ---------------------------------------------------------------------------
# Categorical / label distribution (Fig. 2a)
# ---------------------------------------------------------------------------

def label_distribution(labels: np.ndarray, num_classes: int) -> np.ndarray:
    """Normalized class frequencies of one block / data set."""
    counts = np.bincount(np.asarray(labels).astype(np.int64).reshape(-1), minlength=num_classes)
    return counts / max(counts.sum(), 1)


def max_label_divergence(
    block_labels: np.ndarray, data_labels: np.ndarray, num_classes: int
) -> float:
    """L-inf distance between block and full-data label distributions."""
    return float(
        np.max(
            np.abs(
                label_distribution(block_labels, num_classes)
                - label_distribution(data_labels, num_classes)
            )
        )
    )
