"""Block-level data-quality / drift monitoring (paper Sec. 10 extension).

The paper notes that RSP blocks from *different data centres* may follow
different distributions and that a "combination criterion" is needed before
pooling them.  ``DriftMonitor`` operationalizes this: a reference sketch is
built from an initial block-level sample, and every incoming block is scored
with the Sec.-7 toolkit (MMD^2 + per-feature mean z-scores).  Blocks that
exceed the thresholds are flagged instead of pooled -- usable both for
cross-datacenter combination and as a training-time data-quality tripwire
(a corrupted shard shows up as a drifted block long before it shows up in
the loss).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.estimators import BlockLevelEstimator
from repro.core.similarity import median_heuristic_gamma, mmd2_rbf

import jax.numpy as jnp


@dataclasses.dataclass
class DriftReport:
    block_id: int
    mmd2: float
    max_mean_z: float
    worst_std_ratio: float    # max over features of max(s/s_ref, s_ref/s)
    drifted: bool


class DriftMonitor:
    """Score incoming RSP blocks against a reference block-level sample."""

    def __init__(
        self,
        reference_blocks: np.ndarray,          # [g, n, F]
        *,
        mmd_threshold: float | None = None,
        z_threshold: float = 6.0,
        std_ratio_threshold: float = 1.5,
        max_points: int = 512,
        seed: int = 0,
    ):
        self.std_ratio_threshold = std_ratio_threshold
        ref = np.asarray(reference_blocks)
        self._ref = ref.reshape(-1, ref.shape[-1]).astype(np.float32)
        rng = np.random.default_rng(seed)
        take = min(max_points, self._ref.shape[0])
        self._ref_sample = self._ref[rng.choice(self._ref.shape[0], take, replace=False)]
        self._gamma = median_heuristic_gamma(self._ref_sample)
        self._est = BlockLevelEstimator()
        for b in ref:
            self._est.update(jnp.asarray(b))
        self._max_points = max_points
        self._rng = rng
        self.history: list[DriftReport] = []

        if mmd_threshold is None:
            # calibrate: MMD^2 between two halves of the reference, x8 margin
            half = self._ref_sample.shape[0] // 2
            base = float(
                mmd2_rbf(
                    jnp.asarray(self._ref_sample[:half]),
                    jnp.asarray(self._ref_sample[half : 2 * half]),
                    jnp.asarray(self._gamma),
                )
            )
            mmd_threshold = max(abs(base) * 8.0, 1e-3)
        self.mmd_threshold = mmd_threshold
        self.z_threshold = z_threshold

    def score(self, block: np.ndarray, block_id: int = -1) -> DriftReport:
        x = np.asarray(block).reshape(-1, self._ref.shape[-1]).astype(np.float32)
        take = min(self._max_points, x.shape[0])
        xs = x[self._rng.choice(x.shape[0], take, replace=False)]
        mmd = float(mmd2_rbf(jnp.asarray(xs), jnp.asarray(self._ref_sample), jnp.asarray(self._gamma)))
        stats = self._est.stats
        se = stats.std / np.sqrt(max(x.shape[0], 1)) + 1e-12
        z = float(np.max(np.abs(x.mean(0) - stats.mean) / se))
        # variance shift: catches dead/clipped features that keep their mean
        s_block = x.std(0, ddof=1) + 1e-12
        s_ref = stats.std + 1e-12
        ratio = float(np.max(np.maximum(s_block / s_ref, s_ref / s_block)))
        report = DriftReport(
            block_id=block_id,
            mmd2=mmd,
            max_mean_z=z,
            worst_std_ratio=ratio,
            drifted=(
                (mmd > self.mmd_threshold)
                or (z > self.z_threshold)
                or (ratio > self.std_ratio_threshold)
            ),
        )
        self.history.append(report)
        return report

    def drifted_blocks(self) -> list[int]:
        return [r.block_id for r in self.history if r.drifted]
