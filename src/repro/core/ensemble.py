"""Asymptotic ensemble learning framework (paper Sec. 9, Algorithm 2).

Base models are trained on RSP data blocks drawn by block-level sampling and
folded into an ensemble that is re-evaluated after every batch; the loop stops
when the evaluation metric plateaus or blocks run out.

Beyond-paper adaptation: all ``g`` base models of a batch are trained
*simultaneously* with ``jax.vmap`` over the stacked blocks -- the paper's
"perfectly parallel" executor pool becomes a single vectorized XLA program.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sampler import BlockSampler

Array = jax.Array
Params = dict


# ---------------------------------------------------------------------------
# Base learners (pure JAX; substrate built in-repo, no sklearn)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BaseLearner:
    """init/fit/predict triple.  ``fit`` trains on one block; all functions
    are vmap-able over a leading block axis."""

    name: str
    init: Callable[[Array, int, int], Params]
    fit: Callable[[Params, Array, Array], Params]
    predict_proba: Callable[[Params, Array], Array]


def _gd_train(loss_fn, params: Params, steps: int, lr: float) -> Params:
    grad_fn = jax.grad(loss_fn)

    def body(_, p):
        g = grad_fn(p)
        return jax.tree.map(lambda w, gw: w - lr * gw, p, g)

    return jax.lax.fori_loop(0, steps, body, params)


def make_logreg(num_features: int, num_classes: int, *, steps: int = 300, lr: float = 0.5) -> BaseLearner:
    """Multinomial logistic regression trained with full-batch GD."""

    def init(key: Array, f: int = num_features, c: int = num_classes) -> Params:
        return {
            "w": 0.01 * jax.random.normal(key, (f, c), jnp.float32),
            "b": jnp.zeros((c,), jnp.float32),
        }

    def fit(params: Params, x: Array, y: Array) -> Params:
        x = x.astype(jnp.float32)
        y1h = jax.nn.one_hot(y, num_classes)

        def loss(p):
            logits = x @ p["w"] + p["b"]
            return -(y1h * jax.nn.log_softmax(logits)).sum(-1).mean() + 1e-4 * (p["w"] ** 2).sum()

        return _gd_train(loss, params, steps, lr)

    def predict_proba(params: Params, x: Array) -> Array:
        return jax.nn.softmax(x.astype(jnp.float32) @ params["w"] + params["b"])

    return BaseLearner("logreg", init, fit, predict_proba)


def make_mlp(
    num_features: int,
    num_classes: int,
    *,
    hidden: int = 32,
    steps: int = 400,
    lr: float = 0.05,
) -> BaseLearner:
    """One-hidden-layer MLP trained with full-batch GD + momentum."""

    def init(key: Array, f: int = num_features, c: int = num_classes) -> Params:
        k1, k2 = jax.random.split(key)
        return {
            "w1": jax.random.normal(k1, (f, hidden), jnp.float32) * (2.0 / f) ** 0.5,
            "b1": jnp.zeros((hidden,), jnp.float32),
            "w2": jax.random.normal(k2, (hidden, c), jnp.float32) * (2.0 / hidden) ** 0.5,
            "b2": jnp.zeros((c,), jnp.float32),
        }

    def fit(params: Params, x: Array, y: Array) -> Params:
        x = x.astype(jnp.float32)
        y1h = jax.nn.one_hot(y, num_classes)

        def loss(p):
            h = jax.nn.relu(x @ p["w1"] + p["b1"])
            logits = h @ p["w2"] + p["b2"]
            return -(y1h * jax.nn.log_softmax(logits)).sum(-1).mean()

        grad_fn = jax.grad(loss)
        mom = jax.tree.map(jnp.zeros_like, params)

        def body(_, carry):
            p, m = carry
            g = grad_fn(p)
            m = jax.tree.map(lambda mi, gi: 0.9 * mi + gi, m, g)
            p = jax.tree.map(lambda w, mi: w - lr * mi, p, m)
            return p, m

        params, _ = jax.lax.fori_loop(0, steps, body, (params, mom))
        return params

    def predict_proba(params: Params, x: Array) -> Array:
        h = jax.nn.relu(x.astype(jnp.float32) @ params["w1"] + params["b1"])
        return jax.nn.softmax(h @ params["w2"] + params["b2"])

    return BaseLearner("mlp", init, fit, predict_proba)


# ---------------------------------------------------------------------------
# Vectorized batch training (beyond-paper)
# ---------------------------------------------------------------------------

def train_base_models_vmapped(
    learner: BaseLearner, key: Array, xs: Array, ys: Array
) -> Params:
    """Train g base models simultaneously on stacked blocks [g, n, F]/[g, n]."""
    g = xs.shape[0]
    keys = jax.random.split(key, g)

    @jax.jit
    def run(keys, xs, ys):
        def one(k, x, y):
            return learner.fit(learner.init(k), x, y)

        return jax.vmap(one)(keys, xs, ys)

    return run(keys, xs, ys)


# ---------------------------------------------------------------------------
# Ensemble container + Algorithm 2 loop
# ---------------------------------------------------------------------------

class Ensemble:
    """A bag of base models with probability-averaging combination."""

    def __init__(self, learner: BaseLearner):
        self.learner = learner
        self._stacked: Params | None = None  # leaves have leading model axis
        self.num_models = 0

    def add_stacked(self, params: Params, count: int) -> None:
        if self._stacked is None:
            self._stacked = params
        else:
            self._stacked = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), self._stacked, params
            )
        self.num_models += count

    def predict_proba(self, x: Array) -> Array:
        if self._stacked is None:
            raise ValueError("empty ensemble")
        probs = jax.vmap(lambda p: self.learner.predict_proba(p, x))(self._stacked)
        return probs.mean(axis=0)

    def accuracy(self, x: Array, y: Array) -> float:
        return float((jnp.argmax(self.predict_proba(x), -1) == y).mean())


@dataclasses.dataclass
class EnsembleHistory:
    blocks_used: list[int] = dataclasses.field(default_factory=list)
    accuracy: list[float] = dataclasses.field(default_factory=list)


def asymptotic_ensemble_learn(
    blocks_x: Array | None = None,
    blocks_y: Array | None = None,
    *,
    learner: BaseLearner,
    eval_x: Array,
    eval_y: Array,
    g: int,
    seed: int = 0,
    improvement_tol: float = 1e-3,
    patience: int = 2,
    max_batches: int | None = None,
    num_blocks: int | None = None,
    fetch_blocks: Callable[[list[int]], tuple[Array, Array]] | None = None,
) -> tuple[Ensemble, EnsembleHistory]:
    """Algorithm 2: batches of g blocks -> vmapped base models -> ensemble
    update -> evaluation; stop on plateau or block exhaustion.

    Either pass stacked in-memory blocks (``blocks_x``: [K, n, F],
    ``blocks_y``: [K, n]) or a lazy source (``fetch_blocks(ids) ->
    (xs, ys)`` with ``num_blocks``) so each batch loads only its sampled
    blocks -- the paper's touch-only-the-sample property for stored RSPs.
    """
    if fetch_blocks is None:
        if blocks_x is None or blocks_y is None:
            raise ValueError("need blocks_x/blocks_y or fetch_blocks + num_blocks")
        K = blocks_x.shape[0]

        def fetch_blocks(ids: list[int]) -> tuple[Array, Array]:
            idx = jnp.asarray(ids)
            return blocks_x[idx], blocks_y[idx]

    else:
        if num_blocks is None:
            raise ValueError("fetch_blocks needs num_blocks")
        K = num_blocks
    sampler = BlockSampler(K, seed=seed)
    ensemble = Ensemble(learner)
    history = EnsembleHistory()
    key = jax.random.PRNGKey(seed)
    stall = 0
    batch_idx = 0
    while sampler.remaining_in_epoch() > 0:
        if max_batches is not None and batch_idx >= max_batches:
            break
        ids = sampler.sample(min(g, sampler.remaining_in_epoch()))
        key, sub = jax.random.split(key)
        bx, by = fetch_blocks(ids)
        params = train_base_models_vmapped(learner, sub, bx, by)
        ensemble.add_stacked(params, len(ids))
        acc = ensemble.accuracy(eval_x, eval_y)
        history.blocks_used.append(ensemble.num_models)
        history.accuracy.append(acc)
        if len(history.accuracy) > 1:
            if acc - max(history.accuracy[:-1]) < improvement_tol:
                stall += 1
            else:
                stall = 0
            if stall >= patience:
                break
        batch_idx += 1
    return ensemble, history


def ensemble_vs_single_model(
    blocks_x: Array,
    blocks_y: Array,
    eval_x: Array,
    eval_y: Array,
    *,
    learner: BaseLearner,
    seed: int = 0,
) -> tuple[float, float]:
    """Fig-6 comparison: (ensemble accuracy, single-full-data-model accuracy)."""
    ens, _ = asymptotic_ensemble_learn(
        blocks_x,
        blocks_y,
        learner=learner,
        eval_x=eval_x,
        eval_y=eval_y,
        g=min(5, blocks_x.shape[0]),
        seed=seed,
    )
    full_x = blocks_x.reshape(-1, blocks_x.shape[-1])
    full_y = blocks_y.reshape(-1)
    params = learner.fit(learner.init(jax.random.PRNGKey(seed + 1)), full_x, full_y)
    single_acc = float(
        (jnp.argmax(learner.predict_proba(params, eval_x), -1) == eval_y).mean()
    )
    return ens.accuracy(eval_x, eval_y), single_acc
