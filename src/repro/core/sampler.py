"""Block-level sampling (Definition 4) and host-level block scheduling.

A *block level sample* draws ``g < K`` RSP blocks without replacement with
equal probability.  Because every block is a random sample of the corpus,
this replaces record-level sampling at zero scan cost.  The sampler is
deterministic given ``(seed, epoch, cursor)`` -- the entire data-pipeline
checkpoint is three integers (see core.types.SamplerState).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Iterator, Sequence

import numpy as np

from repro.core.types import SamplerState


def _epoch_permutation(seed: int, epoch: int, num_blocks: int) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xB10C, epoch]))
    return rng.permutation(num_blocks)


class BlockSampler:
    """Without-replacement block-level sampler over K RSP blocks.

    Within one epoch no block is repeated (paper Sec. 7: "without repeating a
    block neither in the same sample nor in other samples in the same analysis
    process").  Crossing an epoch boundary reshuffles with a fresh
    deterministic permutation.
    """

    def __init__(self, num_blocks: int, seed: int = 0, state: SamplerState | None = None):
        if num_blocks <= 0:
            raise ValueError("num_blocks must be positive")
        self.num_blocks = num_blocks
        self.state = state if state is not None else SamplerState(seed=seed)
        self._perm = _epoch_permutation(self.state.seed, self.state.epoch, num_blocks)

    # -- Definition 4 ------------------------------------------------------
    def sample(self, g: int) -> list[int]:
        """Draw the next ``g`` blocks without replacement (one batch)."""
        if g <= 0:
            raise ValueError("g must be positive")
        out: list[int] = []
        while len(out) < g:
            if self.state.cursor >= self.num_blocks:
                self._advance_epoch()
            take = min(g - len(out), self.num_blocks - self.state.cursor)
            out.extend(self._perm[self.state.cursor : self.state.cursor + take].tolist())
            self.state.cursor += take
        return out

    def remaining_in_epoch(self) -> int:
        return self.num_blocks - self.state.cursor

    def _advance_epoch(self) -> None:
        self.state.epoch += 1
        self.state.cursor = 0
        self._perm = _epoch_permutation(self.state.seed, self.state.epoch, self.num_blocks)

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> dict[str, int]:
        return self.state.to_dict()

    @classmethod
    def from_state_dict(cls, num_blocks: int, d: dict[str, int]) -> "BlockSampler":
        return cls(num_blocks, state=SamplerState.from_dict(d))

    def batches(self, g: int, *, max_batches: int | None = None) -> Iterator[list[int]]:
        """Iterate block-level samples until the epoch's blocks are used up."""
        count = 0
        while self.remaining_in_epoch() > 0:
            if max_batches is not None and count >= max_batches:
                return
            yield self.sample(min(g, self.remaining_in_epoch()))
            count += 1


@dataclasses.dataclass
class HostAssignment:
    """Deal of block ids to hosts for one epoch (multi-host training)."""

    host_blocks: dict[int, list[int]]

    def blocks_for(self, host: int) -> list[int]:
        return self.host_blocks.get(host, [])

    def redistribute(self, failed_hosts: Sequence[int]) -> "HostAssignment":
        """Re-deal a failed host's blocks to the survivors (round-robin).

        Theorem 1 makes the re-dealt unions statistically valid: unions of
        RSP blocks in corpus proportion are RSP blocks of the union.
        """
        failed = set(failed_hosts)
        survivors = sorted(h for h in self.host_blocks if h not in failed)
        if not survivors:
            raise ValueError("no surviving hosts")
        orphaned: list[int] = []
        for h in sorted(failed):
            orphaned.extend(self.host_blocks.get(h, []))
        new = {h: list(self.host_blocks[h]) for h in survivors}
        for i, b in enumerate(orphaned):
            new[survivors[i % len(survivors)]].append(b)
        return HostAssignment(new)


def deal_blocks(
    num_blocks: int, num_hosts: int, seed: int = 0, epoch: int = 0
) -> HostAssignment:
    """Deterministically deal a fresh epoch permutation across hosts."""
    perm = _epoch_permutation(seed, epoch, num_blocks)
    return HostAssignment(
        {h: perm[h::num_hosts].tolist() for h in range(num_hosts)}
    )


# ---------------------------------------------------------------------------
# Sampling policies: sketch-guided block selection
# ---------------------------------------------------------------------------

class SamplingPolicy:
    """Strategy for choosing which blocks a block-level sample contains.

    ``uniform`` is the paper's Definition 4 (every block equally likely,
    without replacement).  The non-uniform policies use the partition-time
    sketches to *bias* selection toward informative blocks -- in the style of
    summary-statistics-driven partition selection (Rong et al., 2020) --
    and expose the Horvitz-Thompson ``weights`` that make downstream
    moment estimates unbiased again (``combine_summaries(..., weights=)``).

    Interface: ``sample(g) -> ids`` (stateful, deterministic from seed +
    draw counter), ``weights(ids)`` (HT weights for a draw, ``None`` when
    the plain average is already unbiased), ``epoch`` (a monotone tag for
    per-visit block permutations in the loader), and ``state_dict`` /
    ``load_state_dict`` for O(1) resume.
    """

    name = "base"

    def sample(self, g: int) -> list[int]:
        raise NotImplementedError

    def weights(self, ids: Sequence[int]) -> np.ndarray | None:
        return None

    @property
    def epoch(self) -> int:
        return 0

    def state_dict(self) -> dict:
        raise NotImplementedError

    def load_state_dict(self, state: dict) -> None:
        raise NotImplementedError

    def _fingerprint_payload(self) -> dict:
        raise NotImplementedError

    def fingerprint(self) -> str:
        """Digest of the selection *distribution* (not the draw counter).

        Two policies with equal fingerprints produce identical block-id
        sequences from identical draw counters.  Distributed hosts compare
        fingerprints before a query so a stale manifest / divergent summary
        set fails loudly instead of silently skewing HT weights.
        """
        payload = json.dumps(self._fingerprint_payload(), sort_keys=True)
        return hashlib.sha1(payload.encode()).hexdigest()


class UniformPolicy(SamplingPolicy):
    """Definition-4 sampling: equal probability, without replacement within
    an epoch (delegates to :class:`BlockSampler`)."""

    name = "uniform"

    def __init__(self, num_blocks: int, *, seed: int = 0):
        self.sampler = BlockSampler(num_blocks, seed=seed)

    def sample(self, g: int) -> list[int]:
        return self.sampler.sample(g)

    @property
    def epoch(self) -> int:
        return self.sampler.state.epoch

    def state_dict(self) -> dict:
        return {"kind": self.name, "sampler": self.sampler.state_dict()}

    def load_state_dict(self, state: dict) -> None:
        self.sampler = BlockSampler.from_state_dict(
            self.sampler.num_blocks, state["sampler"]
        )

    def _fingerprint_payload(self) -> dict:
        return {
            "kind": self.name,
            "seed": int(self.sampler.state.seed),
            "num_blocks": int(self.sampler.num_blocks),
        }


def sketch_dispersion(summaries: Sequence) -> np.ndarray:
    """Per-block selection score from the partition-time sketches: the
    feature-averaged spread plus mean magnitude, ``mean_j(std_j + |mean_j|)``.

    For skewed corpora this tracks each block's contribution to the corpus
    totals (blocks with large/spread-out values score high), which is what
    probability-proportional-to-size selection wants.  Any positive score
    stays *unbiased* under HT reweighting; the score only moves variance.
    """
    return np.array(
        [float(np.mean(s.std + np.abs(s.mean))) for s in summaries], dtype=np.float64
    )


class WeightedPolicy(SamplingPolicy):
    """PPS selection: g independent draws with replacement, block ``k`` with
    probability proportional to its sketch dispersion.  ``weights`` returns
    the Hansen-Hurwitz / HT factors ``1 / (g * p_k)`` so that
    ``sum_k w_k * t_k`` is an unbiased estimate of the corpus total of any
    per-block total ``t_k``."""

    name = "weighted"

    def __init__(
        self,
        num_blocks: int,
        summaries: Sequence | None = None,
        *,
        probabilities: np.ndarray | None = None,
        seed: int = 0,
        floor: float = 0.05,
    ):
        if probabilities is None:
            if summaries is None:
                raise ValueError("weighted policy needs summaries or probabilities")
            score = sketch_dispersion(summaries)
            # floor keeps every block reachable (and HT weights bounded)
            score = score + floor * max(score.mean(), 1e-12)
            probabilities = score
        p = np.asarray(probabilities, dtype=np.float64)
        if p.shape != (num_blocks,) or np.any(p < 0) or p.sum() <= 0:
            raise ValueError("probabilities must be non-negative, one per block")
        self.probabilities = p / p.sum()
        self.seed = seed
        self._draws = 0

    def sample(self, g: int) -> list[int]:
        if g <= 0:
            raise ValueError("g must be positive")
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, 0x5E1EC7, self._draws])
        )
        self._draws += 1
        return rng.choice(
            self.probabilities.shape[0], size=g, replace=True, p=self.probabilities
        ).tolist()

    def weights(self, ids: Sequence[int]) -> np.ndarray:
        p = self.probabilities[np.asarray(ids, dtype=np.int64)]
        return 1.0 / (len(ids) * p)

    @property
    def epoch(self) -> int:
        return self._draws

    def state_dict(self) -> dict:
        return {"kind": self.name, "seed": self.seed, "draws": self._draws}

    def load_state_dict(self, state: dict) -> None:
        self.seed = int(state["seed"])
        self._draws = int(state["draws"])

    def _fingerprint_payload(self) -> dict:
        # exact float64 bytes: the PPS distribution IS the policy
        return {
            "kind": self.name,
            "seed": int(self.seed),
            "probabilities": hashlib.sha1(
                np.ascontiguousarray(self.probabilities).tobytes()
            ).hexdigest(),
        }


class StratifiedPolicy(SamplingPolicy):
    """Label-histogram stratification: blocks are grouped by their dominant
    label (argmax of the sketch's label histogram), draws are allocated to
    strata proportionally to stratum size (largest remainder), and blocks are
    drawn uniformly without replacement within each stratum.  ``weights``
    returns ``B_h / g_h`` (stratum size over draws taken from it), the HT
    expansion for stratified totals -- exactly unbiased once every stratum
    receives a draw; with ``g`` below the stratum count, strata are included
    randomly in proportion, so single-draw estimates cover a random subset
    of strata and are only approximately unbiased (use ``weighted`` when
    small-``g`` exactness matters)."""

    name = "stratified"

    def __init__(self, num_blocks: int, summaries: Sequence, *, seed: int = 0):
        if len(summaries) != num_blocks:
            raise ValueError("need one summary per block")
        if any(getattr(s, "label_hist", None) is None for s in summaries):
            raise ValueError("stratified policy needs label histograms in the sketches")
        strata: dict[int, list[int]] = {}
        for k, s in enumerate(summaries):
            strata.setdefault(int(np.argmax(s.label_hist)), []).append(k)
        self.strata = {h: np.asarray(ids) for h, ids in sorted(strata.items())}
        self._stratum_of = np.empty(num_blocks, dtype=np.int64)
        for h, ids in self.strata.items():
            self._stratum_of[ids] = h
        self.seed = seed
        self._draws = 0

    def _allocate(self, g: int, rng: np.random.Generator) -> dict[int, int]:
        """Proportional allocation of g draws to strata, capped at stratum
        size: integer parts are deterministic, the fractional remainder draws
        are assigned *randomly* with probability proportional to the
        remainders -- so even ``g=1`` streams (the loader's refill pattern)
        visit every stratum in corpus proportion instead of starving the
        small ones."""
        sizes = {h: len(ids) for h, ids in self.strata.items()}
        total = sum(sizes.values())
        g = min(g, total)
        exact = {h: g * b / total for h, b in sizes.items()}
        alloc = {h: min(int(e), sizes[h]) for h, e in exact.items()}
        short = g - sum(alloc.values())
        while short > 0:
            open_strata = [h for h in self.strata if alloc[h] < sizes[h]]
            rem = np.array(
                [max(exact[h] - int(exact[h]), 1e-9) for h in open_strata]
            )
            h = open_strata[int(rng.choice(len(open_strata), p=rem / rem.sum()))]
            alloc[h] += 1
            short -= 1
        return alloc

    def sample(self, g: int) -> list[int]:
        if g <= 0:
            raise ValueError("g must be positive")
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, 0x57A7A, self._draws])
        )
        self._draws += 1
        out: list[int] = []
        for h, take in self._allocate(g, rng).items():
            if take > 0:
                ids = self.strata[h]
                out.extend(rng.choice(ids, size=take, replace=False).tolist())
        return out

    def weights(self, ids: Sequence[int]) -> np.ndarray:
        ids = np.asarray(ids, dtype=np.int64)
        strata = self._stratum_of[ids]
        drawn = {h: int((strata == h).sum()) for h in np.unique(strata)}
        return np.array(
            [len(self.strata[h]) / drawn[h] for h in strata], dtype=np.float64
        )

    @property
    def epoch(self) -> int:
        return self._draws

    def state_dict(self) -> dict:
        return {"kind": self.name, "seed": self.seed, "draws": self._draws}

    def load_state_dict(self, state: dict) -> None:
        self.seed = int(state["seed"])
        self._draws = int(state["draws"])

    def _fingerprint_payload(self) -> dict:
        return {
            "kind": self.name,
            "seed": int(self.seed),
            "strata": {
                str(h): [int(b) for b in ids] for h, ids in self.strata.items()
            },
        }


class QueryAwarePolicy(WeightedPolicy):
    """PPS selection scored against the *specific aggregate being asked*
    (in the style of Rong et al., 2020) instead of dispersion alone.

    Per-block score = expected matching rows x target-feature dispersion x
    group coverage:

    * **predicate selectivity** -- each block's expected fraction of rows
      passing the query's conjunctive predicates, estimated from its
      per-column KLL quantile sketch (``SketchSuite.selectivity``; v1
      suites fall back to a uniform-over-[min, max] interpolation), scaled
      by the block's record count;
    * **target dispersion** -- ``std + |mean|`` of the aggregated feature
      only (all features averaged when the query has no single target),
      the same magnitude proxy :func:`sketch_dispersion` uses globally;
    * **group coverage** -- for grouped queries, the fraction of label
      classes the block's label histogram covers, so blocks that can renew
      every group's estimate are preferred.

    The same probability floor as :class:`WeightedPolicy` keeps every block
    reachable, so the Hansen-Hurwitz/HT ``weights`` stay bounded and the
    downstream estimates unbiased.  Selection only moves variance: blocks
    rich in predicate-passing, high-signal rows arrive first and the
    stopping rule fires after fewer reads.
    """

    name = "query_aware"

    def __init__(
        self,
        num_blocks: int,
        summaries: Sequence,
        *,
        predicates: Sequence = (),
        feature: int | None = None,
        by_label: bool = False,
        seed: int = 0,
        floor: float = 0.05,
    ):
        if summaries is None or len(summaries) != num_blocks:
            raise ValueError("query_aware policy needs one summary per block")
        score = self.score_blocks(
            summaries, predicates=predicates, feature=feature, by_label=by_label
        )
        score = score + floor * max(score.mean(), 1e-12)
        super().__init__(num_blocks, probabilities=score, seed=seed)

    @staticmethod
    def score_blocks(
        summaries: Sequence,
        *,
        predicates: Sequence = (),
        feature: int | None = None,
        by_label: bool = False,
    ) -> np.ndarray:
        score = np.empty(len(summaries), dtype=np.float64)
        for k, s in enumerate(summaries):
            sel = 1.0
            if predicates:
                sel = (
                    s.selectivity(predicates)
                    if hasattr(s, "selectivity")
                    else 1.0
                )
            if feature is not None:
                disp = float(s.std[feature] + np.abs(s.mean[feature]))
            else:
                disp = float(np.mean(s.std + np.abs(s.mean)))
            cover = 1.0
            if by_label:
                hist = getattr(s, "label_hist", None)
                if hist is not None and len(hist):
                    cover = float(np.count_nonzero(hist)) / len(hist)
            score[k] = s.count * sel * disp * cover
        return score


POLICIES = ("uniform", "weighted", "stratified", "query_aware")


def make_policy(
    policy: str | SamplingPolicy,
    num_blocks: int,
    *,
    seed: int = 0,
    summaries: Sequence | None = None,
    **kwargs,
) -> SamplingPolicy:
    """Resolve a policy name (or pass through an instance).

    ``"uniform"`` needs nothing beyond the block count; ``"weighted"`` and
    ``"stratified"`` need the per-block sketches (``RSPDataset.summaries``).
    """
    if isinstance(policy, SamplingPolicy):
        return policy
    if policy == "uniform":
        return UniformPolicy(num_blocks, seed=seed, **kwargs)
    if policy == "weighted":
        return WeightedPolicy(num_blocks, summaries, seed=seed, **kwargs)
    if policy == "stratified":
        if summaries is None:
            raise ValueError("stratified policy needs summaries")
        return StratifiedPolicy(num_blocks, summaries, seed=seed, **kwargs)
    if policy == "query_aware":
        if summaries is None:
            raise ValueError("query_aware policy needs summaries")
        return QueryAwarePolicy(num_blocks, summaries, seed=seed, **kwargs)
    raise ValueError(
        f"unknown sampling policy {policy!r}"
        " (uniform | weighted | stratified | query_aware)"
    )
