"""Block-level sampling (Definition 4) and host-level block scheduling.

A *block level sample* draws ``g < K`` RSP blocks without replacement with
equal probability.  Because every block is a random sample of the corpus,
this replaces record-level sampling at zero scan cost.  The sampler is
deterministic given ``(seed, epoch, cursor)`` -- the entire data-pipeline
checkpoint is three integers (see core.types.SamplerState).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

from repro.core.types import SamplerState


def _epoch_permutation(seed: int, epoch: int, num_blocks: int) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xB10C, epoch]))
    return rng.permutation(num_blocks)


class BlockSampler:
    """Without-replacement block-level sampler over K RSP blocks.

    Within one epoch no block is repeated (paper Sec. 7: "without repeating a
    block neither in the same sample nor in other samples in the same analysis
    process").  Crossing an epoch boundary reshuffles with a fresh
    deterministic permutation.
    """

    def __init__(self, num_blocks: int, seed: int = 0, state: SamplerState | None = None):
        if num_blocks <= 0:
            raise ValueError("num_blocks must be positive")
        self.num_blocks = num_blocks
        self.state = state if state is not None else SamplerState(seed=seed)
        self._perm = _epoch_permutation(self.state.seed, self.state.epoch, num_blocks)

    # -- Definition 4 ------------------------------------------------------
    def sample(self, g: int) -> list[int]:
        """Draw the next ``g`` blocks without replacement (one batch)."""
        if g <= 0:
            raise ValueError("g must be positive")
        out: list[int] = []
        while len(out) < g:
            if self.state.cursor >= self.num_blocks:
                self._advance_epoch()
            take = min(g - len(out), self.num_blocks - self.state.cursor)
            out.extend(self._perm[self.state.cursor : self.state.cursor + take].tolist())
            self.state.cursor += take
        return out

    def remaining_in_epoch(self) -> int:
        return self.num_blocks - self.state.cursor

    def _advance_epoch(self) -> None:
        self.state.epoch += 1
        self.state.cursor = 0
        self._perm = _epoch_permutation(self.state.seed, self.state.epoch, self.num_blocks)

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> dict[str, int]:
        return self.state.to_dict()

    @classmethod
    def from_state_dict(cls, num_blocks: int, d: dict[str, int]) -> "BlockSampler":
        return cls(num_blocks, state=SamplerState.from_dict(d))

    def batches(self, g: int, *, max_batches: int | None = None) -> Iterator[list[int]]:
        """Iterate block-level samples until the epoch's blocks are used up."""
        count = 0
        while self.remaining_in_epoch() > 0:
            if max_batches is not None and count >= max_batches:
                return
            yield self.sample(min(g, self.remaining_in_epoch()))
            count += 1


@dataclasses.dataclass
class HostAssignment:
    """Deal of block ids to hosts for one epoch (multi-host training)."""

    host_blocks: dict[int, list[int]]

    def blocks_for(self, host: int) -> list[int]:
        return self.host_blocks.get(host, [])

    def redistribute(self, failed_hosts: Sequence[int]) -> "HostAssignment":
        """Re-deal a failed host's blocks to the survivors (round-robin).

        Theorem 1 makes the re-dealt unions statistically valid: unions of
        RSP blocks in corpus proportion are RSP blocks of the union.
        """
        failed = set(failed_hosts)
        survivors = sorted(h for h in self.host_blocks if h not in failed)
        if not survivors:
            raise ValueError("no surviving hosts")
        orphaned: list[int] = []
        for h in sorted(failed):
            orphaned.extend(self.host_blocks.get(h, []))
        new = {h: list(self.host_blocks[h]) for h in survivors}
        for i, b in enumerate(orphaned):
            new[survivors[i % len(survivors)]].append(b)
        return HostAssignment(new)


def deal_blocks(
    num_blocks: int, num_hosts: int, seed: int = 0, epoch: int = 0
) -> HostAssignment:
    """Deterministically deal a fresh epoch permutation across hosts."""
    perm = _epoch_permutation(seed, epoch, num_blocks)
    return HostAssignment(
        {h: perm[h::num_hosts].tolist() for h in range(num_hosts)}
    )
