"""Core dataclasses for the Random Sample Partition (RSP) data model.

Terminology follows the paper:
  N  -- number of records in the big data set ``D``
  P  -- number of *original* data blocks (the chunking stage)
  K  -- number of RSP data blocks produced
  n  -- records per RSP data block (n = N / K)
  delta -- records per sub-block sliced from a randomized original block.

The paper states ``delta = n / K`` under its experimental setting P == K.  In
general each RSP block is assembled from one sub-block of each of the P
original blocks, hence ``delta = n / P = N / (P * K)``; we implement the
general form and keep the paper's P == K as the default configuration.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any


@dataclasses.dataclass(frozen=True)
class RSPSpec:
    """Static description of an RSP layout of a data set."""

    num_records: int            # N
    num_blocks: int             # K
    num_original_blocks: int    # P
    record_shape: tuple[int, ...] = ()
    dtype: str = "float32"
    seed: int = 0

    def __post_init__(self) -> None:
        if self.num_records <= 0 or self.num_blocks <= 0:
            raise ValueError("num_records and num_blocks must be positive")
        if self.num_records % self.num_blocks != 0:
            raise ValueError(
                f"N={self.num_records} must be divisible by K={self.num_blocks}"
            )
        if self.num_original_blocks <= 0:
            raise ValueError("num_original_blocks must be positive")
        if self.num_records % self.num_original_blocks != 0:
            raise ValueError(
                f"N={self.num_records} must be divisible by P="
                f"{self.num_original_blocks}"
            )
        if (self.num_records // self.num_original_blocks) % self.num_blocks != 0:
            raise ValueError(
                "original block size N/P must be divisible by K so sub-blocks"
                " have uniform size delta = N/(P*K)"
            )

    @property
    def block_size(self) -> int:
        """n -- records per RSP block."""
        return self.num_records // self.num_blocks

    @property
    def original_block_size(self) -> int:
        return self.num_records // self.num_original_blocks

    @property
    def slice_size(self) -> int:
        """delta -- records per sub-block."""
        return self.num_records // (self.num_original_blocks * self.num_blocks)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @classmethod
    def from_json(cls, payload: str) -> "RSPSpec":
        raw: dict[str, Any] = json.loads(payload)
        raw["record_shape"] = tuple(raw.get("record_shape", ()))
        return cls(**raw)


@dataclasses.dataclass(frozen=True)
class BlockDescriptor:
    """One RSP data block inside a stored RSP (see core.registry)."""

    block_id: int
    num_records: int
    path: str = ""
    checksum: str = ""


@dataclasses.dataclass
class SamplerState:
    """O(1) resumable state of the block-level sampler (Definition 4).

    ``seed``/``epoch`` regenerate the epoch permutation deterministically;
    ``cursor`` is the number of blocks already consumed this epoch.  This pair
    of integers *is* the entire data-pipeline checkpoint.
    """

    seed: int
    epoch: int = 0
    cursor: int = 0

    def to_dict(self) -> dict[str, int]:
        return {"seed": self.seed, "epoch": self.epoch, "cursor": self.cursor}

    @classmethod
    def from_dict(cls, d: dict[str, int]) -> "SamplerState":
        return cls(seed=int(d["seed"]), epoch=int(d["epoch"]), cursor=int(d["cursor"]))
