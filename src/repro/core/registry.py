"""On-disk RSP store: the 'generated in advance and stored on the cluster'
half of the paper.  A partition is materialized once; afterwards block-level
samples are served by path lookup (no scan of the corpus).

Layout:
    <root>/manifest.json          RSPSpec + block descriptors + checksums
    <root>/block_00042.npy        one RSP data block per file (mmap-readable)
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Iterable

import numpy as np

from repro.core.types import BlockDescriptor, RSPSpec


def _checksum(arr: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(str(arr.shape).encode())
    h.update(str(arr.dtype).encode())
    h.update(np.ascontiguousarray(arr).data)
    return h.hexdigest()[:16]


class RSPStore:
    """Directory-backed store of one RSP data model."""

    MANIFEST = "manifest.json"

    def __init__(self, root: str):
        self.root = root

    # -- write --------------------------------------------------------------
    def write_partition(self, blocks: np.ndarray | Iterable[np.ndarray], spec: RSPSpec) -> None:
        os.makedirs(self.root, exist_ok=True)
        descriptors: list[BlockDescriptor] = []
        for k, block in enumerate(blocks):
            block = np.asarray(block)
            path = self._block_path(k)
            # atomic write: temp file + rename
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            os.close(fd)
            np.save(tmp, block, allow_pickle=False)
            os.replace(tmp + ".npy" if os.path.exists(tmp + ".npy") else tmp, path)
            descriptors.append(
                BlockDescriptor(
                    block_id=k,
                    num_records=int(block.shape[0]),
                    path=os.path.basename(path),
                    checksum=_checksum(block),
                )
            )
        manifest = {
            "spec": json.loads(spec.to_json()),
            "blocks": [dataclasses.asdict(d) for d in descriptors],
        }
        tmp_manifest = os.path.join(self.root, self.MANIFEST + ".tmp")
        with open(tmp_manifest, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp_manifest, os.path.join(self.root, self.MANIFEST))

    # -- read ---------------------------------------------------------------
    def spec(self) -> RSPSpec:
        return RSPSpec.from_json(json.dumps(self._manifest()["spec"]))

    def descriptors(self) -> list[BlockDescriptor]:
        return [BlockDescriptor(**d) for d in self._manifest()["blocks"]]

    def load_block(self, block_id: int, *, mmap: bool = True, verify: bool = False) -> np.ndarray:
        path = self._block_path(block_id)
        arr = np.load(path, mmap_mode="r" if mmap else None, allow_pickle=False)
        if verify:
            want = self.descriptors()[block_id].checksum
            got = _checksum(np.asarray(arr))
            if want != got:
                raise IOError(f"checksum mismatch for block {block_id}: {want} != {got}")
        return arr

    def load_blocks(self, block_ids: Iterable[int], **kw) -> np.ndarray:
        return np.stack([np.asarray(self.load_block(b, **kw)) for b in block_ids])

    def num_blocks(self) -> int:
        return len(self._manifest()["blocks"])

    # -- internals ----------------------------------------------------------
    def _manifest(self) -> dict:
        with open(os.path.join(self.root, self.MANIFEST)) as f:
            return json.load(f)

    def _block_path(self, block_id: int) -> str:
        return os.path.join(self.root, f"block_{block_id:05d}.npy")
