"""On-disk RSP store: the 'generated in advance and stored on the cluster'
half of the paper.  A partition is materialized once; afterwards block-level
samples are served by path lookup (no scan of the corpus).

Layout:
    <root>/manifest.json          RSPSpec + block descriptors + checksums
                                  (+ optional per-block summaries and meta)
    <root>/block_00042.npy        one RSP data block per file (mmap-readable)

The parsed manifest (and the descriptors built from it) is cached per store
instance and invalidated when the manifest file's mtime changes, so repeated
``load_block(verify=True)`` calls don't re-read and re-parse JSON.

Prefer the ``repro.rsp.RSPDataset`` facade (``ds.save(path)`` /
``rsp.open(path)``) for new code; it plumbs this store underneath.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Iterable

import numpy as np

from repro.core.types import BlockDescriptor, RSPSpec


def _checksum(arr: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(str(arr.shape).encode())
    h.update(str(arr.dtype).encode())
    h.update(np.ascontiguousarray(arr).data)
    return h.hexdigest()[:16]


class RSPStore:
    """Directory-backed store of one RSP data model."""

    MANIFEST = "manifest.json"

    def __init__(self, root: str):
        self.root = root
        self._cached_manifest: dict | None = None
        self._cached_descriptors: list[BlockDescriptor] | None = None
        self._cached_stat: tuple[int, int] | None = None

    # -- write --------------------------------------------------------------
    def write_partition(
        self,
        blocks: np.ndarray | Iterable[np.ndarray],
        spec: RSPSpec,
        *,
        summaries: list[dict] | None = None,
        meta: dict | None = None,
    ) -> None:
        """Materialize blocks + manifest.  ``summaries`` (per-block sketch
        dicts, see repro.rsp.summaries) and ``meta`` (free-form dataset
        metadata) ride along in the manifest when provided.

        Single-writer per store root: temp names are deterministic
        (``<block>.tmp.npy`` -> one ``os.replace``), so concurrent writers
        to the same root could publish each other's half-written temps.
        Readers are always safe -- blocks and manifest appear atomically."""
        os.makedirs(self.root, exist_ok=True)
        descriptors: list[BlockDescriptor] = []
        for k, block in enumerate(blocks):
            block = np.asarray(block)
            path = self._block_path(k)
            # atomic write: deterministic temp name, one replace.  The .npy
            # suffix stops np.save from appending its own, so the temp file
            # written is exactly the file renamed.
            tmp = path + ".tmp.npy"
            np.save(tmp, block, allow_pickle=False)
            os.replace(tmp, path)
            descriptors.append(
                BlockDescriptor(
                    block_id=k,
                    num_records=int(block.shape[0]),
                    path=os.path.basename(path),
                    checksum=_checksum(block),
                )
            )
        # drop stale blocks from any previous, larger partition in this root
        # so derived paths beyond the new K cannot serve old data
        for stray in os.listdir(self.root):
            if stray.startswith("block_") and stray.endswith(".npy"):
                try:
                    k = int(stray[len("block_"):-len(".npy")])
                except ValueError:
                    continue
                if k >= len(descriptors):
                    os.remove(os.path.join(self.root, stray))
        manifest = {
            "spec": json.loads(spec.to_json()),
            "blocks": [dataclasses.asdict(d) for d in descriptors],
        }
        if summaries is not None:
            manifest["summaries"] = summaries
        if meta is not None:
            manifest["meta"] = meta
        tmp_manifest = os.path.join(self.root, self.MANIFEST + ".tmp")
        with open(tmp_manifest, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp_manifest, os.path.join(self.root, self.MANIFEST))
        self._invalidate()

    # -- read ---------------------------------------------------------------
    def spec(self) -> RSPSpec:
        return RSPSpec.from_json(json.dumps(self._manifest()["spec"]))

    def descriptors(self) -> list[BlockDescriptor]:
        self._manifest()  # refresh cache if the file changed
        if self._cached_descriptors is None:
            self._cached_descriptors = [
                BlockDescriptor(**d) for d in self._cached_manifest["blocks"]
            ]
        return self._cached_descriptors

    def summaries(self) -> list[dict] | None:
        """Per-block summary sketches from the manifest (None if absent)."""
        return self._manifest().get("summaries")

    def meta(self) -> dict:
        """Free-form dataset metadata from the manifest ({} if absent)."""
        return self._manifest().get("meta", {})

    def load_block(self, block_id: int, *, mmap: bool = True, verify: bool = False) -> np.ndarray:
        n = self.num_blocks()
        if not 0 <= block_id < n:
            raise IndexError(f"block {block_id} out of range [0, {n})")
        path = self._block_path(block_id)
        arr = np.load(path, mmap_mode="r" if mmap else None, allow_pickle=False)
        if verify:
            want = self.descriptors()[block_id].checksum
            got = _checksum(np.asarray(arr))
            if want != got:
                raise IOError(f"checksum mismatch for block {block_id}: {want} != {got}")
        return arr

    def load_blocks(self, block_ids: Iterable[int], **kw) -> np.ndarray:
        return np.stack([np.asarray(self.load_block(b, **kw)) for b in block_ids])

    def num_blocks(self) -> int:
        return len(self._manifest()["blocks"])

    # -- internals ----------------------------------------------------------
    def _invalidate(self) -> None:
        self._cached_manifest = None
        self._cached_descriptors = None
        self._cached_stat = None

    def _manifest(self) -> dict:
        """Parsed manifest, cached until the file changes.  The key is
        (mtime_ns, size) so rewrites within one coarse-mtime tick are still
        caught when the payload length differs."""
        path = os.path.join(self.root, self.MANIFEST)
        st = os.stat(path)
        key = (st.st_mtime_ns, st.st_size)
        if self._cached_manifest is None or key != self._cached_stat:
            with open(path) as f:
                self._cached_manifest = json.load(f)
            self._cached_descriptors = None
            self._cached_stat = key
        return self._cached_manifest

    def _block_path(self, block_id: int) -> str:
        return os.path.join(self.root, f"block_{block_id:05d}.npy")
