"""On-disk RSP store: the 'generated in advance and stored on the cluster'
half of the paper.  A partition is materialized once; afterwards block-level
samples are served by path lookup (no scan of the corpus).

Layout:
    <root>/manifest.json          RSPSpec + block descriptors + checksums
                                  (+ optional per-block summaries and meta)
    <root>/block_00042.npy        one RSP data block per file (mmap-readable)

The parsed manifest (and the descriptors built from it) is cached per store
instance and invalidated when the manifest file's mtime changes, so repeated
``load_block(verify=True)`` calls don't re-read and re-parse JSON.

Prefer the ``repro.rsp.RSPDataset`` facade (``ds.save(path)`` /
``rsp.open(path)``) for new code; it plumbs this store underneath.
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
from typing import Iterable

import numpy as np

from repro.core.types import BlockDescriptor, RSPSpec

_CHECKSUM_STEP_BYTES = 4 << 20


def _checksum(arr: np.ndarray) -> str:
    """Content hash of one block.  Hashing proceeds in bounded row slabs so
    memmapped blocks larger than RAM stream through without materializing."""
    h = hashlib.sha256()
    h.update(str(arr.shape).encode())
    h.update(str(arr.dtype).encode())
    if arr.ndim == 0 or arr.shape[0] == 0:
        h.update(np.ascontiguousarray(arr).data)
        return h.hexdigest()[:16]
    row_bytes = max(1, arr.nbytes // arr.shape[0])
    step = max(1, _CHECKSUM_STEP_BYTES // row_bytes)
    for a in range(0, arr.shape[0], step):
        h.update(np.ascontiguousarray(arr[a : a + step]).data)
    return h.hexdigest()[:16]


class RSPStore:
    """Directory-backed store of one RSP data model."""

    MANIFEST = "manifest.json"
    SKETCHES = "sketches.json"

    def __init__(self, root: str):
        self.root = root
        self._cached_manifest: dict | None = None
        self._cached_descriptors: list[BlockDescriptor] | None = None
        self._cached_stat: tuple[int, int] | None = None
        # in-memory handoff from a streaming ingest: the SketchSuites folded
        # during the write, so the dataset facade need not re-parse the
        # (large) sketch sidecar it just streamed out.  Reopened stores
        # leave this None and parse the sidecar on demand.
        self.last_ingest_summaries: list | None = None

    # -- write --------------------------------------------------------------
    def write_partition(
        self,
        blocks: np.ndarray | Iterable[np.ndarray],
        spec: RSPSpec,
        *,
        summaries: list | None = None,
        meta: dict | None = None,
        sketch_schema: dict | None = None,
    ) -> None:
        """Materialize blocks + manifest.  ``summaries`` -- per-block sketch
        dicts or objects with ``to_dict()`` (see repro.rsp.sketch /
        repro.rsp.summaries) -- ``meta`` (free-form dataset metadata) and
        ``sketch_schema`` (the versioned descriptor of the sketch kinds each
        summary carries) ride along when provided.  With a ``sketch_schema``
        the (large) sketch payloads go to a ``sketches.json`` sidecar and
        the manifest stays light; without one they embed inline, which is
        the v1 layout old readers understand.

        Single-writer per store root: temp names are deterministic
        (``<block>.tmp.npy`` -> one ``os.replace``), so concurrent writers
        to the same root could publish each other's half-written temps.
        Readers are always safe -- blocks and manifest appear atomically."""
        os.makedirs(self.root, exist_ok=True)
        descriptors: list[BlockDescriptor] = []
        for k, block in enumerate(blocks):
            block = np.asarray(block)
            path = self._block_path(k)
            # atomic write: deterministic temp name, one replace.  The .npy
            # suffix stops np.save from appending its own, so the temp file
            # written is exactly the file renamed.
            tmp = path + ".tmp.npy"
            np.save(tmp, block, allow_pickle=False)
            os.replace(tmp, path)
            descriptors.append(
                BlockDescriptor(
                    block_id=k,
                    num_records=int(block.shape[0]),
                    path=os.path.basename(path),
                    checksum=_checksum(block),
                )
            )
        self._sweep_stale(len(descriptors))
        self._publish_manifest(
            spec, descriptors, summaries=summaries, meta=meta,
            sketch_schema=sketch_schema,
        )

    def create_writer(self, spec: RSPSpec) -> "PartitionWriter":
        """Open a :class:`PartitionWriter` for streaming ingest: preallocated
        per-block ``.npy`` temps accepting offset-range row writes, published
        atomically by ``finalize()`` (see ``repro.rsp.ingest``)."""
        return PartitionWriter(self, spec)

    # -- read ---------------------------------------------------------------
    def spec(self) -> RSPSpec:
        return RSPSpec.from_json(json.dumps(self._manifest()["spec"]))

    def descriptors(self) -> list[BlockDescriptor]:
        self._manifest()  # refresh cache if the file changed
        if self._cached_descriptors is None:
            self._cached_descriptors = [
                BlockDescriptor(**d) for d in self._cached_manifest["blocks"]
            ]
        return self._cached_descriptors

    def summaries(self) -> list[dict] | None:
        """Per-block summary sketch dicts (None if absent).  v1 manifests
        carry them inline (cached with the manifest); v2 stores keep them in
        the ``sketches.json`` sidecar, parsed on every call and *not*
        cached -- the payload is large and callers (``RSPDataset``,
        ``BlockSource``) cache the converted suites instead."""
        m = self._manifest()
        if "summaries" in m:
            return m["summaries"]
        name = m.get("sketches_file")
        if name is None:
            return None
        with open(os.path.join(self.root, name)) as f:
            return json.load(f)["summaries"]

    def sketch_schema(self) -> dict | None:
        """Versioned sketch-schema descriptor (None for v1 manifests, which
        predate suites; their summaries upgrade lazily on load)."""
        return self._manifest().get("sketch_schema")

    def meta(self) -> dict:
        """Free-form dataset metadata from the manifest ({} if absent)."""
        return self._manifest().get("meta", {})

    def load_block(self, block_id: int, *, mmap: bool = True, verify: bool = False) -> np.ndarray:
        n = self.num_blocks()
        if not 0 <= block_id < n:
            raise IndexError(f"block {block_id} out of range [0, {n})")
        path = self._block_path(block_id)
        arr = np.load(path, mmap_mode="r" if mmap else None, allow_pickle=False)
        if verify:
            want = self.descriptors()[block_id].checksum
            got = _checksum(np.asarray(arr))
            if want != got:
                raise IOError(f"checksum mismatch for block {block_id}: {want} != {got}")
        return arr

    def load_blocks(self, block_ids: Iterable[int], **kw) -> np.ndarray:
        return np.stack([np.asarray(self.load_block(b, **kw)) for b in block_ids])

    def num_blocks(self) -> int:
        return len(self._manifest()["blocks"])

    # -- internals ----------------------------------------------------------
    def _sweep_stale(self, keep_blocks: int) -> None:
        """Drop stale blocks from any previous, larger partition in this root
        (so derived paths beyond the new K cannot serve old data) *and*
        orphaned ``.tmp.npy`` temps left by a crashed writer -- the
        single-writer contract means no live writer's temps coexist with a
        completed write."""
        for stray in os.listdir(self.root):
            if not stray.startswith("block_") or not stray.endswith(".npy"):
                continue
            path = os.path.join(self.root, stray)
            if stray.endswith(".tmp.npy"):
                with contextlib.suppress(FileNotFoundError):
                    os.remove(path)
                continue
            try:
                k = int(stray[len("block_"):-len(".npy")])
            except ValueError:
                continue
            if k >= keep_blocks:
                with contextlib.suppress(FileNotFoundError):
                    os.remove(path)

    def _publish_manifest(
        self,
        spec: RSPSpec,
        descriptors: list[BlockDescriptor],
        *,
        summaries: list | None = None,
        meta: dict | None = None,
        sketch_schema: dict | None = None,
    ) -> None:
        """Atomically publish the manifest -- the last step of any write, so
        readers never observe a manifest ahead of its blocks (the sketch
        sidecar, when any, lands just before it)."""
        manifest = {
            "spec": json.loads(spec.to_json()),
            "blocks": [dataclasses.asdict(d) for d in descriptors],
        }
        sketches_path = os.path.join(self.root, self.SKETCHES)
        if summaries is not None and sketch_schema is not None:
            # v2 layout: heavy sketch payloads stream to the sidecar one
            # suite at a time -- the writer never materializes the whole
            # serialized payload, and manifest reads stay cheap
            tmp = sketches_path + ".tmp"
            with open(tmp, "w") as f:
                f.write('{"version": %d, "summaries": [' % int(sketch_schema["version"]))
                for i, s in enumerate(summaries):
                    if i:
                        f.write(",")
                    json.dump(s.to_dict() if hasattr(s, "to_dict") else s, f)
                f.write("]}")
            os.replace(tmp, sketches_path)
            manifest["sketches_file"] = self.SKETCHES
            manifest["sketch_schema"] = sketch_schema
        elif summaries is not None:
            # v1 layout (no schema descriptor): inline summary dicts
            manifest["summaries"] = [
                s.to_dict() if hasattr(s, "to_dict") else s for s in summaries
            ]
        else:
            # this partition has no summaries: retire any stale sidecar so
            # a future layout change cannot pair it with this manifest
            with contextlib.suppress(FileNotFoundError):
                os.remove(sketches_path)
        if meta is not None:
            manifest["meta"] = meta
        tmp_manifest = os.path.join(self.root, self.MANIFEST + ".tmp")
        with open(tmp_manifest, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp_manifest, os.path.join(self.root, self.MANIFEST))
        self._invalidate()

    def _invalidate(self) -> None:
        self._cached_manifest = None
        self._cached_descriptors = None
        self._cached_stat = None

    def _manifest(self) -> dict:
        """Parsed manifest, cached until the file changes.  The key is
        (mtime_ns, size) so rewrites within one coarse-mtime tick are still
        caught when the payload length differs."""
        path = os.path.join(self.root, self.MANIFEST)
        st = os.stat(path)
        key = (st.st_mtime_ns, st.st_size)
        if self._cached_manifest is None or key != self._cached_stat:
            with open(path) as f:
                self._cached_manifest = json.load(f)
            self._cached_descriptors = None
            self._cached_stat = key
        return self._cached_manifest

    def _block_path(self, block_id: int) -> str:
        return os.path.join(self.root, f"block_{block_id:05d}.npy")


class PartitionWriter:
    """Offset-range block writer for streaming ingest (``repro.rsp.ingest``).

    Each block is preallocated as a ``<block>.tmp.npy`` temp via
    ``np.lib.format.open_memmap`` so row slices land directly at their
    destination offsets with no in-RAM assembly.  ``finalize()`` flushes,
    computes checksums *from the finished files*, retracts any previously
    published manifest, renames every temp into place, sweeps strays, and
    publishes the new manifest last.  A crash before the retraction leaves
    the old store fully intact (plus ``.tmp.npy`` orphans the next write
    sweeps); a crash after it leaves *no* manifest -- readers see a clean
    absence, never a stale manifest over replaced block files.

    Single-writer per store root, like ``write_partition``.
    """

    def __init__(self, store: RSPStore, spec: RSPSpec):
        os.makedirs(store.root, exist_ok=True)
        self.store = store
        self.spec = spec
        shape = (spec.block_size, *spec.record_shape)
        dtype = np.dtype(spec.dtype)
        self._tmp_paths = [
            store._block_path(k) + ".tmp.npy" for k in range(spec.num_blocks)
        ]
        self._mms: list[np.memmap] | None = [
            np.lib.format.open_memmap(p, mode="w+", dtype=dtype, shape=shape)
            for p in self._tmp_paths
        ]

    def write_rows(
        self, block_id: int, offsets: np.ndarray, values: np.ndarray
    ) -> None:
        """Write ``values`` into rows ``offsets`` of block ``block_id``.
        Disjoint offset ranges may be written concurrently from worker
        threads; each (block, row) is written exactly once per ingest."""
        self._mms[block_id][offsets] = values

    def finalize(
        self,
        *,
        summaries: list[dict] | None = None,
        meta: dict | None = None,
        sketch_schema: dict | None = None,
    ) -> RSPStore:
        """Publish the partition: checksum finished temps, rename into place,
        sweep strays, write the manifest.  Returns the store."""
        if self._mms is None:
            raise RuntimeError("writer already finalized or aborted")
        descriptors: list[BlockDescriptor] = []
        for k, mm in enumerate(self._mms):
            mm.flush()
            checksum = _checksum(mm)
            descriptors.append(
                BlockDescriptor(
                    block_id=k,
                    num_records=int(mm.shape[0]),
                    path=os.path.basename(self.store._block_path(k)),
                    checksum=checksum,
                )
            )
        self._mms = None  # drop the memmap references before renaming
        # retract any previously published manifest BEFORE touching its block
        # files: if we die mid-swap, readers find no store rather than an old
        # manifest silently describing a mixture of old and new blocks
        with contextlib.suppress(FileNotFoundError):
            os.remove(os.path.join(self.store.root, self.store.MANIFEST))
        self.store._invalidate()
        for k, tmp in enumerate(self._tmp_paths):
            os.replace(tmp, self.store._block_path(k))
        self.store._sweep_stale(len(descriptors))
        self.store._publish_manifest(
            self.spec, descriptors, summaries=summaries, meta=meta,
            sketch_schema=sketch_schema,
        )
        return self.store

    def abort(self) -> None:
        """Remove the temps (failed ingest); the store root is left exactly
        as it was -- in particular any previously published manifest and its
        blocks stay intact."""
        self._mms = None
        for tmp in self._tmp_paths:
            with contextlib.suppress(FileNotFoundError):
                os.remove(tmp)
