"""Block-level statistics estimation (paper Sec. 8, Figs. 3/4).

Per-block summaries are combined with Chan-style parallel moments so the
estimator is a streaming fold over block-level samples: after ``b`` blocks the
estimate equals the record-level statistic over the union of those blocks,
and (because each block is a random sample) is an unbiased estimator of the
full-data statistic with SE shrinking as 1/sqrt(b*n).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class MomentStats:
    """Count / mean / M2 (+ extrema) per feature, combinable."""

    count: float
    mean: np.ndarray
    m2: np.ndarray
    min: np.ndarray
    max: np.ndarray

    @property
    def variance(self) -> np.ndarray:
        return self.m2 / np.maximum(self.count - 1.0, 1.0)

    @property
    def std(self) -> np.ndarray:
        return np.sqrt(self.variance)

    @property
    def stderr(self) -> np.ndarray:
        return self.std / np.sqrt(max(self.count, 1.0))


@jax.jit
def _block_moments(block: Array) -> tuple[Array, Array, Array, Array]:
    x = block.reshape(block.shape[0], -1).astype(jnp.float32)
    mean = x.mean(axis=0)
    m2 = ((x - mean) ** 2).sum(axis=0)
    return mean, m2, x.min(axis=0), x.max(axis=0)


def block_moments(block: Array) -> MomentStats:
    mean, m2, mn, mx = _block_moments(block)
    return MomentStats(
        count=float(block.shape[0]),
        mean=np.asarray(mean),
        m2=np.asarray(m2),
        min=np.asarray(mn),
        max=np.asarray(mx),
    )


def combine_moments(a: MomentStats, b: MomentStats) -> MomentStats:
    """Chan et al. parallel combine -- exact, order-independent (delegates
    to the shared :func:`repro.core.moments.chan_merge`)."""
    from repro.core.moments import chan_merge

    n, mean, m2 = chan_merge(a.count, a.mean, a.m2, b.count, b.mean, b.m2)
    return MomentStats(
        count=n,
        mean=mean,
        m2=m2,
        min=np.minimum(a.min, b.min),
        max=np.maximum(a.max, b.max),
    )


class BlockLevelEstimator:
    """Streaming block-level estimator with convergence history (Figs. 3/4)."""

    def __init__(self) -> None:
        self._acc: MomentStats | None = None
        self.history_mean: list[np.ndarray] = []
        self.history_std: list[np.ndarray] = []
        self.blocks_seen = 0

    def update(self, block: Array) -> None:
        stats = block_moments(block)
        self._acc = stats if self._acc is None else combine_moments(self._acc, stats)
        self.blocks_seen += 1
        self.history_mean.append(self._acc.mean.copy())
        self.history_std.append(self._acc.std.copy())

    def consume(
        self,
        blocks,
        *,
        rel_tol: float | None = None,
        window: int = 3,
    ) -> "BlockLevelEstimator":
        """Fold a block stream (e.g. ``BlockExecutor.map_blocks(None, ids)``)
        into the estimator.  With ``rel_tol`` set, stop early once
        :meth:`converged` fires -- on a prefetching stream the next blocks are
        already in flight, so the scan overlaps fetch and combine."""
        for block in blocks:
            self.update(block)
            if rel_tol is not None and self.converged(rel_tol, window):
                break
        return self

    @property
    def stats(self) -> MomentStats:
        if self._acc is None:
            raise ValueError("no blocks consumed yet")
        return self._acc

    def converged(self, rel_tol: float = 1e-3, window: int = 3) -> bool:
        """Plateau test: relative change of the mean over the last ``window``
        updates below ``rel_tol`` (the paper's stopping idea applied to
        estimation)."""
        if len(self.history_mean) <= window:
            return False
        cur = self.history_mean[-1]
        prev = self.history_mean[-1 - window]
        denom = np.maximum(np.abs(cur), 1e-12)
        return bool(np.max(np.abs(cur - prev) / denom) < rel_tol)


def streaming_estimate(
    executor,
    ids: Sequence[int],
    *,
    rel_tol: float | None = None,
    window: int = 3,
) -> BlockLevelEstimator:
    """Run the block-level estimation loop over an executor's prefetched
    stream: ``executor`` is anything with ``map_blocks(fn, ids)`` (see
    ``repro.rsp.engine.BlockExecutor``); blocks load ahead of the combine."""
    return BlockLevelEstimator().consume(
        executor.map_blocks(None, ids), rel_tol=rel_tol, window=window
    )


@jax.jit
def batched_block_moments(blocks: Array) -> tuple[Array, Array]:
    """vmap'd per-block (mean, std) for a stacked block sample [g, n, M]."""
    def one(b: Array) -> tuple[Array, Array]:
        x = b.reshape(b.shape[0], -1).astype(jnp.float32)
        return x.mean(axis=0), x.std(axis=0, ddof=1)

    return jax.vmap(one)(blocks)


def block_histogram(block: Array, *, bins: int, lo, hi) -> np.ndarray:
    """Fixed-grid histogram per feature [F, bins]; combinable by addition (for
    block-level quantile estimation).  ``lo`` / ``hi`` are scalars or
    per-feature arrays.  Mass outside ``[lo, hi]`` is clipped into the edge
    bins -- every histogram sums to the block's record count, so merged
    histograms stay consistent with merged counts (values beyond the grid
    used to be dropped silently, biasing tail quantiles inward)."""
    from repro.kernels.block_sketch.ref import _grid, grid_histogram

    x = np.asarray(block, dtype=np.float64).reshape(np.shape(block)[0], -1)
    glo, ghi = _grid(lo, hi, x.shape[1])
    return grid_histogram(x, glo, ghi, bins)


def quantile_from_histogram(
    hist: np.ndarray, qs: Sequence[float], *, lo, hi
) -> np.ndarray:
    """Per-feature quantiles [F, Q] from a combined histogram [F, bins],
    linearly interpolated *within* the covering bin (quantiles used to snap
    to the bin's upper edge, a +half-bin-width bias).  ``lo`` / ``hi`` are
    scalars or per-feature arrays matching the histogram's grid."""
    hist = np.asarray(hist, dtype=np.float64)
    f, bins = hist.shape
    lo = np.broadcast_to(np.asarray(lo, dtype=np.float64), (f,))
    hi = np.broadcast_to(np.asarray(hi, dtype=np.float64), (f,))
    width = (hi - lo) / bins                                     # [F]
    qs = np.asarray(qs, dtype=np.float64)
    cdf = np.cumsum(hist, axis=-1)                               # [F, bins]
    total = np.maximum(cdf[:, -1:], 1.0)                         # [F, 1]
    target = qs[None, :] * total                                 # [F, Q]
    idx = np.argmax(cdf[:, None, :] >= target[:, :, None], axis=-1)  # [F, Q]
    below = np.where(idx > 0, np.take_along_axis(cdf, np.maximum(idx - 1, 0), 1), 0.0)
    in_bin = np.take_along_axis(hist, idx, axis=1)               # [F, Q]
    frac = np.clip((target - below) / np.maximum(in_bin, 1e-300), 0.0, 1.0)
    return lo[:, None] + (idx + frac) * width[:, None]
