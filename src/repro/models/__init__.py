# Intentionally minimal: submodules are imported directly
# (repro.models.api, repro.models.transformer, ...) to avoid import cycles
# with repro.distributed.sharding.
