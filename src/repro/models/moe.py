"""Mixture-of-Experts layer with top-k routing and capacity-bounded,
sort-free local dispatch.

Tokens are viewed as ``[G, T_local, d]`` where ``G`` (``moe_groups``) matches
the data-parallel axis size at launch time.  Dispatch is *local to a group*:
each group scatters its tokens into a per-group expert buffer
``[G, E, C, d]`` (G -> data, E -> model), so the only cross-device traffic is
the combine reduction over the model axis -- the pattern EP hardware wants.
Capacity is per-group: ``C = ceil(T_local * k / E * capacity_factor)``;
overflow tokens are dropped (their combine weight is zero), as in
Switch/GShard.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import ParamSpec, linear_spec

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                 # per-expert hidden
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # perf: compute capacity positions via a stable argsort over assignments
    # instead of the one-hot running count (removes the [T*k, E] int tensor
    # and its cumsum -- the dominant MoE memory term).  Same semantics:
    # first-come-first-served within each expert.
    sort_dispatch: bool = False


def moe_specs(cfg: MoEConfig) -> dict:
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    # experts carry the TP split; the per-expert hidden dim stays local
    # ("expert_ff" -> replicated) so no tensor maps 'model' twice.
    return {
        "router": linear_spec(d, E, ("embed", "experts")),
        "gate": ParamSpec((E, d, f), ("experts", "embed", "expert_ff"), "normal", 1.0 / math.sqrt(d)),
        "up": ParamSpec((E, d, f), ("experts", "embed", "expert_ff"), "normal", 1.0 / math.sqrt(d)),
        "down": ParamSpec((E, f, d), ("experts", "expert_ff", "embed"), "normal", 1.0 / math.sqrt(f)),
    }


def _sorted_positions(flat_e: Array, num_experts: int) -> Array:
    """Position of each assignment within its expert (first-come order),
    via one stable argsort per group -- O(A log A) memory-light replacement
    for the one-hot cumsum."""

    def per_group(e: Array) -> Array:
        A = e.shape[0]
        order = jnp.argsort(e, stable=True)                     # [A]
        sorted_e = e[order]
        counts = jnp.zeros((num_experts,), jnp.int32).at[e].add(1)
        starts = jnp.cumsum(counts) - counts                    # [E]
        ranks = jnp.arange(A, dtype=jnp.int32) - starts[sorted_e]
        return jnp.zeros((A,), jnp.int32).at[order].set(ranks)

    return jax.vmap(per_group)(flat_e)


def moe_capacity(tokens_per_group: int, cfg: MoEConfig) -> int:
    return max(
        1,
        int(math.ceil(tokens_per_group * cfg.top_k / cfg.num_experts * cfg.capacity_factor)),
    )


def moe_apply(
    params: dict,
    x: Array,                    # [B, S, d]
    cfg: MoEConfig,
    *,
    moe_groups: int = 1,
    dropless: bool = False,
    compute_dtype=jnp.bfloat16,
) -> tuple[Array, Array]:
    """Returns (output [B, S, d], aux_loss scalar).

    ``dropless=True`` (decode path) sizes capacity so no assignment can
    overflow (C = tokens-per-group), guaranteeing serve-time exactness.
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    G = moe_groups
    T = B * S
    if T % G != 0:
        raise ValueError(f"tokens {T} not divisible by moe_groups {G}")
    Tg = T // G
    C = Tg if dropless else moe_capacity(Tg, cfg)

    xt = constrain(x.reshape(G, Tg, d), ("moe_group", None, "embed"))

    # ---- routing (fp32 for numerics) ------------------------------------
    router_logits = jnp.einsum(
        "gtd,de->gte", xt.astype(jnp.float32), params["router"]["w"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(router_logits, axis=-1)              # [G, Tg, E]
    top_probs, top_idx = jax.lax.top_k(probs, k)                # [G, Tg, k]
    top_w = top_probs / jnp.maximum(top_probs.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing auxiliary loss (Switch) --------------------------
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(top_idx, E, dtype=jnp.float32), axis=(1, 2)
    )                                                           # [G, E] mean over Tg,k
    prob_frac = probs.mean(axis=1)                              # [G, E]
    aux = cfg.router_aux_weight * E * jnp.mean(
        jnp.sum(dispatch_frac * prob_frac, axis=-1)
    )

    # ---- capacity positions ------------------------------------------------
    flat_e = top_idx.reshape(G, Tg * k)
    if cfg.sort_dispatch:
        pos = _sorted_positions(flat_e, E)
    else:
        # baseline: running count via one-hot cumsum [G, Tg*k, E]
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(onehot, axis=1) - 1
        pos = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=-1)[..., 0]
    keep = pos < C
    w_flat = top_w.reshape(G, Tg * k) * keep.astype(jnp.float32)

    # ---- dispatch: tokens -> [G, E, C, d] ----------------------------------
    token_of_assign = jnp.tile(jnp.arange(Tg)[:, None], (1, k)).reshape(Tg * k)
    clipped_pos = jnp.minimum(pos, C - 1)

    if cfg.sort_dispatch:
        # slot-gather: scatter token *ids* into [E, C] (2 ints per slot),
        # then one gather builds the buffer -- never materializes the
        # [Tg*k, d] per-assignment activations (the dominant MoE buffer).
        def dispatch_group(xg, e_g, p_g, keep_g):
            slot_token = jnp.full((E, C), Tg, jnp.int32)         # Tg = padding row
            # dropped assignments get an out-of-range expert id so the
            # scatter discards them instead of clobbering slot (e, C-1)
            e_safe = jnp.where(keep_g, e_g, E).astype(jnp.int32)
            slot_token = slot_token.at[e_safe, p_g].set(
                token_of_assign.astype(jnp.int32), mode="drop"
            )
            xg_pad = jnp.concatenate(
                [xg.astype(compute_dtype), jnp.zeros((1, d), compute_dtype)], axis=0
            )
            return xg_pad[slot_token]                            # [E, C, d]

        buf = jax.vmap(dispatch_group)(xt, flat_e, clipped_pos, keep)
    else:
        # baseline: gather per-assignment activations then scatter-add
        def scatter_group(buf_g, xg, e_g, p_g, keep_g):
            src = xg[token_of_assign].astype(compute_dtype)
            src = src * keep_g[:, None].astype(compute_dtype)
            return buf_g.at[e_g, p_g].add(src, mode="drop")

        buf = jax.vmap(scatter_group)(
            jnp.zeros((G, E, C, d), compute_dtype), xt, flat_e, clipped_pos, keep
        )
    buf = constrain(buf, ("moe_group", "experts", None, "embed"))

    # ---- expert computation (stacked einsum over E) ------------------------
    h_gate = jnp.einsum("gecd,edf->gecf", buf, params["gate"].astype(compute_dtype))
    h_up = jnp.einsum("gecd,edf->gecf", buf, params["up"].astype(compute_dtype))
    h = constrain(jax.nn.silu(h_gate) * h_up, ("moe_group", "experts", None, "expert_ff"))
    y = jnp.einsum("gecf,efd->gecd", h, params["down"].astype(compute_dtype))
    y = constrain(y, ("moe_group", "experts", None, "embed"))

    # ---- combine: gather back and weight -----------------------------------
    def gather_group(y_g, e_g, p_g, w_g):
        vals = y_g[e_g, p_g]                                    # [Tg*k, d]
        vals = vals * w_g[:, None].astype(vals.dtype)
        return jnp.zeros((Tg, d), vals.dtype).at[token_of_assign].add(vals)

    out = jax.vmap(gather_group)(y, flat_e, clipped_pos, w_flat)
    out = constrain(out, ("moe_group", None, "embed"))
    return out.reshape(B, S, d).astype(compute_dtype), aux


def moe_ref(params: dict, x: Array, cfg: MoEConfig) -> Array:
    """Dense oracle: every token through its top-k experts, no capacity.

    O(T*k) gathers -- fine for tests, used to validate the dispatch path
    (tokens under capacity must match exactly).
    """
    B, S, d = x.shape
    xt = x.reshape(-1, d).astype(jnp.float32)
    logits = xt @ params["router"]["w"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_probs, top_idx = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_probs / jnp.maximum(top_probs.sum(-1, keepdims=True), 1e-9)

    def per_token(xi, ei, wi):
        def per_expert(e, w):
            g = xi @ params["gate"][e].astype(jnp.float32)
            u = xi @ params["up"][e].astype(jnp.float32)
            return w * ((jax.nn.silu(g) * u) @ params["down"][e].astype(jnp.float32))

        return jax.vmap(per_expert)(ei, wi).sum(0)

    out = jax.vmap(per_token)(xt, top_idx, top_w)
    return out.reshape(B, S, d)
