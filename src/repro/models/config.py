"""Unified model configuration covering all assigned architecture families."""

from __future__ import annotations

import dataclasses

from repro.models.attention import AttentionConfig
from repro.models.mamba2 import Mamba2Config
from repro.models.moe import MoEConfig
from repro.models.rwkv6 import RWKV6Config


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | rwkv | encoder
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    mlp_type: str = "swiglu"    # swiglu | gelu
    rope: bool = True
    rope_theta: float = 500000.0
    causal: bool = True
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # MoE
    num_experts: int = 0
    num_experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # SSM / hybrid (zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    ssm_chunk: int = 128
    attn_every: int = 0         # hybrid: shared attn block period
    # rwkv
    rwkv_head_dim: int = 64
    lora_rank: int = 32
    # execution
    remat: bool = True
    use_pallas: bool = False
    k_block: int = 512          # flash kv-block
    # beyond-paper perf flags (baseline keeps all off; see EXPERIMENTS.md §Perf)
    flat_attention: bool = False   # flat-head TP layout (even 'model' split)
    loss_seq_chunks: int = 0       # seq-chunked CE (stream fp32 logits)
    moe_sort_dispatch: bool = False  # argsort capacity positions

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def attention_config(self) -> AttentionConfig:
        return AttentionConfig(
            d_model=self.d_model if self.family != "hybrid" else self.d_model,
            num_heads=self.num_heads,
            num_kv_heads=self.num_kv_heads,
            head_dim=self.resolved_head_dim,
            qk_norm=self.qk_norm,
            qkv_bias=self.qkv_bias,
            rope=self.rope,
            rope_theta=self.rope_theta,
            causal=self.causal,
            norm_eps=self.norm_eps,
            k_block=self.k_block,
            flat=self.flat_attention,
        )

    def moe_config(self) -> MoEConfig:
        return MoEConfig(
            d_model=self.d_model,
            d_ff=self.d_ff,
            num_experts=self.num_experts,
            top_k=self.num_experts_per_token,
            capacity_factor=self.moe_capacity_factor,
            sort_dispatch=self.moe_sort_dispatch,
        )

    def mamba_config(self) -> Mamba2Config:
        return Mamba2Config(
            d_model=self.d_model,
            d_state=self.ssm_state,
            head_dim=self.ssm_head_dim,
            expand=self.ssm_expand,
            conv_kernel=self.conv_kernel,
            chunk=self.ssm_chunk,
            norm_eps=self.norm_eps,
        )

    def rwkv_config(self) -> RWKV6Config:
        return RWKV6Config(
            d_model=self.d_model,
            d_ff=self.d_ff,
            head_dim=self.rwkv_head_dim,
            lora_rank=self.lora_rank,
            norm_eps=self.norm_eps,
        )
