"""Grouped-query attention with a memory-sane chunked-flash implementation.

The default path is the flash algorithm expressed in jnp (lax.scan over KV
blocks with an online softmax) so that lowering never materializes the
[S, S] score matrix -- this is what makes the 32k-prefill dry-run cells fit.
``use_pallas=True`` swaps the hot loop for the Pallas TPU kernel in
``repro.kernels.flash_attention`` (same math, MXU-tiled).

GQA is computed in grouped form [B, Hkv, G, S, D] so K/V are never expanded
to the full head count.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import (
    ParamSpec,
    apply_rope,
    linear,
    linear_spec,
    rmsnorm_1d,
)

Array = jax.Array

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    causal: bool = True
    norm_eps: float = 1e-5
    k_block: int = 512  # flash kv-block size (jnp path)
    # perf: flat-head layout -- q/k/v as [B, H, S, D] with H sharded evenly
    # over 'model' (KV broadcast-expanded to H).  The grouped layout shards
    # tiny Hkv/G dims (heavy GSPMD padding + score all-gathers); flat is the
    # beyond-paper optimized path.  See EXPERIMENTS.md §Perf.
    flat: bool = False


def attention_specs(cfg: AttentionConfig) -> dict:
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "q": linear_spec(cfg.d_model, H * D, ("embed", "heads"), bias=cfg.qkv_bias),
        "k": linear_spec(cfg.d_model, Hkv * D, ("embed", "kv_heads"), bias=cfg.qkv_bias),
        "v": linear_spec(cfg.d_model, Hkv * D, ("embed", "kv_heads"), bias=cfg.qkv_bias),
        "o": linear_spec(H * D, cfg.d_model, ("heads", "embed")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((D,), (None,), "ones")
        specs["k_norm"] = ParamSpec((D,), (None,), "ones")
    return specs


# ---------------------------------------------------------------------------
# chunked flash (jnp): scan over KV blocks with online softmax
# ---------------------------------------------------------------------------

def flash_attention_jnp(
    q: Array,                   # [B, Hkv, G, Sq, D]
    k: Array,                   # [B, Hkv, Skv, D]
    v: Array,                   # [B, Hkv, Skv, D]
    *,
    q_positions: Array,         # [Sq]
    kv_positions: Array,        # [Skv]
    causal: bool,
    k_block: int,
) -> Array:
    B, Hkv, G, Sq, D = q.shape
    Skv = k.shape[2]
    scale = 1.0 / (D**0.5)
    k_block = min(k_block, Skv)
    if Skv % k_block != 0:
        # pad kv to a block multiple; padded keys are masked out by position
        pad = k_block - Skv % k_block
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=2**30)
        Skv += pad
    nblk = Skv // k_block

    qf = q.astype(jnp.float32) * scale
    ks = jnp.moveaxis(k.reshape(B, Hkv, nblk, k_block, D), 2, 0)
    vs = jnp.moveaxis(v.reshape(B, Hkv, nblk, k_block, D), 2, 0)
    kpos = kv_positions.reshape(nblk, k_block)

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)

    def step(carry, blk):
        m, l, acc = carry
        kb, vb, kp = blk
        s = jnp.einsum("bhgsd,bhtd->bhgst", qf, kb.astype(jnp.float32))
        if causal:
            valid = kp[None, :] <= q_positions[:, None]
        else:
            valid = (kp < 2**30)[None, :] & jnp.ones((Sq, 1), bool)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgst,bhtd->bhgsd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (ks, vs, kpos))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out


def flash_attention_flat(
    q: Array,                   # [B, H, Sq, D]
    k: Array,                   # [B, H, Skv, D]  (already group-expanded)
    v: Array,                   # [B, H, Skv, D]
    *,
    q_positions: Array,
    kv_positions: Array,
    causal: bool,
    k_block: int,
) -> Array:
    """Flat-head flash: every tensor carries the full head dim H, which is
    sharded evenly over 'model' -- scores stay rank-local under TP."""
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    scale = 1.0 / (D**0.5)
    k_block = min(k_block, Skv)
    if Skv % k_block != 0:
        pad = k_block - Skv % k_block
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=2**30)
        Skv += pad
    nblk = Skv // k_block

    qf = q.astype(jnp.float32) * scale
    ks = jnp.moveaxis(k.reshape(B, H, nblk, k_block, D), 2, 0)
    vs = jnp.moveaxis(v.reshape(B, H, nblk, k_block, D), 2, 0)
    kpos = kv_positions.reshape(nblk, k_block)

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, D), jnp.float32)

    def step(carry, blk):
        m, l, acc = carry
        kb, vb, kp = blk
        s = jnp.einsum("bhsd,bhtd->bhst", qf, kb.astype(jnp.float32))
        if causal:
            valid = kp[None, :] <= q_positions[:, None]
        else:
            valid = (kp < 2**30)[None, :] & jnp.ones((Sq, 1), bool)
        s = jnp.where(valid[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhst,bhtd->bhsd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (ks, vs, kpos))
    return acc / jnp.maximum(l[..., None], 1e-30)


# ---------------------------------------------------------------------------
# flat flash with custom VJP: backward recomputes scores blockwise instead of
# saving per-step probabilities/masks (the flash-attention backward).  This
# removes the O(S * k_block * nblk) fp32 residuals the autodiff scan saves.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_flat_cvjp(q, k, v, causal: bool, k_block: int):
    out, _ = _flash_flat_fwd_impl(q, k, v, causal, k_block)
    return out


def _flash_flat_fwd_impl(q, k, v, causal, k_block):
    B, H, S, D = q.shape
    pos = jnp.arange(S)
    out, (m, l) = _flash_flat_stats(q, k, v, causal=causal, k_block=k_block)
    return out, (m, l)


def _flash_flat_stats(q, k, v, *, causal, k_block):
    B, H, S, D = q.shape
    scale = 1.0 / (D**0.5)
    nblk = S // k_block
    qf = q.astype(jnp.float32) * scale
    ks = jnp.moveaxis(k.reshape(B, H, nblk, k_block, D), 2, 0)
    vs = jnp.moveaxis(v.reshape(B, H, nblk, k_block, D), 2, 0)
    kpos = jnp.arange(S).reshape(nblk, k_block)
    qpos = jnp.arange(S)

    def step(carry, blk):
        m, l, acc = carry
        kb, vb, kp = blk
        s = jnp.einsum("bhsd,bhtd->bhst", qf, kb.astype(jnp.float32))
        if causal:
            s = jnp.where((kp[None, :] <= qpos[:, None])[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        return (
            m_new,
            l * alpha + p.sum(-1),
            acc * alpha[..., None] + jnp.einsum("bhst,bhtd->bhsd", p, vb.astype(jnp.float32)),
        ), None

    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (ks, vs, kpos))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out, (m, l)


def _flash_flat_cvjp_fwd(q, k, v, causal, k_block):
    out, (m, l) = _flash_flat_fwd_impl(q, k, v, causal, k_block)
    return out, (q, k, v, out, m, l)


def _flash_flat_cvjp_bwd(causal, k_block, res, dout):
    q, k, v, out, m, l = res
    B, H, S, D = q.shape
    scale = 1.0 / (D**0.5)
    nblk = S // k_block
    qf = q.astype(jnp.float32)
    dout = dout.astype(jnp.float32)
    # Di = sum_d dout * out  (the softmax jacobian diagonal term)
    Dvec = jnp.sum(dout * out.astype(jnp.float32), axis=-1)          # [B,H,S]
    lsafe = jnp.maximum(l, 1e-30)
    ks = jnp.moveaxis(k.reshape(B, H, nblk, k_block, D), 2, 0)
    vs = jnp.moveaxis(v.reshape(B, H, nblk, k_block, D), 2, 0)
    kpos = jnp.arange(S).reshape(nblk, k_block)
    qpos = jnp.arange(S)

    def step(dq_acc, blk):
        kb, vb, kp = blk
        s = jnp.einsum("bhsd,bhtd->bhst", qf * scale, kb.astype(jnp.float32))
        if causal:
            s = jnp.where((kp[None, :] <= qpos[:, None])[None, None], s, NEG_INF)
        p = jnp.exp(s - m[..., None]) / lsafe[..., None]             # [B,H,S,t]
        dp = jnp.einsum("bhsd,bhtd->bhst", dout, vb.astype(jnp.float32))
        ds = p * (dp - Dvec[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bhst,bhtd->bhsd", ds, kb.astype(jnp.float32))
        dkb = jnp.einsum("bhst,bhsd->bhtd", ds, qf)
        dvb = jnp.einsum("bhst,bhsd->bhtd", p, dout)
        return dq_acc, (dkb, dvb)

    dq0 = jnp.zeros((B, H, S, D), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0, (ks, vs, kpos))
    dk = jnp.moveaxis(dks, 0, 2).reshape(B, H, S, D)
    dv = jnp.moveaxis(dvs, 0, 2).reshape(B, H, S, D)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_flat_cvjp.defvjp(_flash_flat_cvjp_fwd, _flash_flat_cvjp_bwd)


def _reference_attention(q, k, v, *, q_positions, kv_positions, causal):
    """Naive masked attention (oracle for tests; materializes scores)."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bhgsd,bhtd->bhgst", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        mask = kv_positions[None, :] <= q_positions[:, None]
    else:
        mask = jnp.ones((q.shape[3], k.shape[2]), bool)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgst,bhtd->bhgsd", p, v.astype(jnp.float32))


# ---------------------------------------------------------------------------
# full module: projections + rope + flash + output
# ---------------------------------------------------------------------------

def attention_apply(
    params: dict,
    x: Array,                       # [B, S, d_model]
    cfg: AttentionConfig,
    *,
    positions: Array | None = None, # [S] absolute positions
    cache: dict | None = None,      # decode: {"k","v": [B,Hkv,T,D], "length": []}
    use_pallas: bool = False,
    compute_dtype=jnp.bfloat16,
) -> tuple[Array, dict | None]:
    B, S, _ = x.shape
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // Hkv
    if positions is None:
        positions = jnp.arange(S)
        if cache is not None:
            positions = positions + cache["length"]

    q = linear(params["q"], x, compute_dtype=compute_dtype).reshape(B, S, H, D)
    k = linear(params["k"], x, compute_dtype=compute_dtype).reshape(B, S, Hkv, D)
    v = linear(params["v"], x, compute_dtype=compute_dtype).reshape(B, S, Hkv, D)

    if cfg.qk_norm:
        q = rmsnorm_1d(params["q_norm"], q, eps=cfg.norm_eps)
        k = rmsnorm_1d(params["k_norm"], k, eps=cfg.norm_eps)
    if cfg.rope:
        q = apply_rope(q, positions[None, :, None], theta=cfg.rope_theta)
        k = apply_rope(k, positions[None, :, None], theta=cfg.rope_theta)

    kh = k.transpose(0, 2, 1, 3)        # [B, Hkv, S, D] (cache layout)
    vh = v.transpose(0, 2, 1, 3)

    new_cache = None
    if cache is not None:
        start = cache["length"]
        ck = jax.lax.dynamic_update_slice(cache["k"], kh.astype(cache["k"].dtype), (0, 0, start, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], vh.astype(cache["v"].dtype), (0, 0, start, 0))
        ck = constrain(ck, ("batch", "kv_heads", "kv_seq", None))
        cv = constrain(cv, ("batch", "kv_heads", "kv_seq", None))
        new_cache = {"k": ck, "v": cv, "length": cache["length"] + S}

    if cache is not None and S == 1:
        # token decode: grouped attention against the full cache
        qg = q.reshape(B, S, Hkv, G, D).transpose(0, 2, 3, 1, 4)
        qg = constrain(qg, ("batch", "kv_heads", "heads_inner", None, None))
        out = _decode_attention(
            qg, ck, cv,
            q_positions=positions, kv_positions=jnp.arange(cache["k"].shape[2]),
        )
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H * D).astype(compute_dtype)
    elif cfg.flat:
        # flat-head layout: H sharded evenly over 'model'; KV group-expanded
        # (broadcast -- each rank materializes only its own head slice)
        qt = constrain(q.transpose(0, 2, 1, 3), ("batch", "heads", None, None))
        kt = constrain(jnp.repeat(kh, G, axis=1), ("batch", "heads", None, None))
        vt = constrain(jnp.repeat(vh, G, axis=1), ("batch", "heads", None, None))
        kb = min(cfg.k_block, S)
        if use_pallas:
            from repro.kernels.flash_attention import ops as fa_ops

            out = fa_ops.flash_attention(qt, kt, vt, causal=cfg.causal)
        elif S % kb == 0:
            # custom-VJP flash: backward recomputes scores blockwise
            out = flash_flat_cvjp(qt, kt, vt, cfg.causal, kb)
        else:
            out = flash_attention_flat(
                qt, kt, vt,
                q_positions=positions, kv_positions=positions,
                causal=cfg.causal, k_block=cfg.k_block,
            )
        out = out.transpose(0, 2, 1, 3).reshape(B, S, H * D).astype(compute_dtype)
    else:
        # grouped (paper-faithful baseline) flash
        qg = q.reshape(B, S, Hkv, G, D).transpose(0, 2, 3, 1, 4)
        qg = constrain(qg, ("batch", "kv_heads", "heads_inner", None, None))
        kg = constrain(kh, ("batch", "kv_heads", None, None))
        vg = constrain(vh, ("batch", "kv_heads", None, None))
        if use_pallas:
            from repro.kernels.flash_attention import ops as fa_ops

            out = fa_ops.flash_attention(qg, kg, vg, causal=cfg.causal)
        else:
            out = flash_attention_jnp(
                qg, kg, vg,
                q_positions=positions, kv_positions=positions,
                causal=cfg.causal, k_block=cfg.k_block,
            )
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H * D).astype(compute_dtype)

    out = constrain(out, ("batch", None, "heads"))
    return linear(params["o"], out, compute_dtype=compute_dtype), new_cache


def _decode_attention(q, k, v, *, q_positions, kv_positions):
    """Single/few-token attention against a (possibly longer) cache."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bhgsd,bhtd->bhgst", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    mask = kv_positions[None, :] <= q_positions[:, None]
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgst,bhtd->bhgsd", p, v.astype(jnp.float32))


def init_cache(cfg: AttentionConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, cfg.num_kv_heads, max_len, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, cfg.num_kv_heads, max_len, cfg.head_dim), dtype),
        "length": jnp.zeros((), jnp.int32),
    }
