"""Unified backbone: dense / MoE decoder LMs, zamba2-style hybrid,
RWKV6 stack, and encoder-only (hubert) -- all scan-over-layers.

Scan keeps HLO size (and compile time) independent of depth; layer params
are stacked on a leading "layers" axis.  ``jax.checkpoint`` around the layer
body implements activation rematerialization.  Train and decode take
separate scan paths (decode threads per-layer caches through scan xs/ys).

The zamba2 hybrid is structured in *rounds*: one shared attention block
followed by ``attn_every`` mamba2 layers; the scan runs over full rounds and
a small epilogue handles the remainder (81 = 13*6 + 3), so decode caches
stay per-invocation (14 copies) instead of per-layer (81).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import ffn, mamba2, moe as moe_lib, rwkv6
from repro.models.common import (
    ParamSpec,
    embed,
    embedding_spec,
    layernorm,
    layernorm_spec,
    linear,
    linear_spec,
    rmsnorm,
    rmsnorm_spec,
    softmax_cross_entropy,
    stack_specs,
    unembed_logits,
)
from repro.models.config import ModelConfig

Array = jax.Array


# ===========================================================================
# Parameter specs
# ===========================================================================

def _dense_layer_specs(cfg: ModelConfig) -> dict:
    specs = {
        "norm1": rmsnorm_spec(cfg.d_model),
        "attn": attn.attention_specs(cfg.attention_config()),
        "norm2": rmsnorm_spec(cfg.d_model),
    }
    if cfg.family == "moe":
        specs["moe"] = moe_lib.moe_specs(cfg.moe_config())
    elif cfg.mlp_type == "gelu":
        specs["mlp"] = ffn.gelu_mlp_specs(cfg.d_model, cfg.d_ff, bias=False)
    else:
        specs["mlp"] = ffn.swiglu_specs(cfg.d_model, cfg.d_ff)
    return specs


def _shared_block_specs(cfg: ModelConfig) -> dict:
    return {
        "in_proj": linear_spec(2 * cfg.d_model, cfg.d_model, (None, "embed")),
        "norm1": rmsnorm_spec(cfg.d_model),
        "attn": attn.attention_specs(cfg.attention_config()),
        "norm2": rmsnorm_spec(cfg.d_model),
        "mlp": ffn.swiglu_specs(cfg.d_model, cfg.d_ff),
    }


def _mamba_layer_specs(cfg: ModelConfig) -> dict:
    return {"norm": rmsnorm_spec(cfg.d_model), "mamba": mamba2.mamba2_specs(cfg.mamba_config())}


def hybrid_layout(cfg: ModelConfig) -> tuple[int, int, int]:
    """(full_rounds, layers_per_round, epilogue_mamba_layers)."""
    period = max(cfg.attn_every, 1)
    full = cfg.num_layers // period
    rem = cfg.num_layers - full * period
    return full, period, rem


def _rwkv_layer_specs(cfg: ModelConfig) -> dict:
    rcfg = cfg.rwkv_config()
    return {
        "ln1": layernorm_spec(cfg.d_model),
        "time": rwkv6.rwkv6_timemix_specs(rcfg),
        "ln2": layernorm_spec(cfg.d_model),
        "channel": rwkv6.rwkv6_channelmix_specs(rcfg),
    }


def _encoder_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": layernorm_spec(cfg.d_model),
        "attn": attn.attention_specs(cfg.attention_config()),
        "ln2": layernorm_spec(cfg.d_model),
        "mlp": ffn.gelu_mlp_specs(cfg.d_model, cfg.d_ff),
    }


def model_specs(cfg: ModelConfig) -> dict:
    if cfg.family in ("dense", "moe"):
        specs: dict[str, Any] = {
            "embed": embedding_spec(cfg.vocab_size, cfg.d_model),
            "layers": stack_specs(_dense_layer_specs(cfg), cfg.num_layers),
            "final_norm": rmsnorm_spec(cfg.d_model),
        }
    elif cfg.family == "hybrid":
        full, period, rem = hybrid_layout(cfg)
        layer = _mamba_layer_specs(cfg)
        specs = {
            "embed": embedding_spec(cfg.vocab_size, cfg.d_model),
            "rounds": stack_specs(stack_specs(layer, period, "inner"), full, "layers"),
            "shared": _shared_block_specs(cfg),
            "final_norm": rmsnorm_spec(cfg.d_model),
        }
        if rem:
            specs["epilogue"] = stack_specs(layer, rem)
    elif cfg.family == "rwkv":
        specs = {
            "embed": embedding_spec(cfg.vocab_size, cfg.d_model),
            "ln_in": layernorm_spec(cfg.d_model),
            "layers": stack_specs(_rwkv_layer_specs(cfg), cfg.num_layers),
            "ln_out": layernorm_spec(cfg.d_model),
        }
    elif cfg.family == "encoder":
        # modality frontend is a stub: inputs are precomputed frame embeddings
        return {
            "in_proj": linear_spec(cfg.d_model, cfg.d_model, ("embed", "embed"), bias=True),
            "pos_conv": ParamSpec((128, cfg.d_model), (None, "embed"), "normal", 0.02),
            "ln_in": layernorm_spec(cfg.d_model),
            "layers": stack_specs(_encoder_layer_specs(cfg), cfg.num_layers),
            "ln_out": layernorm_spec(cfg.d_model),
            "head": linear_spec(cfg.d_model, cfg.vocab_size, ("embed", "vocab"), bias=True),
        }
    else:
        raise ValueError(f"unknown family {cfg.family}")
    if not cfg.tie_embeddings:
        specs["unembed"] = embedding_spec(cfg.vocab_size, cfg.d_model)
    return specs


# ===========================================================================
# Layer bodies
# ===========================================================================

def _maybe_remat(fn, enable: bool):
    return jax.checkpoint(fn) if enable else fn


def _dense_layer(cfg, acfg, layer_params, h, positions, cache, *, moe_groups):
    a_in = rmsnorm(layer_params["norm1"], h, eps=cfg.norm_eps)
    a_out, new_cache = attn.attention_apply(
        layer_params["attn"], a_in, acfg,
        positions=positions, cache=cache, use_pallas=cfg.use_pallas,
    )
    h = h + a_out
    f_in = rmsnorm(layer_params["norm2"], h, eps=cfg.norm_eps)
    if cfg.family == "moe":
        f_out, aux = moe_lib.moe_apply(
            layer_params["moe"], f_in, cfg.moe_config(), moe_groups=moe_groups,
            dropless=cache is not None,
        )
    else:
        if cfg.mlp_type == "gelu":
            f_out = ffn.gelu_mlp_apply(layer_params["mlp"], f_in)
        else:
            f_out = ffn.swiglu_apply(layer_params["mlp"], f_in)
        aux = jnp.zeros((), jnp.float32)
    return h + f_out, new_cache, aux


def _shared_block(cfg, acfg, params, h, x_emb, positions, cache):
    """zamba2 shared transformer block on concat(embedding, hidden)."""
    z = linear(params["in_proj"], jnp.concatenate([x_emb, h], axis=-1))
    a_in = rmsnorm(params["norm1"], z, eps=cfg.norm_eps)
    a_out, new_cache = attn.attention_apply(
        params["attn"], a_in, acfg,
        positions=positions, cache=cache, use_pallas=cfg.use_pallas,
    )
    z = z + a_out
    f_in = rmsnorm(params["norm2"], z, eps=cfg.norm_eps)
    z = z + ffn.swiglu_apply(params["mlp"], f_in)
    return h + z, new_cache


def _mamba_layer(cfg, mcfg, layer_params, h, state):
    m_in = rmsnorm(layer_params["norm"], h, eps=cfg.norm_eps)
    m_out, new_state = mamba2.mamba2_apply(
        layer_params["mamba"], m_in, mcfg, state=state, use_pallas=cfg.use_pallas
    )
    return h + m_out, new_state


def _rwkv_layer(cfg, rcfg, layer_params, h, state):
    t_state = state["time"] if state is not None else None
    c_state = state["channel"] if state is not None else None
    t_in = layernorm(layer_params["ln1"], h, eps=cfg.norm_eps)
    t_out, new_t = rwkv6.rwkv6_timemix_apply(
        layer_params["time"], t_in, rcfg, state=t_state, use_pallas=cfg.use_pallas
    )
    h = h + t_out
    c_in = layernorm(layer_params["ln2"], h, eps=cfg.norm_eps)
    c_out, new_c = rwkv6.rwkv6_channelmix_apply(layer_params["channel"], c_in, rcfg, state=c_state)
    h = h + c_out
    new_state = None if state is None else {"time": new_t, "channel": new_c}
    return h, new_state


# ===========================================================================
# Stacks (train path: no caches; decode path: caches through scan xs/ys)
# ===========================================================================

def _stack_dense(cfg, params, h, positions, caches, *, moe_groups):
    acfg = cfg.attention_config()
    if caches is None:
        def body(h, layer_params):
            h, _, aux = _dense_layer(cfg, acfg, layer_params, h, positions, None, moe_groups=moe_groups)
            return h, aux
        h, auxes = jax.lax.scan(_maybe_remat(body, cfg.remat), h, params["layers"])
        return h, None, auxes.sum()

    def body(h, xs):
        layer_params, cache = xs
        h, new_cache, aux = _dense_layer(cfg, acfg, layer_params, h, positions, cache, moe_groups=moe_groups)
        return h, (new_cache, aux)

    h, (new_caches, auxes) = jax.lax.scan(body, h, (params["layers"], caches))
    return h, new_caches, auxes.sum()


def _stack_hybrid(cfg, params, h, x_emb, positions, caches):
    acfg, mcfg = cfg.attention_config(), cfg.mamba_config()
    full, period, rem = hybrid_layout(cfg)
    decode = caches is not None

    def round_body_train(h, round_params):
        h, _ = _shared_block(cfg, acfg, params["shared"], h, x_emb, positions, None)

        def inner(h, lp):
            h, _ = _mamba_layer(cfg, mcfg, lp, h, None)
            return h, None

        h, _ = jax.lax.scan(inner, h, round_params)
        return h, None

    def round_body_decode(h, xs):
        round_params, attn_cache, mstates = xs
        h, new_attn = _shared_block(cfg, acfg, params["shared"], h, x_emb, positions, attn_cache)

        def inner(h, xs2):
            lp, st = xs2
            h, new_st = _mamba_layer(cfg, mcfg, lp, h, st)
            return h, new_st

        h, new_mstates = jax.lax.scan(inner, h, (round_params, mstates))
        return h, (new_attn, new_mstates)

    if not decode:
        h, _ = jax.lax.scan(_maybe_remat(round_body_train, cfg.remat), h, params["rounds"])
        if rem:
            h, _ = _shared_block(cfg, acfg, params["shared"], h, x_emb, positions, None)

            def inner(h, lp):
                h, _ = _mamba_layer(cfg, mcfg, lp, h, None)
                return h, None

            h, _ = jax.lax.scan(inner, h, params["epilogue"])
        return h, None

    # decode: caches = {"attn": [n_inv, ...], "mamba": [L, ...]}
    n_inv = full + (1 if rem else 0)
    attn_caches = caches["attn"]
    mamba_states = caches["mamba"]
    main_attn = jax.tree.map(lambda a: a[:full], attn_caches)
    main_m = jax.tree.map(lambda a: a[: full * period].reshape(full, period, *a.shape[1:]), mamba_states)
    h, (new_attn_main, new_m_main) = jax.lax.scan(
        round_body_decode, h, (params["rounds"], main_attn, main_m)
    )
    new_m_main = jax.tree.map(lambda a: a.reshape(full * period, *a.shape[2:]), new_m_main)
    if rem:
        epi_attn = jax.tree.map(lambda a: a[full], attn_caches)
        h, new_attn_epi = _shared_block(cfg, acfg, params["shared"], h, x_emb, positions, epi_attn)

        def inner(h, xs2):
            lp, st = xs2
            h, new_st = _mamba_layer(cfg, mcfg, lp, h, st)
            return h, new_st

        epi_m = jax.tree.map(lambda a: a[full * period :], mamba_states)
        h, new_m_epi = jax.lax.scan(inner, h, (params["epilogue"], epi_m))
        new_attn = jax.tree.map(
            lambda m, e: jnp.concatenate([m, e[None]], axis=0), new_attn_main, new_attn_epi
        )
        new_m = jax.tree.map(lambda m, e: jnp.concatenate([m, e], axis=0), new_m_main, new_m_epi)
    else:
        new_attn, new_m = new_attn_main, new_m_main
    return h, {"attn": new_attn, "mamba": new_m}


def _stack_rwkv(cfg, params, h, states):
    rcfg = cfg.rwkv_config()
    if states is None:
        def body(h, layer_params):
            h, _ = _rwkv_layer(cfg, rcfg, layer_params, h, None)
            return h, None
        h, _ = jax.lax.scan(_maybe_remat(body, cfg.remat), h, params["layers"])
        return h, None

    def body(h, xs):
        layer_params, state = xs
        h, new_state = _rwkv_layer(cfg, rcfg, layer_params, h, state)
        return h, new_state

    h, new_states = jax.lax.scan(body, h, (params["layers"], states))
    return h, new_states


def _stack_encoder(cfg, params, h):
    acfg = cfg.attention_config()

    def body(h, layer_params):
        a_in = layernorm(layer_params["ln1"], h, eps=cfg.norm_eps)
        a_out, _ = attn.attention_apply(layer_params["attn"], a_in, acfg, use_pallas=cfg.use_pallas)
        h = h + a_out
        f_in = layernorm(layer_params["ln2"], h, eps=cfg.norm_eps)
        h = h + ffn.gelu_mlp_apply(layer_params["mlp"], f_in)
        return h, None

    h, _ = jax.lax.scan(_maybe_remat(body, cfg.remat), h, params["layers"])
    return h


# ===========================================================================
# Top-level forward / loss / decode
# ===========================================================================

def _logits(cfg: ModelConfig, params, h) -> Array:
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return constrain(unembed_logits(table, h), ("batch", None, "vocab"))


def forward_lm(
    cfg: ModelConfig,
    params: dict,
    tokens: Array,                    # [B, S]
    *,
    caches: Any = None,
    moe_groups: int = 1,
) -> tuple[Array, Any, Array]:
    """Returns (logits [B, S, vocab], new_caches, aux_loss)."""
    h = constrain(embed(params["embed"], tokens), ("batch", None, "embed"))
    S = tokens.shape[1]
    aux = jnp.zeros((), jnp.float32)
    positions = jnp.arange(S) + (caches["pos"] if caches is not None else 0)
    inner = caches["layers"] if caches is not None else None

    if cfg.family in ("dense", "moe"):
        h, new_inner, aux = _stack_dense(cfg, params, h, positions, inner, moe_groups=moe_groups)
        h = rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)
    elif cfg.family == "hybrid":
        h, new_inner = _stack_hybrid(cfg, params, h, h, positions, inner)
        h = rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)
    elif cfg.family == "rwkv":
        h = layernorm(params["ln_in"], h, eps=cfg.norm_eps)
        h, new_inner = _stack_rwkv(cfg, params, h, inner)
        h = layernorm(params["ln_out"], h, eps=cfg.norm_eps)
    else:
        raise ValueError(f"forward_lm does not support family {cfg.family}")

    new_caches = None
    if caches is not None:
        new_caches = {"layers": new_inner, "pos": caches["pos"] + S}
    return _logits(cfg, params, h), new_caches, aux


def forward_encoder(cfg: ModelConfig, params: dict, frames: Array) -> Array:
    """hubert: frames [B, T, d_model] (stub frontend) -> logits [B, T, vocab]."""
    h = linear(params["in_proj"], frames)
    # conv positional embedding (strided taps keep the unrolled HLO small)
    pos = params["pos_conv"].astype(h.dtype)                   # [128, d]
    Kw = pos.shape[0]
    hp = jnp.pad(h, ((0, 0), (Kw // 2, Kw - 1 - Kw // 2), (0, 0)))
    conv = jnp.zeros_like(h)
    for i in range(0, Kw, 16):
        conv = conv + hp[:, i : i + h.shape[1], :] * pos[i][None, None, :]
    h = h + jax.nn.gelu(conv)
    h = layernorm(params["ln_in"], h, eps=cfg.norm_eps)
    h = _stack_encoder(cfg, params, h)
    h = layernorm(params["ln_out"], h, eps=cfg.norm_eps)
    return linear(params["head"], h)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def _backbone_hidden(cfg: ModelConfig, params: dict, tokens: Array, *, moe_groups: int = 1):
    """Hidden states before the unembedding (for streamed losses)."""
    h = constrain(embed(params["embed"], tokens), ("batch", None, "embed"))
    S = tokens.shape[1]
    positions = jnp.arange(S)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family in ("dense", "moe"):
        h, _, aux = _stack_dense(cfg, params, h, positions, None, moe_groups=moe_groups)
        h = rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)
    elif cfg.family == "hybrid":
        h, _ = _stack_hybrid(cfg, params, h, h, positions, None)
        h = rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)
    elif cfg.family == "rwkv":
        h = layernorm(params["ln_in"], h, eps=cfg.norm_eps)
        h, _ = _stack_rwkv(cfg, params, h, None)
        h = layernorm(params["ln_out"], h, eps=cfg.norm_eps)
    else:
        raise ValueError(cfg.family)
    return h, aux


def lm_loss(cfg: ModelConfig, params: dict, batch: dict, *, moe_groups: int = 1):
    tokens = batch["tokens"]
    if cfg.loss_seq_chunks > 1:
        from repro.models.common import seq_chunked_cross_entropy

        h, aux = _backbone_hidden(cfg, params, tokens[:, :-1], moe_groups=moe_groups)
        table = (params["embed"] if cfg.tie_embeddings else params["unembed"])["table"]
        ce = seq_chunked_cross_entropy(h, table, tokens[:, 1:], chunks=cfg.loss_seq_chunks)
    else:
        logits, _, aux = forward_lm(cfg, params, tokens[:, :-1], moe_groups=moe_groups)
        ce = softmax_cross_entropy(logits, tokens[:, 1:])
    return ce + aux, {"ce": ce, "aux": aux}


def encoder_loss(cfg: ModelConfig, params: dict, batch: dict, **_):
    logits = forward_encoder(cfg, params, batch["frames"])
    ce = softmax_cross_entropy(logits, batch["targets"], mask=batch["mask"])
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, *, moe_groups: int = 1):
    if cfg.family == "encoder":
        return encoder_loss(cfg, params, batch)
    return lm_loss(cfg, params, batch, moe_groups=moe_groups)


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    acfg = cfg.attention_config()
    if cfg.family in ("dense", "moe"):
        c = attn.init_cache(acfg, batch, max_len, dtype)
        stacked = jax.tree.map(lambda a: jnp.zeros((cfg.num_layers, *a.shape), a.dtype), c)
        return {"layers": stacked, "pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        full, period, rem = hybrid_layout(cfg)
        n_inv = full + (1 if rem else 0)
        ac = attn.init_cache(acfg, batch, max_len, dtype)
        ms = mamba2.init_mamba_state(cfg.mamba_config(), batch, dtype)
        return {
            "layers": {
                "attn": jax.tree.map(lambda a: jnp.zeros((n_inv, *a.shape), a.dtype), ac),
                "mamba": jax.tree.map(lambda a: jnp.zeros((cfg.num_layers, *a.shape), a.dtype), ms),
            },
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "rwkv":
        s = rwkv6.init_rwkv_state(cfg.rwkv_config(), batch, dtype)
        return {
            "layers": jax.tree.map(lambda a: jnp.zeros((cfg.num_layers, *a.shape), a.dtype), s),
            "pos": jnp.zeros((), jnp.int32),
        }
    raise ValueError(f"no decode caches for family {cfg.family}")


def decode_step(cfg: ModelConfig, params: dict, caches, tokens: Array, *, moe_groups: int = 1):
    """One serve step: tokens [B, 1] -> (logits [B, 1, V], new_caches)."""
    logits, new_caches, _ = forward_lm(cfg, params, tokens, caches=caches, moe_groups=moe_groups)
    return logits, new_caches
