"""Feed-forward blocks: SwiGLU (llama/qwen/granite family) and GELU MLP."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import linear, linear_spec

Array = jax.Array


def swiglu_specs(d_model: int, d_ff: int) -> dict:
    return {
        "gate": linear_spec(d_model, d_ff, ("embed", "ff")),
        "up": linear_spec(d_model, d_ff, ("embed", "ff")),
        "down": linear_spec(d_ff, d_model, ("ff", "embed")),
    }


def swiglu_apply(params: dict, x: Array, *, compute_dtype=jnp.bfloat16) -> Array:
    g = linear(params["gate"], x, compute_dtype=compute_dtype)
    u = linear(params["up"], x, compute_dtype=compute_dtype)
    h = constrain(jax.nn.silu(g) * u, ("batch", None, "ff"))
    return linear(params["down"], h, compute_dtype=compute_dtype)


def gelu_mlp_specs(d_model: int, d_ff: int, *, bias: bool = True) -> dict:
    return {
        "fc1": linear_spec(d_model, d_ff, ("embed", "ff"), bias=bias),
        "fc2": linear_spec(d_ff, d_model, ("ff", "embed"), bias=bias, bias_axis="embed"),
    }


def gelu_mlp_apply(params: dict, x: Array, *, compute_dtype=jnp.bfloat16) -> Array:
    h = jax.nn.gelu(linear(params["fc1"], x, compute_dtype=compute_dtype))
    h = constrain(h, ("batch", None, "ff"))
    return linear(params["fc2"], h, compute_dtype=compute_dtype)
