"""Public model API: specs, abstract inputs per (arch x shape) cell, and
step builders used by the launcher, dry-run, and tests."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import ShapeCell
from repro.models import transformer
from repro.models.config import ModelConfig

Array = jax.Array


def model_specs(cfg: ModelConfig):
    return transformer.model_specs(cfg)


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict[str, jax.ShapeDtypeStruct]:
    """Abstract model inputs for one shape cell (no allocation).

    train (LM):    tokens [B, S+1]  (loss predicts S positions)
    train (enc):   frames [B, S, d], targets [B, S], mask [B, S]
    prefill:       tokens [B, S]
    decode:        tokens [B, 1]   (+ caches, built by ``cache_specs``)
    """
    B, S = cell.global_batch, cell.seq_len
    if cfg.family == "encoder":
        if cell.kind == "train":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
                "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "mask": jax.ShapeDtypeStruct((B, S), jnp.bool_),
            }
        if cell.kind == "prefill":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)}
        raise ValueError("encoder-only arch has no decode inputs")
    if cell.kind == "train":
        return {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
    if cell.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def concrete_inputs(cfg: ModelConfig, cell: ShapeCell, seed: int = 0) -> dict[str, Array]:
    """Small real inputs matching ``input_specs`` (smoke tests)."""
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, cell)
    out: dict[str, Array] = {}
    for k, s in specs.items():
        if s.dtype == jnp.int32:
            hi = cfg.vocab_size if k in ("tokens", "targets") else 2
            out[k] = jnp.asarray(rng.integers(0, hi, size=s.shape, dtype=np.int32))
        elif s.dtype == jnp.bool_:
            out[k] = jnp.asarray(rng.random(s.shape) < 0.3)
        else:
            out[k] = jnp.asarray(rng.normal(size=s.shape).astype(np.float32), dtype=s.dtype)
    return out


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    """Abstract decode caches (ShapeDtypeStructs) via eval_shape."""
    return jax.eval_shape(lambda: transformer.init_caches(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# Step functions (pure; jit/pjit applied by callers)
# ---------------------------------------------------------------------------

def make_loss_fn(cfg: ModelConfig, *, moe_groups: int = 1):
    def f(params, batch):
        return transformer.loss_fn(cfg, params, batch, moe_groups=moe_groups)

    return f


def make_forward_fn(cfg: ModelConfig, *, moe_groups: int = 1):
    if cfg.family == "encoder":
        def f(params, batch):
            return transformer.forward_encoder(cfg, params, batch["frames"])
    else:
        def f(params, batch):
            logits, _, _ = transformer.forward_lm(cfg, params, batch["tokens"], moe_groups=moe_groups)
            return logits

    return f


def make_prefill_fn(cfg: ModelConfig, *, moe_groups: int = 1):
    """Prefill: run the full prompt and return (last-token logits, caches)."""
    if cfg.family == "encoder":
        def f(params, batch):
            return transformer.forward_encoder(cfg, params, batch["frames"]), None
    else:
        def f(params, caches, batch):
            logits, new_caches, _ = transformer.forward_lm(
                cfg, params, batch["tokens"], caches=caches, moe_groups=moe_groups
            )
            return logits[:, -1:], new_caches

    return f


def make_decode_fn(cfg: ModelConfig, *, moe_groups: int = 1):
    def f(params, caches, batch):
        return transformer.decode_step(cfg, params, caches, batch["tokens"], moe_groups=moe_groups)

    return f
