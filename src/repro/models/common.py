"""Pure-JAX module substrate (no flax): parameter-spec trees, initializers,
logical-axis sharding metadata, and basic layers.

A model is described by a nested dict of ``ParamSpec`` leaves.  From that one
tree we derive, without ever materializing parameters:
  * ``init_params``      -- real parameter values (smoke tests / training)
  * ``abstract_params``  -- ShapeDtypeStruct stand-ins (multi-pod dry-run)
  * ``logical_axes``     -- per-dimension logical axis names, mapped to mesh
                            axes by ``distributed.sharding``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any

# Logical axis vocabulary.  distributed/sharding.py maps these to mesh axes.
#   "batch"   -> (pod, data)        "vocab"   -> model
#   "heads"   -> model              "kv_heads"-> model (if wide enough)
#   "ff"      -> model              "embed"   -> None (replicated)
#   "experts" -> model              "layers"  -> None (scan axis)
#   "seq"/"kv_seq" -> None (or data for long-context decode)


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | embed | conv
    scale: float | None = None    # stddev override for "normal"
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def _fan_in(shape: tuple[int, ...]) -> int:
    # weight layout convention: last dim is output features
    return int(math.prod(shape[:-1])) if len(shape) > 1 else int(shape[0])


def _init_leaf(spec: ParamSpec, key: Array) -> Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    scale = spec.scale
    if scale is None:
        scale = 1.0 if spec.init == "embed" else (1.0 / math.sqrt(max(_fan_in(spec.shape), 1)))
    return (scale * jax.random.normal(key, spec.shape)).astype(spec.dtype)


def _is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs: PyTree, key: Array) -> PyTree:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs: PyTree, sharding_fn: Callable[[ParamSpec], Any] | None = None) -> PyTree:
    """ShapeDtypeStruct tree for .lower() -- no allocation.

    ``sharding_fn(spec) -> Sharding | None`` attaches shardings for the
    dry-run.
    """

    def leaf(s: ParamSpec):
        sh = sharding_fn(s) if sharding_fn else None
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)

    return jax.tree.map(leaf, specs, is_leaf=_is_spec)


def logical_axes(specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def param_count(specs: PyTree) -> int:
    return sum(
        int(math.prod(s.shape)) for s in jax.tree.leaves(specs, is_leaf=_is_spec)
    )


def stack_specs(specs: PyTree, num: int, axis_name: str = "layers") -> PyTree:
    """Prepend a stacked (scan) dimension to every leaf."""
    return jax.tree.map(
        lambda s: ParamSpec((num, *s.shape), (axis_name, *s.axes), s.init, s.scale, s.dtype),
        specs,
        is_leaf=_is_spec,
    )


# ---------------------------------------------------------------------------
# Basic layers (functional; params are dicts produced from spec trees)
# ---------------------------------------------------------------------------

def linear_spec(
    d_in: int, d_out: int, axes: tuple[str | None, str | None], *, bias: bool = False,
    bias_axis: str | None = None, scale: float | None = None,
) -> dict:
    out = {"w": ParamSpec((d_in, d_out), axes, "normal", scale)}
    if bias:
        out["b"] = ParamSpec((d_out,), (bias_axis if bias_axis is not None else axes[1],), "zeros")
    return out


def linear(params: dict, x: Array, *, compute_dtype=jnp.bfloat16) -> Array:
    w = params["w"].astype(compute_dtype)
    y = x.astype(compute_dtype) @ w
    if "b" in params:
        y = y + params["b"].astype(compute_dtype)
    return y


def rmsnorm_spec(d: int, axis: str | None = "embed") -> dict:
    return {"scale": ParamSpec((d,), (axis,), "ones")}


def rmsnorm(params: dict, x: Array, *, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_spec(d: int, axis: str | None = "embed") -> dict:
    return {"scale": ParamSpec((d,), (axis,), "ones"), "bias": ParamSpec((d,), (axis,), "zeros")}


def layernorm(params: dict, x: Array, *, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = x32.mean(axis=-1, keepdims=True)
    var = x32.var(axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dtype)


def rmsnorm_1d(scale: Array, x: Array, *, eps: float = 1e-5) -> Array:
    """RMS norm over the last dim with an explicit scale vector (qk-norm)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dtype)


def embedding_spec(vocab: int, d: int, *, scale: float = 0.02) -> dict:
    return {"table": ParamSpec((vocab, d), ("vocab", "embed"), "embed", scale)}


def embed(params: dict, ids: Array, *, compute_dtype=jnp.bfloat16) -> Array:
    return params["table"].astype(compute_dtype)[ids]


def unembed_logits(params: dict, x: Array, *, compute_dtype=jnp.bfloat16) -> Array:
    """x [.., d] @ table.T -> logits [.., vocab] (vocab stays sharded)."""
    table = params["table"].astype(compute_dtype)
    return jnp.einsum("...d,vd->...v", x.astype(compute_dtype), table)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, *, theta: float = 10000.0) -> Array:
    """x: [..., seq, head_dim]; positions: broadcastable to [..., seq]."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                     # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs     # [..., seq, hd/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: Array, labels: Array, *, mask: Array | None = None) -> Array:
    """Mean CE over (optionally masked) positions.  fp32 reduction."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def seq_chunked_cross_entropy(
    h: Array,            # [B, S, d] final hidden states
    table: Array,        # [V, d] unembedding table (vocab may be TP-sharded)
    labels: Array,       # [B, S]
    *,
    chunks: int,
    compute_dtype=jnp.bfloat16,
) -> Array:
    """CE without materializing the full fp32 [B, S, V] logits: the sequence
    is processed in ``chunks`` slices under remat, so peak logits memory
    drops by ``chunks``x while the vocab TP split is preserved (the
    logsumexp/gather over the sharded vocab dim reduce to small
    all-reduces).  Beyond-paper perf path; see EXPERIMENTS.md §Perf."""
    B, S, d = h.shape
    if S % chunks:
        return softmax_cross_entropy(
            jnp.einsum("bsd,vd->bsv", h.astype(compute_dtype), table.astype(compute_dtype)),
            labels,
        )
    Sc = S // chunks
    hs = jnp.moveaxis(h.reshape(B, chunks, Sc, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, chunks, Sc), 1, 0)

    @jax.checkpoint
    def body(total, xs):
        hc, lc = xs
        logits = jnp.einsum(
            "bsd,vd->bsv", hc.astype(compute_dtype), table.astype(compute_dtype)
        ).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None].astype(jnp.int32), axis=-1)[..., 0]
        return total + (logz - gold).sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (B * S)
