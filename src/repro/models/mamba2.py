"""Mamba2 (SSD) layer in chunked matmul form.

The selective state-space recurrence
    h_t = exp(dA_t) * h_{t-1} + B_t (dt_t x_t)^T,      y_t = C_t . h_t + D x_t
is computed chunk-parallel (Dao & Gu, 2024): intra-chunk contributions are a
masked [Q, Q] matmul (MXU work), inter-chunk state is a short ``lax.scan``
over L/Q chunks.  All pairwise decay factors are exp of *non-positive*
numbers, so the chunked form is numerically safe at any chunk size.

Projections are kept un-fused (separate z/x/B/C/dt weights) so each gets a
clean logical sharding axis.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import ParamSpec, linear, linear_spec, rmsnorm_1d

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64          # N
    head_dim: int = 64         # P
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 128
    norm_eps: float = 1e-5

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def num_heads(self) -> int:
        return self.d_inner // self.head_dim


def mamba2_specs(cfg: Mamba2Config) -> dict:
    di, N, H = cfg.d_inner, cfg.d_state, cfg.num_heads
    return {
        "z": linear_spec(cfg.d_model, di, ("embed", "heads")),
        "x": linear_spec(cfg.d_model, di, ("embed", "heads")),
        "B": linear_spec(cfg.d_model, N, ("embed", None)),
        "C": linear_spec(cfg.d_model, N, ("embed", None)),
        "dt": linear_spec(cfg.d_model, H, ("embed", "heads")),
        "dt_bias": ParamSpec((H,), ("heads",), "zeros"),
        "A_log": ParamSpec((H,), ("heads",), "normal", 0.5),
        "D": ParamSpec((H,), ("heads",), "ones"),
        "conv": ParamSpec((cfg.conv_kernel, di + 2 * N), (None, "heads"), "normal", 0.5),
        "norm": ParamSpec((di,), ("heads",), "ones"),
        "out": linear_spec(di, cfg.d_model, ("heads", "embed")),
    }


def _causal_conv(xbc: Array, kernel: Array, state: Array | None) -> tuple[Array, Array]:
    """Depthwise causal conv over [B, L, Ch]; returns (out, new_state)."""
    Kw = kernel.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], Kw - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    new_state = xp[:, -(Kw - 1):, :]
    out = jnp.zeros_like(xbc)
    for i in range(Kw):
        out = out + xp[:, i : i + xbc.shape[1], :] * kernel[i][None, None, :]
    return out, new_state


def ssd_chunked(
    xbar: Array,      # [B, L, H, P]  (dt-scaled inputs)
    dA: Array,        # [B, L, H]     log-decay per step (<= 0)
    Bm: Array,        # [B, L, N]
    Cm: Array,        # [B, L, N]
    *,
    chunk: int,
    h0: Array | None = None,   # [B, H, P, N] initial state
) -> tuple[Array, Array]:
    """Returns (y [B, L, H, P], h_final [B, H, P, N])."""
    B, L, H, P = xbar.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    orig_L = L
    if L % Q != 0:
        # pad with zero inputs and zero log-decay: padded steps leave the
        # state untouched (decay exp(0)=1, no input), outputs are sliced off
        pad = Q - L % Q
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        L += pad
    nc = L // Q

    x_ = xbar.reshape(B, nc, Q, H, P).astype(jnp.float32)
    dA_ = dA.reshape(B, nc, Q, H).astype(jnp.float32)
    B_ = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    C_ = Cm.reshape(B, nc, Q, N).astype(jnp.float32)

    cum = jnp.cumsum(dA_, axis=2)                      # [B, nc, Q, H]
    # intra-chunk: scores[i,j] = (C_i . B_j) * exp(cum_i - cum_j) for j <= i
    CB = jnp.einsum("bcqn,bckn->bcqk", C_, B_)
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # [B,nc,Q,K,H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    y_diag = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", CB, M, x_)

    # per-chunk state contribution: sum_j exp(cum_end - cum_j) B_j xbar_j^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)            # [B,nc,Q,H]
    S_c = jnp.einsum("bckn,bckh,bckhp->bchpn", B_, decay_to_end, x_)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # [B,nc,H]

    # inter-chunk recurrence
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        s_c, g = inp                                            # [B,H,P,N], [B,H]
        h_start = h
        h_next = h * g[:, :, None, None] + s_c
        return h_next, h_start

    h_final, h_starts = jax.lax.scan(
        step,
        h0.astype(jnp.float32),
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_starts = jnp.moveaxis(h_starts, 0, 1)                     # [B,nc,H,P,N]

    # inter-chunk output: C_i . (exp(cum_i) * h_start)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", C_, h_starts, jnp.exp(cum))
    y = (y_diag + y_off).reshape(B, L, H, P)
    return y[:, :orig_L], h_final


def ssd_reference(xbar, dA, Bm, Cm, *, h0=None):
    """Step-by-step recurrence oracle."""
    B, L, H, P = xbar.shape
    N = Bm.shape[-1]
    h = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        x_t, a_t, b_t, c_t = t
        h = h * jnp.exp(a_t)[:, :, None, None] + jnp.einsum("bhp,bn->bhpn", x_t, b_t)
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y

    xs = (
        jnp.moveaxis(xbar.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dA.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Cm.astype(jnp.float32), 1, 0),
    )
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), h


def mamba2_apply(
    params: dict,
    x: Array,                  # [B, L, d_model]
    cfg: Mamba2Config,
    *,
    state: dict | None = None,  # decode: {"conv": [B,K-1,Ch], "ssm": [B,H,P,N]}
    use_pallas: bool = False,
    compute_dtype=jnp.bfloat16,
) -> tuple[Array, dict | None]:
    B, L, _ = x.shape
    H, P, N = cfg.num_heads, cfg.head_dim, cfg.d_state

    z = linear(params["z"], x, compute_dtype=compute_dtype)
    xi = linear(params["x"], x, compute_dtype=compute_dtype)
    Bm = linear(params["B"], x, compute_dtype=compute_dtype)
    Cm = linear(params["C"], x, compute_dtype=compute_dtype)
    dt = jax.nn.softplus(
        linear(params["dt"], x, compute_dtype=jnp.float32).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )                                                            # [B, L, H]

    xbc = jnp.concatenate([xi, Bm, Cm], axis=-1)
    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv"].astype(compute_dtype), conv_state)
    xbc = jax.nn.silu(xbc)
    xi, Bm, Cm = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + N], axis=-1)

    a = -jnp.exp(params["A_log"].astype(jnp.float32))            # [H], < 0
    dA = dt * a[None, None, :]                                   # [B, L, H] <= 0
    xh = xi.reshape(B, L, H, P).astype(jnp.float32)
    xh = constrain(xh, ("batch", None, "heads", None))
    xbar = xh * dt[..., None]

    h0 = state["ssm"] if state is not None else None
    if state is not None and L == 1:
        # decode: single recurrence step
        y, h_final = ssd_reference(xbar, dA, Bm, Cm, h0=h0)
    elif use_pallas:
        from repro.kernels.mamba2_ssd import ops as ssd_ops

        y, h_final = ssd_ops.ssd(xbar, dA, Bm.astype(jnp.float32), Cm.astype(jnp.float32), chunk=cfg.chunk)
    else:
        y, h_final = ssd_chunked(xbar, dA, Bm, Cm, chunk=cfg.chunk, h0=h0)

    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(B, L, cfg.d_inner).astype(compute_dtype)
    y = rmsnorm_1d(params["norm"], y * jax.nn.silu(z), eps=cfg.norm_eps)
    out = linear(params["out"], y, compute_dtype=compute_dtype)
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv.astype(state["conv"].dtype), "ssm": h_final}
    return out, new_state


def init_mamba_state(cfg: Mamba2Config, batch: int, dtype=jnp.bfloat16) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner + 2 * cfg.d_state), dtype),
        "ssm": jnp.zeros((batch, cfg.num_heads, cfg.head_dim, cfg.d_state), jnp.float32),
    }
