"""RWKV6 ("Finch") layer: data-dependent-decay linear attention.

Time-mix recurrence (per head, key-dim C, value-dim V):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t
with per-channel decay w_t = exp(-exp(w0 + lora_w(x))) in (0, 1), data
dependent.  The jnp path runs the exact recurrence as one ``lax.scan`` over
time (simple, numerically exact); the Pallas kernel
(``repro.kernels.rwkv6_wkv``) is the chunked VMEM-resident version.

Token-shift mixing uses the paper's ddlerp (low-rank data-dependent lerp).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import ParamSpec, linear, linear_spec

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RWKV6Config:
    d_model: int
    d_ff: int
    head_dim: int = 64
    lora_rank: int = 32
    norm_eps: float = 1e-5

    @property
    def num_heads(self) -> int:
        return self.d_model // self.head_dim


def rwkv6_timemix_specs(cfg: RWKV6Config) -> dict:
    d, r = cfg.d_model, cfg.lora_rank
    specs = {
        "mu_base": ParamSpec((5, d), (None, "embed"), "normal", 0.1),
        "lora_a": ParamSpec((d, r), ("embed", None), "normal"),
        "lora_b": ParamSpec((5, r, d), (None, None, "embed"), "zeros"),
        "w0": ParamSpec((d,), ("embed",), "normal", 0.5),
        "w_lora_a": ParamSpec((d, r), ("embed", None), "normal"),
        "w_lora_b": ParamSpec((r, d), (None, "embed"), "zeros"),
        "u": ParamSpec((d,), ("embed",), "normal", 0.5),
        "r": linear_spec(d, d, ("embed", "heads")),
        "k": linear_spec(d, d, ("embed", "heads")),
        "v": linear_spec(d, d, ("embed", "heads")),
        "g": linear_spec(d, d, ("embed", "heads")),
        "o": linear_spec(d, d, ("heads", "embed")),
        "ln_x": ParamSpec((d,), ("embed",), "ones"),
    }
    return specs


def rwkv6_channelmix_specs(cfg: RWKV6Config) -> dict:
    d = cfg.d_model
    return {
        "mu_k": ParamSpec((d,), ("embed",), "normal", 0.1),
        "key": linear_spec(d, cfg.d_ff, ("embed", "ff")),
        "value": linear_spec(cfg.d_ff, d, ("ff", "embed")),
        "receptance": linear_spec(d, d, ("embed", "embed")),
    }


def _token_shift(x: Array, prev: Array | None) -> Array:
    """x_{t-1} stream: shift right by one; ``prev`` carries across decode."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def wkv6_scan(
    r: Array, k: Array, v: Array, w: Array, u: Array, *, h0: Array | None = None
) -> tuple[Array, Array]:
    """Exact recurrence.  r/k/v/w: [B, L, H, C]; u: [H, C].

    Returns (y [B, L, H, C], final state [B, H, C, C])
    (state: key-dim x value-dim, head_dim == C == V).
    """
    B, L, H, C = r.shape
    h = jnp.zeros((B, H, C, C), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        r_t, k_t, v_t, w_t = t                               # [B,H,C] each
        kv = jnp.einsum("bhc,bhv->bhcv", k_t, v_t)           # outer product
        y = jnp.einsum("bhcv,bhc->bhv", h + u[None, :, :, None] * kv, r_t)
        h = h * w_t[..., None] + kv
        return h, y

    xs = tuple(
        jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w)
    )
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), h


def rwkv6_timemix_apply(
    params: dict,
    x: Array,                   # [B, L, d]
    cfg: RWKV6Config,
    *,
    state: dict | None = None,  # {"shift": [B,1,d], "wkv": [B,H,C,C]}
    use_pallas: bool = False,
    compute_dtype=jnp.bfloat16,
) -> tuple[Array, dict | None]:
    B, L, d = x.shape
    H, C = cfg.num_heads, cfg.head_dim
    prev = state["shift"] if state is not None else None
    xp = _token_shift(x, prev)
    dx = xp - x

    # ddlerp: xi = x + dx * (mu_i + lora_i(x + dx * mu_base_i))
    inner = x[None] + dx[None] * params["mu_base"][:, None, None, :].astype(x.dtype)  # [5,B,L,d]
    lora_h = jnp.tanh(jnp.einsum("nbld,dr->nblr", inner.astype(jnp.float32), params["lora_a"].astype(jnp.float32)))
    lora = jnp.einsum("nblr,nrd->nbld", lora_h, params["lora_b"].astype(jnp.float32))
    mixed = x[None].astype(jnp.float32) + dx[None].astype(jnp.float32) * (
        params["mu_base"][:, None, None, :].astype(jnp.float32) + lora
    )
    xr, xk, xv, xw, xg = [mixed[i].astype(compute_dtype) for i in range(5)]

    r = linear(params["r"], xr, compute_dtype=compute_dtype).reshape(B, L, H, C)
    k = linear(params["k"], xk, compute_dtype=compute_dtype).reshape(B, L, H, C)
    v = linear(params["v"], xv, compute_dtype=compute_dtype).reshape(B, L, H, C)
    r = constrain(r, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "heads", None))
    v = constrain(v, ("batch", None, "heads", None))
    g = linear(params["g"], xg, compute_dtype=compute_dtype)

    w_log = params["w0"].astype(jnp.float32) + jnp.einsum(
        "bld,dr,re->ble",
        xw.astype(jnp.float32),
        params["w_lora_a"].astype(jnp.float32),
        params["w_lora_b"].astype(jnp.float32),
    )
    w = jnp.exp(-jnp.exp(w_log)).reshape(B, L, H, C)         # decay in (0, 1)
    u = params["u"].astype(jnp.float32).reshape(H, C)

    h0 = state["wkv"] if state is not None else None
    if use_pallas and state is None:
        from repro.kernels.rwkv6_wkv import ops as wkv_ops

        y, h_final = wkv_ops.wkv6(
            r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), w, u
        )
    else:
        y, h_final = wkv6_scan(
            r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), w, u, h0=h0
        )

    y = y.reshape(B, L, d)
    # group norm per head, then gate
    y = y.reshape(B, L, H, C)
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1)[..., None]
    y = (y - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
    y = (y.reshape(B, L, d) * params["ln_x"].astype(jnp.float32)).astype(compute_dtype)
    y = y * jax.nn.silu(g)
    out = linear(params["o"], y, compute_dtype=compute_dtype)

    new_state = None
    if state is not None:
        new_state = {"shift": x[:, -1:, :].astype(state["shift"].dtype), "wkv": h_final}
    return out, new_state


def rwkv6_channelmix_apply(
    params: dict,
    x: Array,
    cfg: RWKV6Config,
    *,
    state: dict | None = None,  # {"shift": [B,1,d]}
    compute_dtype=jnp.bfloat16,
) -> tuple[Array, dict | None]:
    prev = state["shift"] if state is not None else None
    xp = _token_shift(x, prev)
    mu = params["mu_k"].astype(x.dtype)
    xk = x + (xp - x) * mu
    k = linear(params["key"], xk, compute_dtype=compute_dtype)
    kv = linear(params["value"], jnp.square(jax.nn.relu(k)), compute_dtype=compute_dtype)
    rgate = jax.nn.sigmoid(linear(params["receptance"], xk, compute_dtype=compute_dtype))
    out = rgate * kv
    new_state = {"shift": x[:, -1:, :].astype(x.dtype)} if state is not None else None
    return out, new_state


def init_rwkv_state(cfg: RWKV6Config, batch: int, dtype=jnp.bfloat16) -> dict:
    H, C = cfg.num_heads, cfg.head_dim
    return {
        "time": {
            "shift": jnp.zeros((batch, 1, cfg.d_model), dtype),
            "wkv": jnp.zeros((batch, H, C, C), jnp.float32),
        },
        "channel": {"shift": jnp.zeros((batch, 1, cfg.d_model), dtype)},
    }
