"""``repro.obs.convergence`` -- per-query statistical convergence traces.

The paper's claim is that estimates from a few RSP blocks converge to
the whole-data answer; a :class:`ConvergenceTrace` records that
trajectory for a *live* query: one :class:`ConvergenceStep` per
progressive emission, carrying blocks consumed, per-aggregate point
estimates and CI half-widths, the worst relative CI half-width, and
cumulative fetch latency.  The trace rides on ``QueryResult.trace``
(enable with ``ds.query(..., explain=True)`` or any progressive
streaming query) and renders a terminal report via :meth:`report`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ConvergenceStep:
    """One progressive emission of a running query."""

    blocks_read: int
    block_id: int | None
    #: worst relative CI half-width across aggregates (inf until defined)
    max_rel_err: float
    #: per-aggregate point estimate, keyed by aggregate name
    estimates: dict[str, float]
    #: per-aggregate CI half-width ((hi - lo) / 2), NaN when CI undefined
    half_widths: dict[str, float]
    #: cumulative seconds this query's caller spent in fetcher.fetch()
    cum_fetch_s: float
    #: seconds since the query started
    elapsed_s: float


@dataclass
class ConvergenceTrace:
    """Append-only trajectory of a progressive query.

    The same trace object is shared by every ``QueryResult`` a streaming
    query emits, so the final result's trace holds the full history.
    """

    confidence: float = 0.95
    target_rel_err: float | None = None
    steps: list[ConvergenceStep] = field(default_factory=list)

    def record(self, step: ConvergenceStep) -> None:
        self.steps.append(step)

    def __len__(self) -> int:
        return len(self.steps)

    @property
    def blocks(self) -> list[int]:
        return [s.blocks_read for s in self.steps]

    @property
    def rel_errs(self) -> list[float]:
        return [s.max_rel_err for s in self.steps]

    def half_widths(self, name: str) -> list[float]:
        """CI half-width trajectory for one aggregate."""
        return [s.half_widths.get(name, math.nan) for s in self.steps]

    def to_dict(self) -> dict:
        return {
            "confidence": self.confidence,
            "target_rel_err": self.target_rel_err,
            "steps": [
                {
                    "blocks_read": s.blocks_read,
                    "block_id": s.block_id,
                    "max_rel_err": s.max_rel_err,
                    "estimates": dict(s.estimates),
                    "half_widths": dict(s.half_widths),
                    "cum_fetch_s": s.cum_fetch_s,
                    "elapsed_s": s.elapsed_s,
                }
                for s in self.steps
            ],
        }

    def report(self, *, width: int = 32, max_rows: int | None = 24) -> str:
        """Terminal-friendly error-vs-blocks report with a log-scale bar
        per step -- the paper's convergence plot, in ASCII.  Traces longer
        than ``max_rows`` are evenly subsampled (first and last steps always
        shown); pass ``max_rows=None`` for every step."""
        if not self.steps:
            return "(no convergence steps recorded)"
        shown = self.steps
        if max_rows is not None and len(shown) > max(2, max_rows):
            last = len(shown) - 1
            idx = sorted({round(i * last / (max_rows - 1)) for i in range(max_rows)})
            shown = [self.steps[i] for i in idx]
        lines = [
            f"convergence: {len(self.steps)} steps, "
            f"{self.steps[-1].blocks_read} blocks, "
            f"{int(self.confidence * 100)}% CI"
            + (f", target rel err {self.target_rel_err:g}" if self.target_rel_err else "")
            + (f" (showing {len(shown)} of {len(self.steps)} steps)"
               if len(shown) < len(self.steps) else "")
        ]
        finite = [s.max_rel_err for s in self.steps if math.isfinite(s.max_rel_err)]
        lo = min(finite) if finite else 1.0
        hi = max(finite) if finite else 1.0
        lo = max(lo, 1e-12)
        span = math.log(max(hi, 1e-12) / lo) or 1.0
        for s in shown:
            if math.isfinite(s.max_rel_err):
                frac = math.log(max(s.max_rel_err, 1e-12) / lo) / span
                bar = "#" * max(1, round(frac * width))
                err = f"{s.max_rel_err:9.2e}"
            else:
                bar, err = "?", "      inf"
            mark = ""
            if self.target_rel_err is not None and s.max_rel_err <= self.target_rel_err:
                mark = "  <- target met"
            lines.append(
                f"  blocks={s.blocks_read:4d}  rel_err={err}  "
                f"fetch={s.cum_fetch_s * 1e3:8.1f}ms  |{bar}{mark}"
            )
        return "\n".join(lines)


__all__ = ["ConvergenceStep", "ConvergenceTrace"]
