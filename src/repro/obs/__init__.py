"""``repro.obs`` -- in-process telemetry for the RSP stack.

Three pillars, all zero-dependency and thread-safe:

* **metrics** (:mod:`repro.obs.metrics`) -- counters / gauges /
  exponential-bucket histograms in a label-set registry, exportable as
  JSON and Prometheus text format.
* **tracing** (:mod:`repro.obs.trace`) -- spans with *explicit* context
  propagation across executor / scheduler / sweeper threads, exported
  as Chrome trace-event JSON (open in Perfetto).
* **convergence** (:mod:`repro.obs.convergence`) -- per-query
  error-vs-blocks trajectories surfaced on ``QueryResult.trace``.

Telemetry is **off by default**: the hot paths check :func:`enabled`
(a plain bool read) and skip all metric/span work when off.  Turn it on
per process::

    from repro import obs
    obs.enable(sample_rate=0.1)        # sample 10% of query traces
    ...
    print(obs.get_registry().to_prometheus())
    obs.get_tracer().export_chrome("trace.json")

or via the environment: ``REPRO_OBS=1`` (optionally
``REPRO_OBS_SAMPLE=0.1``) enables it at import time.

Component-owned registries (e.g. ``QueryService.registry``) are always
live regardless of :func:`enabled` -- they back public accounting APIs
(``QueryService.metrics()``), not optional telemetry.
"""

from __future__ import annotations

import os
import threading

from .convergence import ConvergenceStep, ConvergenceTrace
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import DROPPED, Span, SpanContext, Tracer

_lock = threading.Lock()
_enabled = False
_registry = MetricsRegistry()
_tracer = Tracer()


def enabled() -> bool:
    """Cheap hot-path check: is process-global telemetry on?"""
    return _enabled


def enable(*, sample_rate: float = 1.0) -> None:
    """Turn on global telemetry; ``sample_rate`` applies to new root spans."""
    global _enabled
    with _lock:
        _tracer.sample_rate = float(sample_rate)
        _enabled = True


def disable() -> None:
    global _enabled
    with _lock:
        _enabled = False


def get_registry() -> MetricsRegistry:
    """The process-global metrics registry (hot-path instrumentation)."""
    return _registry


def get_tracer() -> Tracer:
    """The process-global tracer."""
    return _tracer


def reset() -> None:
    """Drop all recorded telemetry and disable.  Intended for tests and
    benchmark phase boundaries; instrument handles cached by components
    become stale, so components re-resolve them lazily."""
    global _enabled, _registry, _tracer
    with _lock:
        _enabled = False
        _registry = MetricsRegistry()
        _tracer = Tracer()


def _init_from_env() -> None:
    raw = os.environ.get("REPRO_OBS", "").strip().lower()
    if raw in ("1", "true", "on", "yes"):
        rate = float(os.environ.get("REPRO_OBS_SAMPLE", "1.0"))
        enable(sample_rate=rate)


_init_from_env()

__all__ = [
    "ConvergenceStep",
    "ConvergenceTrace",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "SpanContext",
    "Tracer",
    "DROPPED",
    "enabled",
    "enable",
    "disable",
    "get_registry",
    "get_tracer",
    "reset",
]
