"""``repro.obs.metrics`` -- a zero-dependency in-process metrics registry.

Three instrument kinds, all thread-safe and allocation-light on the hot
path:

* :class:`Counter` -- monotonically increasing total (``inc``).
* :class:`Gauge` -- last-written value (``set`` / ``add``).
* :class:`Histogram` -- exponential-bucket latency/size distribution
  (``observe``); buckets are ``start * factor**i`` upper bounds plus one
  overflow bucket, with running ``sum`` / ``count`` so means and
  bucket-interpolated quantiles come for free.

A :class:`MetricsRegistry` groups instruments into *families* keyed by
metric name; each family holds one child per label set, so
``registry.counter("rsp_engine_fetch_total", outcome="hit")`` and
``...(outcome="miss")`` are two children of one family.  Handles are
get-or-create and stable -- resolve them once at init and call ``inc`` /
``observe`` in the hot path (a single lock + add).

Snapshots export two ways:

* :meth:`MetricsRegistry.to_json` -- nested dict/JSON for artifacts and
  tests.
* :meth:`MetricsRegistry.to_prometheus` -- Prometheus text exposition
  format (``# TYPE`` headers, ``_bucket{le=...}`` cumulative histogram
  series), ready for a scrape endpoint or textfile collector.

The registry never touches the filesystem or network and has no
dependencies; it is safe to instantiate per component (``QueryService``
owns one) as well as use the process-global one from ``repro.obs``.
"""

from __future__ import annotations

import bisect
import json
import math
import threading
from typing import Iterator

_KINDS = ("counter", "gauge", "histogram")


def _label_key(labels: dict[str, str]) -> tuple[tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render_labels(key: tuple[tuple[str, str], ...]) -> str:
    if not key:
        return ""
    body = ",".join(f'{k}="{_escape(v)}"' for k, v in key)
    return "{" + body + "}"


def _escape(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class Counter:
    """Monotonic total.  ``inc`` is the only mutator."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Last-written value (plus ``add`` for up/down adjustments)."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def add(self, amount: float) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Exponential-bucket histogram.

    Bucket ``i`` counts observations ``<= start * factor**i``; one overflow
    bucket catches the rest.  The defaults (1 us .. ~67 s at factor 2)
    cover every latency in the repo; pass ``start``/``factor``/``buckets``
    for other domains (e.g. row counts).
    """

    __slots__ = ("_lock", "bounds", "counts", "_sum", "_count")

    def __init__(self, *, start: float = 1e-6, factor: float = 2.0, buckets: int = 26):
        if start <= 0 or factor <= 1.0 or buckets < 1:
            raise ValueError("need start > 0, factor > 1, buckets >= 1")
        self._lock = threading.Lock()
        self.bounds = [start * factor**i for i in range(buckets)]
        self.counts = [0] * (buckets + 1)
        self._sum = 0.0
        self._count = 0

    def observe(self, value: float) -> None:
        i = bisect.bisect_left(self.bounds, value)
        with self._lock:
            self.counts[i] += 1
            self._sum += value
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else math.nan

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile estimate (upper bound of the bucket
        holding the ``q``-th observation; NaN when empty)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("q must be in [0, 1]")
        with self._lock:
            total = self._count
            counts = list(self.counts)
        if total == 0:
            return math.nan
        rank = q * total
        seen = 0
        for i, c in enumerate(counts):
            seen += c
            if seen >= rank and c > 0:
                return self.bounds[i] if i < len(self.bounds) else math.inf
        return math.inf

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "count": self._count,
                "sum": self._sum,
                "buckets": dict(zip([*self.bounds, math.inf], self.counts)),
            }


class _Family:
    """One metric name: kind + help text + one child per label set."""

    __slots__ = ("name", "kind", "help", "children", "_hist_kwargs")

    def __init__(self, name: str, kind: str, help: str, hist_kwargs: dict):
        self.name = name
        self.kind = kind
        self.help = help
        self.children: dict[tuple[tuple[str, str], ...], Counter | Gauge | Histogram] = {}
        self._hist_kwargs = hist_kwargs

    def child(self, key: tuple[tuple[str, str], ...]):
        c = self.children.get(key)
        if c is None:
            if self.kind == "counter":
                c = Counter()
            elif self.kind == "gauge":
                c = Gauge()
            else:
                c = Histogram(**self._hist_kwargs)
            self.children[key] = c
        return c


class MetricsRegistry:
    """Thread-safe family registry; see module docstring."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    # -- instrument handles -------------------------------------------------
    def _get(self, name: str, kind: str, help: str, labels: dict, hist_kwargs: dict):
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = _Family(name, kind, help, hist_kwargs)
                self._families[name] = fam
            elif fam.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}, not {kind}"
                )
            return fam.child(key)

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(name, "counter", help, labels, {})

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(name, "gauge", help, labels, {})

    def histogram(
        self,
        name: str,
        help: str = "",
        *,
        start: float = 1e-6,
        factor: float = 2.0,
        buckets: int = 26,
        **labels,
    ) -> Histogram:
        return self._get(
            name, "histogram", help, labels,
            {"start": start, "factor": factor, "buckets": buckets},
        )

    # -- introspection / export --------------------------------------------
    def _iter(self) -> Iterator[tuple[_Family, tuple, Counter | Gauge | Histogram]]:
        with self._lock:
            fams = [
                (f, list(f.children.items()))
                for f in self._families.values()
            ]
        for fam, children in fams:
            for key, child in children:
                yield fam, key, child

    def snapshot(self) -> dict:
        """Nested plain-python snapshot: ``{name: {kind, help, series:
        [{labels, value|hist}]}}``."""
        out: dict = {}
        for fam, key, child in self._iter():
            entry = out.setdefault(
                fam.name, {"kind": fam.kind, "help": fam.help, "series": []}
            )
            rec: dict = {"labels": dict(key)}
            if fam.kind == "histogram":
                rec.update(child.snapshot())
            else:
                rec["value"] = child.value
            entry["series"].append(rec)
        return out

    def to_json(self, indent: int | None = None) -> str:
        def _default(o):
            return repr(o)

        snap = self.snapshot()
        # histogram bucket keys are floats (inf included); stringify for JSON
        for fam in snap.values():
            if fam["kind"] != "histogram":
                continue
            for s in fam["series"]:
                s["buckets"] = {
                    ("+Inf" if math.isinf(le) else repr(le)): c
                    for le, c in s["buckets"].items()
                }
        return json.dumps(snap, indent=indent, sort_keys=True, default=_default)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (one scrape payload)."""
        lines: list[str] = []
        by_fam: dict[str, list[tuple[tuple, Counter | Gauge | Histogram]]] = {}
        kinds: dict[str, _Family] = {}
        for fam, key, child in self._iter():
            by_fam.setdefault(fam.name, []).append((key, child))
            kinds[fam.name] = fam
        for name in sorted(by_fam):
            fam = kinds[name]
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for key, child in by_fam[name]:
                if fam.kind == "histogram":
                    snap = child.snapshot()
                    cum = 0
                    for le, c in snap["buckets"].items():
                        cum += c
                        le_s = "+Inf" if math.isinf(le) else repr(le)
                        k = _render_labels(key + (("le", le_s),))
                        lines.append(f"{name}_bucket{k} {cum}")
                    k = _render_labels(key)
                    lines.append(f"{name}_sum{k} {snap['sum']}")
                    lines.append(f"{name}_count{k} {snap['count']}")
                else:
                    lines.append(f"{name}{_render_labels(key)} {child.value}")
        return "\n".join(lines) + "\n"

    def clear(self) -> None:
        with self._lock:
            self._families.clear()


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]
