"""``repro.obs.trace`` -- span-based tracing with explicit context propagation.

The serving stack hops threads constantly: a query is submitted on a
caller thread, stepped on scheduler workers, fetched on executor pool
threads, and force-answered by the deadline sweeper.  ``contextvars``
do not follow those hops (pool threads are created once and reused), so
context propagation here is *explicit*: a :class:`SpanContext` is passed
as a plain parameter (``trace=...``) and used as the parent of spans
opened on other threads.

Usage::

    tracer = obs.get_tracer()
    root = tracer.start_span("query", attrs={"qid": 7})
    ...
    with tracer.span("engine.fetch", parent=root.ctx, attrs={"block": 3}):
        ...         # runs on a worker thread; still parents under `root`
    root.end()
    tracer.export_chrome("trace.json")

Sampling is decided once per *root* span (``sample_rate`` on the
tracer); children inherit the decision through their parent's context,
so a trace is always either fully recorded or fully dropped -- no
orphan children.  The event buffer is bounded; overflow increments a
drop counter rather than growing without bound.

Export is Chrome trace-event JSON (``"X"`` complete events with
``ts``/``dur`` in microseconds plus ``"M"`` thread-name metadata),
loadable directly in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``.
"""

from __future__ import annotations

import json
import os
import random
import threading
import time
from collections import deque
from dataclasses import dataclass

_ids = threading.local()


def _new_id() -> int:
    # Per-thread RNG: no lock contention, seeded off urandom once per thread.
    rng = getattr(_ids, "rng", None)
    if rng is None:
        rng = _ids.rng = random.Random(int.from_bytes(os.urandom(8), "big"))
    return rng.getrandbits(63) | 1


@dataclass(frozen=True)
class SpanContext:
    """Immutable handle to a span, safe to pass across threads."""

    trace_id: int
    span_id: int
    sampled: bool = True


#: Context of an unsampled root; children of it are suppressed too.
DROPPED = SpanContext(trace_id=0, span_id=0, sampled=False)


class Span:
    """A timed operation.  ``end()`` is idempotent; usable as a context
    manager.  Unsampled spans are inert (still carry a ctx so children
    know to drop themselves)."""

    __slots__ = ("name", "ctx", "parent_id", "attrs", "_tracer", "_t0", "_tid", "_done")

    def __init__(self, name: str, ctx: SpanContext, parent_id: int,
                 attrs: dict | None, tracer: "Tracer | None"):
        self.name = name
        self.ctx = ctx
        self.parent_id = parent_id
        self.attrs = attrs
        self._tracer = tracer
        self._t0 = time.perf_counter() if tracer is not None else 0.0
        self._tid = threading.get_ident()
        self._done = False

    def set_attr(self, key: str, value) -> None:
        if self._tracer is None:
            return
        if self.attrs is None:
            self.attrs = {}
        self.attrs[key] = value

    def end(self) -> None:
        if self._done or self._tracer is None:
            return
        self._done = True
        self._tracer._finish(self, time.perf_counter())

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        self.end()


_NOOP = Span("", DROPPED, 0, None, None)


class Tracer:
    """Collects finished spans in a bounded buffer; exports Chrome JSON."""

    def __init__(self, *, sample_rate: float = 1.0, max_events: int = 200_000):
        if not 0.0 <= sample_rate <= 1.0:
            raise ValueError("sample_rate must be in [0, 1]")
        self.sample_rate = sample_rate
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=max_events)
        self._dropped = 0
        self._thread_names: dict[int, str] = {}
        self._epoch = time.perf_counter()

    # -- span lifecycle -----------------------------------------------------
    def start_span(self, name: str, *, parent: SpanContext | None = None,
                   attrs: dict | None = None) -> Span:
        """Open a span.  ``parent=None`` starts a new trace (root), which is
        where the sampling decision is made; passing a parent inherits both
        the trace id and the decision."""
        if parent is not None:
            if not parent.sampled:
                return _NOOP
            ctx = SpanContext(parent.trace_id, _new_id(), True)
            return Span(name, ctx, parent.span_id, attrs, self)
        if self.sample_rate < 1.0:
            rng = getattr(_ids, "rng", None)
            if rng is None:
                _new_id()  # seeds the per-thread rng
                rng = _ids.rng
            if rng.random() >= self.sample_rate:
                return _NOOP
        tid = _new_id()
        ctx = SpanContext(tid, _new_id(), True)
        return Span(name, ctx, 0, attrs, self)

    def span(self, name: str, *, parent: SpanContext | None = None,
             attrs: dict | None = None) -> Span:
        """Alias of :meth:`start_span`, reads better in ``with`` statements."""
        return self.start_span(name, parent=parent, attrs=attrs)

    def _finish(self, span: Span, t1: float) -> None:
        ev = (span.name, span._tid, span._t0, t1,
              span.ctx.trace_id, span.ctx.span_id, span.parent_id, span.attrs)
        with self._lock:
            if span._tid not in self._thread_names:
                # spans start and end on one thread; label it for the export
                self._thread_names[span._tid] = threading.current_thread().name
            if len(self._events) == self._events.maxlen:
                self._dropped += 1
            self._events.append(ev)

    def set_thread_name(self, name: str, tid: int | None = None) -> None:
        tid = threading.get_ident() if tid is None else tid
        with self._lock:
            self._thread_names[tid] = name

    # -- introspection / export --------------------------------------------
    @property
    def dropped(self) -> int:
        with self._lock:
            return self._dropped

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._dropped = 0

    def chrome_events(self) -> list[dict]:
        """Trace-event list: ``M`` thread-name metadata + ``X`` complete
        events, ts/dur in integer microseconds relative to tracer start."""
        with self._lock:
            events = list(self._events)
            names = dict(self._thread_names)
        pid = os.getpid()
        out: list[dict] = [
            {"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
             "args": {"name": label}}
            for tid, label in sorted(names.items())
        ]
        for name, tid, t0, t1, trace_id, span_id, parent_id, attrs in events:
            args = {"trace_id": f"{trace_id:x}", "span_id": f"{span_id:x}"}
            if parent_id:
                args["parent_id"] = f"{parent_id:x}"
            if attrs:
                args.update(attrs)
            out.append({
                "ph": "X",
                "name": name,
                "pid": pid,
                "tid": tid,
                "ts": round((t0 - self._epoch) * 1e6),
                "dur": max(1, round((t1 - t0) * 1e6)),
                "args": args,
            })
        return out

    def export_chrome(self, path: str | os.PathLike) -> int:
        """Write ``{"traceEvents": [...]}`` JSON; returns the event count."""
        events = self.chrome_events()
        payload = {"traceEvents": events, "displayTimeUnit": "ms"}
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, default=repr)
        os.replace(tmp, path)
        return len(events)


__all__ = ["SpanContext", "Span", "Tracer", "DROPPED"]
