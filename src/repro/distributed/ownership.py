"""``repro.distributed.ownership`` -- which host owns which RSP blocks.

An ownership map is a deterministic deal of the ``K`` stored blocks across
the mesh's hosts (``core.sampler.deal_blocks``: one epoch permutation,
strided across hosts).  Because every RSP block is a random sample of the
corpus (Definition 3) and unions of blocks in corpus proportion are again
RSP blocks (Theorem 1), *any* assignment of blocks to hosts -- and any
re-assignment after a host departs or joins -- is statistically free: the
set of blocks a query folds is unchanged, only where each one is computed
moves.  That theorem is what makes straggler stealing and elastic
re-balancing correctness-preserving operations rather than approximations.

The map round-trips through a stored partition as an ``ownership.json``
sidecar next to the manifest, so a re-started mesh re-opens the same deal.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Sequence

from repro.core.sampler import HostAssignment, deal_blocks

OWNERSHIP_FILE = "ownership.json"


@dataclasses.dataclass(frozen=True)
class BlockOwnership:
    """A validated block -> host deal for one mesh epoch."""

    assignment: HostAssignment
    num_blocks: int
    seed: int = 0
    epoch: int = 0

    def __post_init__(self):
        owner: dict[int, int] = {}
        for h, blocks in self.assignment.host_blocks.items():
            for b in blocks:
                if b in owner:
                    raise ValueError(f"block {b} owned by hosts {owner[b]} and {h}")
                if not 0 <= b < self.num_blocks:
                    raise ValueError(f"block {b} outside [0, {self.num_blocks})")
                owner[b] = int(h)
        if len(owner) != self.num_blocks:
            missing = sorted(set(range(self.num_blocks)) - set(owner))
            raise ValueError(f"blocks {missing[:8]}... have no owner")
        object.__setattr__(self, "_owner", owner)

    @classmethod
    def deal(
        cls, num_blocks: int, num_hosts: int, *, seed: int = 0, epoch: int = 0
    ) -> "BlockOwnership":
        """Deterministic fresh deal (strided epoch permutation)."""
        return cls(
            assignment=deal_blocks(num_blocks, num_hosts, seed=seed, epoch=epoch),
            num_blocks=num_blocks,
            seed=seed,
            epoch=epoch,
        )

    # -- queries -----------------------------------------------------------
    def owner_of(self, block_id: int) -> int:
        return self._owner[int(block_id)]

    def blocks_of(self, host: int) -> list[int]:
        return list(self.assignment.blocks_for(int(host)))

    def hosts(self) -> list[int]:
        return sorted(self.assignment.host_blocks)

    @property
    def num_hosts(self) -> int:
        return len(self.assignment.host_blocks)

    # -- churn (Theorem-1-valid re-deals) ----------------------------------
    def redeal(self, departed: Sequence[int]) -> "BlockOwnership":
        """Re-deal departed hosts' blocks round-robin to the survivors.

        Deterministic given the same departed set, so every survivor derives
        the identical new map without communicating.  Statistically free by
        Theorem 1 (block unions in corpus proportion stay RSP blocks).
        """
        return dataclasses.replace(
            self, assignment=self.assignment.redistribute(departed),
            epoch=self.epoch + 1,
        )

    def rebalance(self, num_hosts: int) -> "BlockOwnership":
        """Fresh balanced deal over ``num_hosts`` hosts (a joining host gets
        its proportional share; Theorem 1 makes the re-deal free)."""
        return BlockOwnership.deal(
            self.num_blocks, num_hosts, seed=self.seed, epoch=self.epoch + 1
        )

    # -- (de)serialization -------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "num_blocks": self.num_blocks,
            "seed": self.seed,
            "epoch": self.epoch,
            "host_blocks": {
                str(h): [int(b) for b in blocks]
                for h, blocks in sorted(self.assignment.host_blocks.items())
            },
        }

    @classmethod
    def from_dict(cls, d: dict) -> "BlockOwnership":
        return cls(
            assignment=HostAssignment(
                {int(h): [int(b) for b in blocks] for h, blocks in d["host_blocks"].items()}
            ),
            num_blocks=int(d["num_blocks"]),
            seed=int(d.get("seed", 0)),
            epoch=int(d.get("epoch", 0)),
        )


def _store_root(store) -> str:
    root = getattr(store, "root", None)
    if root is None:
        raise TypeError("save/load_ownership need an RSPStore (or a .root path)")
    return root


def save_ownership(store, ownership: BlockOwnership) -> str:
    """Persist the deal as an ``ownership.json`` sidecar (atomic replace)."""
    root = _store_root(store)
    path = os.path.join(root, OWNERSHIP_FILE)
    fd, tmp = tempfile.mkstemp(dir=root, suffix=".tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(ownership.to_dict(), f)
    os.replace(tmp, path)
    return path


def load_ownership(store) -> BlockOwnership | None:
    """Load the stored deal, or ``None`` when the store carries none."""
    path = os.path.join(_store_root(store), OWNERSHIP_FILE)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return BlockOwnership.from_dict(json.load(f))
