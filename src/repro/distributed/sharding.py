"""Logical-axis sharding: maps ParamSpec axis names to mesh axes, builds
NamedShardings for params / optimizer state / batches, and provides the
activation-constraint hook the model code consults.

Default rules (DP x TP on a ("data", "model") or ("pod", "data", "model")
mesh):
    batch    -> (pod, data)        vocab   -> model
    heads    -> model              ff      -> model
    kv_heads -> model iff the arch has >= MIN_KV_SHARD kv heads (GQA padding
                waste is bounded); otherwise replicated (MQA keeps the single
                KV head on every model rank)
    experts  -> model              embed   -> replicated
    layers / inner / seq / None -> replicated (scan / contraction dims)

ZeRO-1: optimizer master/m/v additionally shard their largest replicated,
divisible dimension over "data" -- GSPMD then emits reduce-scatter +
all-gather in place of all-reduce for the gradient/update path.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ParamSpec

MIN_KV_SHARD = 4

MeshAxes = tuple[str, ...] | str | None


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    rules: dict[str, MeshAxes]

    def spec_for(self, axes: tuple[str | None, ...]) -> P:
        return P(*[self.rules.get(a) if a is not None else None for a in axes])

    def named(self, axes: tuple[str | None, ...]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(axes))


def default_rules(
    mesh: Mesh,
    *,
    num_kv_heads: int = 8,
    shard_kv_seq: bool = False,
    cfg=None,
) -> ShardingRules:
    """Arch-aware rules.  jit *input* shardings must divide dimensions
    evenly, so every model-axis assignment is gated on divisibility:
      kv_heads: sharded iff kv % model == 0 (MQA/GQA below that replicates
                KV and lets the query-group dim carry the TP split)
      vocab:    sharded iff vocab % model == 0 (e.g. hubert's 504 and
                granite-moe's 49155 stay replicated)
      experts:  sharded iff E % model == 0; otherwise the per-expert hidden
                (expert_ff) takes the TP split instead (granite-moe: E=40)
    """
    axes = mesh.axis_names
    tp = int(mesh.shape["model"]) if "model" in axes else 1
    dp: MeshAxes = tuple(a for a in ("pod", "data") if a in axes)
    if len(dp) == 1:
        dp = dp[0]
    if cfg is not None:
        num_kv_heads = cfg.num_kv_heads
        vocab = cfg.vocab_size
        experts = cfg.num_experts
        expert_ff = cfg.d_ff if cfg.num_experts else 0
        d_ff = cfg.d_ff
        head_dim = cfg.resolved_head_dim
    else:
        vocab, experts, expert_ff, d_ff, head_dim = 1 << 20, 0, 0, 1 << 20, 0

    kv_sharded = num_kv_heads % tp == 0 and num_kv_heads >= tp
    experts_sharded = experts > 0 and experts % tp == 0
    rules: dict[str, MeshAxes] = {
        "batch": dp,
        "heads": "model",
        "kv_heads": "model" if kv_sharded else None,
        # with replicated KV the query-group dim carries the TP split instead
        "heads_inner": None if kv_sharded else "model",
        "ff": "model" if d_ff % tp == 0 else None,
        "vocab": "model" if vocab % tp == 0 else None,
        "experts": "model" if experts_sharded else None,
        "expert_ff": None if experts_sharded or expert_ff % tp else "model",
        "embed": None,
        "moe_group": "data" if "data" in axes else None,
        "kv_seq": "data" if shard_kv_seq and "data" in axes else None,
        # decode KV caches are jit INPUTS: when kv heads are unshardable the
        # cache head_dim carries the model split (contraction-sharded
        # attention; GSPMD inserts the score all-reduce)
        "kv_head_dim": "model" if (not kv_sharded and head_dim and head_dim % tp == 0) else None,
        "layers": None,
        "inner": None,
    }
    return ShardingRules(mesh=mesh, rules=rules)


# ---------------------------------------------------------------------------
# Param / state shardings
# ---------------------------------------------------------------------------

def param_shardings(specs: Any, rules: ShardingRules) -> Any:
    """NamedSharding tree matching a ParamSpec tree."""
    return jax.tree.map(
        lambda s: rules.named(s.axes), specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def _data_axis_size(mesh: Mesh) -> int:
    return int(mesh.shape["data"]) if "data" in mesh.axis_names else 1


def zero_shard_spec(spec: ParamSpec, rules: ShardingRules) -> P:
    """ZeRO-1: extend the param spec by sharding one replicated dim over
    'data'.  Picks the largest dimension that is unsharded and divisible."""
    base = list(rules.spec_for(spec.axes))
    dsize = _data_axis_size(rules.mesh)
    if dsize <= 1:
        return P(*base)
    cand = [
        (dim_size, i)
        for i, (dim_size, assigned) in enumerate(zip(spec.shape, base))
        if assigned is None and dim_size % dsize == 0 and dim_size >= dsize
    ]
    if not cand:
        return P(*base)
    _, idx = max(cand)
    base[idx] = "data"
    return P(*base)


def optimizer_shardings(specs: Any, rules: ShardingRules) -> dict:
    """Shardings for the AdamW state {master, m, v, step}."""
    leaf = lambda s: NamedSharding(rules.mesh, zero_shard_spec(s, rules))
    tree = jax.tree.map(leaf, specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return {
        "master": tree,
        "m": tree,
        "v": tree,
        "step": NamedSharding(rules.mesh, P()),
    }


def batch_shardings(batch_specs: dict, rules: ShardingRules) -> dict:
    """Inputs: leading dim is the global batch -> DP axes."""
    dp = rules.rules["batch"]

    def leaf(s: jax.ShapeDtypeStruct):
        spec = [None] * len(s.shape)
        if s.shape and s.shape[0] > 1:
            spec[0] = dp
        return NamedSharding(rules.mesh, P(*spec))

    return jax.tree.map(leaf, batch_specs)


# ---------------------------------------------------------------------------
# Activation constraints (consulted from model code via `constrain`)
# ---------------------------------------------------------------------------

_ACTIVE: contextvars.ContextVar[ShardingRules | None] = contextvars.ContextVar(
    "sharding_rules", default=None
)


@contextlib.contextmanager
def activation_sharding(rules: ShardingRules | None):
    token = _ACTIVE.set(rules)
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def constrain(x: jax.Array, axes: tuple[str | None, ...]) -> jax.Array:
    """with_sharding_constraint against the active rules (no-op outside a
    mesh context, so smoke tests and single-device runs are unaffected).

    Size-aware: dims of extent 1 stay unsharded (single-stream decode), and
    if two logical axes resolve to the same mesh axis only the first keeps
    it (e.g. batch and kv_seq both wanting 'data' in long-context decode)."""
    rules = _ACTIVE.get()
    if rules is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"axes {axes} rank != array rank {x.ndim}")
    used: set[str] = set()
    spec: list[MeshAxes] = []
    for dim, a in zip(x.shape, axes):
        r = rules.rules.get(a) if a is not None else None
        if r is None or dim <= 1:
            spec.append(None)
            continue
        names = r if isinstance(r, tuple) else (r,)
        if any(n in used for n in names):
            spec.append(None)
            continue
        used.update(names)
        spec.append(r)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*spec))
    )


def cache_shardings(caches_abstract: Any, rules: ShardingRules) -> Any:
    """Shardings for decode caches, matched by leaf path.

    Cache layouts (leading dim = stacked layers / invocations):
      attn k/v   [L, B, Hkv, T, D] -> (None, batch, kv_heads, kv_seq, None)
      attn len   [L]               -> replicated
      mamba conv [L, B, K-1, Ch]   -> (None, batch, None, heads)
      mamba ssm  [L, B, H, P, N]   -> (None, batch, heads, None, None)
      rwkv shift [L, B, 1, d]      -> (None, batch, None, None)
      rwkv wkv   [L, B, H, C, C]   -> (None, batch, heads, None, None)
      pos        []                -> replicated
    Batch stays replicated when B == 1 (long-context single-stream decode).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(caches_abstract)

    def spec_for(path: str, shape: tuple[int, ...]) -> P:
        def b(dim: int) -> MeshAxes:
            return rules.rules["batch"] if shape[dim] > 1 else None

        if path.endswith("['k']") or path.endswith("['v']"):
            return P(
                None, b(1), rules.rules["kv_heads"], rules.rules["kv_seq"],
                rules.rules.get("kv_head_dim"),
            )
        if path.endswith("['conv']"):
            return P(None, b(1), None, rules.rules["heads"])
        if path.endswith("['ssm']"):
            return P(None, b(1), rules.rules["heads"], None, None)
        if path.endswith("['wkv']"):
            return P(None, b(1), rules.rules["heads"], None, None)
        if path.endswith("['shift']"):
            return P(None, b(1), None, None)
        return P()  # length / pos scalars

    out = [
        NamedSharding(rules.mesh, spec_for(jax.tree_util.keystr(path), leaf.shape))
        for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


def attach_shardings(abstract: Any, shardings: Any) -> Any:
    """Rebuild ShapeDtypeStructs with shardings attached."""
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract,
        shardings,
    )


def abstract_state(specs: Any, rules: ShardingRules) -> dict:
    """ShapeDtypeStruct AdamW state with ZeRO shardings (for dry-run)."""
    import jax.numpy as jnp

    def master_leaf(s: ParamSpec):
        return jax.ShapeDtypeStruct(
            s.shape, jnp.float32, sharding=NamedSharding(rules.mesh, zero_shard_spec(s, rules))
        )

    is_spec = lambda x: isinstance(x, ParamSpec)
    tree = jax.tree.map(master_leaf, specs, is_leaf=is_spec)
    return {
        "master": tree,
        "m": tree,
        "v": tree,
        "step": jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(rules.mesh, P())),
    }


def abstract_compute_params(specs: Any, rules: ShardingRules, dtype=None) -> Any:
    import jax.numpy as jnp

    dtype = dtype or jnp.bfloat16
    is_spec = lambda x: isinstance(x, ParamSpec)

    def leaf(s: ParamSpec):
        dt = dtype if np.issubdtype(np.dtype(s.dtype), np.floating) else s.dtype
        return jax.ShapeDtypeStruct(s.shape, dt, sharding=rules.named(s.axes))

    return jax.tree.map(leaf, specs, is_leaf=is_spec)


def block_ownership(num_blocks: int, hosts=None, *, seed: int = 0):
    """Derive the RSP block -> host deal for a mesh.

    ``hosts`` may be a ``jax.sharding.Mesh`` (host count = number of
    distinct processes its devices span), an int, or ``None`` (=
    ``jax.process_count()``).  The deal itself is the deterministic epoch
    permutation of ``core.sampler.deal_blocks`` -- the same sharding
    philosophy as the model rules above, applied to data blocks: the rule
    derives placement from the mesh, placement never changes the statistics
    (Theorem 1: any block union in corpus proportion is again an RSP
    block)."""
    from repro.distributed.ownership import BlockOwnership

    if hosts is None:
        num_hosts = jax.process_count()
    elif isinstance(hosts, Mesh):
        num_hosts = len({d.process_index for d in hosts.devices.flat})
    else:
        num_hosts = int(hosts)
    return BlockOwnership.deal(num_blocks, num_hosts, seed=seed)
