"""``repro.distributed.rsp`` -- mesh-distributed RSP datasets and queries.

The paper's setting is a cluster: RSP blocks live across nodes, and block
sampling "can be refined to select blocks depending on the availability of
nodes" (Sec. 7).  This module makes that concrete:

:class:`DistributedDataset`
    Wraps one host's view of a shared RSP store: a
    :class:`~repro.distributed.ownership.BlockOwnership` deal says which
    blocks this host owns, a :class:`~repro.rsp.engine.ScopedFetcher` makes
    touching anything else a hard error, and a
    :class:`~repro.distributed.mesh.Transport` is the byte plane to the
    peers.  ``note_departed`` / ``rebalance`` apply Theorem-1-valid
    re-deals on host churn.

:class:`DistributedQueryExecutor`
    A :class:`~repro.rsp.query.QueryExecutor` whose ``_payload_source``
    gathers *peer-computed block payloads* instead of streaming local
    blocks.  Everything else -- selection, Chan merging, HT weighting, CIs,
    the stopping rule -- is byte-for-byte the single-host code path, which
    is what makes the distributed answer **bit-identical** to the
    single-host answer with the same seed:

    * every host derives the identical block-id sequence (policies are
      deterministic functions of ``(seed, draw counter)`` and the shared
      manifest sketches -- inclusion probabilities are computed once from
      the manifest, so HT/Hajek estimates stay exactly unbiased no matter
      which host processes which block);
    * each position's payload is a pure function of the block bytes and
      the query shape, computed by the position's *owner* and published on
      the transport (JSON float round-trips are exact, dtypes preserved);
    * every host folds the gathered payloads in canonical position order
      through the same ``_stream_impl`` fold.

    Straggler tolerance rides :class:`~repro.distributed.straggler.
    LeaseScheduler`: when an owner misses its grace window, its unstarted
    positions are re-dealt deterministically to the survivors (statistically
    free by block exchangeability), duplicate publishes are idempotent
    (identical bytes), and a host whose consumer stops early publishes a
    ``fin`` marker so peers steal its remainder without waiting out the
    grace.
"""

from __future__ import annotations

import hashlib
import heapq
import json
import time
from typing import Iterator

import numpy as np

from repro.distributed.mesh import Transport, TransportError
from repro.distributed.ownership import BlockOwnership
from repro.distributed.straggler import LeaseScheduler
from repro.kernels.block_sketch import BlockSketch
from repro.rsp.engine import BlockExecutor, ScopedFetcher
from repro.rsp.query import QueryExecutor, as_query


# ---------------------------------------------------------------------------
# Payload codec: exact JSON round-trip of the per-block fold state
# ---------------------------------------------------------------------------

def encode_payload(payload: dict) -> bytes:
    """Serialize one block's fold payload to canonical bytes.

    Exact to the bit: Python's shortest-repr float encoding round-trips
    every float64 (inf/nan included), array dtypes travel alongside the
    data, and key order is canonical -- so any two hosts encoding the same
    payload produce identical bytes (idempotent duplicate publishes)."""
    d = {
        "whole": None if payload["whole"] is None else _sketch_dict(payload["whole"]),
        "per_class": (
            None
            if payload["per_class"] is None
            else [_sketch_dict(s) for s in payload["per_class"]]
        ),
        "rows_total": payload["rows_total"],
        "rows_selected": payload["rows_selected"],
        "distinct": (
            None if payload.get("distinct") is None else payload["distinct"].to_dict()
        ),
    }
    return json.dumps(d, sort_keys=True).encode()


def _sketch_dict(sk) -> dict:
    if isinstance(sk, BlockSketch):
        return sk.to_dict()
    # accelerator-impl sketches expose the same fields; normalize via numpy
    return BlockSketch(
        count=float(sk.count),
        mean=np.asarray(sk.mean), m2=np.asarray(sk.m2),
        min=np.asarray(sk.min), max=np.asarray(sk.max),
        hist=None if sk.hist is None else np.asarray(sk.hist),
        lo=None if sk.lo is None else np.asarray(sk.lo),
        hi=None if sk.hi is None else np.asarray(sk.hi),
    ).to_dict()


def decode_payload(data: bytes) -> dict:
    from repro.rsp.sketch import DistinctSketch

    d = json.loads(data.decode())
    return {
        "whole": None if d["whole"] is None else BlockSketch.from_dict(d["whole"]),
        "per_class": (
            None
            if d["per_class"] is None
            else [BlockSketch.from_dict(s) for s in d["per_class"]]
        ),
        "rows_total": d["rows_total"],
        "rows_selected": d["rows_selected"],
        "distinct": (
            None if d["distinct"] is None else DistinctSketch.from_dict(d["distinct"])
        ),
    }


# ---------------------------------------------------------------------------
# The distributed query executor
# ---------------------------------------------------------------------------

class DistributedQueryExecutor(QueryExecutor):
    """Fans one query's block work out over the mesh (see module docstring).

    Overrides only ``_payload_source``; the fold and all statistics are the
    inherited single-host code."""

    def __init__(self, dds: "DistributedDataset", query):
        super().__init__(dds, query)
        self._dds = dds
        #: hosts this query declared dead (grace expired with no payload);
        #: DistributedDataset re-deals their blocks after the query
        self.presumed_dead: set[int] = set()

    # -- the one overridden seam -------------------------------------------
    def _payload_source(
        self, ids, lo, hi, *, needs_hist, needs_rows, grouped, need_whole
    ) -> Iterator[tuple[int, dict]]:
        dds = self._dds
        transport: Transport = dds.transport
        me = transport.host_id
        # materialize the full deterministic selection sequence up front --
        # every host derives the same list, so "position" is a global name
        ids = [int(i) for i in ids]
        n = len(ids)

        ns, base, fp = self._namespace(ids, lo, hi)
        transport.put(f"{base}/fp/{me}", fp.encode())

        ownership = dds.ownership
        assign: dict[int, list[int]] = {h: [] for h in ownership.hosts()}
        for p, bid in enumerate(ids):
            assign.setdefault(ownership.owner_of(bid), []).append(p)
        sched = LeaseScheduler.from_assignment(assign)
        assignee = {p: h for h, ps in assign.items() for p in ps}
        failed: set[int] = set()
        my_heap = list(assign.get(me, []))
        heapq.heapify(my_heap)
        computed: dict[int, bytes] = {}

        def compute(p: int) -> bytes:
            block = dds.executor.fetch(ids[p], counter=self.counter)
            data = encode_payload(
                self._make_payload(
                    block, lo, hi, needs_hist, needs_rows, grouped, need_whole
                )
            )
            transport.put(f"{ns}/p/{p}", data)
            computed[p] = data
            sched.complete(me, p)
            return data

        def work_ahead() -> bool:
            """Compute one pending owned/stolen position while waiting."""
            while my_heap:
                p = heapq.heappop(my_heap)
                if p not in computed:
                    compute(p)
                    return True
            return False

        def reassign(p: int) -> None:
            """Declare ``p``'s assignee gone; re-deal its unfinished
            positions deterministically onto the survivors."""
            dead = assignee[p]
            failed.add(dead)
            self.presumed_dead.add(dead)
            sched.fail_host(dead)
            survivors = sorted(
                set(h for h in ownership.hosts() if h not in failed) | {me}
            )
            grants = sched.redeal(survivors)
            for h, ps in grants.items():
                for gp in ps:
                    assignee[gp] = h
            mine = grants.get(me, [])
            if mine:
                dds.allow_blocks(ids[gp] for gp in mine)
                for gp in mine:
                    heapq.heappush(my_heap, gp)

        poll = dds.poll_interval
        grace = dds.straggler_grace
        try:
            for p in range(n):
                data = computed.get(p)
                if data is None and assignee[p] == me:
                    data = compute(p)
                deadline = time.monotonic() + grace
                while data is None:
                    data = transport.get(f"{ns}/p/{p}", poll)
                    if data is not None:
                        break
                    work_ahead()
                    holder = assignee[p]
                    if holder == me:
                        data = compute(p)
                        break
                    if transport.get(f"{ns}/fin/{holder}", 0.0) is not None:
                        # holder ceased computing for this query; one last
                        # look (it may have published p just before), then
                        # steal without waiting out the grace
                        data = transport.get(f"{ns}/p/{p}", poll)
                        if data is not None:
                            break
                        reassign(p)
                        deadline = time.monotonic() + grace
                        continue
                    self._check_fingerprints(transport, base, fp)
                    if time.monotonic() > deadline:
                        reassign(p)
                        deadline = time.monotonic() + grace
                yield ids[p], decode_payload(data)
        finally:
            # reached on convergence, close(), and exhaustion alike: tell
            # the peers this host computes nothing further for this query
            try:
                transport.put(f"{ns}/fin/{me}", b"1")
            except TransportError:
                pass  # dying hosts cannot say goodbye

    # -- naming and divergence detection -----------------------------------
    def _namespace(self, ids, lo, hi) -> tuple[str, str, str]:
        """``(ns, base, fp)`` for this query's keys.

        ``base`` digests the query *shape* (seed, aggregates, predicates,
        stopping rule); ``fp`` digests the *derived state* (policy
        distribution, materialized id sequence, histogram grid).  The
        working namespace is ``base/fp``, so hosts whose manifests diverge
        can never consume each other's payloads -- divergence degrades to
        isolated (still correct) execution, and ``_check_fingerprints``
        turns it into a loud error."""
        q = self.q
        sig = {
            "seed": self.seed,
            "aggs": [(a.kind, a.q, a.feature, a.by_label, a.name) for a in q.aggregates],
            "policy": getattr(self._pol, "name", str(q.policy)),
            "n": len(ids),
            "where": repr(q.where),
            "columns": q.columns,
            "bins": q.bins,
            "bootstrap": q.bootstrap,
            "confidence": q.confidence,
            "target_rel_err": q.target_rel_err,
            "min_blocks": q.min_blocks,
        }
        base = "rspq/" + hashlib.sha1(
            json.dumps(sig, sort_keys=True, default=str).encode()
        ).hexdigest()[:16]
        h = hashlib.sha1()
        try:
            h.update(self._pol.fingerprint().encode())
        except NotImplementedError:  # custom policy: fall back to its name
            h.update(getattr(self._pol, "name", "custom").encode())
        h.update(np.asarray(ids, dtype=np.int64).tobytes())
        if lo is not None:
            h.update(np.ascontiguousarray(np.asarray(lo, np.float64)).tobytes())
            h.update(np.ascontiguousarray(np.asarray(hi, np.float64)).tobytes())
        fp = h.hexdigest()[:16]
        return f"{base}/{fp}", base, fp

    def _check_fingerprints(self, transport: Transport, base: str, fp: str) -> None:
        for key, value in transport.poll(f"{base}/fp/").items():
            if value.decode() != fp:
                raise RuntimeError(
                    f"distributed query fingerprint mismatch ({key} published"
                    f" {value.decode()!r}, this host derived {fp!r}): hosts"
                    " disagree on the manifest sketches / policy distribution"
                    " -- refusing to merge (HT weights would silently skew)"
                )


# ---------------------------------------------------------------------------
# The distributed dataset facade
# ---------------------------------------------------------------------------

class DistributedDataset:
    """One host's view of an RSP shared across a mesh.

    ``dataset`` is this host's (complete) view of the stored partition --
    each host opens the same store, or shares the in-memory blocks
    read-only under :class:`~repro.distributed.mesh.LocalTransport`.  The
    ownership deal decides which of those blocks this host may actually
    *read*: block movement goes through a
    :class:`~repro.rsp.engine.ScopedFetcher`, so any fetch outside the
    owned/stolen scope raises instead of silently breaking the
    "each host streams only its local blocks" contract.

    Requires materialized partition-time sketches: the selection policies'
    inclusion probabilities must come from the *shared* manifest (computing
    them locally would both scan un-owned blocks and risk diverging HT
    weights across hosts).
    """

    def __init__(
        self,
        dataset,
        transport: Transport,
        *,
        ownership: BlockOwnership | None = None,
        straggler_grace: float = 10.0,
        poll_interval: float = 0.05,
    ):
        if not dataset.has_summaries:
            raise ValueError(
                "DistributedDataset needs materialized partition-time"
                " sketches (dataset.has_summaries): inclusion probabilities"
                " must come from the shared manifest so HT weights agree"
                " across hosts"
            )
        if ownership is None:
            ownership = BlockOwnership.deal(
                dataset.num_blocks, transport.num_hosts, seed=dataset.spec.seed
            )
        if ownership.num_blocks != dataset.num_blocks:
            raise ValueError(
                f"ownership covers {ownership.num_blocks} blocks,"
                f" dataset has {dataset.num_blocks}"
            )
        self.dataset = dataset
        self.transport = transport
        self.ownership = ownership
        self.straggler_grace = float(straggler_grace)
        self.poll_interval = float(poll_interval)
        self._scoped = ScopedFetcher(
            dataset._make_fetcher(), ownership.blocks_of(transport.host_id)
        )
        self._executor = BlockExecutor(
            self._scoped,
            prefetch=dataset._prefetch,
            cache_blocks=dataset._cache_blocks,
        )

    # -- RSPDataset protocol surface (QueryExecutor + QueryService) --------
    @property
    def spec(self):
        return self.dataset.spec

    @property
    def num_blocks(self) -> int:
        return self.dataset.num_blocks

    @property
    def num_classes(self):
        return self.dataset.num_classes

    @property
    def label_column(self):
        return self.dataset.label_column

    @property
    def summaries(self):
        return self.dataset.summaries

    @property
    def has_summaries(self) -> bool:
        return self.dataset.has_summaries

    @property
    def executor(self) -> BlockExecutor:
        return self._executor

    def policy(self, policy="uniform", *, seed: int = 0, **kwargs):
        return self.dataset.policy(policy, seed=seed, **kwargs)

    def _compute_summaries(self, counter=None):
        return self.dataset._compute_summaries(counter=counter)

    # -- identity ----------------------------------------------------------
    @property
    def host_id(self) -> int:
        return self.transport.host_id

    @property
    def owned_blocks(self) -> list[int]:
        return self.ownership.blocks_of(self.host_id)

    def allow_blocks(self, block_ids) -> None:
        """Widen this host's read scope (stolen straggler leases)."""
        self._scoped.allow(block_ids)

    # -- queries -----------------------------------------------------------
    def query_executor(self, query) -> DistributedQueryExecutor:
        """Factory consumed by :class:`~repro.serve.QueryService` (and the
        query methods below) so served queries fan out over the mesh too."""
        return DistributedQueryExecutor(self, as_query(query))

    def query(self, aggregates="mean", **kwargs):
        """Distributed :meth:`repro.rsp.dataset.RSPDataset.query`: same
        declarative surface, bit-identical answer, block work fanned out
        over the mesh."""
        qe = self.query_executor(as_query(aggregates, **kwargs))
        try:
            return qe.run()
        finally:
            self._after_query(qe)

    def query_stream(self, aggregates="mean", **kwargs):
        """Progressive variant: one anytime result per folded block."""
        qe = self.query_executor(as_query(aggregates, **kwargs))

        def gen():
            try:
                yield from qe.stream()
            finally:
                self._after_query(qe)

        return gen()

    def serve(self, **kwargs):
        """A :class:`~repro.serve.QueryService` whose queries execute
        distributed (via the ``query_executor`` factory hook)."""
        from repro.serve.query_service import QueryService

        return QueryService(self, **kwargs)

    # -- elastic membership (Theorem-1-valid re-deals) ---------------------
    def _after_query(self, qe: DistributedQueryExecutor) -> None:
        gone = {h for h in qe.presumed_dead if h != self.host_id}
        if gone:
            self.note_departed(gone)

    def note_departed(self, hosts) -> BlockOwnership:
        """Re-deal departed hosts' blocks to the survivors for subsequent
        queries.  Statistically free (Theorem 1): re-assignment moves where
        blocks are *computed*, never which blocks exist."""
        current = set(self.ownership.hosts())
        hosts = [h for h in hosts if h in current and h != self.host_id]
        if hosts:
            self.ownership = self.ownership.redeal(hosts)
            self._scoped.replace(self.ownership.blocks_of(self.host_id))
        return self.ownership

    def rebalance(self, num_hosts: int | None = None) -> BlockOwnership:
        """Fresh balanced deal (a joined host gets its share)."""
        self.ownership = self.ownership.rebalance(
            self.transport.num_hosts if num_hosts is None else int(num_hosts)
        )
        self._scoped.replace(self.ownership.blocks_of(self.host_id))
        return self.ownership

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        self._executor.close()

    def __enter__(self) -> "DistributedDataset":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
