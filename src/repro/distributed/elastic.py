"""Elastic re-sharding: restore any checkpoint onto any mesh.

Checkpoints store plain host arrays; shardings are derived from the
ParamSpec logical axes against the *target* mesh at restore time, so the
same checkpoint restores onto 8, 256, or 512 devices (or a different
data/model split) as long as logical dimensions stay divisible (uneven dims
fall back to GSPMD padding exactly like at train time).

Node-failure recovery = restore onto the shrunken mesh + re-deal the failed
hosts' RSP blocks (``core.sampler.HostAssignment.redistribute``); Theorem 1
keeps the re-dealt block unions statistically valid.
"""

from __future__ import annotations

from typing import Any

import jax

from repro.checkpoint import store as ckpt
from repro.distributed.sharding import (
    ShardingRules,
    optimizer_shardings,
    param_shardings,
)
from repro.models import api
from repro.models.config import ModelConfig


def state_shardings(cfg: ModelConfig, rules: ShardingRules) -> dict:
    specs = api.model_specs(cfg)
    return {
        "params": param_shardings(specs, rules),
        "opt": optimizer_shardings(specs, rules),
    }


def reshard_state(state: Any, shardings: Any) -> Any:
    """device_put every leaf onto its target sharding (cross-mesh safe)."""
    return jax.tree.map(
        lambda leaf, sh: jax.device_put(leaf, sh),
        state,
        shardings,
        is_leaf=lambda x: not isinstance(x, dict),
    )


def restore_for_mesh(
    root: str,
    step: int,
    cfg: ModelConfig,
    rules: ShardingRules,
    *,
    like: Any,
) -> tuple[Any, dict]:
    """Elastic restore: checkpoint (any origin mesh) -> target-mesh state."""
    sh = state_shardings(cfg, rules)
    # step is a replicated scalar
    sh_full = {"params": sh["params"], "opt": sh["opt"]}
    return ckpt.restore(root, step, like, shardings=sh_full)
