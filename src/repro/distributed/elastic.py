"""Elastic membership: restore checkpoints onto any mesh, re-deal RSP
blocks on host churn.

Checkpoints store plain host arrays; shardings are derived from the
ParamSpec logical axes against the *target* mesh at restore time, so the
same checkpoint restores onto 8, 256, or 512 devices (or a different
data/model split) as long as logical dimensions stay divisible (uneven dims
fall back to GSPMD padding exactly like at train time).

Node-failure recovery = restore onto the shrunken mesh + re-deal the failed
hosts' RSP blocks (:func:`redeal_departed`); a joining host triggers
:func:`rebalance_join`.  Both are statistically free by Theorem 1: any
union of RSP blocks in corpus proportion is again an RSP block, so moving
*where* a block is computed never changes *what* the estimates see.  The
resulting deal round-trips through the store's ``ownership.json`` sidecar
(:func:`~repro.distributed.ownership.save_ownership`), so a restarted mesh
re-opens exactly the deal it left.

Model-state helpers import jax / the model stack lazily, so the RSP-side
churn helpers stay importable in lightweight (query-only) processes.
"""

from __future__ import annotations

from typing import Any, Sequence

from repro.distributed.ownership import (
    BlockOwnership,
    load_ownership,
    save_ownership,
)


# ---------------------------------------------------------------------------
# RSP block churn (Theorem-1-valid re-deals)
# ---------------------------------------------------------------------------

def redeal_departed(
    ownership: BlockOwnership, departed: Sequence[int], *, store=None
) -> BlockOwnership:
    """Deal departed hosts' blocks round-robin onto the survivors.

    Deterministic given the same departed set (every survivor derives the
    identical map without communicating); persisted to ``store`` when one
    is given so a restarted mesh resumes the post-churn deal."""
    new = ownership.redeal(departed)
    if store is not None:
        save_ownership(store, new)
    return new


def rebalance_join(
    ownership: BlockOwnership, num_hosts: int, *, store=None
) -> BlockOwnership:
    """Fresh balanced deal over ``num_hosts`` (a joining host gets its
    proportional share of blocks; Theorem 1 makes the re-deal free)."""
    new = ownership.rebalance(num_hosts)
    if store is not None:
        save_ownership(store, new)
    return new


def open_or_deal(store, num_blocks: int, num_hosts: int, *, seed: int = 0) -> BlockOwnership:
    """The store's persisted deal when one matches, else a fresh deal
    (persisted).  A stored deal with a different block count or host set is
    replaced -- the store is the source of truth only while it matches the
    mesh it serves."""
    stored = load_ownership(store)
    if (
        stored is not None
        and stored.num_blocks == num_blocks
        and stored.num_hosts == num_hosts
    ):
        return stored
    fresh = BlockOwnership.deal(num_blocks, num_hosts, seed=seed)
    save_ownership(store, fresh)
    return fresh


# ---------------------------------------------------------------------------
# Model-state elasticity (lazy: jax + model stack)
# ---------------------------------------------------------------------------

def state_shardings(cfg, rules) -> dict:
    from repro.distributed.sharding import optimizer_shardings, param_shardings
    from repro.models import api

    specs = api.model_specs(cfg)
    return {
        "params": param_shardings(specs, rules),
        "opt": optimizer_shardings(specs, rules),
    }


def reshard_state(state: Any, shardings: Any) -> Any:
    """device_put every leaf onto its target sharding (cross-mesh safe)."""
    import jax

    return jax.tree.map(
        lambda leaf, sh: jax.device_put(leaf, sh),
        state,
        shardings,
        is_leaf=lambda x: not isinstance(x, dict),
    )


def restore_for_mesh(
    root: str,
    step: int,
    cfg,
    rules,
    *,
    like: Any,
) -> tuple[Any, dict]:
    """Elastic restore: checkpoint (any origin mesh) -> target-mesh state."""
    from repro.checkpoint import store as ckpt

    sh = state_shardings(cfg, rules)
    # step is a replicated scalar
    sh_full = {"params": sh["params"], "opt": sh["opt"]}
    return ckpt.restore(root, step, like, shardings=sh_full)
