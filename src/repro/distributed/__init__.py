# NOTE: keep this init free of modules that import repro.models.api /
# repro.configs (e.g. `elastic`) -- model modules import
# repro.distributed.sharding, and a heavyweight package init here would
# close an import cycle.  Import repro.distributed.elastic directly, and
# the RSP-query layer (DistributedDataset) resolves lazily via __getattr__.
from repro.distributed.sharding import (
    ShardingRules,
    activation_sharding,
    batch_shardings,
    block_ownership,
    constrain,
    default_rules,
    optimizer_shardings,
    param_shardings,
    zero_shard_spec,
)
from repro.distributed.compression import (
    compressed_psum,
    compression_ratio,
    dequantize_int8,
    error_feedback_compress,
    init_residual,
    quantize_int8,
    quantize_roundtrip,
)
from repro.distributed.mesh import (
    CoordinatorTransport,
    HostKilledError,
    LocalTransport,
    Transport,
    TransportError,
    init_from_env,
    run_local_hosts,
)
from repro.distributed.ownership import (
    BlockOwnership,
    load_ownership,
    save_ownership,
)
from repro.distributed.straggler import LeaseScheduler, simulate

__all__ = [k for k in dir() if not k.startswith("_")] + [
    "DistributedDataset",
    "DistributedQueryExecutor",
]

_LAZY = ("DistributedDataset", "DistributedQueryExecutor")


def __getattr__(name: str):
    # lazy: repro.distributed.rsp pulls in the full repro.rsp query stack,
    # which model code importing this package must not pay for
    if name in _LAZY:
        from repro.distributed import rsp

        return getattr(rsp, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
