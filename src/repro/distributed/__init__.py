# NOTE: keep this init free of modules that import repro.models.api /
# repro.configs (e.g. `elastic`) -- model modules import
# repro.distributed.sharding, and a heavyweight package init here would
# close an import cycle.  Import repro.distributed.elastic directly.
from repro.distributed.sharding import (
    ShardingRules,
    activation_sharding,
    batch_shardings,
    constrain,
    default_rules,
    optimizer_shardings,
    param_shardings,
    zero_shard_spec,
)
from repro.distributed.compression import (
    compressed_psum,
    compression_ratio,
    dequantize_int8,
    error_feedback_compress,
    init_residual,
    quantize_int8,
    quantize_roundtrip,
)
from repro.distributed.straggler import LeaseScheduler, simulate

__all__ = [k for k in dir() if not k.startswith("_")]
