"""``repro.distributed.mesh`` -- the byte-level coordination plane for
multi-host RSP.

Distributed queries need exactly one communication primitive: *publish a
small byte payload under a key, and let every host poll for keys it is
waiting on*.  XLA's CPU backend cannot run cross-process computations (so
``psum``-style collectives are unavailable on an emulated CPU mesh), but the
``jax.distributed`` coordination service ships a perfectly good distributed
key-value store -- this module wraps it behind a tiny :class:`Transport`
protocol so the query layer never touches jax internals, and provides an
in-process :class:`LocalTransport` (threads + a shared dict) that emulates
an N-host mesh inside one test process, including fault injection.

Two implementations:

* :class:`CoordinatorTransport` -- rides the ``jax.distributed`` coordination
  service KV store (``key_value_set`` / ``blocking_key_value_get`` /
  ``key_value_dir_get``, payloads base64-coded: the ``*_bytes`` variants
  segfault on present-key reads in some jaxlib builds, while the string
  variants are the ones jax itself exercises).  Real multi-process meshes;
  see :func:`init_from_env` for the ``RSP_COORDINATOR`` bootstrap used by
  the test harness.
* :class:`LocalTransport` -- ``LocalTransport.group(n)`` returns n transports
  over one shared in-memory store.  ``kill_after_puts(k)`` arms deterministic
  fault injection: the k-th subsequent publish raises
  :class:`HostKilledError`, emulating a host dying mid-query (the straggler /
  elastic tests and the fan-out benchmark run on this).
"""

from __future__ import annotations

import base64
import os
import threading
import time
from typing import Callable, Protocol, runtime_checkable


class TransportError(RuntimeError):
    """A transport operation failed (connection lost, duplicate key, ...)."""


class HostKilledError(TransportError):
    """Raised by a :class:`LocalTransport` whose host was fault-injected dead."""


@runtime_checkable
class Transport(Protocol):
    """Minimal mesh coordination surface: identity + a shared KV store."""

    @property
    def host_id(self) -> int: ...

    @property
    def num_hosts(self) -> int: ...

    def put(self, key: str, value: bytes) -> None: ...

    def get(self, key: str, timeout: float = 0.0) -> bytes | None: ...

    def poll(self, prefix: str) -> dict[str, bytes]: ...


# ---------------------------------------------------------------------------
# In-process emulation
# ---------------------------------------------------------------------------

class _LocalStore:
    """Shared dict + condition variable behind a LocalTransport group."""

    def __init__(self):
        self._kv: dict[str, bytes] = {}
        self._cond = threading.Condition()

    def put(self, key: str, value: bytes) -> None:
        with self._cond:
            self._kv[key] = bytes(value)
            self._cond.notify_all()

    def get(self, key: str, timeout: float) -> bytes | None:
        deadline = time.monotonic() + timeout
        with self._cond:
            while True:
                v = self._kv.get(key)
                if v is not None:
                    return v
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return None
                self._cond.wait(remaining)

    def poll(self, prefix: str) -> dict[str, bytes]:
        with self._cond:
            return {k: v for k, v in self._kv.items() if k.startswith(prefix)}


class LocalTransport:
    """One emulated host of an in-process mesh (see ``group``).

    All hosts share one :class:`_LocalStore`; each host runs on its own
    thread (``run_local_hosts``).  Fault injection: ``kill_after_puts(k)``
    makes the k-th subsequent ``put`` (and every transport call after it)
    raise :class:`HostKilledError` -- from the peers' point of view the host
    simply stops publishing, exactly like a crashed process.
    """

    def __init__(self, store: _LocalStore, host_id: int, num_hosts: int):
        self._store = store
        self._host_id = int(host_id)
        self._num_hosts = int(num_hosts)
        self._kill_after: int | None = None
        self._puts = 0
        self._dead = False

    @classmethod
    def group(cls, num_hosts: int) -> list["LocalTransport"]:
        """``num_hosts`` transports over one shared in-memory store."""
        if num_hosts < 1:
            raise ValueError("num_hosts must be >= 1")
        store = _LocalStore()
        return [cls(store, h, num_hosts) for h in range(num_hosts)]

    @property
    def host_id(self) -> int:
        return self._host_id

    @property
    def num_hosts(self) -> int:
        return self._num_hosts

    def kill_after_puts(self, k: int) -> None:
        """Arm fault injection: die on the k-th subsequent publish."""
        self._kill_after = int(k)

    def _check_alive(self) -> None:
        if self._dead:
            raise HostKilledError(f"host {self._host_id} was killed")

    def put(self, key: str, value: bytes) -> None:
        self._check_alive()
        if self._kill_after is not None and self._puts >= self._kill_after:
            self._dead = True
            raise HostKilledError(
                f"host {self._host_id} killed after {self._puts} publishes"
            )
        self._puts += 1
        self._store.put(key, value)

    def get(self, key: str, timeout: float = 0.0) -> bytes | None:
        self._check_alive()
        return self._store.get(key, timeout)

    def poll(self, prefix: str) -> dict[str, bytes]:
        self._check_alive()
        return self._store.poll(prefix)


def run_local_hosts(
    transports: list[LocalTransport], fn: Callable[[LocalTransport], object]
) -> list[object]:
    """Run ``fn(transport)`` for every host on its own thread.

    Returns one result per host, ``None`` for hosts that died via fault
    injection (:class:`HostKilledError`).  Any *other* exception from a host
    is re-raised in the caller after all threads join -- a broken host must
    fail the test, not vanish into a thread.
    """
    results: list[object] = [None] * len(transports)
    errors: list[BaseException] = []

    def run(i: int, t: LocalTransport) -> None:
        try:
            results[i] = fn(t)
        except HostKilledError:
            pass  # injected death: the host's silence is the point
        except BaseException as e:  # noqa: BLE001 -- surface to the caller
            errors.append(e)

    threads = [
        threading.Thread(target=run, args=(i, t), name=f"rsp-host-{i}")
        for i, t in enumerate(transports)
    ]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    if errors:
        raise errors[0]
    return results


# ---------------------------------------------------------------------------
# Real multi-process meshes (jax.distributed coordination service)
# ---------------------------------------------------------------------------

class CoordinatorTransport:
    """KV transport over the ``jax.distributed`` coordination service.

    Requires ``jax.distributed.initialize`` to have run (see
    :func:`init_from_env`).  Cross-process XLA *computations* are not
    available on the CPU backend, but the coordination client's KV store is
    fully functional -- which is all the distributed query protocol needs.
    """

    def __init__(self, client=None, *, host_id: int | None = None,
                 num_hosts: int | None = None):
        if client is None:
            from jax._src import distributed as jax_distributed

            client = jax_distributed.global_state.client
            if client is None:
                raise TransportError(
                    "jax.distributed is not initialized -- call"
                    " repro.distributed.mesh.init_from_env() or"
                    " jax.distributed.initialize() first"
                )
            if host_id is None:
                host_id = jax_distributed.global_state.process_id
            if num_hosts is None:
                num_hosts = jax_distributed.global_state.num_processes
        self._client = client
        self._host_id = int(host_id if host_id is not None else 0)
        self._num_hosts = int(num_hosts if num_hosts is not None else 1)

    @property
    def host_id(self) -> int:
        return self._host_id

    @property
    def num_hosts(self) -> int:
        return self._num_hosts

    def put(self, key: str, value: bytes) -> None:
        encoded = base64.b64encode(bytes(value)).decode("ascii")
        try:
            self._client.key_value_set(key, encoded)
        except Exception as e:
            # payloads are deterministic, so a duplicate publish (two hosts
            # stealing the same straggler position) carries identical bytes:
            # if the key exists, the store already holds our value
            if self._client_get(key, 0.05) is not None:
                return
            raise TransportError(f"key_value_set({key!r}) failed: {e}") from e

    def _client_get(self, key: str, timeout: float) -> bytes | None:
        try:
            encoded = self._client.blocking_key_value_get(
                key, max(int(timeout * 1000), 1)
            )
        except Exception:  # NotFound / DeadlineExceeded surface as RuntimeError
            return None
        return base64.b64decode(encoded)

    def get(self, key: str, timeout: float = 0.0) -> bytes | None:
        return self._client_get(key, timeout)

    def poll(self, prefix: str) -> dict[str, bytes]:
        try:
            items = self._client.key_value_dir_get(prefix)
        except Exception:
            return {}
        pairs = items.items() if isinstance(items, dict) else items
        return {str(k): base64.b64decode(v) for k, v in pairs}

    def barrier(self, name: str, timeout: float = 60.0) -> None:
        self._client.wait_at_barrier(name, max(int(timeout * 1000), 1))


def init_from_env(env=None) -> CoordinatorTransport | None:
    """Bootstrap a real ``jax.distributed`` mesh from harness env vars.

    Reads ``RSP_COORDINATOR`` (``host:port``), ``RSP_NUM_PROCESSES``, and
    ``RSP_PROCESS_ID`` -- the variables ``tests/distributed_harness.py``
    exports into every spawned process.  Returns ``None`` when
    ``RSP_COORDINATOR`` is unset (single-host run), else initializes
    ``jax.distributed`` and returns the :class:`CoordinatorTransport`.
    """
    env = os.environ if env is None else env
    addr = env.get("RSP_COORDINATOR")
    if not addr:
        return None
    import jax

    jax.distributed.initialize(
        coordinator_address=addr,
        num_processes=int(env["RSP_NUM_PROCESSES"]),
        process_id=int(env["RSP_PROCESS_ID"]),
    )
    return CoordinatorTransport()
