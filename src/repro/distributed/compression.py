"""Gradient compression for the cross-pod (DCN) reduction.

int8 block quantization with error feedback: each leaf is quantized per
block of 256 values against its block max; the quantization residual is
carried in an error-feedback buffer and added back before the next round --
the standard trick that keeps compressed SGD/Adam convergence intact.

``compressed_psum`` performs quantize -> psum(int32) -> dequantize inside a
``shard_map`` over the 'pod' axis; the wire format is 1 byte/value + 1 fp32
scale per block (~4x less DCN traffic than fp32, ~2x less than bf16).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x: jax.Array) -> tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), pad


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array, int]:
    """Returns (q [nb, BLOCK] int8, scales [nb] f32, pad)."""
    blocks, pad = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    return q, scale, pad


def dequantize_int8(q: jax.Array, scale: jax.Array, pad: int, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def quantize_roundtrip(x: jax.Array) -> jax.Array:
    q, s, pad = quantize_int8(x)
    return dequantize_int8(q, s, pad, x.shape)


def compressed_psum(x: jax.Array, axis: str) -> jax.Array:
    """Quantized all-reduce (mean) over one mesh axis (inside shard_map).

    Participants first agree on a per-block scale (pmax over a tiny fp32
    scale vector -- negligible traffic), then quantize against the *shared*
    scale so the int8 payloads are summable."""
    blocks, pad = _pad_to_block(x.astype(jnp.float32))
    local_max = jnp.max(jnp.abs(blocks), axis=1)
    scale = jax.lax.pmax(local_max, axis) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    flat = (qsum.astype(jnp.float32) * safe[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(x.shape) / n


def error_feedback_compress(grads: Any, residual: Any) -> tuple[Any, Any]:
    """(compressed grads, new residual): g' = Q(g + r); r' = (g + r) - g'."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        gq = quantize_roundtrip(g32)
        return gq, g32 - gq

    pairs = jax.tree.map(one, grads, residual)
    comp = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return comp, resid


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio(x_dtype=jnp.float32) -> float:
    """Wire bytes ratio vs uncompressed (per BLOCK values)."""
    raw = BLOCK * jnp.dtype(x_dtype).itemsize
    wire = BLOCK * 1 + 4
    return wire / raw
