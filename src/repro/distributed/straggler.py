"""Straggler-aware RSP block scheduling (lease-based work stealing).

Because every RSP block is statistically exchangeable with every other
(Definition 3), the scheduler may re-assign blocks freely: a straggling host
loses its unstarted leases to faster hosts with zero statistical penalty --
the final set of processed blocks is still a uniform block-level sample.
The paper (Sec. 7) anticipates exactly this: "this sampling process can be
refined to select blocks depending on the availability of nodes".

``simulate`` is a deterministic event simulation used by tests and the Fig-7
style benchmark; ``LeaseScheduler`` is the runtime object a real launcher
would drive.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence


@dataclasses.dataclass
class LeaseScheduler:
    """Blocks are leased in small windows; hosts request more when done."""

    block_ids: list[int]
    lease_window: int = 2

    def __post_init__(self):
        self._queue = list(self.block_ids)[::-1]  # pop from end
        self._leases: dict[int, list[int]] = {}
        self._done: set[int] = set()

    def request(self, host: int) -> list[int]:
        grant = []
        while self._queue and len(grant) < self.lease_window:
            grant.append(self._queue.pop())
        self._leases.setdefault(host, []).extend(grant)
        return grant

    def complete(self, host: int, block_id: int) -> None:
        self._leases[host].remove(block_id)
        self._done.add(block_id)

    def steal_from(self, slow_host: int) -> list[int]:
        """Return a slow host's *unstarted* leases to the queue."""
        stolen = self._leases.get(slow_host, [])
        self._leases[slow_host] = []
        self._queue.extend(stolen[::-1])
        return stolen

    @property
    def all_done(self) -> bool:
        return len(self._done) == len(self.block_ids) and not self._queue

    @property
    def done_blocks(self) -> set[int]:
        return set(self._done)


def simulate(
    num_blocks: int,
    host_speeds: Sequence[float],
    *,
    lease_window: int = 2,
    steal: bool = True,
    steal_threshold: float = 2.0,
) -> dict:
    """Event simulation: returns {makespan, per_host_blocks, stolen}.

    ``host_speeds[h]`` = blocks/time-unit.  With ``steal=False`` this is the
    static round-robin deal (the paper's naive batch assignment).
    """
    H = len(host_speeds)
    sched = LeaseScheduler(list(range(num_blocks)), lease_window=lease_window)
    per_host: dict[int, list[int]] = {h: [] for h in range(H)}
    stolen_total = 0

    if not steal:
        # static deal: host h gets blocks h, h+H, ... processes sequentially
        makespan = 0.0
        for h in range(H):
            mine = list(range(h, num_blocks, H))
            per_host[h] = mine
            makespan = max(makespan, len(mine) / host_speeds[h])
        return {"makespan": makespan, "per_host_blocks": per_host, "stolen": 0}

    # dynamic leases: (finish_time, host, block)
    now = 0.0
    events: list[tuple[float, int, int]] = []
    active: dict[int, int] = {}

    def start_next(h: int, t: float) -> None:
        mine = sched._leases.get(h, [])
        running = active.get(h)
        for b in mine:
            if b != running and b not in sched._done:
                active[h] = b
                heapq.heappush(events, (t + 1.0 / host_speeds[h], h, b))
                return
        grant = sched.request(h)
        if grant:
            active[h] = grant[0]
            heapq.heappush(events, (t + 1.0 / host_speeds[h], h, grant[0]))

    for h in range(H):
        sched.request(h)
        start_next(h, 0.0)

    mean_speed = sum(host_speeds) / H
    while events:
        now, h, b = heapq.heappop(events)
        if b in sched._done:
            continue
        sched.complete(h, b)
        per_host[h].append(b)
        # steal unstarted leases from hosts much slower than the mean
        if sched._queue == [] and steal:
            for s in range(H):
                if s != h and host_speeds[s] < mean_speed / steal_threshold:
                    pending = [x for x in sched._leases.get(s, []) if x != active.get(s)]
                    for blk in pending:
                        sched._leases[s].remove(blk)
                        sched._queue.append(blk)
                        stolen_total += 1
        start_next(h, now)

    return {"makespan": now, "per_host_blocks": per_host, "stolen": stolen_total}
