"""Straggler-aware RSP block scheduling (lease-based work stealing).

Because every RSP block is statistically exchangeable with every other
(Definition 3), the scheduler may re-assign blocks freely: a straggling host
loses its unstarted leases to faster hosts with zero statistical penalty --
the final set of processed blocks is still a uniform block-level sample.
The paper (Sec. 7) anticipates exactly this: "this sampling process can be
refined to select blocks depending on the availability of nodes".

``simulate`` is a deterministic event simulation used by tests and the Fig-7
style benchmark; ``LeaseScheduler`` is the runtime object a real launcher
would drive.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence


@dataclasses.dataclass
class LeaseScheduler:
    """Blocks are leased in small windows; hosts request more when done."""

    block_ids: list[int]
    lease_window: int = 2

    def __post_init__(self):
        self._queue = list(self.block_ids)[::-1]  # pop from end
        self._leases: dict[int, list[int]] = {}
        self._done: set[int] = set()

    @classmethod
    def from_assignment(
        cls, assignment: dict[int, list[int]], *, lease_window: int = 2
    ) -> "LeaseScheduler":
        """Seed the ledger from a block-ownership deal: every block starts
        leased to its owner and the queue starts empty.  This is the shape a
        distributed query uses -- blocks flow back into the queue only when
        ``fail_host`` declares an owner dead, and ``redeal`` re-grants them
        deterministically to the survivors."""
        sched = cls(
            [b for h in sorted(assignment) for b in assignment[h]],
            lease_window=lease_window,
        )
        sched._queue = []
        sched._leases = {int(h): list(blocks) for h, blocks in assignment.items()}
        return sched

    def request(self, host: int) -> list[int]:
        grant = []
        while self._queue and len(grant) < self.lease_window:
            grant.append(self._queue.pop())
        self._leases.setdefault(host, []).extend(grant)
        return grant

    def complete(self, host: int, block_id: int) -> None:
        """Mark a block done.  Tolerant of completion by a non-leaseholder
        (a steal race produced a duplicate, identical result): the block is
        recorded done either way and removed from wherever it is leased."""
        leases = self._leases.setdefault(host, [])
        if block_id in leases:
            leases.remove(block_id)
        self._done.add(block_id)

    def steal_from(self, slow_host: int) -> list[int]:
        """Return a slow host's *unstarted* leases to the queue."""
        stolen = [b for b in self._leases.get(slow_host, []) if b not in self._done]
        self._leases[slow_host] = []
        self._queue.extend(stolen[::-1])
        return stolen

    def fail_host(self, host: int) -> list[int]:
        """Declare a host dead: all its unfinished leases go back to the
        queue (identical mechanics to stealing -- a dead host is just a
        straggler that never recovers)."""
        return self.steal_from(host)

    def redeal(self, survivors: Sequence[int]) -> dict[int, list[int]]:
        """Drain the queue round-robin onto the sorted survivors.

        Deterministic: any host computing this from the same failure set
        derives the identical grant map, so distributed peers never need to
        negotiate who takes which orphaned block (and duplicate grants from
        skewed failure *timing* are harmless -- payloads are deterministic).
        """
        survivors = sorted(set(int(h) for h in survivors))
        if not survivors:
            raise ValueError("redeal needs at least one survivor")
        queued = self._queue[::-1]  # FIFO view
        self._queue = []
        grants: dict[int, list[int]] = {h: [] for h in survivors}
        for i, b in enumerate(queued):
            h = survivors[i % len(survivors)]
            grants[h].append(b)
            self._leases.setdefault(h, []).append(b)
        return grants

    @property
    def all_done(self) -> bool:
        return len(self._done) == len(self.block_ids) and not self._queue

    @property
    def done_blocks(self) -> set[int]:
        return set(self._done)


def simulate(
    num_blocks: int,
    host_speeds: Sequence[float],
    *,
    lease_window: int = 2,
    steal: bool = True,
    steal_threshold: float = 2.0,
    fail_at: dict[int, float] | None = None,
) -> dict:
    """Event simulation: returns {makespan, per_host_blocks, stolen,
    completed, dead_hosts}.

    ``host_speeds[h]`` = blocks/time-unit.  With ``steal=False`` this is the
    static round-robin deal (the paper's naive batch assignment).
    ``fail_at[h] = t`` kills host h at time t: its in-flight block never
    finishes, its unfinished leases flow back to the queue, and idle
    survivors wake to drain them -- as long as one host survives, every
    block still completes exactly once.
    """
    H = len(host_speeds)
    fail_at = {int(h): float(t) for h, t in (fail_at or {}).items()}
    sched = LeaseScheduler(list(range(num_blocks)), lease_window=lease_window)
    per_host: dict[int, list[int]] = {h: [] for h in range(H)}
    stolen_total = 0

    if not steal and not fail_at:
        # static deal: host h gets blocks h, h+H, ... processes sequentially
        makespan = 0.0
        for h in range(H):
            mine = list(range(h, num_blocks, H))
            per_host[h] = mine
            makespan = max(makespan, len(mine) / host_speeds[h])
        return {
            "makespan": makespan,
            "per_host_blocks": per_host,
            "stolen": 0,
            "completed": num_blocks,
            "dead_hosts": [],
        }

    # dynamic leases: (time, kind, host, block) with kind 0=fail, 1=finish
    now = 0.0
    events: list[tuple[float, int, int, int]] = []
    active: dict[int, int] = {}
    dead: set[int] = set()

    def start_next(h: int, t: float) -> None:
        if h in dead:
            return
        mine = sched._leases.get(h, [])
        running = active.get(h)
        for b in mine:
            if b != running and b not in sched._done:
                active[h] = b
                heapq.heappush(events, (t + 1.0 / host_speeds[h], 1, h, b))
                return
        grant = sched.request(h)
        if grant:
            active[h] = grant[0]
            heapq.heappush(events, (t + 1.0 / host_speeds[h], 1, h, grant[0]))
        else:
            active.pop(h, None)

    for h, t_fail in fail_at.items():
        heapq.heappush(events, (t_fail, 0, h, -1))
    for h in range(H):
        sched.request(h)
        start_next(h, 0.0)

    mean_speed = sum(host_speeds) / H
    while events:
        now, kind, h, b = heapq.heappop(events)
        if h in dead:
            continue
        if kind == 0:
            dead.add(h)
            active.pop(h, None)
            sched.fail_host(h)  # unfinished leases (incl. in-flight) requeue
            for s in range(H):
                if s not in dead and s not in active:
                    start_next(s, now)
            continue
        if b in sched._done:
            continue
        sched.complete(h, b)
        per_host[h].append(b)
        # steal unstarted leases from live hosts much slower than the mean
        if sched._queue == [] and steal:
            for s in range(H):
                if s != h and s not in dead and host_speeds[s] < mean_speed / steal_threshold:
                    pending = [x for x in sched._leases.get(s, []) if x != active.get(s)]
                    for blk in pending:
                        sched._leases[s].remove(blk)
                        sched._queue.append(blk)
                        stolen_total += 1
        start_next(h, now)

    return {
        "makespan": now,
        "per_host_blocks": per_host,
        "stolen": stolen_total,
        "completed": len(sched._done),
        "dead_hosts": sorted(dead),
    }
