"""Learning-rate schedules (scalar-in, scalar-out; jit-safe)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup_steps: int, total_steps: int, min_ratio: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
    frac = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = min_ratio + (1.0 - min_ratio) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return warm * cos


def linear_decay(step, *, warmup_steps: int, total_steps: int, min_ratio: float = 0.0):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
    frac = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    return warm * (1.0 - (1.0 - min_ratio) * frac)


def constant(step, **_):
    return jnp.ones((), jnp.float32)


SCHEDULES = {"cosine": warmup_cosine, "linear": linear_decay, "constant": constant}
