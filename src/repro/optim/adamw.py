"""AdamW with fp32 master weights and bf16 compute params.

State layout (a pytree mirroring params):
    master -- fp32 copy of params (the source of truth)
    m, v   -- fp32 first/second moments
``adamw_update`` consumes *bf16-computed* grads, applies global-norm
clipping, and returns (new_state, new_compute_params).  All state leaves
carry logical-axis metadata via the same spec tree as the params, with an
extra ZeRO sharding dimension chosen by ``distributed.sharding``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: PyTree) -> dict:
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"master": master, "m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: PyTree) -> Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(
    state: dict,
    grads: PyTree,
    cfg: AdamWConfig,
    *,
    lr_scale: Array | float = 1.0,
    compute_dtype=jnp.bfloat16,
) -> tuple[dict, PyTree, dict]:
    """Returns (new_state, new_compute_params, stats)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t
    lr = cfg.lr * lr_scale

    def upd(master, m, v, g):
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        master = master - lr * (update + cfg.weight_decay * master)
        return master, m, v

    flat_master, treedef = jax.tree.flatten(state["master"])
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_g = jax.tree.leaves(grads)
    out = [upd(a, b, c, d) for a, b, c, d in zip(flat_master, flat_m, flat_v, flat_g)]
    new_master = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(lambda p: p.astype(compute_dtype), new_master)
    new_state = {"master": new_master, "m": new_m, "v": new_v, "step": step}
    return new_state, new_params, {"grad_norm": gnorm, "lr": lr}
