from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.schedule import SCHEDULES, constant, linear_decay, warmup_cosine

__all__ = [k for k in dir() if not k.startswith("_")]
