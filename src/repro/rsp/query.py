"""``repro.rsp.query`` -- progressive approximate queries over RSP blocks.

The paper's central claim is that analysis of a big data set becomes
analysis of a few RSP blocks.  This module makes that loop explicit: a
:class:`Query` *declares* what is wanted -- aggregates (``mean`` / ``var`` /
``sum`` / ``count`` / ``quantile`` / ``histogram`` / ``distinct``,
optionally grouped by
label) plus a stopping rule (``target_rel_err``, ``confidence``,
``max_blocks``) -- and :class:`QueryExecutor` decides how many blocks to
read:

* **Sketch fast path** -- a query that needs only moments or label counts is
  answered from the partition-time sketches alone: *zero* block reads, and
  the answer is the exact corpus statistic (the sketches combine exactly).
  When the manifest carries the v2 sketch suite, ungrouped unfiltered
  ``quantile`` and ``distinct`` aggregates also answer sketch-only: KLL
  sketches give any quantile within an additive rank-error bound, KMV
  sketches give distinct counts within a known relative error -- both with
  honest (non-zero) intervals derived from those bounds.
* **Progressive path** -- otherwise blocks stream one at a time through the
  dataset's prefetching :class:`~repro.rsp.engine.BlockExecutor` under a
  :class:`~repro.core.sampler.SamplingPolicy`.  Each block is folded through
  the fused one-pass sketch kernel (``repro.kernels.block_sketch``) into
  combinable per-aggregate state -- Chan moments for ``mean``/``var``/
  ``sum``/``count``, mergeable fixed-grid histograms for ``quantile``/
  ``histogram`` -- and after every block an *anytime* :class:`QueryResult`
  is emitted with confidence intervals.  The stream stops early once every
  interval is relatively tighter than ``target_rel_err``.

Confidence intervals follow the consistency framework of block-level
estimates (Karmakar & Mukhopadhyay, 2018): each RSP block is a random sample
of the corpus, so per-block estimates are i.i.d. and a CLT *across blocks*
applies -- Student-t intervals over the ``b`` per-block estimates, with a
finite-population correction under uniform without-replacement sampling.
Quantile intervals bootstrap over the per-block histograms (resample blocks
with replacement, re-merge, re-invert the CDF).  Under the ``weighted`` PPS
policy the per-draw estimates are Hansen-Hurwitz expansions (``t_k / p_k``),
which are i.i.d. by construction; ``stratified`` single-block draws are
marginally uniform-with-replacement and are treated as such (approximate).

Entry points: ``RSPDataset.query(...)`` (final result) and
``RSPDataset.query_stream(...)`` (one :class:`QueryResult` per block read).
"""

from __future__ import annotations

import dataclasses
import math
import re
import time
from typing import Iterator, Sequence

import numpy as np

from repro import obs
from repro.core.estimators import quantile_from_histogram
from repro.core.sampler import SamplingPolicy, UniformPolicy, WeightedPolicy
from repro.kernels.block_sketch import BlockSketch, block_sketch
from repro.kernels.plan import Predicate, QueryPlan, as_predicates, plan_sketch
from repro.obs.convergence import ConvergenceStep, ConvergenceTrace
from repro.rsp.engine import CallerStats, ExecutorStats

KINDS = ("mean", "var", "sum", "count", "quantile", "histogram", "distinct")
_SKETCH_ONLY_KINDS = ("mean", "var", "sum", "count")
_EPS = 1e-12


def derive_seed(*components: int) -> int:
    """Collapse integer identifiers (e.g. ``(service seed, query id)``) into
    one seed whose RNG stream is independent of every other combination.

    Concurrent serving needs this: two queries sharing one literal seed would
    share bootstrap/selection streams, and deriving seeds from *submission
    order* would make results depend on scheduling.  Deriving from stable ids
    keeps every query reproducible regardless of interleaving.
    """
    return int(np.random.SeedSequence(list(components)).generate_state(1)[0])


# ---------------------------------------------------------------------------
# Normal / Student-t quantiles (no scipy dependency)
# ---------------------------------------------------------------------------

def norm_ppf(p: float) -> float:
    """Inverse standard-normal CDF (Acklam's rational approximation,
    |err| < 1.2e-8 over (0, 1))."""
    if not 0.0 < p < 1.0:
        raise ValueError(f"p must be in (0, 1), got {p}")
    a = (-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
         1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
         6.680131188771972e+01, -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
         -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
         3.754408661907416e+00)
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        q = math.sqrt(-2 * math.log(p))
        num = ((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]
        return num / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    if p > phigh:
        return -norm_ppf(1 - p)
    q = p - 0.5
    r = q * q
    num = (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * q
    return num / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)


def t_ppf(p: float, df: int) -> float:
    """Inverse Student-t CDF: exact for df 1-2, Cornish-Fisher expansion in
    1/df above (plenty for CI construction; ~1% off at df=3, <0.1% by df=8)."""
    if df <= 0:
        raise ValueError("df must be positive")
    if df == 1:
        return math.tan(math.pi * (p - 0.5))
    if df == 2:
        u = 2 * p - 1
        return u * math.sqrt(2.0 / max(1 - u * u, _EPS))
    z = norm_ppf(p)
    v = float(df)
    return (
        z
        + (z**3 + z) / (4 * v)
        + (5 * z**5 + 16 * z**3 + 3 * z) / (96 * v**2)
        + (3 * z**7 + 19 * z**5 + 17 * z**3 - 15 * z) / (384 * v**3)
    )


# ---------------------------------------------------------------------------
# Query declaration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Aggregate:
    """One requested aggregate.

    ``feature=None`` returns all (flattened) features; an int selects one
    column.  ``by_label=True`` computes the aggregate per class (needs
    ``num_classes`` on the dataset); the result gains a leading class axis.
    ``quantile`` needs ``q`` in (0, 1).
    """

    kind: str
    q: float | None = None
    feature: int | None = None
    by_label: bool = False
    name: str | None = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown aggregate kind {self.kind!r} (one of {KINDS})")
        if self.kind == "quantile":
            if self.q is None or not 0.0 < self.q < 1.0:
                raise ValueError("quantile aggregates need q in (0, 1)")
        elif self.q is not None:
            raise ValueError(f"q= only applies to quantile aggregates, not {self.kind!r}")
        if self.kind == "distinct" and self.by_label:
            raise ValueError("distinct aggregates do not support by_label")

    @property
    def label(self) -> str:
        if self.name:
            return self.name
        s = self.kind if self.q is None else f"p{self.q * 100:g}"
        if self.feature is not None:
            s += f"[{self.feature}]"
        if self.by_label:
            s += "/label"
        return s


_PCT = re.compile(r"^p(\d{1,2}(?:\.\d+)?)$")


def parse_aggregate(spec) -> Aggregate:
    """``"mean" | "var" | "sum" | "count" | "histogram" | "distinct" |
    "median" | "p95" | "p99.9"`` -> :class:`Aggregate` (instances pass
    through)."""
    if isinstance(spec, Aggregate):
        return spec
    if not isinstance(spec, str):
        raise TypeError(f"cannot parse aggregate from {type(spec).__name__}")
    s = spec.strip().lower()
    if s in KINDS and s != "quantile":
        return Aggregate(s)
    if s == "median":
        return Aggregate("quantile", q=0.5)
    m = _PCT.match(s)
    if m:
        return Aggregate("quantile", q=float(m.group(1)) / 100.0)
    raise ValueError(
        f"cannot parse aggregate {spec!r} (mean | var | sum | count | histogram"
        f" | distinct | median | pNN, or an Aggregate instance)"
    )


@dataclasses.dataclass
class Query:
    """A declarative aggregate query plus its stopping rule.

    The stream stops at the first of: every aggregate's relative CI
    half-width <= ``target_rel_err`` (after ``min_blocks``); ``max_blocks``
    blocks read (default: one epoch, i.e. all ``K``).  ``histogram`` and
    progressive ``distinct`` aggregates carry no CI and never drive
    stopping.  ``use_sketches``: ``"auto"`` answers from the partition-time
    sketches when they suffice -- moment/label-count queries exactly, and
    (given v2 suites) ungrouped unfiltered ``quantile``/``distinct``
    within the KLL/KMV error bounds; ``True`` forces the sketch path
    (error if the query needs block data), ``False`` always streams
    blocks.

    ``where=`` restricts every aggregate to the rows passing the
    conjunctive column predicates (``"c3 > 0.5"`` strings, ``(col, op,
    value)`` tuples, :class:`~repro.kernels.plan.Predicate` instances, or a
    sequence of them).  ``columns=`` projects the answer onto those feature
    columns (``feature=`` on an aggregate then indexes the *projected*
    axis).  Either one routes execution through the plan-compiled fused
    kernels (``repro.kernels.plan``): predicates, projection, moments and
    histograms all happen in one pass per block, and a filtered query
    reports its observed :attr:`QueryResult.selectivity`.  Queries with
    ``where=`` cannot use the sketch-only fast path (partition-time
    sketches are unfiltered), so ``use_sketches=True`` raises.

    ``policy="query_aware"`` scores blocks with the query's own shape --
    predicate selectivity from the KLL sketches, dispersion of the
    aggregated feature, class coverage for grouped aggregates -- so the
    progressive scan reads the blocks that matter for *this* query first
    (Horvitz-Thompson reweighting keeps the estimates unbiased).

    ``seed`` drives block selection and the bootstrap; ``None`` (the
    default) means "no seed pinned": direct execution falls back to 0, and
    a :class:`~repro.serve.QueryService` replaces it with
    :func:`derive_seed`\\ ``(service seed, query id)`` so every submitted
    query gets an independent, schedule-invariant RNG stream.
    """

    aggregates: tuple[Aggregate, ...]
    target_rel_err: float | None = None
    confidence: float = 0.95
    max_blocks: int | None = None
    min_blocks: int = 3
    policy: str | SamplingPolicy = "uniform"
    seed: int | None = None
    bins: int = 128
    bootstrap: int = 200
    use_sketches: bool | str = "auto"
    sketch_impl: str = "auto"
    where: tuple[Predicate, ...] = ()
    columns: tuple[int, ...] | None = None
    #: record a convergence step after *every* block (not only when the
    #: stopping rule forces result materialization), so ``result.trace``
    #: reproduces the paper's error-vs-blocks trajectory at full resolution
    explain: bool = False

    def __post_init__(self):
        self.where = as_predicates(self.where)
        if self.columns is not None:
            self.columns = tuple(int(c) for c in self.columns)
            if not self.columns:
                raise ValueError("columns= must name at least one column")
        if not self.aggregates:
            raise ValueError("query needs at least one aggregate")
        if not 0.0 < self.confidence < 1.0:
            raise ValueError("confidence must be in (0, 1)")
        if self.target_rel_err is not None and self.target_rel_err <= 0:
            raise ValueError("target_rel_err must be positive")
        if self.min_blocks < 2:
            raise ValueError("min_blocks must be >= 2 (CIs need two block estimates)")
        if self.bins < 1:
            raise ValueError("bins must be >= 1")
        if self.bootstrap < 1:
            raise ValueError("bootstrap must be >= 1")


def as_query(spec, **kwargs) -> Query:
    """Build a :class:`Query` from a ``Query`` (kwargs must be empty), one
    aggregate spec, or a sequence of aggregate specs."""
    if isinstance(spec, Query):
        if kwargs:
            raise ValueError("pass stopping-rule kwargs inside the Query instance")
        return spec
    if isinstance(spec, (str, Aggregate)):
        spec = [spec]
    return Query(aggregates=tuple(parse_aggregate(a) for a in spec), **kwargs)


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AggregateResult:
    """Anytime estimate of one aggregate.  ``estimate`` / ``ci_lo`` /
    ``ci_hi`` are scalars, ``[F]``, ``[C]`` or ``[C, F]`` arrays (class axis
    first for ``by_label``); entries are NaN until observable (e.g. a class
    not yet seen).  ``rel_err`` is the worst relative CI half-width (None
    for ``histogram``, inf while fewer than two block estimates exist)."""

    name: str
    kind: str
    estimate: np.ndarray | float
    ci_lo: np.ndarray | float | None
    ci_hi: np.ndarray | float | None
    rel_err: float | None


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """One anytime answer: the per-aggregate estimates after ``blocks_read``
    of ``total_blocks`` blocks, plus how the answer was produced
    (``from_sketches``; ``executor_stats`` meters the query's own cache
    hits / misses / fetches so "answered from N of K blocks" is honest).
    ``selectivity`` is the HT-weighted fraction of scanned rows passing the
    query's ``where=`` predicates (``None`` for unfiltered queries) -- the
    quantity that keeps filtered expansions honest.  ``trace`` is the
    query's :class:`~repro.obs.convergence.ConvergenceTrace` -- one step per
    progressive emission (every block with ``explain=True``); all anytime
    results of one query share the same growing trace object."""

    aggregates: tuple[AggregateResult, ...]
    blocks_read: int
    total_blocks: int
    confidence: float
    target_rel_err: float | None
    converged: bool
    from_sketches: bool
    executor_stats: ExecutorStats | None = None
    selectivity: float | None = None
    trace: ConvergenceTrace | None = None

    def __getitem__(self, name: str) -> AggregateResult:
        for a in self.aggregates:
            if a.name == name:
                return a
        raise KeyError(f"no aggregate {name!r} in {[a.name for a in self.aggregates]}")

    @property
    def max_rel_err(self) -> float:
        errs = [a.rel_err for a in self.aggregates if a.rel_err is not None]
        return max(errs) if errs else math.inf

    def __str__(self) -> str:
        how = "sketches" if self.from_sketches else f"{self.blocks_read} blocks"
        parts = ", ".join(
            f"{a.name}={np.asarray(a.estimate).ravel()[0]:.4g}"
            + (f"±{(np.asarray(a.ci_hi) - np.asarray(a.ci_lo)).ravel()[0] / 2:.2g}"
               if a.ci_lo is not None else "")
            for a in self.aggregates
        )
        return (
            f"QueryResult({parts}; from {how} of {self.total_blocks},"
            f" rel_err={self.max_rel_err:.3g}, converged={self.converged})"
        )


# ---------------------------------------------------------------------------
# Per-aggregate streaming state
# ---------------------------------------------------------------------------

class _Ctx:
    """Shared per-query constants handed to every aggregate state."""

    def __init__(
        self, *, K, N, confidence, uniform, num_classes, bootstrap, seed,
        filtered=False,
    ):
        self.K = K                      # total blocks
        self.N = N                      # total records
        self.confidence = confidence
        self.uniform = uniform          # uniform w/o replacement -> exact fold + FPC
        self.num_classes = num_classes
        self.bootstrap = bootstrap
        self.seed = seed
        self.filtered = filtered        # where= predicates: subpopulation size unknown

    def t_half(self, b: int) -> float:
        return t_ppf(0.5 + self.confidence / 2.0, b - 1)

    def fpc(self, b: int) -> float:
        if not self.uniform or self.K <= 1:
            return 1.0
        return math.sqrt(max(self.K - b, 0) / (self.K - 1))


def _sel(arr: np.ndarray, feature: int | None) -> np.ndarray:
    return arr if feature is None else arr[..., feature]


class _MomentAgg:
    """mean / var / sum / count.

    Under the uniform policy the point estimate is the exact Chan fold over
    the blocks read, with Student-t CLT intervals across per-block
    estimates.  Under non-uniform policies every draw contributes
    Hansen-Hurwitz expansions of the corpus totals ``(count, sum, sum x^2)``
    -- ``w_k * t_k`` with ``w_k = 1/p_k`` (or ``K`` for the marginally
    uniform stratified single-draw stream) -- and the point estimates are
    the HT/Hajek forms built from them (mirroring
    ``combine_summaries(weights=...)``), so selection bias divides back out
    for mean, var, and sum alike.  Grouped variants keep one fold and one
    sample list per class; grouped means use the Hajek ratio (class counts
    are unknown), with approximate intervals over per-block class means."""

    def __init__(self, agg: Aggregate, ctx: _Ctx):
        self.agg = agg
        self.ctx = ctx
        self.groups = ctx.num_classes if agg.by_label else 1
        self.acc: list[BlockSketch | None] = [None] * self.groups
        self.samples: list[list[np.ndarray]] = [[] for _ in range(self.groups)]
        # per-draw HH expansions (count_hat, sum_hat, sumsq_hat), non-uniform
        self.ht: list[list[tuple]] = [[] for _ in range(self.groups)]

    def update(self, sketches: Sequence[BlockSketch], weight: float | None) -> None:
        from repro.kernels.block_sketch import merge_sketches

        for g, sk in enumerate(sketches):
            kind = self.agg.kind
            if sk.count > 0:
                self.acc[g] = sk if self.acc[g] is None else merge_sketches(self.acc[g], sk)
            scale = weight if weight is not None else float(self.ctx.K)
            if not self.ctx.uniform:
                self.ht[g].append(
                    (
                        scale * sk.count,
                        scale * sk.sum,
                        scale * (sk.m2 + sk.count * sk.mean**2),
                    )
                )
            if kind == "mean":
                if sk.count > 0:
                    if (
                        weight is not None
                        and not self.agg.by_label
                        and not self.ctx.filtered
                    ):
                        # Hansen-Hurwitz: per-draw corpus-sum expansion over N
                        e = weight * sk.sum / max(self.ctx.N, 1)
                    else:
                        # per-block (sub)population mean; filtered queries
                        # cannot expand over N (subpopulation size unknown)
                        e = sk.mean
                    self.samples[g].append(np.asarray(e, dtype=np.float64))
            elif kind == "var":
                if self.ctx.uniform and sk.count > 1:
                    self.samples[g].append(np.asarray(sk.variance, dtype=np.float64))
            elif kind == "sum":
                self.samples[g].append(np.asarray(scale * sk.sum, dtype=np.float64))
            elif kind == "count":
                self.samples[g].append(np.asarray(scale * sk.count, dtype=np.float64))

    def _ht_totals(self, g: int):
        """Averaged HH expansions -> (count_hat, sum_hat, sumsq_hat)."""
        counts, sums, sumsqs = zip(*self.ht[g])
        return (
            float(np.mean(counts)),
            np.mean(sums, axis=0),
            np.mean(sumsqs, axis=0),
        )

    def _ht_var(self, g: int) -> tuple[np.ndarray, list[np.ndarray]] | None:
        """(point, per-draw plug-in samples) for var under non-uniform
        selection: ``(E_hat[sum x^2] - n * mu^2) / (n - 1)`` with the known
        corpus ``N`` (ungrouped) or the HT class count (grouped)."""
        if not self.ht[g]:
            return None
        c_hat, sum_hat, ss_hat = self._ht_totals(g)
        # filtered subpopulations have unknown size: use the HT count
        use_N = not self.agg.by_label and not self.ctx.filtered
        n = float(self.ctx.N) if use_N else c_hat
        if n <= 1:
            return None
        mu = sum_hat / n
        denom = n - 1.0
        point = np.maximum(ss_hat - n * mu**2, 0.0) / denom
        draws = [
            np.maximum(ss_i - n * mu**2, 0.0) / denom for (_, _, ss_i) in self.ht[g]
        ]
        return point, draws

    def _point(self, g: int) -> np.ndarray | None:
        acc, kind, ctx = self.acc[g], self.agg.kind, self.ctx
        samples = self.samples[g]
        if kind in ("sum", "count"):
            if not samples:
                return None
            return np.mean(samples, axis=0)
        if acc is None:
            return None
        if kind == "mean":
            if not ctx.uniform:
                if self.agg.by_label or ctx.filtered:
                    # Hajek ratio: HT (sub)population sum over HT count --
                    # selection bias divides out without knowing the size
                    c_hat, sum_hat, _ = self._ht_totals(g)
                    return sum_hat / max(c_hat, _EPS) if c_hat > 0 else None
                return np.mean(samples, axis=0)
            return acc.mean
        if not ctx.uniform:  # var under PPS: HT-expanded, not the raw fold
            ht = self._ht_var(g)
            return None if ht is None else ht[0]
        return acc.variance  # var, uniform: exact fold over blocks read

    def _ci_samples(self, g: int) -> list[np.ndarray]:
        if self.agg.kind == "var" and not self.ctx.uniform:
            ht = self._ht_var(g)
            return [] if ht is None else ht[1]
        return self.samples[g]

    def result(self) -> AggregateResult:
        ests, los, his, rels = [], [], [], []
        for g in range(self.groups):
            pt = self._point(g)
            samples = self._ci_samples(g)
            b = len(samples)
            if pt is None:
                ests.append(None)
                los.append(None)
                his.append(None)
                continue
            sl = self.agg.feature if self.agg.kind != "count" else None
            pt = _sel(np.asarray(pt, dtype=np.float64), sl)
            if b >= 2:
                arr = np.stack(samples)
                se = _sel(arr, sl).std(axis=0, ddof=1) / math.sqrt(b)
                half = self.ctx.t_half(b) * self.ctx.fpc(b) * se
            else:
                half = np.full(np.shape(pt), np.inf)
            ests.append(pt)
            los.append(pt - half)
            his.append(pt + half)
            rels.append(float(np.max(half / np.maximum(np.abs(pt), _EPS))))
        est, lo, hi = (_stack_groups(v, self.agg.by_label) for v in (ests, los, his))
        rel = max(rels) if rels and len(rels) == self.groups else math.inf
        return AggregateResult(self.agg.label, self.agg.kind, est, lo, hi, rel)


class _HistAgg:
    """quantile / histogram: mergeable fixed-grid histograms per block, with
    bootstrap-over-block-histograms intervals for quantiles."""

    def __init__(self, agg: Aggregate, ctx: _Ctx, lo: np.ndarray, hi: np.ndarray):
        self.agg = agg
        self.ctx = ctx
        self.lo = lo
        self.hi = hi
        self.groups = ctx.num_classes if agg.by_label else 1
        self.hists: list[list[np.ndarray]] = [[] for _ in range(self.groups)]
        self.weights: list[float] = []

    def update(self, sketches: Sequence[BlockSketch], weight: float | None) -> None:
        for g, sk in enumerate(sketches):
            self.hists[g].append(sk.hist.astype(np.float64))
        self.weights.append(weight if weight is not None else float(self.ctx.K))

    def _weighted(self, g: int) -> np.ndarray:
        """Per-block histograms HT-expanded by their draw weights [b, F, bins]
        (uniform policy: constant K, so quantiles are unaffected)."""
        w = np.asarray(self.weights)[:, None, None]
        return w * np.stack(self.hists[g])

    def _merged(self, g: int) -> np.ndarray:
        """HT estimate of the corpus histogram (counts scaled to N)."""
        return self._weighted(g).sum(axis=0) / len(self.weights)

    def _quantile(self, merged: np.ndarray) -> np.ndarray:
        q = quantile_from_histogram(merged, [self.agg.q], lo=self.lo, hi=self.hi)[:, 0]
        return _sel(q, self.agg.feature)

    def result(self) -> AggregateResult:
        if self.agg.kind == "histogram":
            f = self.agg.feature
            ests = [
                m if f is None else m[f]
                for m in (self._merged(g) for g in range(self.groups))
            ]
            est = _stack_groups(ests, self.agg.by_label)
            return AggregateResult(self.agg.label, "histogram", est, None, None, None)
        ests, los, his, rels = [], [], [], []
        alpha = 1.0 - self.ctx.confidence
        for g in range(self.groups):
            b = len(self.hists[g])
            merged = self._merged(g)
            if merged.sum() <= 0:
                ests.append(None)
                los.append(None)
                his.append(None)
                continue
            pt = self._quantile(merged)
            if b >= 2:
                rng = np.random.default_rng(
                    np.random.SeedSequence([self.ctx.seed, 0xB0075, g, b])
                )
                stacked = self._weighted(g)              # [b, F, bins] HT-scaled
                idx = rng.integers(0, b, size=(self.ctx.bootstrap, b))
                boots = stacked[idx].sum(axis=1)         # [B, F, bins]
                B, F, nbins = boots.shape
                qs = quantile_from_histogram(
                    boots.reshape(B * F, nbins),
                    [self.agg.q],
                    lo=np.tile(self.lo, B),
                    hi=np.tile(self.hi, B),
                )[:, 0].reshape(B, F)
                qs = _sel(qs, self.agg.feature)
                lo = np.quantile(qs, alpha / 2, axis=0)
                hi = np.quantile(qs, 1 - alpha / 2, axis=0)
            else:
                lo = np.full(np.shape(pt), -np.inf)
                hi = np.full(np.shape(pt), np.inf)
            half = (np.asarray(hi) - np.asarray(lo)) / 2.0
            ests.append(pt)
            los.append(lo)
            his.append(hi)
            rels.append(float(np.max(half / np.maximum(np.abs(pt), _EPS))))
        est, lo, hi = (_stack_groups(v, self.agg.by_label) for v in (ests, los, his))
        rel = max(rels) if rels and len(rels) == self.groups else math.inf
        return AggregateResult(self.agg.label, "quantile", est, lo, hi, rel)


class _DistinctAgg:
    """distinct: one KMV sketch per (projected) feature, fed the filtered
    rows of every read block.  A distinct count over a *sample* of blocks is
    a lower bound on the corpus count -- unseen blocks may hold unseen
    values -- so the running estimate carries no CI and never drives early
    stopping; after a full scan it is the KMV estimate of the true count."""

    def __init__(self, agg: Aggregate, ctx: _Ctx):
        from repro.rsp.sketch import DistinctSketch

        self.agg = agg
        self.ctx = ctx
        self.sketch = DistinctSketch()

    def update(self, sketches: Sequence[BlockSketch], weight: float | None) -> None:
        pass  # fed per-block KMV sketches via merge_block, not moments

    def merge_block(self, block_sketch) -> None:
        """Fold one block's KMV sketch.  k-min-of-union == union-of-k-mins,
        so merging per-block sketches is *exactly* equal to feeding the raw
        rows -- which is what lets distributed hosts ship sketches instead
        of rows."""
        if block_sketch is not None:
            self.sketch = self.sketch.merge(block_sketch)

    def result(self) -> AggregateResult:
        try:
            vals = self.sketch.estimate()
        except ValueError:  # no rows survived the predicates yet
            return AggregateResult(self.agg.label, "distinct", math.nan, None, None, None)
        est = _sel(np.asarray(vals, dtype=np.float64), self.agg.feature)
        est = float(est) if np.ndim(est) == 0 else np.asarray(est)
        return AggregateResult(self.agg.label, "distinct", est, None, None, None)


def _stack_groups(values: list, by_label: bool):
    """Stack per-class results into a leading class axis (NaN for classes
    not yet observed); scalar-ize ungrouped single-element results."""
    shaped = [np.asarray(v, dtype=np.float64) for v in values if v is not None]
    if not shaped:
        return math.nan if not by_label else np.full(len(values), np.nan)
    proto = np.full(shaped[0].shape, np.nan)
    filled = [np.asarray(v, np.float64) if v is not None else proto for v in values]
    if not by_label:
        out = filled[0]
        return float(out.reshape(-1)[0]) if out.shape in ((), (1,)) else out
    return np.stack(filled)


def _scalar0(value) -> float:
    """First element of an estimate, for compact convergence-trace rows."""
    arr = np.asarray(value, dtype=np.float64).ravel()
    return float(arr[0]) if arr.size else math.nan


def _half_width(r: AggregateResult) -> float:
    """Worst CI half-width of one aggregate (NaN when it carries no CI)."""
    if r.ci_lo is None or r.ci_hi is None:
        return math.nan
    half = (
        np.asarray(r.ci_hi, dtype=np.float64) - np.asarray(r.ci_lo, dtype=np.float64)
    ) / 2.0
    half = np.atleast_1d(half)
    return float(np.nanmax(half)) if np.any(~np.isnan(half)) else math.nan


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------

class QueryExecutor:
    """Runs one :class:`Query` against an ``RSPDataset``-like object (needs
    ``spec``, ``num_blocks``, ``executor``, ``policy()``, ``summaries`` /
    ``has_summaries``, and ``num_classes`` / ``label_column`` for grouped
    aggregates)."""

    def __init__(self, dataset, query: Query):
        self.ds = dataset
        self.q = query
        self.seed = 0 if query.seed is None else int(query.seed)
        # every access this query makes is attributed here (as well as to the
        # executor's global counters) -- snapshot deltas of the shared
        # executor would claim other queries' I/O the moment two interleave
        self.counter = CallerStats()
        self._t0 = time.perf_counter()
        # root span for this query; its context is handed explicitly to the
        # engine workers (and by QueryService to its scheduler/sweeper) so
        # cross-thread spans parent under it.  None when telemetry is off.
        self.span = (
            obs.get_tracer().start_span(
                "query",
                attrs={"aggs": ",".join(a.label for a in query.aggregates)},
            )
            if obs.enabled()
            else None
        )
        if any(a.by_label for a in query.aggregates) and dataset.num_classes is None:
            raise ValueError("by_label aggregates need num_classes on the dataset")
        # where= / columns= route block passes through the plan-compiled
        # fused kernels instead of the legacy whole-block sketch
        self.planned = bool(query.where) or query.columns is not None

    @property
    def ctx(self):
        """Trace context of this query's root span (None when telemetry is
        off) -- pass as ``parent=`` / ``trace=`` across threads."""
        return self.span.ctx if self.span is not None else None

    def end_span(self) -> None:
        """Idempotently close the root span.  Called when the stream
        finishes or is closed; QueryService also calls it at retire time so
        never-started generators don't leak open spans."""
        if self.span is not None:
            self.span.end()

    def _plan(self, *, grouped: bool) -> QueryPlan:
        if grouped:
            return QueryPlan(
                predicates=self.q.where,
                columns=self.q.columns,
                group_by=self.ds.label_column,
                num_classes=self.ds.num_classes,
            )
        return QueryPlan(predicates=self.q.where, columns=self.q.columns)

    # -- sketch fast path --------------------------------------------------
    def _suites_have(self, kind: str) -> bool:
        """Whether the dataset's sketch suites carry a ``kind`` member.  A
        sketch-less dataset reports True: forcing the fast path computes
        fresh suites, which carry the full default kind set."""
        if not self.ds.has_summaries:
            return True
        summaries = self.ds.summaries
        if not summaries:
            return False
        s = summaries[0]
        return callable(getattr(s, "get", None)) and s.get(kind) is not None

    def _sketch_eligible(self) -> bool:
        if self.q.where:
            # partition-time sketches are unfiltered; a predicate needs rows
            return False
        for a in self.q.aggregates:
            if a.kind in _SKETCH_ONLY_KINDS:
                if a.by_label and a.kind != "count":
                    return False
            elif a.kind == "quantile":
                # KLL answers any ungrouped quantile within its rank bound
                if a.by_label or not self._suites_have("kll"):
                    return False
            elif a.kind == "distinct":
                if not self._suites_have("distinct"):
                    return False
            else:  # histogram needs the query's own grid/bins -> block data
                return False
        return True

    def _merged_sketch(self, summaries, kind: str):
        """Corpus-level sketch of one kind: union of the per-block sketches
        (fresh object -- the stored suites are never mutated)."""
        from repro.rsp.sketch import sketch_from_dict

        acc = None
        for s in summaries:
            sk = s.get(kind) if callable(getattr(s, "get", None)) else None
            if sk is None:
                raise ValueError(
                    f"sketch-only answers need {kind!r} sketches in the"
                    " manifest (re-partition the store, or pass"
                    " use_sketches=False)"
                )
            if acc is None:
                acc = sketch_from_dict(sk.to_dict())
            else:
                acc.merge(sk)
        return acc

    def _answer_from_sketches(self) -> QueryResult:
        from repro.rsp.summaries import combine_summaries

        # forcing this path on a sketch-less dataset computes the sketches
        # (a full-corpus pass through the executor) -- meter it honestly
        summaries = self._materialized_summaries()
        stats = combine_summaries(summaries)
        cols = None
        if self.q.columns is not None:
            f = np.asarray(stats.mean).shape[-1]
            cols = [c % f for c in self.q.columns]

        def proj(arr):
            # columns= projection: sketches cover all features, so a
            # projected query just selects before feature indexing
            return arr if cols is None else np.asarray(arr)[..., cols]

        def shape(v):
            v = np.asarray(v, dtype=np.float64)
            return float(v) if v.ndim == 0 else v

        merged_cache: dict = {}

        def merged(kind):
            if kind not in merged_cache:
                merged_cache[kind] = self._merged_sketch(summaries, kind)
            return merged_cache[kind]

        out = []
        for a in self.q.aggregates:
            lo_v = hi_v = None
            rel = 0.0
            if a.kind == "count" and a.by_label:
                hists = [s.label_hist for s in summaries]
                if any(h is None for h in hists):
                    raise ValueError("grouped count needs label histograms in the sketches")
                est = np.sum(hists, axis=0).astype(np.float64)
            elif a.kind == "count":
                est = float(stats.count)
            elif a.kind == "mean":
                est = _sel(proj(stats.mean), a.feature)
            elif a.kind == "var":
                est = _sel(proj(stats.variance), a.feature)
            elif a.kind == "sum":
                est = _sel(proj(stats.count * stats.mean), a.feature)
            elif a.kind == "quantile":
                # KLL: point at rank q, interval at ranks q -+ eps -- the
                # sketch's additive rank-error bound, mapped through the
                # value axis (an honest, data-dependent interval)
                kll = merged("kll")
                eps = kll.rank_error_bound()
                vals = kll.quantile(
                    [max(a.q - eps, 0.0), a.q, min(a.q + eps, 1.0)]
                )  # [F, 3]
                lo_v = shape(_sel(proj(vals[:, 0]), a.feature))
                est = _sel(proj(vals[:, 1]), a.feature)
                hi_v = shape(_sel(proj(vals[:, 2]), a.feature))
                half = (np.asarray(hi_v) - np.asarray(lo_v)) / 2.0
                rel = float(
                    np.max(half / np.maximum(np.abs(np.asarray(est)), _EPS))
                )
            else:  # distinct: KMV estimate with its known relative SE
                kmv = merged("distinct")
                rel = float(kmv.relative_error_bound())
                est = _sel(proj(kmv.estimate()), a.feature)
                lo_v = shape(np.asarray(est) * (1.0 - rel))
                hi_v = shape(np.asarray(est) * (1.0 + rel))
            est = shape(est)
            if lo_v is None:
                # all K sketches combined == the exact corpus statistic
                lo_v = hi_v = est
            out.append(AggregateResult(a.label, a.kind, est, lo_v, hi_v, rel))
        rels = [r.rel_err for r in out if r.rel_err is not None]
        max_rel = max(rels) if rels else 0.0
        trace = ConvergenceTrace(
            confidence=self.q.confidence, target_rel_err=self.q.target_rel_err
        )
        trace.record(
            ConvergenceStep(
                blocks_read=0,
                block_id=None,
                max_rel_err=max_rel,
                estimates={r.name: _scalar0(r.estimate) for r in out},
                half_widths={r.name: _half_width(r) for r in out},
                cum_fetch_s=self.counter.fetch_seconds(),
                elapsed_s=time.perf_counter() - self._t0,
            )
        )
        return QueryResult(
            aggregates=tuple(out),
            blocks_read=0,
            total_blocks=self.ds.num_blocks,
            confidence=self.q.confidence,
            target_rel_err=self.q.target_rel_err,
            converged=(
                self.q.target_rel_err is None or max_rel <= self.q.target_rel_err
            ),
            from_sketches=True,
            executor_stats=self.counter.stats(),
            trace=trace,
        )

    def _materialized_summaries(self):
        """``ds.summaries``, with a lazy full-corpus sketch pass attributed
        to this query's counter (it is this query's I/O)."""
        if not self.ds.has_summaries:
            self.ds._summaries = self.ds._compute_summaries(counter=self.counter)
        return self.ds.summaries

    # -- progressive path --------------------------------------------------
    def _grid(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-feature histogram grid for the progressive path: the
        partition-time sketches' global extrema, tightened by the merged KLL
        sketch when the query is a pure unfiltered, ungrouped quantile --
        the fixed bin budget then resolves the rank range the query asks
        about instead of stretching over heavy tails (mass outside still
        clips into the edge bins, so merged counts stay consistent).
        Projected onto the query's ``columns=`` when set (filtered data
        always lies inside the unfiltered extrema)."""
        summaries = self._materialized_summaries()
        lo = np.min([s.min for s in summaries], axis=0).astype(np.float64)
        hi = np.max([s.max for s in summaries], axis=0).astype(np.float64)
        tight = self._kll_grid(summaries, lo, hi)
        if tight is not None:
            lo, hi = tight
        pad = np.maximum(1e-9, 1e-9 * (hi - lo))
        lo, hi = lo - pad, hi + pad
        if self.q.columns is not None:
            cols = [c % lo.shape[0] for c in self.q.columns]
            lo, hi = lo[cols], hi[cols]
        return lo, hi

    def _kll_grid(self, summaries, lo, hi):
        """KLL-seeded ``(lo, hi)``, or None to keep the extrema grid.  Only
        safe when every grid consumer is an ungrouped, unfiltered quantile:
        filtered or per-class distributions can concentrate in a corpus
        tail the tightened grid would clip to one bin."""
        aggs = self.q.aggregates
        qs = [a.q for a in aggs if a.kind == "quantile" and not a.by_label]
        if (
            not qs
            or self.q.where
            or any(a.kind == "histogram" for a in aggs)
            or any(a.kind == "quantile" and a.by_label for a in aggs)
        ):
            return None
        try:
            kll = self._merged_sketch(summaries, "kll")
        except ValueError:  # v1 suites: no KLL -> extrema grid
            return None
        eps = kll.rank_error_bound()
        vals = kll.quantile(
            [max(min(qs) - 2.0 * eps, 0.0), min(max(qs) + 2.0 * eps, 1.0)]
        )  # [F, 2]
        margin = 0.05 * (vals[:, 1] - vals[:, 0])
        tlo = np.maximum(vals[:, 0] - margin, lo)
        thi = np.minimum(vals[:, 1] + margin, hi)
        # constant / degenerate features keep their extrema span
        bad = ~np.isfinite(tlo) | ~np.isfinite(thi) | ~(thi > tlo)
        return np.where(bad, lo, tlo), np.where(bad, hi, thi)

    def _make_states(self, needs_hist: bool):
        ctx = _Ctx(
            K=self.ds.num_blocks,
            N=self.ds.spec.num_records,
            confidence=self.q.confidence,
            uniform=isinstance(self._pol, UniformPolicy),
            num_classes=self.ds.num_classes,
            bootstrap=self.q.bootstrap,
            seed=self.seed,
            filtered=bool(self.q.where),
        )
        lo = hi = None
        if needs_hist:
            lo, hi = self._grid()
        states = []
        for a in self.q.aggregates:
            if a.kind in ("quantile", "histogram"):
                states.append(_HistAgg(a, ctx, lo, hi))
            elif a.kind == "distinct":
                states.append(_DistinctAgg(a, ctx))
            else:
                states.append(_MomentAgg(a, ctx))
        return states, lo, hi

    def _plan_sketches(self, block, lo, hi, needs_hist, grouped, need_whole) -> dict:
        """Plan-compiled path for ``where=`` / ``columns=`` queries: one
        fused filter+project+sketch pass per needed grouping, through the
        plan compile cache and the shared autotuner."""
        bins = self.q.bins if needs_hist else 0
        kw = dict(bins=bins) if not needs_hist else dict(bins=bins, lo=lo, hi=hi)
        whole = per_class = None
        res = None
        if need_whole:
            res = plan_sketch(
                block, self._plan(grouped=False), impl=self.q.sketch_impl, **kw
            )
            whole = res.sketches[0]
        if grouped:
            res_g = plan_sketch(
                block, self._plan(grouped=True), impl=self.q.sketch_impl, **kw
            )
            per_class = res_g.sketches
            res = res if res is not None else res_g
        return {
            "whole": whole,
            "per_class": per_class,
            "rows_total": res.rows_total,
            "rows_selected": res.rows_selected,
        }

    def _block_sketches(self, block, lo, hi, needs_hist, grouped, need_whole) -> dict:
        """One fused pass over the block; per-class sub-sketches on demand.
        ``need_whole=False`` (every aggregate grouped) skips the dead
        whole-block pass."""
        from repro.kernels.block_sketch import block_sketch_ref

        if self.planned:
            return self._plan_sketches(block, lo, hi, needs_hist, grouped, need_whole)
        bins = self.q.bins if needs_hist else 0
        kw = dict(bins=bins) if not needs_hist else dict(bins=bins, lo=lo, hi=hi)
        impl = self.q.sketch_impl
        if bins == 0 and impl == "pallas":
            impl = "jax"  # the kernel always histograms; moments-only goes jit
        whole = block_sketch(block, impl=impl, **kw) if need_whole else None
        per_class = None
        if grouped:
            x = np.asarray(block).reshape(np.shape(block)[0], -1)
            labels = x[:, self.ds.label_column % x.shape[1]].astype(np.int64)
            per_class = []
            for c in range(self.ds.num_classes):
                rows = x[labels == c]
                if rows.shape[0] == 0:
                    f = x.shape[1]
                    per_class.append(
                        BlockSketch(
                            count=0.0,
                            mean=np.zeros(f),
                            m2=np.zeros(f),
                            min=np.full(f, np.inf),
                            max=np.full(f, -np.inf),
                            hist=np.zeros((f, bins), np.int64) if needs_hist else None,
                        )
                    )
                else:
                    per_class.append(block_sketch_ref(rows, **kw))
        n = int(np.shape(block)[0])
        return {
            "whole": whole, "per_class": per_class,
            "rows_total": n, "rows_selected": n,
        }

    def _make_payload(
        self, block, lo, hi, needs_hist, needs_rows, grouped, need_whole
    ) -> dict:
        """Everything the fold needs from one block, as mergeable state.

        The payload is a pure function of ``(block bytes, query shape)`` --
        no draw-order or host-local state -- which is what makes distributed
        execution bit-identical to single-host: any host computing this
        block's payload produces the same dict, so *where* it is computed is
        irrelevant to the fold."""
        payload = self._block_sketches(block, lo, hi, needs_hist, grouped, need_whole)
        payload["distinct"] = (
            self._distinct_sketch(block) if needs_rows else None
        )
        return payload

    def _distinct_sketch(self, block):
        """Per-block KMV sketch of the filtered/projected rows (k-min of a
        union == union of k-mins, so folding these per-block sketches is
        exactly the single-pass sketch of all surviving rows)."""
        from repro.rsp.sketch import DistinctSketch

        q = self.q
        rows = np.asarray(block, dtype=np.float64)
        rows = rows.reshape(rows.shape[0], -1)
        if q.where:
            xf = rows.astype(np.float32)
            keep = np.ones(rows.shape[0], dtype=bool)
            for p in q.where:
                keep &= p.mask(xf)
            rows = rows[keep]
        if q.columns is not None:
            cols = [c % rows.shape[1] for c in q.columns]
            rows = rows[:, cols]
        sk = DistinctSketch()
        if rows.size:
            sk.update(rows)
        return sk

    def _payload_source(
        self, ids, lo, hi, *, needs_hist, needs_rows, grouped, need_whole
    ) -> Iterator[tuple[int, dict]]:
        """Yield ``(block_id, payload)`` in selection order.

        This is the single seam between *selecting and computing* blocks and
        *folding* them: the single-host source streams local blocks through
        the executor; ``DistributedQueryExecutor`` overrides only this method
        to gather peer-computed payloads, so both paths fold byte-identical
        payloads through identical code."""
        executor = self.ds.executor
        for bid, block in executor.map_blocks(
            None, ids, with_ids=True, counter=self.counter, trace=self.ctx
        ):
            yield bid, self._make_payload(
                block, lo, hi, needs_hist, needs_rows, grouped, need_whole
            )

    def stream(self) -> Iterator[QueryResult]:
        """One anytime :class:`QueryResult` per block read."""
        return self._stream(anytime=True)

    def _stream(self, *, anytime: bool) -> Iterator[QueryResult]:
        try:
            yield from self._stream_impl(anytime=anytime)
        finally:
            # covers run(), exhausted streams, and gen.close() on a started
            # generator; QueryService additionally closes never-started ones
            self.end_span()

    def _stream_impl(self, *, anytime: bool) -> Iterator[QueryResult]:
        q = self.q
        if q.use_sketches is True or (
            q.use_sketches == "auto" and self._sketch_eligible() and self.ds.has_summaries
        ):
            if not self._sketch_eligible():
                raise ValueError(
                    "use_sketches=True but the query needs block data"
                    " (where= predicates, histogram, grouped non-count"
                    " aggregates, or quantile/distinct without the matching"
                    " partition-time sketches)"
                )
            res = self._answer_from_sketches()
            # auto mode falls through to the progressive path when the
            # sketch error bound (KLL/KMV) cannot meet the requested target;
            # forcing use_sketches=True returns the bound-limited answer
            if q.use_sketches is True or res.converged:
                yield res
                return

        # sketch probabilities (weighted/stratified) and the histogram grid
        # both come from ds.summaries, which on a sketch-less dataset reads
        # every block -- those passes belong in this query's honest I/O count
        if isinstance(q.policy, str) and q.policy != "uniform":
            self._materialized_summaries()
        pol_kwargs = {}
        if q.policy == "query_aware":
            # hand the policy this query's shape: its predicates (KLL
            # selectivity), the aggregated feature (dispersion), and
            # whether it groups by label (class coverage)
            feature = None
            feats = {a.feature for a in q.aggregates if a.feature is not None}
            if len(feats) == 1:
                feature = next(iter(feats))
                if q.columns is not None:  # map back to corpus column ids
                    feature = q.columns[feature % len(q.columns)]
            pol_kwargs = dict(
                predicates=q.where,
                feature=feature,
                by_label=any(a.by_label for a in q.aggregates),
            )
        self._pol = self.ds.policy(q.policy, seed=self.seed, **pol_kwargs)
        uniform = isinstance(self._pol, UniformPolicy)
        K = self.ds.num_blocks
        max_blocks = q.max_blocks if q.max_blocks is not None else K
        if uniform:
            max_blocks = min(max_blocks, K)
        if max_blocks < 1:
            raise ValueError("max_blocks must be >= 1")
        needs_hist = any(a.kind in ("quantile", "histogram") for a in q.aggregates)
        needs_rows = any(a.kind == "distinct" for a in q.aggregates)
        grouped = any(a.by_label for a in q.aggregates)
        need_whole = any(not a.by_label for a in q.aggregates)
        states, lo, hi = self._make_states(needs_hist)

        def gen_ids():
            for _ in range(max_blocks):
                yield self._pol.sample(1)[0]

        b = 0
        filtered = bool(q.where)
        sel_rows = tot_rows = 0.0  # HT-weighted selectivity ratio estimator
        trace = ConvergenceTrace(confidence=q.confidence, target_rel_err=q.target_rel_err)
        source = self._payload_source(
            gen_ids(), lo, hi, needs_hist=needs_hist, needs_rows=needs_rows,
            grouped=grouped, need_whole=need_whole,
        )
        try:
            for bid, sk in source:
                weight = None
                if isinstance(self._pol, WeightedPolicy):
                    weight = float(self._pol.weights([bid])[0])
                if needs_rows:
                    for state in states:
                        if isinstance(state, _DistinctAgg):
                            state.merge_block(sk["distinct"])
                scale = weight if weight is not None else float(K)
                sel_rows += scale * sk["rows_selected"]
                tot_rows += scale * sk["rows_total"]
                for agg, state in zip(q.aggregates, states):
                    state.update(
                        sk["per_class"] if agg.by_label else [sk["whole"]], weight
                    )
                b += 1
                # materializing results is not free (quantile CIs bootstrap
                # over all b histograms); when nothing can stop the scan early
                # and the caller only wants the final answer, skip the
                # intermediate ones
                must_emit = (
                    anytime or q.explain or q.target_rel_err is not None
                    or b == max_blocks
                )
                if not must_emit:
                    continue
                results = tuple(s.result() for s in states)
                errs = [r.rel_err for r in results if r.rel_err is not None]
                converged = (
                    q.target_rel_err is not None
                    and b >= q.min_blocks
                    and bool(errs)
                    and max(errs) <= q.target_rel_err
                )
                trace.record(
                    ConvergenceStep(
                        blocks_read=b,
                        block_id=int(bid),
                        max_rel_err=max(errs) if errs else math.inf,
                        estimates={r.name: _scalar0(r.estimate) for r in results},
                        half_widths={r.name: _half_width(r) for r in results},
                        cum_fetch_s=self.counter.fetch_seconds(),
                        elapsed_s=time.perf_counter() - self._t0,
                    )
                )
                yield QueryResult(
                    aggregates=results,
                    blocks_read=b,
                    total_blocks=K,
                    confidence=q.confidence,
                    target_rel_err=q.target_rel_err,
                    converged=converged,
                    from_sketches=False,
                    executor_stats=self.counter.stats(),
                    selectivity=(
                        sel_rows / max(tot_rows, 1.0) if filtered else None
                    ),
                    trace=trace,
                )
                if converged:
                    return
        finally:
            # GeneratorExit / convergence must reach the source's own finally
            # (a distributed source publishes its stop marker there)
            source.close()

    def run(self) -> QueryResult:
        result = None
        for result in self._stream(anytime=False):
            pass
        assert result is not None  # max_blocks >= 1 guarantees one emission
        return result
