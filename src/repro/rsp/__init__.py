"""repro.rsp -- the unified RSP pipeline facade.

One import surface for the paper's whole workflow::

    from repro import rsp

    ds = rsp.partition(data, blocks=64, seed=1, backend="auto", num_classes=2)
    ds.save("/data/corpus.rsp")                  # stored RSP (manifest + blocks)
    ds = rsp.open("/data/corpus.rsp")            # lazy re-open
    ids = ds.sample(5, seed=7)                   # block-level sample (Def. 4)
    stats = ds.moments(g=5)                      # Sec. 8, from block sketches
    res = ds.query(["mean", "p95"], target_rel_err=0.01)   # anytime CIs,
    #   stops early; moment-only queries answer from sketches (0 reads)
    ens, hist = ds.ensemble(rsp.make_logreg(28, 2), eval_x=xe, eval_y=ye, g=5)
    mmd = ds.similarity(3, metric="mmd")         # Sec. 7 diagnostics

``partition`` dispatches through a backend registry (in-memory numpy, the
out-of-core ``np_stream`` scatter, jit jax, shard_map collective, Pallas
kernel) with capability predicates; ``backend="auto"`` selects shard_map
when a mesh is supplied, Pallas when the kernel's shape constraints hold on
a TPU host, ``np_stream`` for chunked sources (paths, chunk directories,
record-batch iterators, memmaps) and direct-to-store writes (``out=``),
and in-memory numpy otherwise.  ``rsp.from_source(src, blocks=K,
out=path)`` forces the streaming path: corpora that never fit in RAM
partition in one pass with O(chunk) peak memory (see ``repro.rsp.ingest``).

The free functions in ``repro.core`` (``two_stage_partition_*``,
``RSPStore``, ``BlockSampler``, ...) remain as the stable low-level layer
this facade is built on, but new code should start here.
"""

from repro.core.ensemble import (
    BaseLearner,
    Ensemble,
    EnsembleHistory,
    make_logreg,
    make_mlp,
)
from repro.core.estimators import BlockLevelEstimator, MomentStats, streaming_estimate
from repro.core.sampler import (
    POLICIES,
    BlockSampler,
    HostAssignment,
    QueryAwarePolicy,
    SamplingPolicy,
    StratifiedPolicy,
    UniformPolicy,
    WeightedPolicy,
    make_policy,
    sketch_dispersion,
)
from repro.core.types import RSPSpec
from repro.rsp.engine import (
    BlockExecutor,
    BlockFetcher,
    CallerStats,
    ExecutorStats,
    MemoryFetcher,
    MmapFetcher,
    StoreFetcher,
    as_fetcher,
)
from repro.kernels.plan import Predicate, QueryPlan
from repro.rsp.query import (
    Aggregate,
    AggregateResult,
    Query,
    QueryExecutor,
    QueryResult,
    as_query,
    parse_aggregate,
)
from repro.rsp.backends import (
    AUTO,
    PartitionBackend,
    PartitionRequest,
    available_backends,
    backend_eligibility,
    get_backend,
    register_backend,
    run_partition,
    select_backend,
)
from repro.rsp.dataset import RSPDataset
from repro.rsp.ingest import (
    ArrayChunkSource,
    ChunkSource,
    DirectoryChunkSource,
    IterChunkSource,
    NpyChunkSource,
    as_chunk_source,
    stream_partition,
)
from repro.rsp.sketch import (
    SKETCH_KINDS,
    SKETCH_SCHEMA_VERSION,
    DistinctSketch,
    HistogramSketch,
    KLLSketch,
    LabelsSketch,
    MomentsSketch,
    Sketch,
    SketchSuite,
    kll_rank_error_bound,
    load_summaries,
    merge_suites,
    register_sketch,
    sketch_from_dict,
)
from repro.rsp.summaries import (
    BlockSummary,
    combine_summaries,
    max_divergence_from_summaries,
    summarize_block,
    summarize_blocks,
)

partition = RSPDataset.partition
open = RSPDataset.open  # noqa: A001 -- facade verb, mirrors gzip.open
from_source = RSPDataset.from_source

__all__ = [
    "AUTO",
    "POLICIES",
    "Aggregate",
    "AggregateResult",
    "ArrayChunkSource",
    "BaseLearner",
    "BlockExecutor",
    "BlockFetcher",
    "BlockLevelEstimator",
    "BlockSampler",
    "BlockSummary",
    "CallerStats",
    "DistinctSketch",
    "HistogramSketch",
    "KLLSketch",
    "LabelsSketch",
    "MomentsSketch",
    "ChunkSource",
    "DirectoryChunkSource",
    "Ensemble",
    "EnsembleHistory",
    "ExecutorStats",
    "HostAssignment",
    "IterChunkSource",
    "MemoryFetcher",
    "MmapFetcher",
    "MomentStats",
    "NpyChunkSource",
    "PartitionBackend",
    "PartitionRequest",
    "Predicate",
    "Query",
    "QueryExecutor",
    "QueryPlan",
    "QueryAwarePolicy",
    "QueryResult",
    "RSPDataset",
    "RSPSpec",
    "SKETCH_KINDS",
    "SKETCH_SCHEMA_VERSION",
    "SamplingPolicy",
    "Sketch",
    "SketchSuite",
    "StoreFetcher",
    "StratifiedPolicy",
    "UniformPolicy",
    "WeightedPolicy",
    "as_chunk_source",
    "as_fetcher",
    "as_query",
    "available_backends",
    "backend_eligibility",
    "combine_summaries",
    "from_source",
    "get_backend",
    "kll_rank_error_bound",
    "load_summaries",
    "make_logreg",
    "make_mlp",
    "make_policy",
    "max_divergence_from_summaries",
    "merge_suites",
    "open",
    "parse_aggregate",
    "partition",
    "register_backend",
    "run_partition",
    "register_sketch",
    "select_backend",
    "sketch_dispersion",
    "sketch_from_dict",
    "stream_partition",
    "streaming_estimate",
    "summarize_block",
    "summarize_blocks",
]
