"""Unified mergeable-sketch subsystem for RSP blocks.

The per-block sketch is the load-bearing data structure of the whole stack:
a few RSP blocks stand in for the corpus, and everything the query / sampling
layers know about unread blocks comes from their sketches.  This module is
the single home for those sketches:

* a :class:`Sketch` protocol -- ``update(rows)``, ``merge(other)``, versioned
  ``to_dict`` / ``from_dict`` -- with a registry of implementations,
* :class:`MomentsSketch` (count / mean / M2 / extrema; wraps the same Chan
  fold the ``block_sketch`` and ``plan`` kernels produce),
* :class:`HistogramSketch` (mergeable fixed-grid histograms),
* :class:`KLLSketch` (mergeable quantile sketch, Karnin-Lang-Liberty style),
* :class:`DistinctSketch` (KMV / k-minimum-values distinct counting),
* :class:`LabelsSketch` (label histograms for labelled corpora),
* :class:`SketchSuite`, the per-block composition that partition backends
  write, manifests persist (``sketch_schema`` v2; v1 manifests upgrade
  lazily on read), and query / sampler layers consume.

``SketchSuite`` is attribute-compatible with the legacy ``BlockSummary``
(``count`` / ``mean`` / ``m2`` / ``min`` / ``max`` / ``std`` / ``variance`` /
``label_hist`` / ``label_distribution`` / ``moments()``) so every existing
consumer -- ``combine_summaries``, the sampling policies, the query engine --
reads suites without change.
"""

from __future__ import annotations

import math
from typing import Iterable, Sequence

import numpy as np

from repro.core.estimators import MomentStats
from repro.core.moments import chan_merge

#: Manifest schema version written by :meth:`SketchSuite.to_dict`.  v1 is the
#: flat pre-suite ``BlockSummary`` dict (no ``"sketches"`` key); v1 payloads
#: still load through :meth:`SketchSuite.from_dict` as a lazy in-memory
#: upgrade to a moments(+labels)-only suite.
SKETCH_SCHEMA_VERSION = 2

DEFAULT_KLL_K = 160
DEFAULT_KMV_K = 256

# ---------------------------------------------------------------------------
# Protocol + registry
# ---------------------------------------------------------------------------

SKETCH_KINDS: dict[str, type] = {}


def register_sketch(cls: type) -> type:
    """Class decorator: register a :class:`Sketch` implementation under its
    ``kind`` so :func:`sketch_from_dict` can revive it from a manifest."""
    if not getattr(cls, "kind", None):
        raise ValueError(f"{cls.__name__} needs a non-empty `kind`")
    SKETCH_KINDS[cls.kind] = cls
    return cls


def sketch_from_dict(d: dict) -> "Sketch":
    """Revive any registered sketch from its ``to_dict`` payload."""
    kind = d.get("kind")
    if kind not in SKETCH_KINDS:
        raise ValueError(
            f"unknown sketch kind {kind!r} (registered: {sorted(SKETCH_KINDS)})"
        )
    return SKETCH_KINDS[kind].from_dict(d)


class Sketch:
    """One mergeable per-block statistic.

    ``update(rows)`` folds a chunk of rows (``[n, F]`` float array) into the
    sketch; ``merge(other)`` folds another sketch of the same kind/params in
    place and returns ``self``; ``to_dict`` / ``from_dict`` round-trip the
    state losslessly through JSON (manifests).  All implementations are
    deterministic: any randomness (KLL compaction) is seeded from the
    sketch's own state, never from global RNG.
    """

    kind = ""

    def update(self, rows: np.ndarray) -> "Sketch":
        raise NotImplementedError

    def merge(self, other: "Sketch") -> "Sketch":
        raise NotImplementedError

    def to_dict(self) -> dict:
        raise NotImplementedError

    @classmethod
    def from_dict(cls, d: dict) -> "Sketch":
        raise NotImplementedError

    def _check_mergeable(self, other: "Sketch") -> None:
        if self.kind != getattr(other, "kind", None):
            raise ValueError(f"cannot merge {self.kind!r} with {getattr(other, 'kind', other)!r}")


def _as_rows(rows) -> np.ndarray:
    x = np.asarray(rows, dtype=np.float64)
    return x.reshape(x.shape[0], -1)


# ---------------------------------------------------------------------------
# Moments + extrema (the Chan fold the kernels produce)
# ---------------------------------------------------------------------------

@register_sketch
class MomentsSketch(Sketch):
    """Count / per-feature mean / M2 / extrema.  The merge is the shared
    :func:`repro.core.moments.chan_merge` -- the same fold the
    ``block_sketch`` / ``plan`` kernels run on device, so kernel outputs
    wrap into this sketch without recomputation
    (:meth:`from_block_sketch`)."""

    kind = "moments"

    def __init__(self, count: float = 0.0, mean=None, m2=None, min=None, max=None):
        self.count = float(count)
        self.mean = None if mean is None else np.asarray(mean, dtype=np.float64)
        self.m2 = None if m2 is None else np.asarray(m2, dtype=np.float64)
        self.min = None if min is None else np.asarray(min, dtype=np.float64)
        self.max = None if max is None else np.asarray(max, dtype=np.float64)

    @classmethod
    def from_block_sketch(cls, sk) -> "MomentsSketch":
        """Wrap a kernel-produced ``BlockSketch`` (no recompute)."""
        return cls(count=float(sk.count), mean=sk.mean, m2=sk.m2, min=sk.min, max=sk.max)

    def update(self, rows) -> "MomentsSketch":
        x = _as_rows(rows)
        if x.shape[0] == 0:
            return self
        mean = x.mean(axis=0)
        m2 = ((x - mean) ** 2).sum(axis=0)
        return self.merge(
            MomentsSketch(float(x.shape[0]), mean, m2, x.min(axis=0), x.max(axis=0))
        )

    def merge(self, other: "MomentsSketch") -> "MomentsSketch":
        self._check_mergeable(other)
        if other.count <= 0:
            return self
        if self.count <= 0:
            self.count = other.count
            self.mean, self.m2 = other.mean.copy(), other.m2.copy()
            self.min, self.max = other.min.copy(), other.max.copy()
            return self
        self.count, self.mean, self.m2 = chan_merge(
            self.count, self.mean, self.m2, other.count, other.mean, other.m2
        )
        self.min = np.minimum(self.min, other.min)
        self.max = np.maximum(self.max, other.max)
        return self

    @property
    def variance(self) -> np.ndarray:
        return self.m2 / max(self.count - 1.0, 1.0)

    @property
    def std(self) -> np.ndarray:
        return np.sqrt(self.variance)

    def to_dict(self) -> dict:
        return {
            "kind": self.kind,
            "count": self.count,
            "mean": [] if self.mean is None else self.mean.tolist(),
            "m2": [] if self.m2 is None else self.m2.tolist(),
            "min": [] if self.min is None else self.min.tolist(),
            "max": [] if self.max is None else self.max.tolist(),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "MomentsSketch":
        if d["count"] <= 0:
            return cls()
        return cls(d["count"], d["mean"], d["m2"], d["min"], d["max"])


# ---------------------------------------------------------------------------
# Fixed-grid histograms
# ---------------------------------------------------------------------------

@register_sketch
class HistogramSketch(Sketch):
    """Per-feature fixed-grid histogram ``[F, bins]``; merges by addition on
    *identical* grids only.  Out-of-range mass clips into the edge bins so
    every histogram sums to the row count."""

    kind = "histogram"

    def __init__(self, bins: int, lo, hi, hist=None):
        from repro.kernels.block_sketch.ref import _grid

        if bins <= 0:
            raise ValueError("histogram sketch needs bins > 0")
        self.bins = int(bins)
        lo = np.atleast_1d(np.asarray(lo, dtype=np.float64))
        hi = np.atleast_1d(np.asarray(hi, dtype=np.float64))
        f = max(lo.shape[0], hi.shape[0])
        self.lo, self.hi = _grid(lo, hi, f)
        self.hist = (
            np.zeros((f, bins), dtype=np.int64)
            if hist is None
            else np.asarray(hist, dtype=np.int64)
        )
        if self.hist.shape != (f, bins):
            raise ValueError("hist shape must be [F, bins]")

    def update(self, rows) -> "HistogramSketch":
        from repro.kernels.block_sketch.ref import grid_histogram

        x = _as_rows(rows)
        if x.shape[0]:
            self.hist = self.hist + grid_histogram(x, self.lo, self.hi, self.bins)
        return self

    def merge(self, other: "HistogramSketch") -> "HistogramSketch":
        self._check_mergeable(other)
        if (
            other.bins != self.bins
            or not np.array_equal(other.lo, self.lo)
            or not np.array_equal(other.hi, self.hi)
        ):
            raise ValueError("histogram sketches merge only on identical grids")
        self.hist = self.hist + other.hist
        return self

    def quantile(self, qs: Sequence[float]) -> np.ndarray:
        from repro.core.estimators import quantile_from_histogram

        return quantile_from_histogram(self.hist, qs, lo=self.lo, hi=self.hi)

    def to_dict(self) -> dict:
        return {
            "kind": self.kind,
            "bins": self.bins,
            "lo": self.lo.tolist(),
            "hi": self.hi.tolist(),
            "hist": self.hist.tolist(),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "HistogramSketch":
        return cls(d["bins"], d["lo"], d["hi"], hist=d["hist"])


# ---------------------------------------------------------------------------
# KLL quantile sketch
# ---------------------------------------------------------------------------

class _KLLColumn:
    """One column's KLL compactor stack.  ``levels[h]`` holds items of weight
    ``2**h``; level capacities shrink geometrically (ratio 2/3) below the top
    so total space is ``O(k)``.  Compaction keeps every other item of a
    sorted over-full level (random even/odd offset, seeded from the sketch's
    own compaction counter -- fully deterministic given fold order)."""

    __slots__ = ("k", "levels", "n", "seed", "compactions")

    _EMPTY = np.empty(0, dtype=np.float64)

    def __init__(self, k: int, seed: int):
        self.k = int(k)
        # numpy (not Python-list) levels: a list of floats costs ~4x the
        # bytes, which matters when thousands of columns accumulate during
        # a memory-capped ingest
        self.levels: list[np.ndarray] = [self._EMPTY]
        self.n = 0
        self.seed = int(seed)
        self.compactions = 0

    def _capacity(self, h: int) -> int:
        depth = len(self.levels) - 1 - h
        return max(int(math.ceil(self.k * (2.0 / 3.0) ** depth)), 2)

    def _size(self) -> int:
        return sum(lv.size for lv in self.levels)

    def _cap_total(self) -> int:
        return sum(self._capacity(h) for h in range(len(self.levels)))

    def update(self, values: np.ndarray) -> None:
        values = np.asarray(values, dtype=np.float64).ravel()
        if values.size == 0:
            return
        self.levels[0] = np.concatenate([self.levels[0], values])
        self.n += int(values.size)
        self._compress()

    def merge(self, other: "_KLLColumn") -> None:
        while len(self.levels) < len(other.levels):
            self.levels.append(self._EMPTY)
        for h, lv in enumerate(other.levels):
            if lv.size:
                self.levels[h] = np.concatenate([self.levels[h], lv])
        self.n += other.n
        self._compress()

    def _compress(self) -> None:
        while self._size() > self._cap_total():
            for h in range(len(self.levels)):
                if self.levels[h].size >= self._capacity(h) and self.levels[h].size >= 2:
                    self._compact(h)
                    break
            else:
                break

    def _compact(self, h: int) -> None:
        if h == len(self.levels) - 1:
            self.levels.append(self._EMPTY)
        buf = np.sort(self.levels[h])
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed & 0xFFFFFFFF, 0x6B11, self.compactions])
        )
        self.compactions += 1
        keep = self._EMPTY
        if buf.size % 2 == 1:           # odd leftover stays at this level
            keep = buf[-1:]
            buf = buf[:-1]
        offset = int(rng.integers(0, 2))
        self.levels[h + 1] = np.concatenate([self.levels[h + 1], buf[offset::2]])
        self.levels[h] = keep

    def _sorted_weighted(self) -> tuple[np.ndarray, np.ndarray]:
        if self._size() == 0:
            return np.empty(0), np.empty(0)
        v = np.concatenate(self.levels)
        w = np.concatenate(
            [np.full(lv.size, float(1 << h)) for h, lv in enumerate(self.levels)]
        )
        order = np.argsort(v, kind="stable")
        return v[order], w[order]

    def quantile(self, qs: np.ndarray) -> np.ndarray:
        v, w = self._sorted_weighted()
        if v.size == 0:
            return np.full(len(qs), np.nan)
        cum = np.cumsum(w)
        target = np.clip(np.asarray(qs, dtype=np.float64), 0.0, 1.0) * cum[-1]
        idx = np.minimum(np.searchsorted(cum, target, side="left"), v.size - 1)
        return v[idx]

    def rank(self, x: float) -> float:
        """Estimated fraction of items ``<= x``."""
        v, w = self._sorted_weighted()
        if v.size == 0:
            return 0.0
        i = int(np.searchsorted(v, x, side="right"))
        if i == 0:
            return 0.0
        return float(np.cumsum(w)[i - 1] / w.sum())

    def to_dict(self) -> dict:
        return {
            "n": self.n,
            "compactions": self.compactions,
            "levels": [lv.tolist() for lv in self.levels],
        }

    @classmethod
    def from_dict(cls, d: dict, *, k: int, seed: int) -> "_KLLColumn":
        col = cls(k, seed)
        col.n = int(d["n"])
        col.compactions = int(d["compactions"])
        col.levels = [np.asarray(lv, dtype=np.float64) for lv in d["levels"]]
        return col


def kll_rank_error_bound(k: int) -> float:
    """Analytic additive rank-error bound for a KLL sketch with parameter
    ``k`` at ~99% confidence: ``eps = 2.296 / k**0.9`` (the constant the
    Apache DataSketches implementation uses)."""
    return 2.296 / float(k) ** 0.9


@register_sketch
class KLLSketch(Sketch):
    """Mergeable per-column quantile sketch (Karnin-Lang-Liberty).

    Answers any quantile of any column to additive rank error
    :func:`kll_rank_error_bound` ``(k)`` from ``O(k)`` space per column, and
    merges without error growth -- so corpus quantiles come from the
    partition-time sketches with **zero** block reads."""

    kind = "kll"

    def __init__(self, k: int = DEFAULT_KLL_K, *, seed: int = 0, columns=None):
        if k < 8:
            raise ValueError("kll k must be >= 8")
        self.k = int(k)
        self.seed = int(seed)
        self._columns: list[_KLLColumn] | None = columns

    @property
    def num_features(self) -> int | None:
        return None if self._columns is None else len(self._columns)

    @property
    def n(self) -> int:
        return 0 if not self._columns else self._columns[0].n

    def _ensure_columns(self, f: int) -> list[_KLLColumn]:
        if self._columns is None:
            self._columns = [
                _KLLColumn(self.k, (self.seed << 8) + j) for j in range(f)
            ]
        if len(self._columns) != f:
            raise ValueError(
                f"kll sketch has {len(self._columns)} columns, rows have {f}"
            )
        return self._columns

    def update(self, rows) -> "KLLSketch":
        x = _as_rows(rows)
        if x.shape[0] == 0:
            return self
        for j, col in enumerate(self._ensure_columns(x.shape[1])):
            col.update(x[:, j])
        return self

    def merge(self, other: "KLLSketch") -> "KLLSketch":
        self._check_mergeable(other)
        if other.k != self.k:
            raise ValueError("kll sketches merge only with equal k")
        if other._columns is None:
            return self
        if self._columns is None:
            # adopt a deep copy so later folds never mutate `other`
            self._columns = [
                _KLLColumn.from_dict(c.to_dict(), k=self.k, seed=c.seed)
                for c in other._columns
            ]
            return self
        if len(self._columns) != len(other._columns):
            raise ValueError("kll sketches merge only with equal column counts")
        for mine, theirs in zip(self._columns, other._columns):
            mine.merge(theirs)
        return self

    def quantile(self, qs: Sequence[float]) -> np.ndarray:
        """Per-feature quantile estimates ``[F, Q]``."""
        if self._columns is None:
            raise ValueError("empty kll sketch")
        qs = np.atleast_1d(np.asarray(qs, dtype=np.float64))
        return np.stack([c.quantile(qs) for c in self._columns])

    def cdf(self, column: int, value: float) -> float:
        """Estimated fraction of column's values ``<= value``."""
        if self._columns is None:
            raise ValueError("empty kll sketch")
        return self._columns[int(column)].rank(float(value))

    def rank_error_bound(self) -> float:
        return kll_rank_error_bound(self.k)

    def to_dict(self) -> dict:
        return {
            "kind": self.kind,
            "k": self.k,
            "seed": self.seed,
            "columns": None
            if self._columns is None
            else [c.to_dict() for c in self._columns],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "KLLSketch":
        sk = cls(d["k"], seed=d.get("seed", 0))
        if d.get("columns") is not None:
            sk._columns = [
                _KLLColumn.from_dict(c, k=sk.k, seed=(sk.seed << 8) + j)
                for j, c in enumerate(d["columns"])
            ]
        return sk


# ---------------------------------------------------------------------------
# KMV distinct counting
# ---------------------------------------------------------------------------

_U64 = np.uint64
_HASH_SPACE = float(2**64)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer over uint64 (wraps mod 2^64)."""
    z = x + _U64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> _U64(30))) * _U64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> _U64(27))) * _U64(0x94D049BB133111EB)
    return z ^ (z >> _U64(31))


def _hash_values(values: np.ndarray) -> np.ndarray:
    """Hash float64 values by bit pattern (with ``-0.0`` canonicalized to
    ``+0.0`` so equal values always collide)."""
    v = np.asarray(values, dtype=np.float64).copy()
    v[v == 0.0] = 0.0
    return _splitmix64(v.view(np.uint64))


@register_sketch
class DistinctSketch(Sketch):
    """KMV (k-minimum-values) distinct-count sketch per column.

    Keeps the ``k`` smallest 64-bit hashes of each column's values.  Below
    ``k`` observed hashes the count is exact; past it the estimate is
    ``(k - 1) / r_k`` with ``r_k`` the k-th smallest normalized hash
    (relative SE ~ ``1/sqrt(k - 2)``).  Merges by hash-set union + truncate,
    so the merged sketch equals the sketch of the concatenated data."""

    kind = "distinct"

    def __init__(self, k: int = DEFAULT_KMV_K, *, columns=None):
        if k < 8:
            raise ValueError("kmv k must be >= 8")
        self.k = int(k)
        self._columns: list[np.ndarray] | None = columns  # sorted uint64 [<=k]

    @property
    def num_features(self) -> int | None:
        return None if self._columns is None else len(self._columns)

    def _ensure_columns(self, f: int) -> list[np.ndarray]:
        if self._columns is None:
            self._columns = [np.empty(0, dtype=np.uint64) for _ in range(f)]
        if len(self._columns) != f:
            raise ValueError(
                f"distinct sketch has {len(self._columns)} columns, rows have {f}"
            )
        return self._columns

    def update(self, rows) -> "DistinctSketch":
        x = _as_rows(rows)
        if x.shape[0] == 0:
            return self
        cols = self._ensure_columns(x.shape[1])
        for j in range(x.shape[1]):
            h = np.union1d(cols[j], _hash_values(x[:, j]))
            cols[j] = h[: self.k]
        return self

    def merge(self, other: "DistinctSketch") -> "DistinctSketch":
        self._check_mergeable(other)
        if other.k != self.k:
            raise ValueError("distinct sketches merge only with equal k")
        if other._columns is None:
            return self
        if self._columns is None:
            self._columns = [c.copy() for c in other._columns]
            return self
        if len(self._columns) != len(other._columns):
            raise ValueError("distinct sketches merge only with equal column counts")
        for j in range(len(self._columns)):
            self._columns[j] = np.union1d(self._columns[j], other._columns[j])[: self.k]
        return self

    def estimate(self) -> np.ndarray:
        """Per-feature distinct-count estimates ``[F]``."""
        if self._columns is None:
            raise ValueError("empty distinct sketch")
        out = np.empty(len(self._columns), dtype=np.float64)
        for j, h in enumerate(self._columns):
            if h.size < self.k:
                out[j] = float(h.size)
            else:
                r_k = (float(h[self.k - 1]) + 1.0) / _HASH_SPACE
                out[j] = (self.k - 1) / r_k
        return out

    def relative_error_bound(self) -> float:
        """~1-sigma relative standard error of the KMV estimator."""
        return 1.0 / math.sqrt(max(self.k - 2, 1))

    def to_dict(self) -> dict:
        return {
            "kind": self.kind,
            "k": self.k,
            "columns": None
            if self._columns is None
            else [[int(v) for v in c] for c in self._columns],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "DistinctSketch":
        sk = cls(d["k"])
        if d.get("columns") is not None:
            sk._columns = [np.asarray(c, dtype=np.uint64) for c in d["columns"]]
        return sk


# ---------------------------------------------------------------------------
# Label histograms
# ---------------------------------------------------------------------------

@register_sketch
class LabelsSketch(Sketch):
    """Label histogram of one (integer-valued) column.  ``label_column`` may
    be ``None`` for suites upgraded from v1 manifests (histogram known,
    provenance lost) -- such sketches merge but cannot ``update``."""

    kind = "labels"

    def __init__(self, num_classes: int, label_column: int | None = None, hist=None):
        if num_classes <= 0:
            raise ValueError("labels sketch needs num_classes > 0")
        self.num_classes = int(num_classes)
        self.label_column = None if label_column is None else int(label_column)
        self.hist = (
            np.zeros(num_classes, dtype=np.int64)
            if hist is None
            else np.asarray(hist, dtype=np.int64)
        )
        if self.hist.shape != (self.num_classes,):
            raise ValueError("label hist shape must be [num_classes]")

    def update(self, rows) -> "LabelsSketch":
        if self.label_column is None:
            raise ValueError("labels sketch upgraded from v1 has no label column")
        x = _as_rows(rows)
        if x.shape[0] == 0:
            return self
        labels = x[:, self.label_column]
        ilabels = labels.astype(np.int64)
        if (
            np.any(ilabels != labels)
            or ilabels.min(initial=0) < 0
            or ilabels.max(initial=0) >= self.num_classes
        ):
            raise ValueError(
                f"label column {self.label_column} has values outside"
                f" 0..{self.num_classes - 1} (wrong label_column or num_classes?)"
            )
        self.hist = self.hist + np.bincount(ilabels, minlength=self.num_classes)
        return self

    def merge(self, other: "LabelsSketch") -> "LabelsSketch":
        self._check_mergeable(other)
        if other.num_classes != self.num_classes:
            raise ValueError("labels sketches merge only with equal num_classes")
        self.hist = self.hist + other.hist
        return self

    @property
    def distribution(self) -> np.ndarray:
        return self.hist / max(self.hist.sum(), 1)

    def to_dict(self) -> dict:
        return {
            "kind": self.kind,
            "num_classes": self.num_classes,
            "label_column": self.label_column,
            "hist": self.hist.tolist(),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "LabelsSketch":
        return cls(d["num_classes"], d.get("label_column"), hist=d["hist"])


# ---------------------------------------------------------------------------
# The per-block suite
# ---------------------------------------------------------------------------

class SketchSuite:
    """The composition of sketches one RSP block carries.

    Attribute-compatible with the legacy ``BlockSummary`` so the sampling
    policies, ``combine_summaries`` and the query layer consume suites
    unchanged; richer members (``kll`` / ``distinct``) unlock sketch-only
    quantile / distinct-count answers and query-aware block scoring."""

    def __init__(self, block_id: int, sketches: dict[str, Sketch]):
        if "moments" not in sketches:
            raise ValueError("every sketch suite needs a 'moments' member")
        self.block_id = int(block_id)
        self.sketches = dict(sketches)

    # -- construction ------------------------------------------------------
    @classmethod
    def create(
        cls,
        block_id: int,
        *,
        label_column: int | None = None,
        num_classes: int | None = None,
        kll_k: int = DEFAULT_KLL_K,
        kmv_k: int = DEFAULT_KMV_K,
        kinds: Sequence[str] | None = None,
        seed: int = 0,
    ) -> "SketchSuite":
        """An empty suite with the default members: moments + KLL + distinct
        (+ labels when ``label_column``/``num_classes`` are given).  Fixed-grid
        histograms are registered but not default -- their grid needs global
        extrema the writer does not have yet.  KLL compaction randomness is
        seeded per ``(seed, block_id)`` so partition writes are reproducible
        for any chunking of the stream."""
        default = ["moments", "kll", "distinct"]
        if label_column is not None and num_classes is not None:
            default.append("labels")
        sketches: dict[str, Sketch] = {}
        for kind in kinds if kinds is not None else default:
            if kind == "moments":
                sketches[kind] = MomentsSketch()
            elif kind == "kll":
                sketches[kind] = KLLSketch(kll_k, seed=(int(seed) << 20) ^ int(block_id))
            elif kind == "distinct":
                sketches[kind] = DistinctSketch(kmv_k)
            elif kind == "labels":
                if label_column is None or num_classes is None:
                    raise ValueError("labels sketch needs label_column and num_classes")
                sketches[kind] = LabelsSketch(num_classes, label_column)
            else:
                raise ValueError(f"no default constructor for sketch kind {kind!r}")
        return cls(block_id, sketches)

    # -- Sketch protocol, suite-wide --------------------------------------
    def update(self, rows) -> "SketchSuite":
        x = _as_rows(rows)
        for sk in self.sketches.values():
            sk.update(x)
        return self

    def merge(self, other: "SketchSuite") -> "SketchSuite":
        """Fold ``other`` in (shared kinds only -- a v1-upgraded suite merges
        into a v2 suite on the moments/labels they both carry)."""
        for kind in list(self.sketches):
            if kind in other.sketches:
                self.sketches[kind].merge(other.sketches[kind])
            else:
                del self.sketches[kind]
        return self

    def get(self, kind: str) -> Sketch | None:
        return self.sketches.get(kind)

    # -- BlockSummary-compatible surface -----------------------------------
    @property
    def _moments(self) -> MomentsSketch:
        return self.sketches["moments"]  # type: ignore[return-value]

    @property
    def count(self) -> int:
        return int(self._moments.count)

    @property
    def mean(self) -> np.ndarray:
        return self._moments.mean

    @property
    def m2(self) -> np.ndarray:
        return self._moments.m2

    @property
    def min(self) -> np.ndarray:
        return self._moments.min

    @property
    def max(self) -> np.ndarray:
        return self._moments.max

    @property
    def variance(self) -> np.ndarray:
        return self._moments.variance

    @property
    def std(self) -> np.ndarray:
        return self._moments.std

    @property
    def label_hist(self) -> np.ndarray | None:
        labels = self.sketches.get("labels")
        return None if labels is None else labels.hist

    @property
    def label_distribution(self) -> np.ndarray:
        labels = self.sketches.get("labels")
        if labels is None:
            raise ValueError(f"block {self.block_id} has no label histogram")
        return labels.distribution

    def moments(self) -> MomentStats:
        m = self._moments
        return MomentStats(
            count=float(m.count),
            mean=m.mean.copy(),
            m2=m.m2.copy(),
            min=m.min.copy(),
            max=m.max.copy(),
        )

    # -- query-aware helpers -----------------------------------------------
    def selectivity(self, predicates) -> float:
        """Estimated fraction of the block's rows passing the conjunctive
        ``predicates``.  Per-predicate marginals come from the block's KLL
        CDF when present, else from linear interpolation over the moment
        sketch's ``[min, max]`` span (v1 suites); conjunction assumes
        independence.  Always in ``[0, 1]``."""
        sel = 1.0
        kll = self.sketches.get("kll")
        for p in predicates:
            c, v = int(p.column), float(p.value)
            if kll is not None and kll.num_features is not None:
                frac_le = kll.cdf(c, v)
            else:
                lo, hi = float(self.min[c]), float(self.max[c])
                if hi <= lo:
                    frac_le = 1.0 if lo <= v else 0.0
                else:
                    frac_le = float(np.clip((v - lo) / (hi - lo), 0.0, 1.0))
            if p.op in ("lt", "le"):
                frac = frac_le
            elif p.op in ("gt", "ge"):
                frac = 1.0 - frac_le
            elif p.op == "eq":
                # point mass: visible to the sketch only through rank steps
                eps = 1e-9 * max(abs(v), 1.0)
                if kll is not None and kll.num_features is not None:
                    frac = max(frac_le - kll.cdf(c, v - eps), 0.0)
                else:
                    frac = 1.0 if float(self.min[c]) <= v <= float(self.max[c]) else 0.0
            else:  # ne
                frac = 1.0 - self.selectivity([type(p)(c, "eq", v)])
            sel *= float(np.clip(frac, 0.0, 1.0))
        return sel

    # -- versioned (de)serialization ---------------------------------------
    def to_dict(self) -> dict:
        return {
            "schema_version": SKETCH_SCHEMA_VERSION,
            "block_id": self.block_id,
            "count": self.count,
            "sketches": {kind: sk.to_dict() for kind, sk in self.sketches.items()},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "SketchSuite":
        """Revive a suite from a manifest entry.  v1 payloads (flat
        ``BlockSummary`` dicts, no ``"sketches"`` key) upgrade lazily to a
        moments(+labels)-only suite that answers every moment/label question
        identically to the original."""
        if "sketches" not in d:  # v1 lazy upgrade
            sketches: dict[str, Sketch] = {
                "moments": MomentsSketch(
                    float(d["count"]), d["mean"], d["m2"], d["min"], d["max"]
                )
            }
            hist = d.get("label_hist")
            if hist is not None:
                sketches["labels"] = LabelsSketch(len(hist), None, hist=hist)
            return cls(int(d["block_id"]), sketches)
        return cls(
            int(d["block_id"]),
            {kind: sketch_from_dict(sd) for kind, sd in d["sketches"].items()},
        )


def load_summaries(raw: Iterable[dict]) -> list[SketchSuite]:
    """Manifest ``summaries`` payload (any schema version) -> suites."""
    return [SketchSuite.from_dict(d) for d in raw]


def merge_suites(suites: Sequence[SketchSuite]) -> SketchSuite:
    """Corpus-level suite from per-block suites (shared kinds).  The result
    is a fresh object -- the inputs are never mutated."""
    if not suites:
        raise ValueError("need at least one suite")
    acc = SketchSuite.from_dict(suites[0].to_dict())
    for s in suites[1:]:
        acc.merge(s)
    acc.block_id = -1
    return acc


def sketch_schema_descriptor(suites: Sequence[SketchSuite]) -> dict:
    """The manifest's ``sketch_schema`` entry: version + the sketch kinds
    (and size parameters) every block of the store carries."""
    kinds: dict[str, dict] = {}
    if suites:
        for kind, sk in suites[0].sketches.items():
            params = {}
            if hasattr(sk, "k"):
                params["k"] = sk.k
            if hasattr(sk, "bins"):
                params["bins"] = sk.bins
            if hasattr(sk, "num_classes"):
                params["num_classes"] = sk.num_classes
            kinds[kind] = params
    return {"version": SKETCH_SCHEMA_VERSION, "kinds": kinds}
