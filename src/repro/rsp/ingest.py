"""``repro.rsp.ingest`` -- the out-of-core streaming partitioner.

The paper's premise is that RSP blocks are *generated in advance* from a big
distributed data set precisely because the whole set cannot be loaded and
scanned.  The in-memory backends behind ``rsp.partition`` all take the full
corpus as one array; this module closes the gap with a single-pass scatter
form of Algorithm 1 whose peak memory is O(chunk + write buffers), never
O(N):

``ChunkSource``
    The pluggable input protocol -- corpus dimensions plus a ``chunks()``
    iterator of record batches in storage order.  Four adapters ship:
    :class:`ArrayChunkSource` (in-RAM or memmapped array),
    :class:`NpyChunkSource` (``np.load(mmap_mode="r")`` -- pages stream from
    disk), :class:`DirectoryChunkSource` (a directory of ``.npy`` chunk
    files), and :class:`IterChunkSource` (a plain record-batch iterator).
    :func:`as_chunk_source` adapts arrays, paths, directories, and batch
    sequences.

``stream_partition``
    Algorithm 1 as a scatter pass.  The key identity: the two-stage
    construction ``out[:, i*delta:(i+1)*delta] = original[i][perm].reshape(
    K, delta, ...)[assign]`` fixes every record's destination *before any
    data is seen* -- row ``r`` of original block ``i`` lands in RSP block
    ``inv_assign[inv_perm[r] // delta]`` at offset ``i*delta + inv_perm[r]
    % delta``.  So each incoming chunk is split at original-block
    boundaries and each segment's rows are written directly into their
    destination offsets of a preallocated per-block ``.npy`` (via
    ``RSPStore.create_writer`` / ``np.lib.format.open_memmap``), with the
    per-block ``block_sketch`` state folded incrementally (Chan combine)
    during the write -- the finished store has exact partition-time
    summaries with zero extra corpus scans.  The output is bit-identical
    to ``two_stage_partition_np`` for the same spec and seed, for any
    chunking of the input.

Scatter writes run on a bounded thread pool (the engine's prefetch-window
pattern): ``workers`` threads keep at most ``max_inflight`` chunk segments
in flight, results are reaped in submission order so sketch folding is
deterministic, and worker exceptions abort the ingest (temps removed, no
manifest published).

``benchmarks/ingest_bench.py`` partitions a corpus several times larger
than its enforced memory cap through this path.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Iterable, Iterator, Protocol, Sequence, runtime_checkable

import numpy as np

from repro import obs
from repro.core.partition import _np_rng
from repro.core.registry import RSPStore
from repro.core.types import RSPSpec
from repro.kernels.block_sketch.ref import BlockSketch, block_sketch_ref, merge_sketches
from repro.rsp.sketch import (
    LabelsSketch,
    MomentsSketch,
    SketchSuite,
    sketch_schema_descriptor,
)

_DEFAULT_CHUNK_BYTES = 8 << 20  # ~8 MiB of records per auto-sized chunk


# ---------------------------------------------------------------------------
# ChunkSource protocol + adapters
# ---------------------------------------------------------------------------

@runtime_checkable
class ChunkSource(Protocol):
    """Anything that can stream a corpus as record batches in storage order.

    A source may additionally declare ``owns_chunks = True`` to promise that
    every yielded batch is a freshly allocated array nobody mutates
    afterwards; the parallel scatter then skips its defensive per-chunk
    detach copy.  Absent (the default), batches are assumed to alias a
    producer-owned buffer and are copied before asynchronous use.
    """

    @property
    def num_records(self) -> int: ...

    @property
    def record_shape(self) -> tuple[int, ...]: ...

    @property
    def dtype(self) -> np.dtype: ...

    def chunks(self) -> Iterator[np.ndarray]: ...


def _auto_chunk_records(record_shape: tuple[int, ...], dtype: np.dtype) -> int:
    row_bytes = int(np.dtype(dtype).itemsize * max(1, int(np.prod(record_shape, dtype=np.int64))))
    return max(1, _DEFAULT_CHUNK_BYTES // row_bytes)


class ArrayChunkSource:
    """Chunked view of an array already in RAM (or an ``np.memmap``): chunks
    are materialized copies, so downstream holds no reference to the mmap."""

    owns_chunks = True  # chunks() yields fresh copies

    def __init__(self, array: np.ndarray, *, chunk_records: int | None = None):
        self._array = array
        self._chunk = int(chunk_records) if chunk_records else _auto_chunk_records(
            tuple(array.shape[1:]), array.dtype
        )

    @property
    def num_records(self) -> int:
        return int(self._array.shape[0])

    @property
    def record_shape(self) -> tuple[int, ...]:
        return tuple(self._array.shape[1:])

    @property
    def dtype(self) -> np.dtype:
        return np.dtype(self._array.dtype)

    def chunks(self) -> Iterator[np.ndarray]:
        for a in range(0, self.num_records, self._chunk):
            yield np.array(self._array[a : a + self._chunk])


class NpyChunkSource:
    """One ``.npy`` corpus file streamed via ``np.load(mmap_mode="r")`` --
    pages come off disk chunk by chunk, the file is never loaded whole."""

    owns_chunks = True  # chunks() yields fresh copies

    def __init__(self, path: str, *, chunk_records: int | None = None):
        self.path = os.fspath(path)
        mm = np.load(self.path, mmap_mode="r", allow_pickle=False)
        self._shape = tuple(mm.shape)
        self._dtype = np.dtype(mm.dtype)
        del mm
        self._chunk = int(chunk_records) if chunk_records else _auto_chunk_records(
            self._shape[1:], self._dtype
        )

    @property
    def num_records(self) -> int:
        return int(self._shape[0])

    @property
    def record_shape(self) -> tuple[int, ...]:
        return tuple(self._shape[1:])

    @property
    def dtype(self) -> np.dtype:
        return self._dtype

    def chunks(self) -> Iterator[np.ndarray]:
        mm = np.load(self.path, mmap_mode="r", allow_pickle=False)
        for a in range(0, self.num_records, self._chunk):
            yield np.array(mm[a : a + self._chunk])


class DirectoryChunkSource:
    """A directory of ``.npy`` chunk files, concatenated in sorted filename
    order (the 'distributed data set already on the cluster' layout)."""

    owns_chunks = True  # chunks() yields fresh copies

    def __init__(self, root: str, *, chunk_records: int | None = None):
        self.root = os.fspath(root)
        names = sorted(n for n in os.listdir(self.root) if n.endswith(".npy"))
        if not names:
            raise ValueError(f"no .npy chunk files in {self.root!r}")
        self._files = [NpyChunkSource(os.path.join(self.root, n), chunk_records=chunk_records)
                       for n in names]
        head = self._files[0]
        for f in self._files[1:]:
            if f.record_shape != head.record_shape or f.dtype != head.dtype:
                raise ValueError(
                    f"chunk file {f.path!r} has records {f.record_shape}/{f.dtype},"
                    f" expected {head.record_shape}/{head.dtype}"
                )

    @property
    def num_records(self) -> int:
        return sum(f.num_records for f in self._files)

    @property
    def record_shape(self) -> tuple[int, ...]:
        return self._files[0].record_shape

    @property
    def dtype(self) -> np.dtype:
        return self._files[0].dtype

    def chunks(self) -> Iterator[np.ndarray]:
        for f in self._files:
            yield from f.chunks()


class IterChunkSource:
    """A plain record-batch iterable.  Sequences of arrays are introspected
    for dimensions; true one-shot iterators must declare ``num_records``,
    ``record_shape``, and ``dtype`` up front (the spec and the preallocated
    store need them before the first batch arrives) and can stream only once.
    """

    def __init__(
        self,
        batches: Iterable[np.ndarray],
        *,
        num_records: int | None = None,
        record_shape: tuple[int, ...] | None = None,
        dtype: Any = None,
    ):
        if isinstance(batches, (list, tuple)):
            arrs = [np.asarray(b) for b in batches]
            if not arrs:
                raise ValueError("need at least one batch")
            num_records = sum(int(a.shape[0]) for a in arrs)
            record_shape = tuple(arrs[0].shape[1:])
            dtype = arrs[0].dtype
            batches = arrs
            self._reiterable = True
        else:
            if num_records is None or record_shape is None or dtype is None:
                raise ValueError(
                    "IterChunkSource over a one-shot iterator needs num_records,"
                    " record_shape, and dtype declared up front"
                )
            self._reiterable = False
        self._batches = batches
        self._consumed = False
        self._num_records = int(num_records)
        self._record_shape = tuple(record_shape)
        self._dtype = np.dtype(dtype)

    @property
    def num_records(self) -> int:
        return self._num_records

    @property
    def record_shape(self) -> tuple[int, ...]:
        return self._record_shape

    @property
    def dtype(self) -> np.dtype:
        return self._dtype

    def chunks(self) -> Iterator[np.ndarray]:
        if self._consumed and not self._reiterable:
            raise RuntimeError(
                "this IterChunkSource wraps a one-shot iterator that was already"
                " consumed; rebuild the source to stream again"
            )
        self._consumed = True
        for b in self._batches:
            yield np.asarray(b)


def as_chunk_source(obj: Any, *, chunk_records: int | None = None) -> ChunkSource:
    """Adapt ``obj`` into a :class:`ChunkSource`.

    Accepts an existing source, an array (in-RAM or ``np.memmap``), a path to
    a ``.npy`` file or to a directory of ``.npy`` chunk files, or a
    list/tuple of record batches.
    """
    if (
        hasattr(obj, "chunks")
        and callable(obj.chunks)
        and hasattr(obj, "num_records")
        and not isinstance(obj, np.ndarray)
    ):
        return obj
    if isinstance(obj, np.ndarray):
        return ArrayChunkSource(obj, chunk_records=chunk_records)
    if isinstance(obj, (str, os.PathLike)):
        path = os.fspath(obj)
        if os.path.isdir(path):
            return DirectoryChunkSource(path, chunk_records=chunk_records)
        if os.path.isfile(path) and path.endswith(".npy"):
            return NpyChunkSource(path, chunk_records=chunk_records)
        raise TypeError(f"path {path!r} is neither a .npy file nor a chunk directory")
    if isinstance(obj, (list, tuple)):
        return IterChunkSource(obj)
    raise TypeError(f"cannot build a ChunkSource from {type(obj).__name__}")


def maybe_chunk_source(obj: Any, *, chunk_records: int | None = None) -> ChunkSource | None:
    """:func:`as_chunk_source`, returning None instead of raising -- both for
    unadaptable types and for adapter construction failures (empty chunk
    directory, mismatched shard shapes), so capability predicates built on
    this keep their reason-or-None contract."""
    try:
        return as_chunk_source(obj, chunk_records=chunk_records)
    except (TypeError, ValueError):
        return None


def is_stream_source(obj: Any) -> bool:
    """True for inputs that must stream: everything :func:`as_chunk_source`
    adapts *except* plain in-RAM arrays (the in-memory backends serve those)
    and bare lists/tuples, which are ambiguous -- the streaming layer reads
    them as record *batches* while array construction reads them as records.
    Wrap a batch list in :class:`IterChunkSource` to stream it explicitly."""
    if isinstance(obj, np.ndarray) and not isinstance(obj, np.memmap):
        return False
    if isinstance(obj, (list, tuple)):
        return False
    return maybe_chunk_source(obj) is not None


def resolve_stream_source(
    obj: Any, *, chunk_records: int | None = None
) -> ChunkSource | None:
    """The facade's one-shot detection: the :class:`ChunkSource` for inputs
    that must stream, or None for array-like inputs (same classification as
    :func:`is_stream_source`, but the adapter is built exactly once and
    returned).  Path-like inputs that *should* adapt but cannot raise with
    the adapter's detailed reason instead of degrading to array handling."""
    if isinstance(obj, np.ndarray) and not isinstance(obj, np.memmap):
        return None
    if isinstance(obj, (list, tuple)):
        return None
    if isinstance(obj, (str, os.PathLike)):
        return as_chunk_source(obj, chunk_records=chunk_records)
    return maybe_chunk_source(obj, chunk_records=chunk_records)


# ---------------------------------------------------------------------------
# Streaming scatter pass (Algorithm 1, out of core)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _SketchAcc:
    """Per-RSP-block fold state, merged in deterministic segment order.

    ``sketch`` folds the kernel-grade moment sketch; ``suite`` carries the
    richer mergeable members (KLL quantiles, KMV distinct counts) that are
    updated with the same rows on the fold thread, in the same deterministic
    submission order."""

    sketch: BlockSketch | None = None
    label_hist: np.ndarray | None = None
    suite: SketchSuite | None = None


def _destinations(i: int, pos: np.ndarray, inv_assign: np.ndarray, delta: int):
    """RSP-block ids and in-block row offsets for original-block ``i`` rows
    whose randomized positions are ``pos`` (the inverse-permutation image)."""
    k = inv_assign[pos // delta]
    dest = i * delta + pos % delta
    return k, dest


def _scatter_segment(
    write_rows,
    rows: np.ndarray,
    i: int,
    pos: np.ndarray,
    inv_assign: np.ndarray,
    delta: int,
    block_size: int,
    *,
    with_summaries: bool,
    num_classes: int | None,
    label_column: int,
) -> list[tuple[int, BlockSketch | None, np.ndarray | None, np.ndarray | None]]:
    """Write one chunk segment (rows of original block ``i``) to its
    destination offsets; returns per-RSP-block mini-sketches (and the flat
    float64 rows, for the richer suite members) for folding."""
    k, dest = _destinations(i, pos, inv_assign, delta)
    order = np.argsort(k.astype(np.int64) * block_size + dest)
    ks = k[order]
    cuts = np.flatnonzero(np.diff(ks)) + 1
    results: list[tuple[int, BlockSketch | None, np.ndarray | None, np.ndarray | None]] = []
    for group in np.split(order, cuts):
        kk = int(k[group[0]])
        vals = rows[group]
        write_rows(kk, dest[group], vals)
        sketch = hist = flat = None
        if with_summaries:
            f64 = np.asarray(vals, dtype=np.float64).reshape(vals.shape[0], -1)
            sketch = block_sketch_ref(f64)
            # retain the source-dtype rows (not the f64 copy) for the
            # KLL/KMV fold on the main thread: the in-flight window holds
            # several of these, and the ingest memory cap is real
            flat = vals.reshape(vals.shape[0], -1)
            if num_classes is not None:
                labels = f64[:, label_column]
                ilabels = labels.astype(np.int64)
                if (
                    np.any(ilabels != labels)
                    or ilabels.min(initial=0) < 0
                    or ilabels.max(initial=0) >= num_classes
                ):
                    raise ValueError(
                        f"block {kk}: label column {label_column} has values outside"
                        f" 0..{num_classes - 1} (wrong label_column or num_classes?)"
                    )
                hist = np.bincount(ilabels, minlength=num_classes)
        results.append((kk, sketch, hist, flat))
    return results


def stream_partition(
    source: Any,
    spec: RSPSpec,
    *,
    out: str | None = None,
    permute_assignment: bool = True,
    with_summaries: bool = True,
    num_classes: int | None = None,
    label_column: int = -1,
    chunk_records: int | None = None,
    workers: int = 4,
    max_inflight: int | None = None,
) -> tuple[np.ndarray | RSPStore, list[SketchSuite] | None]:
    """Single-pass Algorithm 1 over a :class:`ChunkSource` with bounded memory.

    With ``out`` set, blocks are written into preallocated per-block ``.npy``
    temps under ``out`` and published atomically (checksums from the finished
    files, manifest last); the return value is the finished
    :class:`RSPStore`.  With ``out=None`` the scatter targets one in-RAM
    ``[K, n, ...]`` array (the small-corpus / testing path).  Either way the
    result is bit-identical to ``two_stage_partition_np(full_array, spec)``
    and the returned summaries are the sketches folded during the write.

    ``workers=0`` runs the scatter synchronously on the caller's thread (the
    reference behavior, like the engine's ``prefetch=0``).
    """
    src = as_chunk_source(source, chunk_records=chunk_records)
    if src.num_records != spec.num_records:
        raise ValueError(
            f"source has {src.num_records} records, spec says {spec.num_records}"
        )
    if tuple(src.record_shape) != tuple(spec.record_shape):
        raise ValueError(
            f"source records have shape {tuple(src.record_shape)},"
            f" spec says {tuple(spec.record_shape)}"
        )
    P, K = spec.num_original_blocks, spec.num_blocks
    if spec.num_records % (P * K) != 0:
        raise ValueError(
            f"spec unsatisfiable: N={spec.num_records} must be divisible by"
            f" P*K={P * K} so sub-blocks have uniform size delta"
        )
    delta, R, n = spec.slice_size, spec.original_block_size, spec.block_size
    tail = tuple(spec.record_shape)
    dtype = np.dtype(spec.dtype)

    writer = dest = None
    if out is not None:
        writer = RSPStore(out).create_writer(spec)
        write_rows = writer.write_rows
    else:
        dest = np.empty((K, n, *tail), dtype=dtype)

        def write_rows(block_id: int, offsets: np.ndarray, values: np.ndarray) -> None:
            dest[block_id][offsets] = values

    acc = [_SketchAcc() for _ in range(K)]

    def fold(results) -> None:
        if not with_summaries:
            return
        for kk, sketch, hist, flat in results:
            a = acc[kk]
            a.sketch = sketch if a.sketch is None else merge_sketches(a.sketch, sketch)
            if hist is not None:
                a.label_hist = hist if a.label_hist is None else a.label_hist + hist
            if flat is not None:
                if a.suite is None:
                    # KLL/KMV members; moments/labels attach at the end from
                    # the kernel-grade folds above
                    a.suite = SketchSuite.create(kk, kinds=("moments", "kll", "distinct"))
                a.suite.sketches["kll"].update(flat)
                a.suite.sketches["distinct"].update(flat)

    pool = ThreadPoolExecutor(max_workers=max(1, workers), thread_name_prefix="rsp-ingest") \
        if workers > 0 else None
    window: collections.deque[Future] = collections.deque()
    cap = max_inflight if max_inflight is not None else 2 * max(1, workers)

    def submit(i: int, a: int, rows: np.ndarray, inv_perm: np.ndarray,
               inv_assign: np.ndarray) -> None:
        args = (write_rows, rows, i, inv_perm[a : a + rows.shape[0]], inv_assign,
                delta, n)
        kw = dict(with_summaries=with_summaries, num_classes=num_classes,
                  label_column=label_column)
        if pool is None:
            fold(_scatter_segment(*args, **kw))
            return
        while len(window) >= cap:
            fold(window.popleft().result())
        window.append(pool.submit(_scatter_segment, *args, **kw))

    metrics = None
    if obs.enabled():
        reg = obs.get_registry()
        sink = "store" if out is not None else "memory"
        metrics = {
            "chunks": reg.counter(
                "rsp_ingest_chunks_total", "chunks scattered", sink=sink),
            "rows": reg.counter(
                "rsp_ingest_rows_scattered_total", "records scattered", sink=sink),
            "chunk_s": reg.histogram(
                "rsp_ingest_chunk_seconds",
                "split + submit + backpressure time per chunk", sink=sink),
            "rate": reg.gauge(
                "rsp_ingest_rows_per_second", "overall scatter throughput", sink=sink),
        }
        t_ingest = time.perf_counter()

    cursor = 0
    cached_i = -1
    inv_perm = inv_assign = None
    try:
        for chunk in src.chunks():
            t_chunk = time.perf_counter() if metrics is not None else 0.0
            chunk = np.asarray(chunk)
            if chunk.shape[0] == 0:
                continue
            if tuple(chunk.shape[1:]) != tail:
                raise ValueError(
                    f"chunk records have shape {tuple(chunk.shape[1:])}, spec says {tail}"
                )
            if chunk.dtype != dtype:
                chunk = chunk.astype(dtype)
            elif pool is not None and not getattr(src, "owns_chunks", False):
                # detach from any producer-owned buffer: segments are views
                # into the chunk that workers read *after* the producer has
                # moved on, so a source that reuses its batch buffer would
                # otherwise silently corrupt the partition.  Sources that
                # promise fresh per-chunk allocations (owns_chunks) skip the
                # copy -- it would double the hot path's memcpy for nothing.
                chunk = np.array(chunk)
            c0 = 0
            while c0 < chunk.shape[0]:
                i = cursor // R
                if i >= P:
                    raise ValueError(
                        f"source produced more than the {spec.num_records} records"
                        " the spec describes"
                    )
                a = cursor - i * R
                take = min(chunk.shape[0] - c0, R - a)
                if i != cached_i:
                    perm = _np_rng(spec.seed, 0, i).permutation(R)
                    inv_perm = np.argsort(perm)
                    if permute_assignment:
                        assign = _np_rng(spec.seed, 1, i).permutation(K)
                        inv_assign = np.argsort(assign)
                    else:
                        inv_assign = np.arange(K)
                    cached_i = i
                submit(i, a, chunk[c0 : c0 + take], inv_perm, inv_assign)
                cursor += take
                c0 += take
            if metrics is not None:
                metrics["chunks"].inc()
                metrics["rows"].inc(chunk.shape[0])
                metrics["chunk_s"].observe(time.perf_counter() - t_chunk)
        if cursor != spec.num_records:
            raise ValueError(
                f"source produced {cursor} records, spec says {spec.num_records}"
            )
        while window:
            fold(window.popleft().result())
        if metrics is not None:
            elapsed = max(time.perf_counter() - t_ingest, 1e-9)
            metrics["rate"].set(cursor / elapsed)
    except BaseException:
        for fut in window:
            fut.cancel()
        if pool is not None:
            pool.shutdown(wait=True, cancel_futures=True)
            pool = None
        if writer is not None:
            writer.abort()
        raise
    finally:
        if pool is not None:
            pool.shutdown(wait=True)

    summaries = None
    if with_summaries:
        summaries = []
        for k, a in enumerate(acc):
            suite = a.suite if a.suite is not None else SketchSuite.create(
                k, kinds=("moments", "kll", "distinct")
            )
            suite.sketches["moments"] = MomentsSketch.from_block_sketch(a.sketch)
            if a.label_hist is not None:
                suite.sketches["labels"] = LabelsSketch(
                    num_classes, label_column, hist=a.label_hist
                )
            summaries.append(suite)

    if writer is not None:
        store = writer.finalize(
            summaries=summaries,
            meta={
                "backend": "np_stream",
                "num_classes": num_classes,
                "label_column": label_column,
            },
            sketch_schema=None if summaries is None else sketch_schema_descriptor(summaries),
        )
        store.last_ingest_summaries = summaries
        return store, summaries
    return dest, summaries


__all__ = [
    "ArrayChunkSource",
    "ChunkSource",
    "DirectoryChunkSource",
    "IterChunkSource",
    "NpyChunkSource",
    "as_chunk_source",
    "is_stream_source",
    "maybe_chunk_source",
    "resolve_stream_source",
    "stream_partition",
]
