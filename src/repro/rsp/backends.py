"""Partition backend registry behind ``rsp.partition(..., backend=...)``.

Each backend runs Algorithm 1 (two-stage RSP partitioning) through a
different execution substrate and declares a *capability predicate* that
says whether it can serve a given request:

    np        -- paper-faithful numpy in-memory path; the fallback for
                 non-float / non-2D array data.
    np_stream -- out-of-core single-pass scatter (``repro.rsp.ingest``):
                 anything ``as_chunk_source`` can adapt (memmapped ``.npy``,
                 chunk-file directories, record-batch iterators, arrays)
                 streams to a stored RSP (``out=``) or an in-RAM assembly
                 with O(chunk) peak memory; bit-identical to ``np``.
    jax       -- jit'd in-memory path (vmapped permutation + reshape).
    shard_map -- one collective program over a device mesh (all_to_all);
                 requires a mesh with P = K = mesh size.
    pallas    -- the ``rsp_shuffle`` TPU kernel: hierarchical tile shuffle
                 per original block with the delta-slice dealing expressed
                 as DMA scheduling; requires 2-D floating-point data.

``backend="auto"`` selects shard_map when a mesh is supplied, Pallas when
the kernel's shape constraints hold *and* a TPU is attached (off-TPU the
kernel would run in interpret mode, slower than numpy), ``np_stream`` for
every non-array source (paths, chunk directories, batch iterators,
memmaps -- the corpora that never fit in RAM) and whenever ``out=`` asks
for a direct-to-store write, and the in-memory numpy path otherwise
(highest ``auto_priority`` whose predicates pass).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import (
    distributed_rsp_partition,
    two_stage_partition_jax,
    two_stage_partition_np,
)
from repro.core.registry import RSPStore
from repro.core.types import RSPSpec
from repro.kernels.rsp_shuffle.ops import rsp_randomize_block
from repro.rsp.ingest import (
    is_stream_source,
    maybe_chunk_source,
    resolve_stream_source,
    stream_partition,
)

AUTO = "auto"


@dataclasses.dataclass(frozen=True)
class PartitionRequest:
    """Everything a backend needs to decide eligibility and to run.

    ``data`` is array-like [N, ...] for the in-memory backends, or anything
    ``repro.rsp.ingest.as_chunk_source`` adapts (a ``.npy`` path, a chunk
    directory, a record-batch iterator, a memmap) for ``np_stream``.  The
    streaming fields (``out``, ``with_summaries``, ``num_classes``,
    ``label_column``, ``chunk_records``) are read only by ``np_stream``:
    with ``out`` set its result is the finished :class:`RSPStore` (sketches
    folded during the write land in the manifest) instead of stacked blocks.
    """

    data: Any                                   # array-like [N, ...] or ChunkSource
    spec: RSPSpec
    mesh: jax.sharding.Mesh | None = None
    mesh_axis: str = "data"
    permute_assignment: bool = True
    out: str | None = None
    with_summaries: bool = True
    num_classes: int | None = None
    label_column: int = -1
    chunk_records: int | None = None


@dataclasses.dataclass(frozen=True)
class PartitionBackend:
    """A named Algorithm-1 implementation with a capability predicate.

    ``supports`` returns ``None`` when the backend *can* serve the request
    and a human-readable refusal reason otherwise; it gates explicit
    ``backend=<name>`` dispatch.  ``auto_eligible`` (optional) adds a
    preference predicate consulted only by ``backend="auto"`` -- a backend
    that would run but poorly (e.g. an interpret-mode kernel off-TPU) can
    decline auto-selection while remaining explicitly requestable.  ``run``
    returns the stacked RSP blocks [K, n, ...] as a numpy array, or -- for
    streaming backends writing directly to ``request.out`` -- the finished
    :class:`RSPStore`.
    """

    name: str
    capabilities: frozenset[str]
    supports: Callable[[PartitionRequest], str | None]
    run: Callable[[PartitionRequest], "np.ndarray | RSPStore"]
    auto_priority: int
    auto_eligible: Callable[[PartitionRequest], str | None] | None = None


_REGISTRY: dict[str, PartitionBackend] = {}


def register_backend(backend: PartitionBackend) -> PartitionBackend:
    if backend.name == AUTO:
        raise ValueError(f"'{AUTO}' is reserved for automatic selection")
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> PartitionBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


def backend_eligibility(request: PartitionRequest) -> dict[str, str | None]:
    """Map backend name -> None (eligible) or the refusal reason."""
    return {name: b.supports(request) for name, b in _REGISTRY.items()}


def select_backend(request: PartitionRequest) -> PartitionBackend:
    """The ``backend="auto"`` rule: highest-priority eligible backend."""
    ranked = sorted(_REGISTRY.values(), key=lambda b: -b.auto_priority)
    reasons: list[str] = []
    for b in ranked:
        reason = b.supports(request)
        if reason is None and b.auto_eligible is not None:
            reason = b.auto_eligible(request)
        if reason is None:
            return b
        reasons.append(f"{b.name}: {reason}")
    raise ValueError("no backend can serve this request; " + "; ".join(reasons))


def run_partition(
    request: PartitionRequest, backend: str = AUTO
) -> tuple["np.ndarray | RSPStore", str]:
    """Dispatch a partition request; returns (result, backend) where the
    result is the stacked blocks [K, n, ...] or, for a streaming backend
    writing to ``request.out``, the finished :class:`RSPStore`."""
    if not isinstance(request.data, np.ndarray):
        # resolve a path/directory/iterator input to its ChunkSource ONCE:
        # every capability predicate and the eventual run then reuse it
        # instead of re-listing directories and re-reading .npy headers
        src = resolve_stream_source(request.data, chunk_records=request.chunk_records)
        if src is not None and src is not request.data:
            request = dataclasses.replace(request, data=src)
    b = select_backend(request) if backend == AUTO else get_backend(backend)
    if backend != AUTO:
        reason = b.supports(request)
        if reason is not None:
            raise ValueError(f"backend {b.name!r} cannot serve this request: {reason}")
    return b.run(request), b.name


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------

def _non_array_source(req: PartitionRequest) -> str | None:
    """Refusal reason the in-memory backends share: they can serve any
    ndarray (memmaps included -- they materialize on use) but not a
    chunk-stream object, which only ``np_stream`` knows how to drain."""
    if not isinstance(req.data, np.ndarray) and is_stream_source(req.data):
        return "streaming ChunkSource input needs backend='np_stream'"
    return None


def _supports_np(req: PartitionRequest) -> str | None:
    reason = _non_array_source(req)
    if reason is not None:
        return reason
    return None  # the in-memory fallback serves every array the spec admits


def _run_np(req: PartitionRequest) -> np.ndarray:
    return two_stage_partition_np(
        np.asarray(req.data), req.spec, permute_assignment=req.permute_assignment
    )


def _supports_np_stream(req: PartitionRequest) -> str | None:
    if maybe_chunk_source(req.data) is None:
        return (
            "input is not chunkable (need an array, a .npy path, a chunk-file"
            " directory, a batch sequence, or a ChunkSource)"
        )
    return None


def _auto_np_stream(req: PartitionRequest) -> str | None:
    # memmaps, paths, directories, and ChunkSources always stream; in-RAM
    # arrays stream only for direct-to-store writes (out=); everything else
    # (plain arrays, ambiguous record lists) keeps the np path, where it is
    # served with the same bits and no scatter bookkeeping.
    if is_stream_source(req.data):
        return None
    if req.out is not None and isinstance(req.data, np.ndarray):
        return None
    return "in-memory input without out= is served by the np path"


def _run_np_stream(req: PartitionRequest) -> np.ndarray | RSPStore:
    # without out= the facade gets stacked in-memory blocks back and computes
    # summaries the same way as every in-memory backend, so folding sketches
    # during the scatter would be duplicated work; with out= the folded
    # sketches ARE the store's manifest summaries (no second corpus scan)
    result, _ = stream_partition(
        req.data,
        req.spec,
        out=req.out,
        permute_assignment=req.permute_assignment,
        with_summaries=req.with_summaries and req.out is not None,
        num_classes=req.num_classes,
        label_column=req.label_column,
        chunk_records=req.chunk_records,
    )
    return result


def _supports_jax(req: PartitionRequest) -> str | None:
    reason = _non_array_source(req)
    if reason is not None:
        return reason
    return None  # in-memory jit path; spec divisibility is validated upstream


def _run_jax(req: PartitionRequest) -> np.ndarray:
    out = two_stage_partition_jax(
        jnp.asarray(req.data),
        jax.random.PRNGKey(req.spec.seed),
        num_blocks=req.spec.num_blocks,
        num_original_blocks=req.spec.num_original_blocks,
        permute_assignment=req.permute_assignment,
    )
    return np.asarray(out)


def _supports_shard_map(req: PartitionRequest) -> str | None:
    reason = _non_array_source(req)
    if reason is not None:
        return reason
    if req.mesh is None:
        return "requires a device mesh"
    if req.mesh_axis not in req.mesh.shape:
        return f"mesh has no axis {req.mesh_axis!r}"
    d = req.mesh.shape[req.mesh_axis]
    if req.spec.num_blocks != d or req.spec.num_original_blocks != d:
        return (
            f"needs P = K = mesh size ({d}), got P={req.spec.num_original_blocks}"
            f" K={req.spec.num_blocks}"
        )
    if req.spec.num_records % (d * d) != 0:
        return f"N={req.spec.num_records} not divisible by mesh_size^2={d * d}"
    return None


def _run_shard_map(req: PartitionRequest) -> np.ndarray:
    out = distributed_rsp_partition(
        jnp.asarray(req.data),
        jax.random.PRNGKey(req.spec.seed),
        req.mesh,
        axis=req.mesh_axis,
        permute_assignment=req.permute_assignment,
    )
    return np.asarray(out)


def _supports_pallas(req: PartitionRequest) -> str | None:
    reason = _non_array_source(req)
    if reason is not None:
        return reason
    shape = np.shape(req.data)
    if len(shape) != 2:
        return f"kernel needs 2-D [records, features] data, got shape {shape}"
    dtype = getattr(req.data, "dtype", None)
    if dtype is None or not np.issubdtype(np.dtype(dtype), np.floating):
        return f"kernel shuffles via an MXU matmul and needs a float dtype, got {dtype}"
    if not req.permute_assignment:
        return "sub-block assignment permutation is intrinsic to the tile dealing"
    return None


def _auto_pallas(req: PartitionRequest) -> str | None:
    # off-TPU the kernel runs in interpret mode, far slower than the numpy
    # path -- don't win auto-selection there (explicit backend="pallas"
    # still works, e.g. for kernel plumbing tests).
    if jax.default_backend() != "tpu":
        return "interpret-mode off-TPU is slower than np (request it explicitly)"
    return None


def _run_pallas(req: PartitionRequest) -> np.ndarray:
    """Algorithm 1 with the randomize step on the ``rsp_shuffle`` kernel.

    Per original block, ``tile_rows = delta`` makes the kernel's tile
    permutation *be* the sub-block dealing: output tile k of block i is the
    (intra-shuffled) sub-block destined for RSP block k.  Lemma 1 applies at
    slice granularity (see kernels.rsp_shuffle.kernel).
    """
    spec = req.spec
    P, K, delta = spec.num_original_blocks, spec.num_blocks, spec.slice_size
    x = jnp.asarray(req.data)
    R, F = spec.original_block_size, x.shape[1]
    interpret = jax.default_backend() != "tpu"
    key = jax.random.PRNGKey(spec.seed)
    sub = jnp.stack(
        [
            rsp_randomize_block(
                x[i * R : (i + 1) * R],
                jax.random.fold_in(key, i),
                tile_rows=delta,
                interpret=interpret,
            ).reshape(K, delta, F)
            for i in range(P)
        ]
    )  # [P, K, delta, F]
    return np.asarray(sub.transpose(1, 0, 2, 3).reshape(K, P * delta, F))


register_backend(
    PartitionBackend(
        name="np",
        capabilities=frozenset({"in-memory"}),
        supports=_supports_np,
        run=_run_np,
        auto_priority=20,
    )
)
register_backend(
    PartitionBackend(
        name="np_stream",
        capabilities=frozenset({"streaming", "out-of-core", "direct-to-store"}),
        supports=_supports_np_stream,
        run=_run_np_stream,
        # above np: wins auto for everything chunkable unless auto_eligible
        # hands plain in-RAM arrays back to the np path
        auto_priority=25,
        auto_eligible=_auto_np_stream,
    )
)
register_backend(
    PartitionBackend(
        name="jax",
        capabilities=frozenset({"in-memory", "jit"}),
        supports=_supports_jax,
        run=_run_jax,
        auto_priority=10,
    )
)
register_backend(
    PartitionBackend(
        name="shard_map",
        capabilities=frozenset({"in-memory", "collective", "mesh"}),
        supports=_supports_shard_map,
        run=_run_shard_map,
        auto_priority=40,
    )
)
register_backend(
    PartitionBackend(
        name="pallas",
        capabilities=frozenset({"in-memory", "kernel"}),
        supports=_supports_pallas,
        run=_run_pallas,
        auto_priority=30,
        auto_eligible=_auto_pallas,
    )
)
