"""``RSPDataset`` -- the one-object facade over the RSP pipeline.

The paper's workflow is a single conceptual pipeline: randomize, partition
into RSP blocks (Algorithm 1), store, block-sample (Definition 4), then
estimate (Sec. 8) or ensemble-learn (Sec. 9, Algorithm 2).  This class
exposes that pipeline as chainable methods over one carrier object:

    ds = rsp.partition(data, blocks=64, seed=1, num_classes=2)
    ds.save("/data/corpus.rsp")
    stats = ds.moments(g=5)                       # from per-block sketches
    ens, hist = ds.ensemble(make_logreg(28, 2), eval_x=xe, eval_y=ye, g=5)

Construction dispatches through the backend registry (numpy streaming, jit
jax, shard_map collective, Pallas kernel); the resulting dataset carries its
``RSPSpec``, lazy block access (in-memory or store-backed), and per-block
summary statistics computed once at partition time.

All block movement goes through one ``repro.rsp.engine.BlockExecutor``
(``ds.executor``): a pluggable fetcher (``fetcher="auto" | "memory" |
"store" | "mmap"``) behind a bounded thread-pool prefetch pipeline with an
LRU block cache, so estimation, ensemble learning, similarity probes, and
the training loader share the same fast read path.  Block *selection* is a
pluggable ``SamplingPolicy`` (``policy="uniform" | "weighted" |
"stratified"``): the non-uniform policies use the partition-time sketches to
bias selection and expose Horvitz-Thompson weights that keep the moment
estimates unbiased.
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.core.ensemble import (
    BaseLearner,
    Ensemble,
    EnsembleHistory,
    asymptotic_ensemble_learn,
)
from repro.core.estimators import BlockLevelEstimator, MomentStats, streaming_estimate
from repro.core.registry import RSPStore
from repro.core.sampler import (
    BlockSampler,
    HostAssignment,
    SamplingPolicy,
    deal_blocks,
    make_policy,
)
from repro.core.similarity import ks_statistic, max_label_divergence, mmd_block_vs_data
from repro.core.types import RSPSpec
from repro.rsp.backends import AUTO, PartitionRequest, run_partition
from repro.rsp.ingest import resolve_stream_source
from repro.rsp.engine import (
    BlockExecutor,
    BlockFetcher,
    MemoryFetcher,
    MmapFetcher,
    StoreFetcher,
    as_fetcher,
)
from repro.rsp.sketch import SketchSuite, load_summaries, sketch_schema_descriptor
from repro.rsp.summaries import (
    BlockSummary,
    combine_summaries,
    max_divergence_from_summaries,
    summarize_blocks,
)


class RSPDataset:
    """A materialized Random Sample Partition with chainable analysis ops."""

    def __init__(
        self,
        spec: RSPSpec,
        *,
        blocks: np.ndarray | None = None,
        store: RSPStore | None = None,
        backend: str = "np",
        summaries: list[SketchSuite] | list[BlockSummary] | None = None,
        num_classes: int | None = None,
        label_column: int = -1,
        fetcher: str | BlockFetcher = "auto",
        prefetch: int = 4,
        cache_blocks: int = 8,
    ):
        if blocks is None and store is None:
            raise ValueError("provide in-memory blocks and/or a store")
        self.spec = spec
        self.backend = backend
        self.num_classes = num_classes
        self.label_column = label_column
        self._blocks = None if blocks is None else np.asarray(blocks)
        self._store = store
        self._summaries = summaries
        self._fetcher_mode = fetcher
        self._prefetch = prefetch
        self._cache_blocks = cache_blocks
        self._executor: BlockExecutor | None = None

    # ------------------------------------------------------------------
    # Construction: Algorithm 1 through the backend registry
    # ------------------------------------------------------------------
    @classmethod
    def partition(
        cls,
        data: Any,
        blocks: int,
        *,
        original_blocks: int | None = None,
        seed: int = 0,
        backend: str = AUTO,
        mesh: jax.sharding.Mesh | None = None,
        mesh_axis: str = "data",
        permute_assignment: bool = True,
        num_classes: int | None = None,
        label_column: int = -1,
        summaries: bool = True,
        out: str | None = None,
        chunk_records: int | None = None,
    ) -> "RSPDataset":
        """Partition ``data`` [N, ...] into an RSP of ``blocks`` blocks.

        ``data`` may be an in-memory array, or any streaming source
        ``repro.rsp.ingest.as_chunk_source`` adapts (a ``.npy`` path read
        via mmap, a directory of chunk files, a record-batch
        ``ChunkSource``, a memmap) -- streaming sources never load the
        corpus whole.  ``backend="auto"`` picks shard_map when ``mesh`` is
        supplied, the Pallas kernel when its shape constraints hold on a
        TPU host, the out-of-core ``np_stream`` scatter for streaming
        sources and whenever ``out=`` is given, and the in-memory numpy
        path otherwise; pass an explicit name to force one.

        ``out`` writes the partition directly into a store at that path:
        the streaming backend scatters chunk slices straight to their
        block-file offsets (peak memory O(chunk), the corpus never
        materializes) and the returned dataset is store-backed; in-memory
        backends save their result there.  ``num_classes`` marks column
        ``label_column`` as a class label so label histograms join the
        per-block summaries and ``.ensemble`` / ``.label_divergence`` know
        how to split records.
        """
        # memmaps are ndarrays: when an in-memory backend is forced they stay
        # raw (it serves them fine); under auto/np_stream they stream
        src = None
        if not isinstance(data, np.ndarray) or backend in (AUTO, "np_stream"):
            src = resolve_stream_source(data, chunk_records=chunk_records)
        if src is not None:
            data = src
            n = src.num_records
            record_shape = tuple(src.record_shape)
            dtype = str(np.dtype(src.dtype))
        else:
            n = np.shape(data)[0]
            record_shape = tuple(np.shape(data)[1:])
            dtype = str(np.dtype(getattr(data, "dtype", np.float32)))
        spec = RSPSpec(
            num_records=n,
            num_blocks=blocks,
            num_original_blocks=blocks if original_blocks is None else original_blocks,
            record_shape=record_shape,
            dtype=dtype,
            seed=seed,
        )
        request = PartitionRequest(
            data=data,
            spec=spec,
            mesh=mesh,
            mesh_axis=mesh_axis,
            permute_assignment=permute_assignment,
            out=out,
            with_summaries=summaries,
            num_classes=num_classes,
            label_column=label_column,
            chunk_records=chunk_records,
        )
        result, chosen = run_partition(request, backend=backend)
        if isinstance(result, RSPStore):
            # streaming backend wrote directly to the store; prefer the
            # suites folded during the write (in-memory handoff) over
            # re-parsing the sketch sidecar it just streamed out
            folded = result.last_ingest_summaries
            if folded is None:
                raw = result.summaries()
                folded = None if raw is None else load_summaries(raw)
            return cls(
                spec,
                store=result,
                backend=chosen,
                summaries=folded,
                num_classes=num_classes,
                label_column=label_column,
            )
        ds = cls(
            spec,
            blocks=result,
            backend=chosen,
            num_classes=num_classes,
            label_column=label_column,
        )
        if summaries:
            ds._summaries = ds._compute_summaries()
        if out is not None:
            ds.save(out)
        return ds

    @classmethod
    def from_source(
        cls,
        source: Any,
        blocks: int,
        *,
        out: str | None = None,
        original_blocks: int | None = None,
        seed: int = 0,
        permute_assignment: bool = True,
        num_classes: int | None = None,
        label_column: int = -1,
        summaries: bool = True,
        chunk_records: int | None = None,
    ) -> "RSPDataset":
        """Build an RSP from a chunked source with bounded memory (the
        out-of-core ingest path, forced).  ``source`` is anything
        ``as_chunk_source`` adapts; with ``out`` set the corpus streams
        straight into a stored RSP whose manifest carries the
        partition-time sketches -- peak memory stays O(chunk + write
        buffers) no matter how large the corpus is."""
        return cls.partition(
            source,
            blocks,
            original_blocks=original_blocks,
            seed=seed,
            backend="np_stream",
            permute_assignment=permute_assignment,
            num_classes=num_classes,
            label_column=label_column,
            summaries=summaries,
            out=out,
            chunk_records=chunk_records,
        )

    # ------------------------------------------------------------------
    # Block access: one executor owns all block movement
    # ------------------------------------------------------------------
    @property
    def num_blocks(self) -> int:
        return self.spec.num_blocks

    @property
    def block_size(self) -> int:
        return self.spec.block_size

    def __len__(self) -> int:
        return self.num_blocks

    @property
    def executor(self) -> BlockExecutor:
        """The dataset's :class:`BlockExecutor` (built lazily): prefetch
        pipeline + LRU cache over the configured fetcher."""
        if self._executor is None:
            self._executor = BlockExecutor(
                self._make_fetcher(),
                prefetch=self._prefetch,
                cache_blocks=self._cache_blocks,
            )
        return self._executor

    def _make_fetcher(self) -> BlockFetcher:
        mode = self._fetcher_mode
        if not isinstance(mode, str):
            return as_fetcher(mode)
        if mode == "auto":
            if self._blocks is not None:
                return MemoryFetcher(self._blocks)
            return StoreFetcher(self._store)
        if mode == "memory":
            if self._blocks is None:
                # materialize directly from the store -- self.stacked() would
                # recurse through self.executor, which is being built here
                with BlockExecutor(
                    StoreFetcher(self._store), prefetch=self._prefetch, cache_blocks=0
                ) as loadall:
                    self._blocks = loadall.take(range(self.num_blocks))
            return MemoryFetcher(self._blocks)
        if mode in ("store", "mmap"):
            if self._store is None:
                raise ValueError(f"fetcher={mode!r} needs a store-backed dataset")
            return StoreFetcher(self._store) if mode == "store" else MmapFetcher(self._store)
        raise ValueError(
            f"unknown fetcher {mode!r} (auto | memory | store | mmap | BlockFetcher)"
        )

    def close(self) -> None:
        """Release the executor's worker threads (optional; idle otherwise)."""
        if self._executor is not None:
            self._executor.close()
            self._executor = None

    def block(self, block_id: int) -> np.ndarray:
        if not 0 <= block_id < self.num_blocks:
            raise IndexError(f"block {block_id} out of range [0, {self.num_blocks})")
        return self.executor.fetch(block_id)

    def __getitem__(self, block_id: int) -> np.ndarray:
        return self.block(block_id)

    def take(self, block_ids: Sequence[int]) -> np.ndarray:
        """Stack the given blocks -> [g, n, ...] (prefetched)."""
        return self.executor.take(block_ids)

    def stacked(self) -> np.ndarray:
        """All blocks as one [K, n, ...] array (loads everything)."""
        if self._blocks is None:
            self._blocks = self.executor.take(range(self.num_blocks))
        return self._blocks

    # ------------------------------------------------------------------
    # Per-block summary statistics (partition-time sketches)
    # ------------------------------------------------------------------
    @property
    def summaries(self) -> list[SketchSuite]:
        if self._summaries is None:
            self._summaries = self._compute_summaries()
        return self._summaries

    @property
    def has_summaries(self) -> bool:
        """Whether partition-time sketches are already materialized (without
        triggering the full-corpus pass that computes them)."""
        return self._summaries is not None

    def _compute_summaries(self, counter=None) -> list[SketchSuite]:
        label_column = self.label_column if self.num_classes is not None else None
        return summarize_blocks(
            self.executor.map_blocks(None, range(self.num_blocks), counter=counter),
            label_column=label_column,
            num_classes=self.num_classes,
        )

    # ------------------------------------------------------------------
    # Storage (re-plumbs RSPStore)
    # ------------------------------------------------------------------
    def save(self, path: str) -> "RSPDataset":
        """Materialize to ``path`` (blocks + manifest with sketches); chainable."""
        store = RSPStore(path)
        summaries = self.summaries
        schema = (
            sketch_schema_descriptor(summaries)
            if summaries and isinstance(summaries[0], SketchSuite)
            else None
        )
        store.write_partition(
            self.stacked(),
            self.spec,
            summaries=summaries,
            meta={
                "backend": self.backend,
                "num_classes": self.num_classes,
                "label_column": self.label_column,
            },
            sketch_schema=schema,
        )
        self._store = store
        return self

    @classmethod
    def open(
        cls,
        path: str,
        *,
        fetcher: str | BlockFetcher = "auto",
        prefetch: int = 4,
        cache_blocks: int = 8,
    ) -> "RSPDataset":
        """Open a stored RSP; blocks load lazily, sketches from the manifest.

        ``fetcher="mmap"`` memory-maps blocks instead of materializing them
        (for corpora larger than RAM); ``prefetch``/``cache_blocks`` size the
        executor's pipeline.
        """
        store = RSPStore(path)
        meta = store.meta()
        raw = store.summaries()
        return cls(
            store.spec(),
            store=store,
            backend=str(meta.get("backend", "np")),
            summaries=None if raw is None else load_summaries(raw),
            num_classes=meta.get("num_classes"),
            label_column=int(meta.get("label_column", -1)),
            fetcher=fetcher,
            prefetch=prefetch,
            cache_blocks=cache_blocks,
        )

    @property
    def store(self) -> RSPStore | None:
        return self._store

    # ------------------------------------------------------------------
    # Block-level sampling (Definition 4 + sketch-guided policies)
    # ------------------------------------------------------------------
    def sampler(self, seed: int = 0) -> BlockSampler:
        return BlockSampler(self.num_blocks, seed=seed)

    def policy(
        self, policy: str | SamplingPolicy = "uniform", *, seed: int = 0, **kwargs
    ) -> SamplingPolicy:
        """Resolve a block-selection policy over this dataset.  ``weighted``,
        ``stratified`` and ``query_aware`` read the partition-time sketches;
        ``query_aware`` additionally accepts the query context
        (``predicates=``, ``feature=``, ``by_label=``) it scores blocks
        against."""
        needs_sketches = isinstance(policy, str) and policy != "uniform"
        return make_policy(
            policy,
            self.num_blocks,
            seed=seed,
            summaries=self.summaries if needs_sketches else None,
            **kwargs,
        )

    def sample(
        self, g: int, *, seed: int = 0, policy: str | SamplingPolicy = "uniform"
    ) -> list[int]:
        """One block-level sample: g block ids (without replacement for
        ``uniform``; PPS-with-replacement for ``weighted``; proportional
        strata draws for ``stratified``)."""
        return self.policy(policy, seed=seed).sample(g)

    def deal(self, num_hosts: int, *, seed: int = 0, epoch: int = 0) -> HostAssignment:
        """Deal block ids across hosts for one epoch (multi-host training)."""
        return deal_blocks(self.num_blocks, num_hosts, seed=seed, epoch=epoch)

    # ------------------------------------------------------------------
    # Estimation (Sec. 8)
    # ------------------------------------------------------------------
    def moments(
        self,
        g: int | None = None,
        *,
        seed: int = 0,
        ids: Sequence[int] | None = None,
        policy: str | SamplingPolicy = "uniform",
    ) -> MomentStats:
        """Corpus moments estimated from a block-level sample of ``g`` blocks
        (``ids`` if given, all blocks when both are None) -- combined from the
        partition-time sketches, so no block data is read.  A non-uniform
        ``policy`` selects blocks by their sketches and Horvitz-Thompson
        reweights the combine, so the estimate stays unbiased."""
        summaries = self.summaries
        non_uniform = isinstance(policy, SamplingPolicy) or policy != "uniform"
        if ids is not None and non_uniform:
            raise ValueError(
                "pass either ids or a non-uniform policy, not both: explicit ids"
                " have no selection probabilities to HT-reweight by"
            )
        if non_uniform:
            if g is None:
                raise ValueError("non-uniform policies need g")
            pol = self.policy(policy, seed=seed)
            ids = pol.sample(g)
            return combine_summaries(
                [summaries[k] for k in ids],
                weights=pol.weights(ids),
                total_count=self.spec.num_records,
            )
        if ids is None:
            ids = range(self.num_blocks) if g is None else self.sample(g, seed=seed)
        return combine_summaries([summaries[k] for k in ids])

    def estimator(
        self,
        g: int | None = None,
        *,
        seed: int = 0,
        ids: Sequence[int] | None = None,
        rel_tol: float | None = None,
    ) -> BlockLevelEstimator:
        """A ``BlockLevelEstimator`` fed through the executor's prefetched
        block stream -- use when the convergence history / plateau detector
        is wanted.  ``rel_tol`` stops the scan at the plateau."""
        if ids is None:
            ids = range(self.num_blocks) if g is None else self.sample(g, seed=seed)
        return streaming_estimate(self.executor, ids, rel_tol=rel_tol)

    def estimate(
        self,
        fn: Callable[[np.ndarray], Any],
        g: int | None = None,
        *,
        seed: int = 0,
        policy: str | SamplingPolicy = "uniform",
    ) -> Any:
        """Block-level estimate of an arbitrary statistic: mean of ``fn(block)``
        over a block-level sample (each block is a random sample, so the
        average is an unbiased estimate of the corpus statistic).  ``fn`` runs
        on the executor's workers, overlapping with the fetch of later blocks.
        Non-uniform policies contribute self-normalized HT weights."""
        pol = None
        if isinstance(policy, SamplingPolicy) or policy != "uniform":
            if g is None:
                raise ValueError("non-uniform policies need g")
            pol = self.policy(policy, seed=seed)
            ids = pol.sample(g)
        else:
            ids = (
                list(range(self.num_blocks)) if g is None else self.sample(g, seed=seed)
            )
        values = [np.asarray(v) for v in self.executor.map_blocks(fn, ids)]
        weights = pol.weights(ids) if pol is not None else None
        return np.average(values, axis=0, weights=weights)

    # ------------------------------------------------------------------
    # Declarative queries (progressive, anytime CIs)
    # ------------------------------------------------------------------
    def query(self, aggregates="mean", **kwargs):
        """Answer a declarative aggregate query with anytime confidence
        intervals, reading as few blocks as the stopping rule allows.

        ``aggregates`` is a ``Query``, an aggregate spec (``"mean"``,
        ``"p95"``, ``Aggregate("quantile", q=0.5, by_label=True)``, ...), or
        a sequence of specs; stopping-rule kwargs (``target_rel_err=``,
        ``confidence=``, ``max_blocks=``, ``policy=``, ...) are forwarded to
        :class:`repro.rsp.query.Query`.  ``where=`` restricts the query to
        rows passing column predicates (``where="c3 > 0.5"``) and
        ``columns=`` projects the answer onto a feature subset -- both run
        through the plan-compiled fused kernels, one filtered pass per
        block.  Moment/label-count-only queries *without* predicates are
        answered from the partition-time sketches with zero block reads;
        everything else streams blocks through the executor and stops early
        once every CI is tighter than ``target_rel_err``.  Returns the final
        :class:`repro.rsp.query.QueryResult`.
        """
        from repro.rsp.query import QueryExecutor, as_query

        return QueryExecutor(self, as_query(aggregates, **kwargs)).run()

    def query_stream(self, aggregates="mean", **kwargs):
        """Progressive variant of :meth:`query`: yields one anytime
        ``QueryResult`` per block read (a single result for sketch-only
        queries), so callers can watch the intervals narrow."""
        from repro.rsp.query import QueryExecutor, as_query

        return QueryExecutor(self, as_query(aggregates, **kwargs)).stream()

    def distribute(self, transport, *, ownership=None, **kwargs):
        """This dataset as one host of a mesh: a
        :class:`~repro.distributed.DistributedDataset` whose queries fan
        block work out over ``transport`` (a
        :class:`~repro.distributed.mesh.Transport`), with this host reading
        only its owned blocks.  ``ownership`` defaults to the deterministic
        deal of ``num_blocks`` over ``transport.num_hosts`` seeded by the
        partition seed; ``straggler_grace=`` / ``poll_interval=`` forward to
        ``DistributedDataset``.  Requires materialized partition-time
        sketches (open a store that carries them, or partition with
        ``summaries=True``)."""
        from repro.distributed.rsp import DistributedDataset

        return DistributedDataset(self, transport, ownership=ownership, **kwargs)

    def serve(self, **kwargs):
        """A concurrent multi-tenant :class:`~repro.serve.QueryService` over
        this dataset: many simultaneous queries share this dataset's
        ``BlockExecutor`` block cache, an admission controller bounds
        in-flight block-I/O demand, a deadline-aware scheduler interleaves
        one-block progressive steps across tenants, and every query can
        return an anytime result when its deadline fires.  Keyword arguments
        (``capacity=``, ``max_queue=``, ``workers=``, ``seed=``,
        ``default_deadline_ms=``) forward to ``QueryService``.  Use as a
        context manager or call ``close()`` to release the worker threads.
        """
        from repro.serve.query_service import QueryService

        return QueryService(self, **kwargs)

    # ------------------------------------------------------------------
    # Ensemble learning (Sec. 9, Algorithm 2)
    # ------------------------------------------------------------------
    def ensemble(
        self,
        learner: BaseLearner,
        *,
        eval_x: Any,
        eval_y: Any,
        g: int = 5,
        batches: int | None = None,
        seed: int = 0,
        improvement_tol: float = 1e-3,
        patience: int = 2,
    ) -> tuple[Ensemble, EnsembleHistory]:
        """Asymptotic ensemble learning over block-level samples.  Records
        are split into features/label via ``label_column`` (set
        ``num_classes`` at partition time).  Blocks stream through the
        executor per batch, so a store-backed dataset only reads the sampled
        blocks -- prefetched while the previous batch trains."""
        import jax.numpy as jnp

        if self.num_classes is None:
            raise ValueError("ensemble needs num_classes (set it at partition time)")

        def fetch(ids):
            xs, ys = self._split_xy(self.executor.take(ids))
            return jnp.asarray(xs), jnp.asarray(ys)

        return asymptotic_ensemble_learn(
            learner=learner,
            eval_x=jnp.asarray(eval_x),
            eval_y=jnp.asarray(eval_y),
            g=g,
            seed=seed,
            improvement_tol=improvement_tol,
            patience=patience,
            max_batches=batches,
            num_blocks=self.num_blocks,
            fetch_blocks=fetch,
        )

    def _split_xy(self, stacked: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        col = self.label_column % stacked.shape[-1]
        ys = stacked[..., col].astype(np.int32)
        xs = np.delete(stacked, col, axis=-1)
        return xs, ys

    # ------------------------------------------------------------------
    # Similarity / diagnostics (Sec. 7)
    # ------------------------------------------------------------------
    def similarity(
        self,
        block_id: int,
        *,
        metric: str = "mmd",
        feature: int = 0,
        max_points: int = 1024,
        seed: int = 0,
    ) -> float:
        """How close block ``block_id`` is to the full corpus.

        ``metric="mmd"``: unbiased MMD^2 (RBF, median-heuristic bandwidth);
        ``metric="ks"``: two-sample KS statistic on one feature column;
        ``metric="labels"``: L-inf label-distribution distance (needs
        ``num_classes``).

        The corpus reference is the full in-memory partition when available
        (the probed block is legitimately a 1/K fraction of it); for
        store-backed datasets it is a bounded block-level sample (valid by
        Lemma 1 -- each block is a random sample) that *excludes* the probed
        block, since a small reference that contained the probe would
        overweight it far beyond its 1/K corpus share and shrink every
        distance.
        """
        block = self.block(block_id)
        corpus = self._corpus_reference(
            max(max_points, 4096), seed=seed, exclude=block_id
        )
        if metric == "mmd":
            return mmd_block_vs_data(block, corpus, max_points=max_points, seed=seed)
        if metric == "ks":
            return ks_statistic(block[:, feature], corpus[:, feature])
        if metric == "labels":
            if self.num_classes is None:
                raise ValueError("metric='labels' needs num_classes")
            col = self.label_column
            return max_label_divergence(block[:, col], corpus[:, col], self.num_classes)
        raise ValueError(f"unknown metric {metric!r} (mmd | ks | labels)")

    def _corpus_reference(
        self, max_records: int, *, seed: int = 0, exclude: int | None = None
    ) -> np.ndarray:
        """Flat [M, ...] corpus sample for similarity comparisons: the whole
        partition when in memory, else >= ``max_records`` records from a
        block-level sample (no full-corpus load).  ``exclude`` keeps a probed
        block out of its own reference set (self-inclusion shrinks any
        block-vs-corpus distance)."""
        if self._blocks is not None:
            return self._blocks.reshape(-1, *self.spec.record_shape)
        g = min(self.num_blocks, max(1, -(-max_records // self.block_size)))
        request = min(self.num_blocks, g + (1 if exclude is not None else 0))
        ids = self.sample(request, seed=seed)
        if exclude is not None:
            ids = [i for i in ids if i != exclude][:g]
            if not ids:
                # single-block store: the probe IS the corpus (degenerate)
                ids = [exclude]
        return self.executor.take(ids).reshape(-1, *self.spec.record_shape)

    def label_divergence(self) -> float:
        """Worst block-vs-corpus label L-inf distance, from the sketches alone."""
        return max_divergence_from_summaries(self.summaries)

    # ------------------------------------------------------------------
    # Training pipeline
    # ------------------------------------------------------------------
    def loader(
        self,
        batch_size: int,
        *,
        seed: int = 0,
        policy: str | SamplingPolicy = "uniform",
        prefetch: int = 2,
        **kwargs,
    ):
        """An ``RSPLoader`` over this dataset (block-level sampled batches,
        prefetched through the engine; ``policy`` selects blocks)."""
        from repro.data.loader import BlockSource, RSPLoader

        # the loader gets the dataset's configured fetcher (memory / store /
        # mmap / custom) but its own cache-free executor: blocks stream in
        # one hop, not through this dataset's executor and LRU cache (which
        # would retain single-use training blocks)
        return RSPLoader(
            BlockSource(dataset=self),
            batch_size=batch_size,
            seed=seed,
            policy=policy,
            prefetch=prefetch,
            fetcher=self._make_fetcher(),
            **kwargs,
        )

    def __repr__(self) -> str:
        src = "memory" if self._blocks is not None else f"store:{self._store.root}"
        return (
            f"RSPDataset(K={self.num_blocks}, n={self.block_size}, "
            f"record_shape={self.spec.record_shape}, backend={self.backend!r}, "
            f"source={src})"
        )
