"""``repro.rsp.engine`` -- the streaming block-execution engine.

Every block-consuming operation in the repo (statistics estimation, ensemble
learning, similarity probes, the training loader) reduces to the same shape
of work: *move a sequence of RSP blocks from a source to a consumer function
as fast as the storage allows*.  This module owns that movement so the
consumers don't have to:

``BlockFetcher``
    The pluggable source protocol -- ``num_blocks`` plus ``fetch(block_id)``.
    Three implementations ship: :class:`MemoryFetcher` (stacked in-RAM
    array, fetch is a view), :class:`StoreFetcher` (materializing
    ``RSPStore`` reads), and :class:`MmapFetcher` (``np.load(mmap_mode="r")``
    -- pages stream from disk on touch, so corpora larger than RAM work).
    :func:`as_fetcher` adapts arrays, stores, datasets, and loader sources.

``BlockExecutor``
    Wraps a fetcher with a bounded thread-pool prefetch pipeline
    (``prefetch`` blocks in flight on ``workers`` threads) and a small LRU
    block cache.  Worker exceptions propagate to the consumer at the point
    of consumption -- nothing dies silently.  Two primitives cover every
    consumer:

    * ``map_blocks(fn, ids)`` -- yield ``fn(block)`` for each id *in
      order*, while the next ``prefetch`` blocks load in the background.
      With ``fn=None`` it yields the raw blocks.
    * ``stream_batches(ids, batch_size, ...)`` -- assemble fixed-size
      record batches from the concatenated records of the id stream,
      again with prefetch underneath.

With ``prefetch=0`` the executor degrades to a plain synchronous loop (no
threads), which is the reference behavior the pipeline is tested against.
``executor.stats()`` exposes hit/miss/eviction counters and the total
blocks-fetched count, so consumers (e.g. ``repro.rsp.query``) can report how
many blocks an answer actually touched.  ``benchmarks/engine_bench.py``
measures the three fetch paths.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Iterable, Iterator, Protocol, Sequence, runtime_checkable

import numpy as np

from repro import obs
from repro.core.registry import RSPStore
from repro.obs.trace import SpanContext


@dataclasses.dataclass(frozen=True)
class ExecutorStats:
    """Counters for one :class:`BlockExecutor`'s block movement.

    ``hits`` / ``misses`` are LRU-cache outcomes (with the cache disabled
    every access is a miss); ``evictions`` counts LRU drops;
    ``blocks_fetched`` is the total number of blocks pulled from the
    underlying fetcher -- the honest I/O count behind a query's "answered
    from N of K blocks" claim.  Snapshots subtract, so a consumer can report
    only its own window: ``after - before``.
    """

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    rows_fetched: int = 0  # rows pulled from the fetcher (misses only)

    @property
    def blocks_fetched(self) -> int:
        return self.misses

    @property
    def accesses(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.accesses if self.accesses else 0.0

    def __sub__(self, other: "ExecutorStats") -> "ExecutorStats":
        return ExecutorStats(
            hits=self.hits - other.hits,
            misses=self.misses - other.misses,
            evictions=self.evictions - other.evictions,
            rows_fetched=self.rows_fetched - other.rows_fetched,
        )

    def __add__(self, other: "ExecutorStats") -> "ExecutorStats":
        return ExecutorStats(
            hits=self.hits + other.hits,
            misses=self.misses + other.misses,
            evictions=self.evictions + other.evictions,
            rows_fetched=self.rows_fetched + other.rows_fetched,
        )


class CallerStats:
    """A per-caller block-access counter.

    Snapshot deltas of the executor-wide :meth:`BlockExecutor.stats` are racy
    the moment two consumers interleave on one executor: each would claim the
    other's I/O.  Instead a caller passes its own ``CallerStats`` into
    ``fetch`` / ``fetch_async`` / ``map_blocks`` and every access is counted
    on *both* the executor's global counters and the caller's -- so per-caller
    counts always sum to the executor total, no matter how requests
    interleave.  Thread-safe; ``stats()`` returns an immutable snapshot.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._hits = 0
        self._misses = 0
        self._rows = 0
        self._fetch_s = 0.0

    def _hit(self) -> None:
        with self._lock:
            self._hits += 1

    def _miss(self, rows: int = 0, seconds: float = 0.0) -> None:
        with self._lock:
            self._misses += 1
            self._rows += rows
            self._fetch_s += seconds

    def stats(self) -> ExecutorStats:
        with self._lock:
            return ExecutorStats(
                hits=self._hits, misses=self._misses, rows_fetched=self._rows
            )

    def fetch_seconds(self) -> float:
        """Cumulative wall-clock seconds this caller's misses spent inside
        ``fetcher.fetch`` -- the I/O cost behind the counts in :meth:`stats`
        (kept off :class:`ExecutorStats` so its integer conservation
        arithmetic stays exact)."""
        with self._lock:
            return self._fetch_s


# ---------------------------------------------------------------------------
# Fetchers
# ---------------------------------------------------------------------------

@runtime_checkable
class BlockFetcher(Protocol):
    """Anything that can serve RSP blocks by id."""

    @property
    def num_blocks(self) -> int: ...

    def fetch(self, block_id: int) -> np.ndarray: ...


class MemoryFetcher:
    """Blocks already stacked in memory -- ``fetch`` returns a view."""

    def __init__(self, blocks: np.ndarray):
        self._blocks = np.asarray(blocks)

    @property
    def num_blocks(self) -> int:
        return self._blocks.shape[0]

    def fetch(self, block_id: int) -> np.ndarray:
        return self._blocks[block_id]


class StoreFetcher:
    """Materializing ``RSPStore`` reads: each fetch copies the block into RAM
    (the right default when blocks are consumed more than once)."""

    def __init__(self, store: RSPStore, *, verify: bool = False):
        self.store = store
        self.verify = verify

    @property
    def num_blocks(self) -> int:
        return self.store.num_blocks()

    def fetch(self, block_id: int) -> np.ndarray:
        return np.asarray(self.store.load_block(block_id, mmap=False, verify=self.verify))


class MmapFetcher:
    """Memory-mapped ``RSPStore`` reads for corpora larger than RAM: blocks
    come back as ``np.memmap`` views and pages stream from disk on touch."""

    def __init__(self, store: RSPStore):
        self.store = store

    @property
    def num_blocks(self) -> int:
        return self.store.num_blocks()

    def fetch(self, block_id: int) -> np.ndarray:
        return self.store.load_block(block_id, mmap=True)


class _AdapterFetcher:
    """Wraps any object exposing ``num_blocks`` and a block-loading method."""

    def __init__(self, obj: Any, load: Callable[[int], np.ndarray]):
        self._obj = obj
        self._load = load

    @property
    def num_blocks(self) -> int:
        n = self._obj.num_blocks
        return n() if callable(n) else n

    def fetch(self, block_id: int) -> np.ndarray:
        return self._load(block_id)


class ScopedFetcher:
    """A fetcher restricted to an allowed block set (per-host ownership).

    A distributed host must only ever touch blocks it owns (plus blocks it
    has legitimately stolen from a straggler) -- anything else means the
    scheduler leaked work and the "each host streams only its local blocks"
    invariant is broken.  ``ScopedFetcher`` turns that invariant into a hard
    failure: fetching outside the allowed set raises ``PermissionError``.
    ``allow`` widens the scope when leases are stolen; ``replace`` resets it
    after an elastic re-deal.
    """

    def __init__(self, inner: BlockFetcher, allowed: Iterable[int]):
        self._inner = inner
        self._allowed = set(int(b) for b in allowed)

    @property
    def num_blocks(self) -> int:
        return self._inner.num_blocks

    @property
    def allowed(self) -> frozenset[int]:
        return frozenset(self._allowed)

    def allow(self, block_ids: Iterable[int]) -> None:
        """Widen the scope (stolen straggler leases)."""
        self._allowed.update(int(b) for b in block_ids)

    def replace(self, block_ids: Iterable[int]) -> None:
        """Reset the scope (elastic re-deal changed this host's ownership)."""
        self._allowed = set(int(b) for b in block_ids)

    def fetch(self, block_id: int) -> np.ndarray:
        if int(block_id) not in self._allowed:
            raise PermissionError(
                f"block {block_id} is outside this host's owned/stolen scope"
            )
        return self._inner.fetch(block_id)


def as_fetcher(source: Any, *, mode: str = "auto") -> BlockFetcher:
    """Adapt ``source`` into a :class:`BlockFetcher`.

    Accepts an existing fetcher, a stacked ``np.ndarray``, an ``RSPStore``
    (``mode="store"`` materializes, ``"mmap"`` memory-maps, ``"auto"`` ==
    ``"store"``), or any object with ``num_blocks`` and ``block``/``load``.
    """
    if isinstance(
        source, (MemoryFetcher, StoreFetcher, MmapFetcher, _AdapterFetcher, ScopedFetcher)
    ):
        return source
    if isinstance(source, np.ndarray):
        return MemoryFetcher(source)
    if isinstance(source, RSPStore):
        if mode == "mmap":
            return MmapFetcher(source)
        if mode in ("auto", "store"):
            return StoreFetcher(source)
        raise ValueError(f"unknown fetcher mode {mode!r} for a store (auto | store | mmap)")
    for name in ("block", "load", "fetch"):
        load = getattr(source, name, None)
        if callable(load) and hasattr(source, "num_blocks"):
            return _AdapterFetcher(source, load)
    raise TypeError(f"cannot build a BlockFetcher from {type(source).__name__}")


_NULL_CM = contextlib.nullcontext()  # stateless; safe to share


def _fetcher_kind(fetcher: Any) -> str:
    """Telemetry label for the fetch path: memory | store | mmap | other."""
    if isinstance(fetcher, MemoryFetcher):
        return "memory"
    if isinstance(fetcher, StoreFetcher):
        return "store"
    if isinstance(fetcher, MmapFetcher):
        return "mmap"
    return "other"


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------

class BlockExecutor:
    """Prefetching block pipeline over a :class:`BlockFetcher`.

    ``prefetch`` blocks are kept in flight on a bounded thread pool while the
    consumer works; ``cache_blocks`` most-recently-used blocks are retained so
    repeated probes (similarity references, overlapping samples) skip the
    fetch entirely.  ``prefetch=0`` disables threading: every primitive then
    runs as a plain synchronous loop with identical results.

    Exceptions raised by the fetcher (or by a mapped ``fn``) inside a worker
    thread are re-raised in the consumer when the failing block's result is
    consumed.
    """

    def __init__(
        self,
        fetcher: BlockFetcher | Any,
        *,
        prefetch: int = 4,
        cache_blocks: int = 8,
        workers: int | None = None,
    ):
        self.fetcher = as_fetcher(fetcher)
        self.prefetch = max(0, int(prefetch))
        self._kind = _fetcher_kind(self.fetcher)
        self._obs: tuple[Any, dict] | None = None  # (registry, handles) cache
        self._cache: collections.OrderedDict[int, np.ndarray] = collections.OrderedDict()
        self._cache_cap = max(0, int(cache_blocks))
        self._cache_lock = threading.Lock()
        self._inflight: dict[int, threading.Event] = {}
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._rows_fetched = 0
        if self.prefetch > 0:
            n = workers if workers is not None else min(self.prefetch, 8)
            self._pool: ThreadPoolExecutor | None = ThreadPoolExecutor(
                max_workers=max(1, n), thread_name_prefix="rsp-engine"
            )
        else:
            self._pool = None

    def _m(self) -> dict:
        """Lazy per-executor metric handles against the *current* global
        registry (``obs.reset()`` swaps the registry, so re-resolve when the
        identity changes).  Call only under ``obs.enabled()``."""
        reg = obs.get_registry()
        cached = self._obs
        if cached is None or cached[0] is not reg:
            k = self._kind
            handles = {
                "hit": reg.counter(
                    "rsp_engine_fetch_total", "block accesses", kind=k, outcome="hit"),
                "miss": reg.counter(
                    "rsp_engine_fetch_total", "block accesses", kind=k, outcome="miss"),
                "fetch_s": reg.histogram(
                    "rsp_engine_fetch_seconds", "fetcher.fetch latency", kind=k),
                "flight_s": reg.histogram(
                    "rsp_engine_singleflight_wait_seconds",
                    "time followers wait on the single-flight leader", kind=k),
                "queue_s": reg.histogram(
                    "rsp_engine_queue_wait_seconds",
                    "submit-to-start wait on the prefetch pool", kind=k),
                "rows": reg.counter(
                    "rsp_engine_rows_fetched_total", "rows pulled from the fetcher", kind=k),
            }
            self._obs = cached = (reg, handles)
        return cached[1]

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None

    def __enter__(self) -> "BlockExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- single-block access ----------------------------------------------
    @property
    def num_blocks(self) -> int:
        return self.fetcher.num_blocks

    def fetch(self, block_id: int, *, counter: CallerStats | None = None) -> np.ndarray:
        """Cache-aware synchronous fetch of one block.  Returned arrays are
        marked read-only: blocks are shared (between the cache and every
        consumer), so an in-place write would silently corrupt later reads --
        copy first to mutate.

        Concurrent callers asking for the same uncached block are
        single-flighted: one fetches, the rest wait and take the cache hit,
        so contention never multiplies the I/O (cache-disabled executors skip
        this -- there is nowhere to share the result from).  ``counter``
        attributes the access to one caller (see :class:`CallerStats`).
        """
        telemetry = obs.enabled()
        while True:
            with self._cache_lock:
                if block_id in self._cache:
                    self._cache.move_to_end(block_id)
                    self._hits += 1
                    if counter is not None:
                        counter._hit()
                    block = self._cache[block_id]
                    if telemetry:
                        self._m()["hit"].inc()
                    return block
                event = self._inflight.get(block_id) if self._cache_cap > 0 else None
                if event is None:
                    if self._cache_cap > 0:
                        self._inflight[block_id] = event = threading.Event()
                    break  # this caller leads the fetch
            # another caller is already fetching this block -- wait, then
            # re-check the cache (a failed or instantly-evicted leader makes
            # this caller lead the retry)
            if telemetry:
                t0 = time.perf_counter()
                event.wait()
                self._m()["flight_s"].observe(time.perf_counter() - t0)
            else:
                event.wait()
        try:
            t0 = time.perf_counter()
            block = self.fetcher.fetch(block_id)
            fetch_s = time.perf_counter() - t0
            if isinstance(block, np.ndarray):
                block.setflags(write=False)
            rows = int(np.shape(block)[0]) if np.ndim(block) else 0
            with self._cache_lock:
                self._misses += 1
                self._rows_fetched += rows
                if counter is not None:
                    counter._miss(rows, fetch_s)
                if self._cache_cap > 0:
                    self._cache[block_id] = block
                    self._cache.move_to_end(block_id)
                    while len(self._cache) > self._cache_cap:
                        self._cache.popitem(last=False)
                        self._evictions += 1
            if telemetry:
                m = self._m()
                m["miss"].inc()
                m["fetch_s"].observe(fetch_s)
                m["rows"].inc(rows)
            return block
        finally:
            if event is not None:
                with self._cache_lock:
                    self._inflight.pop(block_id, None)
                event.set()

    def stats(self) -> ExecutorStats:
        """Snapshot of the hit/miss/eviction counters (see
        :class:`ExecutorStats`); subtract two snapshots to meter one
        consumer's window."""
        with self._cache_lock:
            return ExecutorStats(
                hits=self._hits,
                misses=self._misses,
                evictions=self._evictions,
                rows_fetched=self._rows_fetched,
            )

    def reset_stats(self) -> None:
        with self._cache_lock:
            self._hits = self._misses = self._evictions = self._rows_fetched = 0

    def fetch_async(
        self,
        block_id: int,
        fn: Callable[[np.ndarray], Any] | None = None,
        *,
        counter: CallerStats | None = None,
        trace: SpanContext | None = None,
    ) -> Future:
        """Start fetching ``block_id`` (and applying ``fn``) on a worker.

        Returns a future; without a pool (``prefetch=0``) the work runs
        immediately on the caller's thread and the future is already done.
        Either way, errors surface on ``.result()``.  ``trace`` parents the
        worker-side span under the submitting caller's span (explicitly --
        context vars do not follow pool threads).
        """
        submitted = time.perf_counter() if obs.enabled() else 0.0
        if self._pool is None:
            fut: Future = Future()
            try:
                fut.set_result(self._task(block_id, fn, counter, trace, submitted))
            except BaseException as e:  # noqa: BLE001 -- mirror executor semantics
                fut.set_exception(e)
            return fut
        return self._pool.submit(self._task, block_id, fn, counter, trace, submitted)

    def _task(
        self,
        block_id: int,
        fn: Callable[[np.ndarray], Any] | None,
        counter: CallerStats | None = None,
        trace: SpanContext | None = None,
        submitted: float = 0.0,
    ) -> Any:
        if not obs.enabled():
            block = self.fetch(block_id, counter=counter)
            return fn(block) if fn is not None else block
        if submitted:
            self._m()["queue_s"].observe(time.perf_counter() - submitted)
        with obs.get_tracer().span(
            "engine.fetch", parent=trace, attrs={"block": block_id, "kind": self._kind}
        ) if trace is not None else _NULL_CM:
            block = self.fetch(block_id, counter=counter)
            return fn(block) if fn is not None else block

    # -- primitive 1: ordered map with prefetch ----------------------------
    def map_blocks(
        self,
        fn: Callable[[np.ndarray], Any] | None,
        ids: Iterable[int],
        *,
        with_ids: bool = False,
        counter: CallerStats | None = None,
        trace: SpanContext | None = None,
    ) -> Iterator[Any]:
        """Yield ``fn(block)`` for every id *in order*, prefetching ahead.

        ``fn`` runs on the worker threads (overlapping fetch and transform);
        ``fn=None`` yields the raw blocks.  ``with_ids=True`` yields
        ``(block_id, result)`` pairs instead.  ``counter`` attributes every
        access of this stream to one caller (see :class:`CallerStats`);
        ``trace`` parents worker-side spans under the caller's span.
        """
        it = iter(ids)
        window: collections.deque[tuple[int, Future]] = collections.deque()

        def submit_one() -> None:
            for b in it:
                window.append((b, self.fetch_async(b, fn, counter=counter, trace=trace)))
                return

        try:
            for _ in range(self.prefetch + 1):
                submit_one()
            while window:
                bid, fut = window.popleft()
                result = fut.result()
                submit_one()
                yield (bid, result) if with_ids else result
        finally:
            for _, fut in window:
                fut.cancel()

    def run(self, fn: Callable[[np.ndarray], Any] | None, ids: Sequence[int]) -> list:
        """Materialized :meth:`map_blocks`."""
        return list(self.map_blocks(fn, ids))

    def take(self, ids: Sequence[int]) -> np.ndarray:
        """Stack the given blocks -> [g, n, ...] (prefetched)."""
        return np.stack([np.asarray(b) for b in self.map_blocks(None, ids)])

    # -- primitive 2: record batches from a block-id stream -----------------
    def stream_batches(
        self,
        ids: Iterable[int],
        batch_size: int,
        *,
        prepare: Callable[[int, np.ndarray], np.ndarray] | None = None,
        transform: Callable[[np.ndarray], np.ndarray] | None = None,
        drop_last: bool = True,
    ) -> Iterator[np.ndarray]:
        """Assemble ``batch_size``-record batches from the records of the
        block-id stream ``ids`` (finite or infinite), prefetching blocks
        ahead.  ``prepare(block_id, block)`` runs on the workers (e.g.
        within-block permutation); ``transform`` runs on each built batch.
        """
        if batch_size <= 0:
            raise ValueError("batch_size must be positive")
        it = iter(ids)
        window: collections.deque[Future] = collections.deque()

        def submit_one() -> None:
            for b in it:
                fn = None if prepare is None else (lambda block, _b=b: prepare(_b, block))
                window.append(self.fetch_async(b, fn))
                return

        pending: list[np.ndarray] = []
        have = 0
        try:
            for _ in range(self.prefetch + 1):
                submit_one()
            while window:
                fut = window.popleft()
                arr = np.asarray(fut.result())
                submit_one()
                pending.append(arr)
                have += arr.shape[0]
                while have >= batch_size:
                    batch, pending, have = _assemble(pending, have, batch_size)
                    yield transform(batch) if transform is not None else batch
            if have > 0 and not drop_last:
                batch = np.concatenate(pending, axis=0)
                yield transform(batch) if transform is not None else batch
        finally:
            for fut in window:
                fut.cancel()


def _assemble(
    pending: list[np.ndarray], have: int, batch_size: int
) -> tuple[np.ndarray, list[np.ndarray], int]:
    """Split ``batch_size`` records off the front of ``pending``."""
    out: list[np.ndarray] = []
    need = batch_size
    while need > 0:
        head = pending[0]
        if head.shape[0] <= need:
            out.append(head)
            need -= head.shape[0]
            pending = pending[1:]
        else:
            out.append(head[:need])
            pending = [head[need:]] + pending[1:]
            need = 0
    return np.concatenate(out, axis=0), pending, have - batch_size
