"""Per-block summaries, computed once at partition time.

In the style of partition-selection summary stats (Rong et al., 2020), every
RSP block carries a small sketch suite -- record count, per-feature moments
and extrema, a KLL quantile sketch, a KMV distinct-count sketch, and (for
labelled data) a label histogram -- written alongside the block at
partition/store time.  Downstream consumers then answer questions like
"estimate the corpus mean / median / cardinality from the sketches" or "how
far is block k's label distribution from the corpus" without touching block
data at all: every member sketch merges exactly or within its analytic
error bound (see :mod:`repro.rsp.sketch`).

``summarize_block`` returns a :class:`repro.rsp.sketch.SketchSuite`; the
frozen :class:`BlockSummary` dataclass remains as the v1 manifest container
(old stores deserialize through it) and is attribute-compatible with the
suite, so consumers are agnostic to which one they hold.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from repro.core.estimators import MomentStats
from repro.core.moments import chan_merge
from repro.rsp.sketch import (
    DEFAULT_KLL_K,
    DEFAULT_KMV_K,
    MomentsSketch,
    SketchSuite,
)


@dataclasses.dataclass(frozen=True)
class BlockSummary:
    """Legacy (schema v1) container: moments + extrema (+ label histogram).

    New code receives :class:`repro.rsp.sketch.SketchSuite` from
    ``summarize_block``; this dataclass persists as the v1 wire format and
    the minimal duck-type the consumers rely on."""

    block_id: int
    count: int
    mean: np.ndarray                 # [F] per flattened feature
    m2: np.ndarray                   # [F] sum of squared deviations
    min: np.ndarray                  # [F]
    max: np.ndarray                  # [F]
    label_hist: np.ndarray | None = None   # [num_classes] counts, optional

    @property
    def variance(self) -> np.ndarray:
        return self.m2 / max(self.count - 1.0, 1.0)

    @property
    def std(self) -> np.ndarray:
        return np.sqrt(self.variance)

    @property
    def label_distribution(self) -> np.ndarray:
        if self.label_hist is None:
            raise ValueError(f"block {self.block_id} has no label histogram")
        return self.label_hist / max(self.label_hist.sum(), 1)

    def moments(self) -> MomentStats:
        return MomentStats(
            count=float(self.count),
            mean=self.mean.copy(),
            m2=self.m2.copy(),
            min=self.min.copy(),
            max=self.max.copy(),
        )

    # -- manifest (de)serialization ----------------------------------------
    def to_dict(self) -> dict:
        d = {
            "block_id": self.block_id,
            "count": self.count,
            "mean": self.mean.tolist(),
            "m2": self.m2.tolist(),
            "min": self.min.tolist(),
            "max": self.max.tolist(),
        }
        if self.label_hist is not None:
            d["label_hist"] = self.label_hist.tolist()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "BlockSummary":
        hist = d.get("label_hist")
        return cls(
            block_id=int(d["block_id"]),
            count=int(d["count"]),
            mean=np.asarray(d["mean"], dtype=np.float64),
            m2=np.asarray(d["m2"], dtype=np.float64),
            min=np.asarray(d["min"], dtype=np.float64),
            max=np.asarray(d["max"], dtype=np.float64),
            label_hist=None if hist is None else np.asarray(hist, dtype=np.int64),
        )


def summarize_block(
    block: np.ndarray,
    block_id: int,
    *,
    label_column: int | None = None,
    num_classes: int | None = None,
    kll_k: int = DEFAULT_KLL_K,
    kmv_k: int = DEFAULT_KMV_K,
    seed: int = 0,
    kinds: tuple[str, ...] | list[str] | None = None,
) -> SketchSuite:
    """Compute one block's sketch suite.  ``label_column`` (with
    ``num_classes``) additionally records the label histogram of that column.
    ``kinds`` restricts which sketches are folded (default: the full suite)
    -- e.g. ``("moments",)`` when only exact moments are needed and the
    KLL/KMV folding cost would be waste.

    Moments/extrema come from the fused one-pass block sketch
    (``repro.kernels.block_sketch``) -- the same primitive the query layer
    folds at read time -- wrapped unmodified into the suite's ``moments``
    member; the richer members (KLL quantiles, KMV distinct counts) fold the
    same rows on the host."""
    from repro.kernels.block_sketch import block_sketch_ref

    x = np.asarray(block, dtype=np.float64).reshape(block.shape[0], -1)
    sk = block_sketch_ref(x)
    suite = SketchSuite.create(
        block_id,
        label_column=label_column,
        num_classes=num_classes,
        kll_k=kll_k,
        kmv_k=kmv_k,
        seed=seed,
        kinds=kinds,
    )
    suite.sketches["moments"] = MomentsSketch.from_block_sketch(sk)
    for kind, member in suite.sketches.items():
        if kind != "moments":
            member.update(x)
    return suite


def summarize_blocks(
    blocks: Iterable[np.ndarray],
    *,
    label_column: int | None = None,
    num_classes: int | None = None,
    **kwargs,
) -> list[SketchSuite]:
    return [
        summarize_block(
            b, k, label_column=label_column, num_classes=num_classes, **kwargs
        )
        for k, b in enumerate(blocks)
    ]


def combine_summaries(
    summaries: Sequence,
    *,
    weights: Sequence[float] | np.ndarray | None = None,
    total_count: int | None = None,
) -> MomentStats:
    """Corpus-level moments from block sketches alone (no data reads).

    Accepts any mix of :class:`BlockSummary` and
    :class:`~repro.rsp.sketch.SketchSuite` (they share the moment surface).
    Without ``weights`` this is the exact Chan-style parallel combine over
    the given sketches.  With ``weights`` (one per sketch, e.g. from
    ``SamplingPolicy.weights``) it is the Horvitz-Thompson estimate for a
    non-uniform block-level sample: block totals are expanded by their weight
    (``sum_k w_k * t_k`` estimates the corpus total), which undoes the
    selection bias of weighted/stratified policies.  Pass ``total_count``
    (the corpus record count ``N``, known from ``RSPSpec``) to normalize the
    mean by the true ``N`` -- the estimator is then exactly unbiased;
    otherwise the HT-estimated count is used (self-normalized / Hajek form).
    ``min``/``max`` are taken over the sampled sketches only.
    """
    if not summaries:
        raise ValueError("need at least one block summary")
    if weights is None:
        acc = summaries[0].moments()
        for s in summaries[1:]:
            m = s.moments()
            acc.count, acc.mean, acc.m2 = chan_merge(
                acc.count, acc.mean, acc.m2, m.count, m.mean, m.m2
            )
            acc.min = np.minimum(acc.min, m.min)
            acc.max = np.maximum(acc.max, m.max)
        return acc
    w = np.asarray(weights, dtype=np.float64)
    if w.shape != (len(summaries),) or np.any(w < 0):
        raise ValueError("weights must be non-negative, one per summary")
    counts = np.array([s.count for s in summaries], dtype=np.float64)
    means = np.stack([s.mean for s in summaries])
    m2s = np.stack([s.m2 for s in summaries])
    count_hat = float((w * counts).sum())
    n = float(total_count) if total_count is not None else count_hat
    if n <= 0:
        raise ValueError("estimated/total count must be positive")
    sum_hat = (w[:, None] * counts[:, None] * means).sum(axis=0)
    # HT estimate of the corpus sum of squares: per block, sum x^2 = m2 + c*mean^2
    sumsq_hat = (w[:, None] * (m2s + counts[:, None] * means**2)).sum(axis=0)
    mean = sum_hat / n
    m2 = np.maximum(sumsq_hat - n * mean**2, 0.0)
    return MomentStats(
        count=n,
        mean=mean,
        m2=m2,
        min=np.min([s.min for s in summaries], axis=0),
        max=np.max([s.max for s in summaries], axis=0),
    )


def max_divergence_from_summaries(summaries: Sequence) -> float:
    """Worst L-inf distance between any block's label distribution and the
    corpus label distribution, computed purely from the sketches (Fig. 2a)."""
    hists = [s.label_hist for s in summaries]
    if any(h is None for h in hists):
        raise ValueError("all blocks need label histograms")
    total = np.sum(hists, axis=0)
    corpus = total / max(total.sum(), 1)
    return float(
        max(np.max(np.abs(s.label_distribution - corpus)) for s in summaries)
    )
