"""Per-block summary statistics, computed once at partition time.

In the style of partition-selection summary stats (Rong et al., 2020), every
RSP block carries a small sketch -- record count, per-feature moments and
extrema, and (for labelled data) a label histogram -- written alongside the
block at partition/store time.  Downstream consumers then answer questions
like "estimate the corpus mean from g blocks" or "how far is block k's label
distribution from the corpus" without touching block data at all: the
sketches combine exactly (Chan-style parallel moments, histogram addition).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from repro.core.estimators import MomentStats, combine_moments


@dataclasses.dataclass(frozen=True)
class BlockSummary:
    """Sketch of one RSP block: moments + extrema (+ label histogram)."""

    block_id: int
    count: int
    mean: np.ndarray                 # [F] per flattened feature
    m2: np.ndarray                   # [F] sum of squared deviations
    min: np.ndarray                  # [F]
    max: np.ndarray                  # [F]
    label_hist: np.ndarray | None = None   # [num_classes] counts, optional

    @property
    def variance(self) -> np.ndarray:
        return self.m2 / max(self.count - 1.0, 1.0)

    @property
    def std(self) -> np.ndarray:
        return np.sqrt(self.variance)

    @property
    def label_distribution(self) -> np.ndarray:
        if self.label_hist is None:
            raise ValueError(f"block {self.block_id} has no label histogram")
        return self.label_hist / max(self.label_hist.sum(), 1)

    def moments(self) -> MomentStats:
        return MomentStats(
            count=float(self.count),
            mean=self.mean.copy(),
            m2=self.m2.copy(),
            min=self.min.copy(),
            max=self.max.copy(),
        )

    # -- manifest (de)serialization ----------------------------------------
    def to_dict(self) -> dict:
        d = {
            "block_id": self.block_id,
            "count": self.count,
            "mean": self.mean.tolist(),
            "m2": self.m2.tolist(),
            "min": self.min.tolist(),
            "max": self.max.tolist(),
        }
        if self.label_hist is not None:
            d["label_hist"] = self.label_hist.tolist()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "BlockSummary":
        hist = d.get("label_hist")
        return cls(
            block_id=int(d["block_id"]),
            count=int(d["count"]),
            mean=np.asarray(d["mean"], dtype=np.float64),
            m2=np.asarray(d["m2"], dtype=np.float64),
            min=np.asarray(d["min"], dtype=np.float64),
            max=np.asarray(d["max"], dtype=np.float64),
            label_hist=None if hist is None else np.asarray(hist, dtype=np.int64),
        )


def summarize_block(
    block: np.ndarray,
    block_id: int,
    *,
    label_column: int | None = None,
    num_classes: int | None = None,
) -> BlockSummary:
    """Compute one block's sketch.  ``label_column`` (with ``num_classes``)
    additionally records the label histogram of that column.

    Moments/extrema come from the fused one-pass block sketch
    (``repro.kernels.block_sketch``) -- the same primitive the query layer
    folds at read time, so partition- and query-time sketching share one
    single-pass implementation."""
    from repro.kernels.block_sketch import block_sketch_ref

    x = np.asarray(block, dtype=np.float64).reshape(block.shape[0], -1)
    sk = block_sketch_ref(x)
    hist = None
    if label_column is not None and num_classes is not None:
        labels = x[:, label_column]
        ilabels = labels.astype(np.int64)
        if (
            np.any(ilabels != labels)
            or ilabels.min(initial=0) < 0
            or ilabels.max(initial=0) >= num_classes
        ):
            raise ValueError(
                f"block {block_id}: label column {label_column} has values outside"
                f" 0..{num_classes - 1} (wrong label_column or num_classes?)"
            )
        hist = np.bincount(ilabels, minlength=num_classes)
    return BlockSummary(
        block_id=block_id,
        count=int(sk.count),
        mean=sk.mean,
        m2=sk.m2,
        min=sk.min,
        max=sk.max,
        label_hist=hist,
    )


def summarize_blocks(
    blocks: Iterable[np.ndarray],
    *,
    label_column: int | None = None,
    num_classes: int | None = None,
) -> list[BlockSummary]:
    return [
        summarize_block(b, k, label_column=label_column, num_classes=num_classes)
        for k, b in enumerate(blocks)
    ]


def combine_summaries(
    summaries: Sequence[BlockSummary],
    *,
    weights: Sequence[float] | np.ndarray | None = None,
    total_count: int | None = None,
) -> MomentStats:
    """Corpus-level moments from block sketches alone (no data reads).

    Without ``weights`` this is the exact Chan-style parallel combine over the
    given sketches.  With ``weights`` (one per sketch, e.g. from
    ``SamplingPolicy.weights``) it is the Horvitz-Thompson estimate for a
    non-uniform block-level sample: block totals are expanded by their weight
    (``sum_k w_k * t_k`` estimates the corpus total), which undoes the
    selection bias of weighted/stratified policies.  Pass ``total_count``
    (the corpus record count ``N``, known from ``RSPSpec``) to normalize the
    mean by the true ``N`` -- the estimator is then exactly unbiased;
    otherwise the HT-estimated count is used (self-normalized / Hajek form).
    ``min``/``max`` are taken over the sampled sketches only.
    """
    if not summaries:
        raise ValueError("need at least one block summary")
    if weights is None:
        acc = summaries[0].moments()
        for s in summaries[1:]:
            acc = combine_moments(acc, s.moments())
        return acc
    w = np.asarray(weights, dtype=np.float64)
    if w.shape != (len(summaries),) or np.any(w < 0):
        raise ValueError("weights must be non-negative, one per summary")
    counts = np.array([s.count for s in summaries], dtype=np.float64)
    means = np.stack([s.mean for s in summaries])
    m2s = np.stack([s.m2 for s in summaries])
    count_hat = float((w * counts).sum())
    n = float(total_count) if total_count is not None else count_hat
    if n <= 0:
        raise ValueError("estimated/total count must be positive")
    sum_hat = (w[:, None] * counts[:, None] * means).sum(axis=0)
    # HT estimate of the corpus sum of squares: per block, sum x^2 = m2 + c*mean^2
    sumsq_hat = (w[:, None] * (m2s + counts[:, None] * means**2)).sum(axis=0)
    mean = sum_hat / n
    m2 = np.maximum(sumsq_hat - n * mean**2, 0.0)
    return MomentStats(
        count=n,
        mean=mean,
        m2=m2,
        min=np.min([s.min for s in summaries], axis=0),
        max=np.max([s.max for s in summaries], axis=0),
    )


def max_divergence_from_summaries(summaries: Sequence[BlockSummary]) -> float:
    """Worst L-inf distance between any block's label distribution and the
    corpus label distribution, computed purely from the sketches (Fig. 2a)."""
    hists = [s.label_hist for s in summaries]
    if any(h is None for h in hists):
        raise ValueError("all blocks need label histograms")
    total = np.sum(hists, axis=0)
    corpus = total / max(total.sum(), 1)
    return float(
        max(np.max(np.abs(s.label_distribution - corpus)) for s in summaries)
    )
