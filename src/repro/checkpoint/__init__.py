from repro.checkpoint.store import AsyncCheckpointer, all_steps, latest_step, restore, save

__all__ = [k for k in dir() if not k.startswith("_")]
