"""Atomic, async-capable checkpoint store.

Layout:
    <root>/step_00001000/
        manifest.json        {step, keys, shapes, dtypes, extra}
        arr_<i>.npy          one file per pytree leaf
    <root>/step_00001000.tmp (during write; renamed atomically on success)

Design points for fault tolerance:
  * write-to-temp + ``os.replace`` -- a crash mid-write never corrupts the
    latest checkpoint; restore always reads a complete directory.
  * ``extra`` carries the O(1) RSP sampler state (the whole data-pipeline
    checkpoint) plus user metadata (mesh shape, config name) for elastic
    restore validation.
  * ``AsyncCheckpointer`` snapshots to host memory synchronously (cheap) and
    writes in a background thread, overlapping I/O with the next train steps.
  * ``keep_last`` garbage-collects old steps after a successful write.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")

# dtypes numpy can't natively save/load: stored as raw uint16 + manifest tag
_EXOTIC = {"bfloat16": ml_dtypes.bfloat16}


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save(root: str, step: int, state: Any, *, extra: dict | None = None, keep_last: int = 3) -> str:
    """Synchronous atomic save.  Returns the checkpoint directory."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _leaf_paths(state)
    manifest = {"step": int(step), "keys": [], "extra": extra or {}}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        dtype_str = str(arr.dtype)
        if dtype_str in _EXOTIC:
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr, allow_pickle=False)
        manifest["keys"].append({"key": key, "file": f"arr_{i}.npy",
                                 "shape": list(arr.shape), "dtype": dtype_str})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(root, keep_last)
    return final


def _gc(root: str, keep_last: int) -> None:
    steps = sorted(all_steps(root))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"), ignore_errors=True)


def all_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(root, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(root: str) -> int | None:
    steps = all_steps(root)
    return steps[-1] if steps else None


def restore(
    root: str,
    step: int,
    like: Any,
    *,
    shardings: Any = None,
) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (values or ShapeDtypeStructs).

    ``shardings``: optional matching tree of Shardings -- enables *elastic*
    restore onto a different mesh (leaves are device_put with the target
    sharding regardless of the mesh that wrote the checkpoint).
    """
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {k["key"]: k for k in manifest["keys"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        if len(shard_leaves) != len(flat):
            raise ValueError("shardings tree does not match state tree")

    out = []
    for i, (path, leaf) in enumerate(flat):
        key = jax.tree_util.keystr(path)
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(os.path.join(d, by_key[key]["file"]), allow_pickle=False)
        stored_dtype = by_key[key]["dtype"]
        if stored_dtype in _EXOTIC:
            arr = arr.view(_EXOTIC[stored_dtype])
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != expected {want_shape}")
        arr = arr.astype(leaf.dtype)
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


class AsyncCheckpointer:
    """Snapshot-then-write-in-background checkpointer."""

    def __init__(self, root: str, *, keep_last: int = 3):
        self.root = root
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, state: Any, *, extra: dict | None = None) -> None:
        self.wait()
        host_state = jax.tree.map(lambda a: np.asarray(a), state)  # snapshot

        def work():
            try:
                save(self.root, step, host_state, extra=extra, keep_last=self.keep_last)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
