"""``repro.serve`` -- serving layers.

Two independent serving surfaces live here:

* :mod:`repro.serve.query_service` -- concurrent multi-tenant approximate
  *query* serving over one ``RSPDataset`` (admission control, deadline-aware
  step scheduling, anytime responses).  Entry point: ``ds.serve()``.
* :mod:`repro.serve.engine` -- batched *model* serving (prefill + KV-cache
  decode, RSP block-ensemble logit averaging).
"""

from repro.serve.admission import (
    AdmissionController,
    AdmissionRejected,
    AdmissionSnapshot,
)
from repro.serve.engine import EnsembleServer, ServeConfig, Server
from repro.serve.query_service import (
    OUTCOMES,
    QueryService,
    QueryTicket,
    ServiceMetrics,
)
from repro.serve.scheduler import StepScheduler

__all__ = [k for k in dir() if not k.startswith("_")]
