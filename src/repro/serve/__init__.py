from repro.serve.engine import EnsembleServer, ServeConfig, Server

__all__ = [k for k in dir() if not k.startswith("_")]
