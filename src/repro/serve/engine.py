"""Batched serving engine: prefill + KV-cache (or SSM-state) decode.

``EnsembleServer`` realizes the paper's asymptotic-ensemble idea at serve
time: logits from k models trained on disjoint RSP block samples are
averaged per decode step (probability-averaging combination, Sec. 9).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api, transformer
from repro.models.config import ModelConfig

Array = jax.Array


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0     # 0 = greedy
    seed: int = 0
    moe_groups: int = 1


class Server:
    def __init__(self, cfg: ModelConfig, params: dict, serve_cfg: ServeConfig | None = None):
        if cfg.family == "encoder":
            raise ValueError("encoder-only archs do not decode")
        self.cfg = cfg
        self.params = params
        self.serve_cfg = serve_cfg or ServeConfig()
        self._prefill = jax.jit(api.make_prefill_fn(cfg, moe_groups=self.serve_cfg.moe_groups))
        self._decode = jax.jit(api.make_decode_fn(cfg, moe_groups=self.serve_cfg.moe_groups))

    def _sample(self, logits: Array, key: Array) -> Array:
        if self.serve_cfg.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1)
        scaled = logits[:, -1].astype(jnp.float32) / self.serve_cfg.temperature
        return jax.random.categorical(key, scaled, axis=-1)

    def generate(self, prompts: Array, *, max_new_tokens: int) -> np.ndarray:
        """prompts: [B, P] int32 -> [B, P + max_new_tokens]."""
        B, P = prompts.shape
        caches = transformer.init_caches(
            self.cfg, B, P + max_new_tokens, dtype=jnp.float32
        )
        logits, caches = self._prefill(self.params, caches, {"tokens": prompts})
        key = jax.random.PRNGKey(self.serve_cfg.seed)
        out = [prompts]
        tok = self._sample(logits, key)
        for t in range(max_new_tokens):
            out.append(tok[:, None])
            if t == max_new_tokens - 1:
                break
            key, sub = jax.random.split(key)
            logits, caches = self._decode(self.params, caches, {"tokens": tok[:, None].astype(jnp.int32)})
            tok = self._sample(logits, sub)
        return np.asarray(jnp.concatenate(out, axis=1))


class EnsembleServer:
    """Average logits from base models trained on disjoint RSP blocks."""

    def __init__(self, cfg: ModelConfig, stacked_params: Any, serve_cfg: ServeConfig | None = None):
        if cfg.family == "encoder":
            raise ValueError("encoder-only archs do not decode")
        self.cfg = cfg
        self.stacked = stacked_params          # leaves: [k, ...]
        self.serve_cfg = serve_cfg or ServeConfig()
        k = jax.tree.leaves(stacked_params)[0].shape[0]
        self.k = k
        decode = api.make_decode_fn(cfg, moe_groups=self.serve_cfg.moe_groups)
        prefill = api.make_prefill_fn(cfg, moe_groups=self.serve_cfg.moe_groups)

        def ens_prefill(stacked, caches, batch):
            logits, new_caches = jax.vmap(lambda p, c: prefill(p, c, batch))(stacked, caches)
            return jax.nn.logsumexp(
                jax.nn.log_softmax(logits.astype(jnp.float32), -1), axis=0
            ) - jnp.log(float(k)), new_caches

        def ens_decode(stacked, caches, batch):
            logits, new_caches = jax.vmap(lambda p, c: decode(p, c, batch))(stacked, caches)
            return jax.nn.logsumexp(
                jax.nn.log_softmax(logits.astype(jnp.float32), -1), axis=0
            ) - jnp.log(float(k)), new_caches

        self._prefill = jax.jit(ens_prefill)
        self._decode = jax.jit(ens_decode)

    def generate(self, prompts: Array, *, max_new_tokens: int) -> np.ndarray:
        B, P = prompts.shape
        one = transformer.init_caches(self.cfg, B, P + max_new_tokens, dtype=jnp.float32)
        caches = jax.tree.map(lambda a: jnp.stack([a] * self.k), one)
        logits, caches = self._prefill(self.stacked, caches, {"tokens": prompts})
        out = [prompts]
        tok = jnp.argmax(logits[:, -1], axis=-1)
        for t in range(max_new_tokens):
            out.append(tok[:, None])
            if t == max_new_tokens - 1:
                break
            logits, caches = self._decode(self.stacked, caches, {"tokens": tok[:, None].astype(jnp.int32)})
            tok = jnp.argmax(logits[:, -1], axis=-1)
        return np.asarray(jnp.concatenate(out, axis=1))
