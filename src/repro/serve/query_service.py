"""``QueryService`` -- concurrent multi-tenant approximate-query serving.

The paper's payoff is that analysis of a big data set becomes analysis of a
few pre-generated RSP blocks; at scale that only matters if many analysts
can ask at once.  This service multiplexes concurrent
:class:`~repro.rsp.query.Query` submissions over ONE opened
:class:`~repro.rsp.dataset.RSPDataset` and its shared
:class:`~repro.rsp.engine.BlockExecutor` block cache:

* **Budgets.**  Every query carries ``target_rel_err`` / ``max_blocks``
  (how much accuracy to buy) and a ``deadline_ms`` (how long the tenant
  will wait).  A query that converges early returns early; one that hits
  its deadline returns its current **anytime** estimate -- point value,
  confidence interval, and blocks consumed -- instead of failing.
* **Admission control.**  Progressive queries cost fetch slots
  (``prefetch + 1`` in-flight block fetches each); the
  :class:`~repro.serve.admission.AdmissionController` admits up to
  ``capacity`` slots, queues the next ``max_queue`` submissions FIFO, and
  rejects beyond that -- saturation is visible, not a latency cliff.
* **Fair scheduling.**  The :class:`~repro.serve.scheduler.StepScheduler`
  interleaves *one-block* progressive steps across admitted queries
  (earliest deadline first, round-robin within a deadline class), so a
  heavy query cannot starve light ones.
* **Sketch fast path.**  Moment/label-count-only queries are answered
  synchronously at ``submit`` from the partition-time sketches -- zero
  block I/O, never queued, never rejected.
* **Honest metering.**  Each query carries its own
  :class:`~repro.rsp.engine.CallerStats`, so per-query I/O sums exactly to
  the executor total no matter how tenants interleave; ``metrics()``
  reports QPS, latency percentiles, shared-cache hit rate, admission
  rejects, and blocks fetched per query.

Usage::

    with ds.serve(capacity=64, workers=8) as svc:
        tickets = [svc.submit("median", target_rel_err=0.02,
                              deadline_ms=500) for _ in tenants]
        results = [svc.result(t) for t in tickets]

Reproducibility: a submitted query with no pinned seed gets
``derive_seed(service seed, query id)``, so every tenant's bootstrap and
block-selection streams are independent AND identical across runs
regardless of scheduling order.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import threading
import time
from typing import Any, Iterator

from repro import obs
from repro.obs.metrics import MetricsRegistry
from repro.rsp.engine import ExecutorStats
from repro.rsp.query import (
    AggregateResult,
    Query,
    QueryExecutor,
    QueryResult,
    as_query,
    derive_seed,
)
from repro.serve.admission import AdmissionController, AdmissionRejected, AdmissionSnapshot
from repro.serve.scheduler import StepScheduler

# terminal outcomes a ticket can report
OUTCOMES = (
    "sketch",       # answered from partition-time sketches at submit (0 I/O)
    "converged",    # every CI met target_rel_err before the deadline
    "exhausted",    # read max_blocks without converging (answer still valid)
    "deadline",     # deadline fired -> anytime result returned
    "cancelled",    # cancel() or service shutdown
    "rejected",     # admission queue full
    "failed",       # the query raised; see ticket.error
)


class QueryTicket:
    """Handle for one submitted query.

    ``status`` is ``"pending"`` until terminal (``"done"`` / ``"rejected"``);
    ``outcome`` (one of :data:`OUTCOMES`) says *how* it finished.  ``result``
    is the final or anytime :class:`~repro.rsp.query.QueryResult` (``None``
    for rejected queries and queries cancelled before their first block).
    Thread-safe; finalization is idempotent -- the first of worker /
    deadline-waiter / cancel wins and the rest are no-ops.
    """

    def __init__(self, qid: int, query: Query, deadline: float | None):
        self.id = qid
        self.query = query
        self.deadline = deadline          # time.monotonic() instant, or None
        self.submitted_at = time.monotonic()
        self.finished_at: float | None = None
        self.outcome: str | None = None
        self.result: QueryResult | None = None
        self.error: BaseException | None = None
        self._event = threading.Event()
        self._lock = threading.Lock()

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def status(self) -> str:
        if not self.done:
            return "pending"
        return "rejected" if self.outcome == "rejected" else "done"

    @property
    def latency_ms(self) -> float | None:
        if self.finished_at is None:
            return None
        return (self.finished_at - self.submitted_at) * 1e3

    def wait(self, timeout: float | None = None) -> bool:
        return self._event.wait(timeout)

    def _finalize(
        self,
        *,
        outcome: str,
        result: QueryResult | None,
        error: BaseException | None = None,
    ) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self.outcome = outcome
            self.result = result
            self.error = error
            self.finished_at = time.monotonic()
            self._event.set()
            return True

    def __repr__(self) -> str:
        return f"QueryTicket(id={self.id}, status={self.status!r}, outcome={self.outcome!r})"


class _Run:
    """Scheduler-side state of one admitted/queued progressive query."""

    __slots__ = (
        "ticket", "qe", "gen", "cost", "last", "admitted", "released", "enqueued_at",
    )

    def __init__(self, ticket: QueryTicket, qe: QueryExecutor, cost: int):
        self.ticket = ticket
        self.qe = qe
        self.gen: Iterator[QueryResult] = qe.stream()
        self.cost = cost
        self.last: QueryResult | None = None
        self.admitted = False
        self.released = False
        self.enqueued_at = time.monotonic()  # admission-wait metering

    @property
    def deadline(self) -> float | None:  # StepScheduler priority key
        return self.ticket.deadline

    def close_gen(self) -> None:
        """Close the progressive stream; its ``finally`` cancels the query's
        queued prefetch futures inside the shared executor."""
        try:
            self.gen.close()
        except Exception:  # noqa: BLE001 -- closing a dead stream is best-effort
            pass


@dataclasses.dataclass(frozen=True)
class ServiceMetrics:
    """One consistent snapshot of the service counters.

    Latency percentiles are over completed queries (sketch answers
    included); ``qps`` is completions over the first-submit -> last-finish
    window; ``cache_hit_rate`` / ``executor`` meter the shared executor
    since the service opened; ``blocks_per_query`` averages each query's
    own honest ``CallerStats`` fetch count.
    """

    submitted: int
    completed: int
    rejected: int
    cancelled: int
    deadline_hits: int
    sketch_answers: int
    failed: int
    qps: float
    latency_p50_ms: float
    latency_p99_ms: float
    cache_hit_rate: float
    blocks_fetched: int
    blocks_per_query: float
    admission: AdmissionSnapshot
    executor: ExecutorStats


def _percentile(sorted_ms: list[float], q: float) -> float:
    if not sorted_ms:
        return math.nan
    idx = min(len(sorted_ms) - 1, max(0, math.ceil(q * len(sorted_ms)) - 1))
    return sorted_ms[idx]


class QueryService:
    """Concurrent approximate-query serving over one ``RSPDataset``.

    ``capacity`` bounds in-flight block-I/O demand in fetch slots (each
    progressive query holds ``min(prefetch + 1, max_blocks)`` slots while
    admitted); ``max_queue`` bounds the admission wait queue (``None`` =
    unbounded, ``0`` = reject at capacity); ``workers`` are the stepping
    threads that interleave progressive queries; ``seed`` is the service's
    RNG root for :func:`~repro.rsp.query.derive_seed`;
    ``default_deadline_ms`` applies to submissions that don't set one.

    Opening the service materializes the dataset's partition-time sketches
    once (a no-op for stored datasets with a manifest), so the sketch fast
    path and sketch-guided policies never race to compute them later.
    """

    def __init__(
        self,
        dataset,
        *,
        capacity: int = 64,
        max_queue: int | None = None,
        workers: int = 4,
        seed: int = 0,
        default_deadline_ms: float | None = None,
    ):
        self.ds = dataset
        self.seed = seed
        self.default_deadline_ms = default_deadline_ms
        _ = dataset.summaries  # materialize once, before any concurrency
        self._admission = AdmissionController(capacity, max_queue=max_queue)
        self._scheduler = StepScheduler(
            self._step, workers=workers, on_drop=self._drop
        )
        self._ids = itertools.count()
        self._lock = threading.Lock()
        self._runs: dict[int, _Run] = {}
        self._stats0 = dataset.executor.stats()
        self._closed = False
        # deadline sweeper: finalizes tickets AT their deadline instant, so
        # latency honours the budget even when every worker is busy stepping
        # other queries and no result() waiter is parked on the ticket
        self._sweep_cv = threading.Condition()
        self._sweep_heap: list[tuple[float, int, QueryTicket]] = []
        self._sweeper = threading.Thread(
            target=self._sweep, name="rsp-serve-deadline", daemon=True
        )
        self._sweeper.start()
        # metrics: one registry per service is the single book of record --
        # ``metrics()`` is a view over these counters (no parallel private
        # tallies), and ``registry.to_prometheus()`` is scrape-ready.  The
        # registry is always live (it backs the public accounting API), only
        # spans/global-registry hot-path telemetry are gated by repro.obs.
        self.registry = MetricsRegistry()
        self._m_submitted = self.registry.counter(
            "rsp_serve_submitted_total", "queries submitted")
        self._m_outcomes = {
            o: self.registry.counter(
                "rsp_serve_queries_total", "finished queries by outcome", outcome=o)
            for o in OUTCOMES
        }
        self._m_blocks = self.registry.counter(
            "rsp_serve_blocks_fetched_total", "block fetches by finished queries")
        self._m_admission_wait = self.registry.histogram(
            "rsp_serve_admission_wait_seconds",
            "submit-to-admission wait of queued queries")
        self._m_step = self.registry.histogram(
            "rsp_serve_step_seconds", "one-block progressive step latency")
        self._m_slack = self.registry.histogram(
            "rsp_serve_deadline_slack_seconds",
            "remaining budget at answer time (deadline queries, clamped at 0)")
        self._m_overrun = self.registry.counter(
            "rsp_serve_deadline_overrun_total",
            "answers delivered past their deadline")
        # exact latency samples for percentiles (bucketed histograms would
        # round p99 up to a bucket edge and trip latency gates); under _lock
        self._latencies_ms: list[float] = []
        self._first_submit: float | None = None
        self._last_finish: float | None = None

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------
    def submit(
        self,
        aggregates: Any = "mean",
        *,
        deadline_ms: float | None = None,
        on_reject: str = "raise",
        **query_kwargs,
    ) -> QueryTicket:
        """Submit one query; returns immediately with a :class:`QueryTicket`.

        ``aggregates`` / ``query_kwargs`` are anything
        ``RSPDataset.query`` accepts (``target_rel_err=``, ``max_blocks=``,
        ``policy=``, ...).  ``deadline_ms`` is this query's latency budget,
        measured from submission (queue time included): when it fires the
        ticket completes with the current anytime estimate.  Sketch-only
        queries are answered inline before admission (queries with
        ``where=`` predicates never take that path -- partition-time
        sketches are unfiltered -- and stream filtered block passes through
        the plan-compiled kernels instead).  ``on_reject="raise"``
        raises :class:`AdmissionRejected` when the service is saturated;
        ``"ticket"`` returns a rejected ticket instead.
        """
        if on_reject not in ("raise", "ticket"):
            raise ValueError("on_reject must be 'raise' or 'ticket'")
        if self._closed:
            raise RuntimeError("service is closed")
        q = as_query(aggregates, **query_kwargs)
        qid = next(self._ids)
        if q.seed is None:
            q = dataclasses.replace(q, seed=derive_seed(self.seed, qid))
        if deadline_ms is None:
            deadline_ms = self.default_deadline_ms
        deadline = None if deadline_ms is None else time.monotonic() + deadline_ms / 1e3
        ticket = QueryTicket(qid, q, deadline)
        self._m_submitted.inc()
        with self._lock:
            if self._first_submit is None:
                self._first_submit = ticket.submitted_at
        # datasets that provide their own executor factory (e.g. a
        # DistributedDataset fanning block work over a mesh) plug in here;
        # plain RSPDatasets get the stock executor.  Validates the query.
        make_qe = getattr(self.ds, "query_executor", None)
        qe = make_qe(q) if callable(make_qe) else QueryExecutor(self.ds, q)

        # zero-I/O fast path: answer sketch-eligible queries (moments,
        # label counts, and -- with v2 suites -- ungrouped unfiltered
        # quantile/distinct) synchronously from the sketches -- no
        # admission, no scheduling, no fetches.  In auto mode a
        # bound-limited sketch answer that misses the query's
        # target_rel_err is NOT final: the query falls through to the
        # scheduled progressive path instead of silently under-delivering.
        sketch_forced = q.use_sketches is True
        sketch_auto = (
            q.use_sketches == "auto" and qe._sketch_eligible() and self.ds.has_summaries
        )
        if sketch_forced or sketch_auto:
            try:
                # run() validates forced queries (raises if block data is
                # needed); the direct call skips the progressive fallback
                # that must stay behind admission control
                result = qe.run() if sketch_forced else qe._answer_from_sketches()
            except Exception as e:  # noqa: BLE001 -- surface via the ticket
                ticket._finalize(outcome="failed", result=None, error=e)
                self._record(ticket, blocks=0)
                return ticket
            if sketch_forced or result.converged:
                qe.end_span()
                ticket._finalize(outcome="sketch", result=result)
                self._record(ticket, blocks=result.executor_stats.blocks_fetched)
                return ticket

        cost = self.ds.executor.prefetch + 1
        if q.max_blocks is not None:
            cost = min(cost, max(1, q.max_blocks))
        run = _Run(ticket, qe, cost)
        with self._lock:
            self._runs[qid] = run
        decision = self._admission.try_admit(run, cost)
        if decision == "reject":
            ticket._finalize(outcome="rejected", result=None)
            self._record(ticket, blocks=0)
            with self._lock:
                self._runs.pop(qid, None)
            if on_reject == "raise":
                raise AdmissionRejected(
                    f"query {qid}: service saturated "
                    f"({self._admission.snapshot().in_flight} slots in flight)"
                )
            return ticket
        if decision == "admit":
            run.admitted = True
            self._scheduler.submit(run)
        if deadline is not None:
            with self._sweep_cv:
                heapq.heappush(self._sweep_heap, (deadline, qid, ticket))
                self._sweep_cv.notify()
        return ticket

    # ------------------------------------------------------------------
    # Results / cancellation
    # ------------------------------------------------------------------
    def result(self, ticket: QueryTicket, timeout: float | None = None) -> QueryResult:
        """Block until ``ticket`` finishes and return its (final or anytime)
        result.  Enforces the ticket's deadline even if no worker has touched
        the query yet (e.g. it is still queued for admission): at the
        deadline the ticket completes with whatever has been computed.
        ``timeout`` (seconds) bounds this call independently of the query's
        own deadline; on expiry ``TimeoutError`` is raised and the query
        keeps running.
        """
        wait_end = None if timeout is None else time.monotonic() + timeout
        while not ticket.done:
            now = time.monotonic()
            bounds = [b for b in (ticket.deadline, wait_end) if b is not None]
            if not bounds:
                ticket.wait()
                continue
            until = min(bounds)
            if until > now:
                ticket.wait(until - now)
            if ticket.done:
                break
            now = time.monotonic()
            if ticket.deadline is not None and now >= ticket.deadline:
                self._force_deadline(ticket)
                break
            if wait_end is not None and now >= wait_end:
                raise TimeoutError(f"query {ticket.id} still pending after {timeout}s")
        return self._unwrap(ticket)

    def _unwrap(self, ticket: QueryTicket) -> QueryResult:
        if ticket.outcome == "failed":
            raise ticket.error
        if ticket.outcome == "rejected":
            raise AdmissionRejected(f"query {ticket.id} was rejected at admission")
        assert ticket.result is not None
        return ticket.result

    def cancel(self, ticket: QueryTicket) -> bool:
        """Cancel a pending query.  Returns True if this call finalized it
        (with its current anytime estimate, if any); False if it already
        finished.  A cancelled query's queued block fetches are released --
        dropped from the admission queue before admission, or unwound by the
        next worker touch (closing its prefetch window) after."""
        with self._lock:
            run = self._runs.get(ticket.id)
        if run is None:
            return False
        res = run.last if run.last is not None else self._anytime_empty(run)
        if not ticket._finalize(outcome="cancelled", result=res):
            return False
        self._record(ticket, blocks=run.qe.counter.stats().blocks_fetched)
        if self._admission.drop(run):
            # never admitted: nothing holds capacity; tidy up directly
            self._retire(run)
        # admitted runs are retired by the worker/scheduler that next owns
        # them (they observe ticket.done) -- never close a generator that a
        # worker may be executing
        return True

    # ------------------------------------------------------------------
    # Stepping (scheduler callback)
    # ------------------------------------------------------------------
    def _step(self, run: _Run) -> bool:
        """Advance one progressive query by one block.  Returns True to
        re-enqueue (more blocks wanted)."""
        ticket = run.ticket
        if ticket.done:
            self._retire(run)
            return False
        if ticket.deadline is not None and time.monotonic() >= ticket.deadline:
            self._finalize(run, outcome="deadline")
            return False
        span = None
        if obs.enabled() and run.qe.ctx is not None:
            span = obs.get_tracer().start_span(
                "serve.step", parent=run.qe.ctx, attrs={"qid": ticket.id}
            )
        t0 = time.perf_counter()
        try:
            res = next(run.gen)
        except StopIteration:
            self._finalize(run, outcome="exhausted")
            return False
        except Exception as e:  # noqa: BLE001 -- surface via the ticket
            self._finalize(run, outcome="failed", error=e)
            return False
        finally:
            self._m_step.observe(time.perf_counter() - t0)
            if span is not None:
                span.end()
        run.last = res
        if res.converged or res.from_sketches:
            self._finalize(run, outcome="converged")
            return False
        return True

    def _finalize(
        self, run: _Run, *, outcome: str, error: BaseException | None = None
    ) -> None:
        res = run.last
        if res is None and error is None:
            res = self._anytime_empty(run)
        if run.ticket._finalize(outcome=outcome, result=res, error=error):
            self._record(
                run.ticket, blocks=run.qe.counter.stats().blocks_fetched
            )
        self._retire(run)

    def _drop(self, run: _Run) -> None:
        """Scheduler drop hook: the service is closing; finalize as
        cancelled (anytime result preserved)."""
        if run.ticket._finalize(
            outcome="cancelled",
            result=run.last if run.last is not None else self._anytime_empty(run),
        ):
            self._record(run.ticket, blocks=run.qe.counter.stats().blocks_fetched)
        self._retire(run)

    def _retire(self, run: _Run) -> None:
        """Tear down a finished run: close its stream (cancelling queued
        prefetches) and release its admission slots, promoting queued runs."""
        run.close_gen()
        run.qe.end_span()  # closing a never-started gen skips its finally
        with self._lock:
            self._runs.pop(run.ticket.id, None)
        stack = [run]
        while stack:
            r = stack.pop()
            with self._lock:
                if not r.admitted or r.released:
                    continue
                r.released = True
            for nxt in self._admission.release(r.cost):
                nxt.admitted = True
                self._m_admission_wait.observe(time.monotonic() - nxt.enqueued_at)
                if nxt.ticket.done:
                    nxt.close_gen()
                    nxt.qe.end_span()
                    with self._lock:
                        self._runs.pop(nxt.ticket.id, None)
                    stack.append(nxt)
                    continue
                try:
                    self._scheduler.submit(nxt)
                except RuntimeError:  # closed while promoting
                    self._drop(nxt)

    def _sweep(self) -> None:
        """Deadline sweeper thread: sleep until the earliest registered
        deadline, then finalize every expired ticket with its anytime
        estimate.  Workers' pre-step checks and ``result()`` waiters enforce
        deadlines too; the sweeper guarantees it happens *on time* for
        tickets nobody is touching (queued for admission, or admitted but
        starved of worker attention)."""
        while True:
            with self._sweep_cv:
                while not self._closed:
                    if not self._sweep_heap:
                        self._sweep_cv.wait()
                        continue
                    delay = self._sweep_heap[0][0] - time.monotonic()
                    if delay > 0:
                        self._sweep_cv.wait(delay)
                        continue
                    break
                if self._closed:
                    return
                _, _, ticket = heapq.heappop(self._sweep_heap)
            # finalize outside the cv: _force_deadline takes service locks
            if not ticket.done:
                self._force_deadline(ticket)

    def _force_deadline(self, ticket: QueryTicket) -> None:
        """Deadline enforcement from a ``result()`` waiter: finalize with the
        latest anytime estimate even if the run is mid-step or still queued."""
        with self._lock:
            run = self._runs.get(ticket.id)
        if run is None:
            return
        span = None
        if obs.enabled() and run.qe.ctx is not None:
            # runs on the sweeper thread (or a result() waiter); parenting
            # under the query's root span is explicit, not thread-inherited
            span = obs.get_tracer().start_span(
                "serve.deadline", parent=run.qe.ctx, attrs={"qid": ticket.id}
            )
        res = run.last if run.last is not None else self._anytime_empty(run)
        if ticket._finalize(outcome="deadline", result=res):
            self._record(ticket, blocks=run.qe.counter.stats().blocks_fetched)
        if self._admission.drop(run):
            self._retire(run)  # was still queued: safe to tear down here
        if span is not None:
            span.end()

    def _anytime_empty(self, run: _Run) -> QueryResult:
        """The anytime answer before any block has been folded: NaN point
        estimates with infinite intervals (which trivially cover), zero
        blocks read."""
        q = run.ticket.query
        aggs = tuple(
            AggregateResult(
                name=a.label,
                kind=a.kind,
                estimate=math.nan,
                ci_lo=-math.inf if a.kind != "histogram" else None,
                ci_hi=math.inf if a.kind != "histogram" else None,
                rel_err=None if a.kind == "histogram" else math.inf,
            )
            for a in q.aggregates
        )
        return QueryResult(
            aggregates=aggs,
            blocks_read=0,
            total_blocks=self.ds.num_blocks,
            confidence=q.confidence,
            target_rel_err=q.target_rel_err,
            converged=False,
            from_sketches=False,
            executor_stats=run.qe.counter.stats(),
        )

    # ------------------------------------------------------------------
    # Metrics
    # ------------------------------------------------------------------
    def _record(self, ticket: QueryTicket, *, blocks: int) -> None:
        self._m_outcomes[ticket.outcome].inc()
        if ticket.outcome == "rejected":
            return
        self._m_blocks.inc(blocks)
        if ticket.deadline is not None:
            slack = ticket.deadline - ticket.finished_at
            self._m_slack.observe(max(slack, 0.0))
            if slack < 0:
                self._m_overrun.inc()
        with self._lock:
            self._latencies_ms.append(ticket.latency_ms)
            self._last_finish = ticket.finished_at

    def metrics(self) -> ServiceMetrics:
        """One consistent snapshot, read straight off :attr:`registry` (the
        counters) and the exact latency samples -- there is no second set of
        books to drift from the scrape endpoint."""
        executor_delta = self.ds.executor.stats() - self._stats0
        outcomes = {o: int(c.value) for o, c in self._m_outcomes.items()}
        blocks_fetched = int(self._m_blocks.value)
        with self._lock:
            lat = sorted(self._latencies_ms)
            completed = len(lat)
            window = None
            if self._first_submit is not None and self._last_finish is not None:
                window = max(self._last_finish - self._first_submit, 1e-9)
        return ServiceMetrics(
            submitted=int(self._m_submitted.value),
            completed=completed,
            rejected=outcomes["rejected"],
            cancelled=outcomes["cancelled"],
            deadline_hits=outcomes["deadline"],
            sketch_answers=outcomes["sketch"],
            failed=outcomes["failed"],
            qps=0.0 if window is None else completed / window,
            latency_p50_ms=_percentile(lat, 0.50),
            latency_p99_ms=_percentile(lat, 0.99),
            cache_hit_rate=executor_delta.hit_rate,
            blocks_fetched=blocks_fetched,
            blocks_per_query=blocks_fetched / completed if completed else 0.0,
            admission=self._admission.snapshot(),
            executor=executor_delta,
        )

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------
    def close(self) -> None:
        """Stop the workers; outstanding queries finalize as ``cancelled``
        with their current anytime estimates."""
        if self._closed:
            return
        self._closed = True
        with self._sweep_cv:
            self._sweep_cv.notify_all()
        self._sweeper.join(timeout=5.0)
        self._scheduler.close()
        for run in self._admission.drain():
            self._drop(run)
        with self._lock:
            leftovers = list(self._runs.values())
        for run in leftovers:
            self._drop(run)

    def __enter__(self) -> "QueryService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        snap = self._admission.snapshot()
        return (
            f"QueryService(K={self.ds.num_blocks}, capacity={snap.capacity},"
            f" in_flight={snap.in_flight}, queued={snap.queued},"
            f" submitted={int(self._m_submitted.value)})"
        )


# re-export for `from repro.serve.query_service import AdmissionRejected`
__all__ = [
    "OUTCOMES",
    "AdmissionRejected",
    "QueryService",
    "QueryTicket",
    "ServiceMetrics",
]
