"""Admission control for the concurrent RSP query service.

The shared :class:`~repro.rsp.engine.BlockExecutor` has a bounded worker
pool and a finite block cache: past a point, admitting one more progressive
query does not add throughput, it just queues fetches inside the engine and
inflates every tenant's latency.  The admission controller keeps that
pressure *outside* the engine, where it can be measured and refused:

* Every progressive query carries a **cost** in fetch slots -- the number of
  block fetches it keeps in flight while streaming (``prefetch + 1`` under
  the engine's pipelined ``map_blocks``).
* ``capacity`` bounds the total cost of *admitted* (running) queries.
  Submissions beyond capacity are **queued** FIFO, up to ``max_queue``;
  beyond that they are **rejected** immediately (the caller sees
  :class:`AdmissionRejected` rather than an unbounded queue).
* Sketch-only queries never reach admission: their cost is zero block
  fetches, so the service short-circuits them before this layer.

``release`` returns the queued entries that fit into the freed capacity so
the service can hand them to the scheduler; all state transitions are under
one lock and safe for concurrent submitters.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Callable


class AdmissionRejected(RuntimeError):
    """Raised (or recorded on the ticket) when the service is saturated:
    in-flight demand is at capacity and the wait queue is full."""


@dataclasses.dataclass(frozen=True)
class AdmissionSnapshot:
    """Point-in-time admission state: admitted cost vs capacity, queue
    depth, and the running reject counter."""

    capacity: int
    in_flight: int
    queued: int
    admitted_total: int
    rejected_total: int


class AdmissionController:
    """Capacity-bounded admit/queue/reject gate over opaque work items.

    ``try_admit(item, cost)`` returns ``"admit"`` (capacity reserved),
    ``"queue"`` (held FIFO until released capacity fits it), or ``"reject"``.
    ``release(cost)`` frees capacity and returns the newly admitted queued
    items, in order.  ``drop(item)`` removes a queued item (cancellation)
    without charging capacity.
    """

    def __init__(self, capacity: int, *, max_queue: int | None = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1 fetch slot")
        if max_queue is not None and max_queue < 0:
            raise ValueError("max_queue must be >= 0 (None = unbounded)")
        self.capacity = int(capacity)
        self.max_queue = max_queue
        self._lock = threading.Lock()
        self._in_flight = 0
        self._queue: collections.deque[tuple[Any, int]] = collections.deque()
        self._admitted_total = 0
        self._rejected_total = 0

    def try_admit(self, item: Any, cost: int) -> str:
        """Admit, queue, or reject ``item`` needing ``cost`` fetch slots.

        A cost above ``capacity`` is clamped to it (a single over-wide query
        must still be runnable on an idle service, at full capacity).
        """
        cost = min(max(1, int(cost)), self.capacity)
        with self._lock:
            if self._in_flight + cost <= self.capacity and not self._queue:
                self._in_flight += cost
                self._admitted_total += 1
                return "admit"
            if self.max_queue is None or len(self._queue) < self.max_queue:
                self._queue.append((item, cost))
                return "queue"
            self._rejected_total += 1
            return "reject"

    def release(self, cost: int) -> list[Any]:
        """Free ``cost`` slots; admit and return queued items that now fit
        (FIFO -- a wide queued query at the head blocks narrower ones behind
        it, preserving submission fairness)."""
        cost = min(max(1, int(cost)), self.capacity)
        admitted: list[Any] = []
        with self._lock:
            self._in_flight -= cost
            if self._in_flight < 0:  # defensive: double release is a bug
                self._in_flight = 0
            while self._queue:
                item, c = self._queue[0]
                if self._in_flight + c > self.capacity:
                    break
                self._queue.popleft()
                self._in_flight += c
                self._admitted_total += 1
                admitted.append(item)
        return admitted

    def drop(self, item: Any) -> bool:
        """Remove a still-queued item (cancellation before admission)."""
        with self._lock:
            for entry in self._queue:
                if entry[0] is item:
                    self._queue.remove(entry)
                    return True
        return False

    def drain(self, predicate: Callable[[Any], bool] | None = None) -> list[Any]:
        """Remove and return queued items (optionally only those matching
        ``predicate``); used at service shutdown."""
        with self._lock:
            if predicate is None:
                items = [item for item, _ in self._queue]
                self._queue.clear()
                return items
            keep: collections.deque[tuple[Any, int]] = collections.deque()
            out = []
            for item, c in self._queue:
                (out.append(item) if predicate(item) else keep.append((item, c)))
            self._queue = keep
            return out

    def snapshot(self) -> AdmissionSnapshot:
        with self._lock:
            return AdmissionSnapshot(
                capacity=self.capacity,
                in_flight=self._in_flight,
                queued=len(self._queue),
                admitted_total=self._admitted_total,
                rejected_total=self._rejected_total,
            )
