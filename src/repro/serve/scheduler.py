"""Deadline-aware round-robin step scheduler for progressive queries.

A progressive query is a sequence of cheap one-block steps (fetch one block
through the shared engine, fold it, re-emit the anytime estimate).  Running
each query to completion on its own thread would let one heavy tenant (large
``max_blocks``, tight ``target_rel_err``) monopolize the engine while light
queries wait whole-query times.  Instead the scheduler owns a small worker
pool and interleaves *steps*:

* Runnable tasks sit in one heap ordered by ``(deadline, enqueue seq)`` --
  earliest deadline first, FIFO among equal (and among deadline-less)
  deadlines.  After each step a task re-enqueues at the *tail* of its
  deadline class, so equal-urgency tenants round-robin one block at a time
  and a heavy query cannot starve the others.
* The step callback returns ``True`` to re-enqueue (more blocks wanted) or
  ``False`` when the task is finished (converged, exhausted, cancelled,
  deadline fired); the scheduler never inspects task internals beyond the
  optional ``deadline`` attribute (a ``time.monotonic`` instant).
* A task is owned by at most one worker at a time: it is either in the heap
  or being stepped, never both, so step callbacks need no internal locking
  against themselves.

The scheduler is generic over the task object; ``repro.serve.query_service``
plugs in query runs.  ``close()`` stops the workers, then calls the step
function's ``on_drop`` hook for every task still in the heap so owners can
finalize (cancel) them.
"""

from __future__ import annotations

import heapq
import itertools
import math
import threading
from typing import Any, Callable


class StepScheduler:
    """Interleaves one-step work items across a bounded worker pool.

    ``step``: callable ``(task) -> bool`` -- run one step, return whether
    the task wants more.  ``on_drop``: called for tasks discarded at
    ``close()`` without a final step.
    """

    def __init__(
        self,
        step: Callable[[Any], bool],
        *,
        workers: int = 4,
        on_drop: Callable[[Any], None] | None = None,
        name: str = "rsp-serve",
    ):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self._step = step
        self._on_drop = on_drop
        self._cv = threading.Condition()
        self._heap: list[tuple[float, int, Any]] = []
        self._seq = itertools.count()
        self._closed = False
        self._idle_workers = 0
        self._threads = [
            threading.Thread(target=self._loop, name=f"{name}-{i}", daemon=True)
            for i in range(workers)
        ]
        for t in self._threads:
            t.start()

    # -- submission --------------------------------------------------------
    def submit(self, task: Any) -> None:
        """Enqueue ``task`` for its next step.  Priority: its ``deadline``
        attribute (monotonic seconds; ``None`` sorts last), then FIFO."""
        if not self._push(task):
            raise RuntimeError("scheduler is closed")

    def _push(self, task: Any) -> bool:
        deadline = getattr(task, "deadline", None)
        key = math.inf if deadline is None else float(deadline)
        with self._cv:
            if self._closed:
                return False
            heapq.heappush(self._heap, (key, next(self._seq), task))
            self._cv.notify()
            return True

    @property
    def pending(self) -> int:
        with self._cv:
            return len(self._heap)

    def idle(self) -> bool:
        """True when no task is queued or being stepped (used by tests)."""
        with self._cv:
            return not self._heap and self._idle_workers == len(self._threads)

    # -- worker loop -------------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._cv:
                self._idle_workers += 1
                while not self._heap and not self._closed:
                    self._cv.wait()
                self._idle_workers -= 1
                if self._closed:
                    return
                _, _, task = heapq.heappop(self._heap)
            try:
                again = self._step(task)
            except Exception:  # noqa: BLE001 -- a step must never kill a worker
                again = False
            if again and not self._push(task):
                # closed mid-step: hand the task to the drop hook instead
                if self._on_drop is not None:
                    self._on_drop(task)

    # -- lifecycle ---------------------------------------------------------
    def close(self, *, timeout: float = 5.0) -> None:
        """Stop the workers (finishing their current step), then drop every
        still-queued task through ``on_drop``."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=timeout)
        with self._cv:
            dropped = [task for _, _, task in self._heap]
            self._heap.clear()
        if self._on_drop is not None:
            for task in dropped:
                self._on_drop(task)

    def __enter__(self) -> "StepScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
