"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch jax device state.  The dry-run launcher
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benchmarks) sees the real single device.

Target: TPU v5e, 256 chips per pod (16x16 ICI torus), 2 pods over DCN.
Axes: ("pod",) data-parallel over DCN; ("data",) data-parallel over ICI;
("model",) tensor/expert-parallel over ICI.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return make_host_mesh(shape, axes)  # jax < 0.5: no AxisType, plain Mesh


def make_host_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary small mesh over available devices (tests / examples)."""
    n = int(np.prod(shape))
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


# v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_LINK_BW = 50e9              # bytes/s per link (per chip, effective)
CHIPS_PER_POD = 256
HBM_BYTES = 16 * 1024**3        # v5e: 16 GiB per chip
