"""Roofline analysis from compiled (SPMD-partitioned, per-device) HLO.

XLA's ``cost_analysis`` counts while-loop bodies ONCE (trip counts are
ignored), which under-counts scan-over-layers models by ~L x.  This module
does structural accounting instead:

  1. parse the HLO text into computations,
  2. find ``while`` ops and their ``known_trip_count``,
  3. walk the call graph multiplying each computation's cost by the product
     of enclosing trip counts,
  4. count FLOPs from ``dot``/``convolution`` ops (2 * prod(out) * K),
     HBM traffic as operands+outputs of surviving (unfused) instructions,
     and collective bytes per kind.

Fusion bodies are costed at their call site (operands + output only -- the
internal traffic stays on-chip), which matches how TPUs see memory.

Terms (per chip, seconds), v5e constants from launch.mesh:
    T_compute    = flops / 197e12
    T_memory     = hbm_bytes / 819e9
    T_collective = wire_bytes / 50e9      (all-reduce counts 2x)
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

from repro.launch import mesh as mesh_lib

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\-\.]+)")
_INSTR = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\-\.]+)\s*=\s*(\(?)([a-z0-9]+)?(?:\[([\d,]*)\])?[^=]*?\s([a-z][a-z0-9\-]*)\("
)
_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims_str: str) -> int:
    n = _DTYPE_BYTES.get(dtype, 4)
    for d in dims_str.split(","):
        if d:
            n *= int(d)
    return n


def _prod(dims_str: str) -> int:
    out = 1
    for d in dims_str.split(","):
        if d:
            out *= int(d)
    return out


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict | None = None
    while_calls: list | None = None     # (body, cond, trips)
    calls: list | None = None           # (comp_name, kind)  kind: fusion|call|cond


def _parse_operand_shapes(line: str, shapes: dict[str, tuple[str, str]]):
    """Operand names from the first parenthesized group after the opcode."""
    m = re.search(r"[a-z][a-z0-9\-]*\(([^)]*)\)", line)
    if not m:
        return []
    ops = []
    for tok in m.group(1).split(","):
        tok = tok.strip().lstrip("%")
        if tok in shapes:
            ops.append(shapes[tok])
    return ops


def parse_hlo(text: str) -> tuple[dict[str, CompCost], str | None]:
    comps: dict[str, CompCost] = {}
    cur: str | None = None
    entry: str | None = None
    shapes: dict[str, tuple[str, str]] = {}
    cost: CompCost | None = None

    comment_re = re.compile(r"/\*.*?\*/")
    for line in text.splitlines():
        line = comment_re.sub("", line)
        stripped = line.rstrip()
        if (
            not line.startswith(" ")
            and stripped.endswith("{")
            and ("->" in line or stripped.startswith(("ENTRY", "%")))
            and not stripped.startswith("HloModule")
        ):
            m = _COMP_HEADER.match(stripped)
            if m:
                cur = m.group(2)
                if m.group(1):
                    entry = cur
                cost = comps.setdefault(cur, CompCost(collectives={}, while_calls=[], calls=[]))
                shapes = {}
                continue
        if cur is None or cost is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        name, tuple_open, dtype, dims, opcode = im.groups()
        is_tuple = tuple_open == "("
        if not is_tuple and dtype is not None and dims is not None:
            shapes[name] = (dtype, dims)
        out_bytes = 0 if is_tuple or dtype is None else _shape_bytes(dtype, dims or "")

        if opcode in _FREE_OPS:
            continue

        if opcode == "while":
            bm = re.search(r"body=%?([\w\-\.]+)", line)
            cm = re.search(r"condition=%?([\w\-\.]+)", line)
            tm = re.search(r'known_trip_count[^\d]*(\d+)', line)
            trips = int(tm.group(1)) if tm else 1
            if bm:
                cost.while_calls.append((bm.group(1), cm.group(1) if cm else None, trips))
            continue

        if opcode == "conditional":
            for br in re.findall(r"branch_computations=\{([^}]*)\}", line):
                for c in br.split(","):
                    cost.calls.append((c.strip().lstrip("%"), "cond"))
            for c in re.findall(r"(?:true_computation|false_computation)=%?([\w\-\.]+)", line):
                cost.calls.append((c, "cond"))
            continue

        if opcode in ("fusion", "call", "custom-call"):
            operands = _parse_operand_shapes(line, shapes)
            cost.bytes += out_bytes + sum(_shape_bytes(d, s) for d, s in operands)
            fm = re.search(r"(?:calls|to_apply)=%?([\w\-\.]+)", line)
            if fm:
                cost.calls.append((fm.group(1), "fusion"))
            continue

        if opcode in _COLLECTIVES:
            bucket = cost.collectives.setdefault(opcode, {"count": 0, "bytes": 0.0})
            bucket["count"] += 1
            bucket["bytes"] += out_bytes
            cost.bytes += out_bytes  # collectives also touch HBM
            continue

        if opcode in ("dot", "convolution"):
            operands = _parse_operand_shapes(line, shapes)
            k = 1
            cm2 = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            if cm2 and operands:
                lhs_dims = operands[0][1].split(",")
                for ci in cm2.group(1).split(","):
                    if ci:
                        k *= int(lhs_dims[int(ci)])
            cost.flops += 2.0 * _prod(dims or "") * k
            cost.bytes += out_bytes + sum(_shape_bytes(d, s) for d, s in operands)
            continue

        # remaining real ops (copy, slice, dus, reduce, transpose, ...)
        operands = _parse_operand_shapes(line, shapes)
        cost.bytes += out_bytes + sum(_shape_bytes(d, s) for d, s in operands)
        fm = re.search(r"(?:calls|to_apply)=%?([\w\-\.]+)", line)
        if fm:
            cost.calls.append((fm.group(1), "fusion"))

    return comps, entry


def _fusion_flops(comps: dict[str, CompCost]) -> None:
    """Dots fused into fusion bodies: attribute their flops to the call
    site (bytes stay call-site-only)."""
    # comps for fusion bodies already have .flops from their dot lines; the
    # multiplier walk handles attribution -- nothing to do here.  Kept for
    # clarity.
    return


def aggregate(comps: dict[str, CompCost], entry: str | None = None) -> dict[str, Any]:
    """Walk the call graph from the entry computation applying trip-count
    multipliers.  Fusion bodies contribute FLOPs (their dots) but not bytes
    (on-chip traffic)."""
    if entry is None:
        # heuristically: computation that is not referenced by anyone
        referenced = set()
        for c in comps.values():
            referenced.update(b for b, _, _ in c.while_calls)
            referenced.update(cc for cc, _ in c.calls)
        candidates = [n for n in comps if n not in referenced and n.startswith("main")]
        entry = candidates[0] if candidates else next(
            (n for n in comps if n not in referenced), next(iter(comps))
        )

    total = {"flops": 0.0, "bytes": 0.0, "collectives": {}}

    def visit(name: str, mult: float, in_fusion: bool) -> None:
        c = comps.get(name)
        if c is None:
            return
        total["flops"] += c.flops * mult
        if not in_fusion:
            total["bytes"] += c.bytes * mult
            for kind, b in (c.collectives or {}).items():
                bucket = total["collectives"].setdefault(kind, {"count": 0.0, "bytes": 0.0})
                bucket["count"] += b["count"] * mult
                bucket["bytes"] += b["bytes"] * mult
        for body, cond, trips in c.while_calls or []:
            visit(body, mult * trips, in_fusion)
            if cond:
                visit(cond, mult * trips, in_fusion)
        for callee, kind in c.calls or []:
            visit(callee, mult, in_fusion or kind == "fusion")

    visit(entry, 1.0, False)
    return total


def analyze_hlo(text: str) -> dict[str, Any]:
    comps, entry = parse_hlo(text)
    return aggregate(comps, entry)


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS per (arch x shape)
# ---------------------------------------------------------------------------

def model_flops(cfg, cell) -> float:
    """Global useful FLOPs for one step: 6*N*D for train (4x with remat
    excluded -- this is the *useful* count), 2*N*D for fwd-only, plus exact
    attention terms.  MoE uses active params."""
    from repro.models.api import model_specs
    from repro.models.common import param_count
    import jax

    specs = model_specs(cfg)
    total = param_count(specs)
    embed_rows = cfg.vocab_size * cfg.d_model
    if cfg.family == "encoder":
        matmul_params = total
    elif cfg.tie_embeddings:
        matmul_params = total          # single table, used in the unembed matmul
    else:
        matmul_params = total - embed_rows  # input gather is FLOP-free

    if cfg.family == "moe":
        per_expert = 3 * cfg.d_model * cfg.d_ff
        inactive = cfg.num_layers * (cfg.num_experts - cfg.num_experts_per_token) * per_expert
        matmul_params -= inactive

    B, S = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        tokens = B * S
        mult = 6.0
    elif cell.kind == "prefill":
        tokens = B * S
        mult = 2.0
    else:  # decode: one token per sequence
        tokens = B
        mult = 2.0

    flops = mult * matmul_params * tokens

    # attention score/value matmuls (full-attention families)
    Dh = cfg.resolved_head_dim
    H = cfg.num_heads
    if cfg.family in ("dense", "moe", "encoder"):
        L_attn = cfg.num_layers
    elif cfg.family == "hybrid":
        L_attn = math.ceil(cfg.num_layers / max(cfg.attn_every, 1))
    else:
        L_attn = 0
    if L_attn:
        if cell.kind == "decode":
            # one new token attends over the full cache: QK^T + PV
            flops += 4.0 * B * H * Dh * S * L_attn
        else:
            causal = 0.5 if cfg.causal else 1.0
            fwd_attn = 4.0 * B * H * Dh * S * S * causal * L_attn
            flops += fwd_attn * (3.0 if cell.kind == "train" else 1.0)

    # SSM/linear-attention state math (mamba2 / rwkv6)
    if cfg.family == "hybrid":
        mcfg = cfg.mamba_config()
        per_tok = 3 * 2 * mcfg.d_inner * mcfg.d_state  # h update + y readout
        flops += mult / 2.0 * per_tok * (B * S if cell.kind != "decode" else B) * cfg.num_layers
    if cfg.family == "rwkv":
        C = cfg.rwkv_head_dim
        per_tok = 3 * 2 * cfg.d_model * C
        flops += mult / 2.0 * per_tok * (B * S if cell.kind != "decode" else B) * cfg.num_layers

    return flops


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

_WIRE_FACTOR = {
    "all-reduce": 2.0,       # reduce-scatter + all-gather equivalent
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def roofline_terms(analysis: dict, *, chips: int) -> dict:
    """Per-chip seconds for each roofline term.  ``analysis`` comes from the
    per-device (partitioned) module, so flops/bytes are already per chip."""
    t_compute = analysis["flops"] / mesh_lib.PEAK_FLOPS_BF16
    t_memory = analysis["bytes"] / mesh_lib.HBM_BW
    wire = 0.0
    for kind, b in analysis.get("collectives", {}).items():
        wire += b["bytes"] * _WIRE_FACTOR.get(kind, 1.0)
    t_coll = wire / mesh_lib.ICI_LINK_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "wire_bytes": wire,
    }


def summarize_cell(result: dict, cfg, cell) -> dict:
    chips = 512 if result.get("multi_pod") else 256
    analysis = result["analysis"]
    terms = roofline_terms(analysis, chips=chips)
    mf = model_flops(cfg, cell)
    hlo_flops_global = analysis["flops"] * chips
    terms.update(
        model_flops_global=mf,
        hlo_flops_global=hlo_flops_global,
        useful_ratio=(mf / hlo_flops_global) if hlo_flops_global else float("nan"),
        # roofline fraction: useful compute time / total modeled time
        step_time_s=max(terms["t_compute_s"], terms["t_memory_s"], terms["t_collective_s"]),
    )
    terms["roofline_fraction"] = (
        (mf / chips / mesh_lib.PEAK_FLOPS_BF16) / terms["step_time_s"]
        if terms["step_time_s"] > 0
        else float("nan")
    )
    return terms
