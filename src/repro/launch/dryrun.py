import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell against the production mesh and extract memory / cost / collective
statistics for the roofline analysis.

The two lines above MUST stay the first statements in this file: jax locks
the device count at first init, and the dry run needs 512 placeholder host
devices to build the 16x16 (single-pod) and 2x16x16 (multi-pod) meshes.

Usage:
    python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    python -m repro.launch.dryrun --all --out results/dryrun      # subprocess per cell
    python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import json
import re
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cell_applicable, cells
from repro.distributed.sharding import (
    abstract_compute_params,
    abstract_state,
    attach_shardings,
    batch_shardings,
    cache_shardings,
    default_rules,
)
from repro.launch import mesh as mesh_lib
from repro.models import api
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainConfig, make_train_step

COLLECTIVE_RE = re.compile(
    r"=\s*(\w[\w\d.]*)\[([\d,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)",
)

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2,
}


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device output bytes of every collective op in the compiled
    (post-SPMD-partitioning) module, bucketed by collective kind."""
    out: dict[str, dict] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        bucket = out.setdefault(kind, {"count": 0, "bytes": 0})
        bucket["count"] += 1
        bucket["bytes"] += nbytes
    return out


def _loop_trip_counts(hlo_text: str) -> list[int]:
    """Extract while-loop trip counts so scan-body collectives can be scaled
    by the number of layer iterations."""
    return [int(x) for x in re.findall(r'"known_trip_count":\{"n":"(\d+)"', hlo_text)]


def build_cell(arch: str, shape: str, *, multi_pod: bool, optimized: bool = False):
    """Returns (jitted_fn, example_args) with fully-sharded abstract inputs.

    ``optimized=True`` applies the beyond-paper perf flags (flat-head
    attention TP layout, seq-chunked CE); the default is the paper-faithful
    baseline.  Both variants are recorded in EXPERIMENTS.md §Perf.
    """
    import dataclasses as _dc

    cfg = ARCHS[arch]
    if optimized:
        cfg = _dc.replace(
            cfg, flat_attention=True, loss_seq_chunks=16, moe_sort_dispatch=True
        )
    cell = SHAPES[shape]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(mesh, cfg=cfg, shard_kv_seq=(shape == "long_500k"))
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    specs = api.model_specs(cfg)
    batch_abs = api.input_specs(cfg, cell)
    batch = attach_shardings(batch_abs, batch_shardings(batch_abs, rules))

    if cell.kind == "train":
        moe_groups = dp if cfg.family == "moe" else 1
        train_cfg = TrainConfig(total_steps=1000, warmup_steps=10, moe_groups=moe_groups)
        step = make_train_step(cfg, AdamWConfig(), train_cfg, rules=rules)
        state = {
            "params": abstract_compute_params(specs, rules),
            "opt": abstract_state(specs, rules),
        }
        return jax.jit(step, donate_argnums=0), (state, batch)

    params = abstract_compute_params(specs, rules)
    if cfg.family == "encoder":
        # prefill == encoder forward
        from repro.distributed.sharding import activation_sharding
        fwd = api.make_forward_fn(cfg)

        def enc_fn(params, batch):
            from repro.distributed.sharding import activation_sharding as ash
            with ash(rules):
                return fwd(params, batch)

        return jax.jit(enc_fn), (params, batch)

    moe_groups = dp if cfg.family == "moe" else 1
    caches_abs = api.cache_specs(cfg, cell.global_batch, cell.seq_len)
    caches = attach_shardings(caches_abs, cache_shardings(caches_abs, rules))

    if cell.kind == "prefill":
        inner = api.make_prefill_fn(cfg, moe_groups=moe_groups)
    else:
        inner = api.make_decode_fn(cfg, moe_groups=moe_groups)

    def fn(params, caches, batch):
        from repro.distributed.sharding import activation_sharding as ash
        with ash(rules):
            return inner(params, caches, batch)

    return jax.jit(fn, donate_argnums=1), (params, caches, batch)


def dryrun_cell(
    arch: str, shape: str, *, multi_pod: bool, save_hlo: str | None = None,
    optimized: bool = False,
) -> dict:
    ok, why = cell_applicable(arch, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod, "skipped": why}
    t0 = time.time()
    fn, args = build_cell(arch, shape, multi_pod=multi_pod, optimized=optimized)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    result: dict = {
        "arch": arch,
        "shape": shape,
        "multi_pod": multi_pod,
        "optimized": optimized,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }

    try:
        mem = compiled.memory_analysis()
        result["memory"] = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
        print("memory_analysis:", result["memory"])
    except Exception as e:  # backend-dependent
        result["memory"] = {"error": str(e)}

    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        result["cost"] = {
            k: float(v)
            for k, v in cost.items()
            if k in ("flops", "bytes accessed", "transcendentals")
            or k.startswith("bytes accessed")
        }
        print("cost_analysis flops:", result["cost"].get("flops"))
    except Exception as e:
        result["cost"] = {"error": str(e)}

    hlo = compiled.as_text()
    result["collectives_unscaled"] = parse_collectives(hlo)
    result["loop_trip_counts"] = _loop_trip_counts(hlo)
    result["hlo_bytes"] = len(hlo)
    # structural accounting: per-computation costs x while trip counts
    from repro.launch.roofline import analyze_hlo

    result["analysis"] = analyze_hlo(hlo)
    print(
        "structural: flops={flops:.3e} bytes={bytes:.3e} collectives={c}".format(
            flops=result["analysis"]["flops"],
            bytes=result["analysis"]["bytes"],
            c={k: f"{v['bytes']:.2e}" for k, v in result["analysis"]["collectives"].items()},
        )
    )
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    del hlo
    return result


def dryrun_rsp_partition(*, multi_pod: bool, records: int | None = None) -> dict:
    """Dry-run the paper's Algorithm-1 collective program (shard_map +
    all_to_all) on the production mesh: the partition stage of the RSP data
    model, lowered exactly as it would run during corpus preparation.

    Records are 4097-token sequences (the train_4k record).  The multi-pod
    variant partitions within each pod; cross-pod RSP validity follows from
    Theorem 1 (proportional unions of RSP blocks).
    """
    from repro.core.partition import distributed_rsp_partition

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    D = int(mesh.shape["data"])
    if records is None:
        records = D * D * 64          # delta = 64 records per sub-block
    seq = 4097
    data = jax.ShapeDtypeStruct(
        (records, seq), jnp.int32,
        sharding=jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec("data", None)
        ),
    )
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def fn(data, key):
        return distributed_rsp_partition(data, key, mesh, axis="data")

    t0 = time.time()
    lowered = jax.jit(fn).lower(data, key)
    compiled = lowered.compile()
    result = {
        "arch": "rsp-partition",
        "shape": f"records{records}x{seq}",
        "multi_pod": multi_pod,
        "compile_s": round(time.time() - t0, 1),
    }
    try:
        mem = compiled.memory_analysis()
        result["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes")
            if hasattr(mem, k)
        }
    except Exception as e:
        result["memory"] = {"error": str(e)}
    from repro.launch.roofline import analyze_hlo

    result["analysis"] = analyze_hlo(compiled.as_text())
    return result


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=sorted(ARCHS) + ["rsp-partition"], default=None)
    p.add_argument("--shape", choices=sorted(SHAPES), default=None)
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--optimized", action="store_true",
                   help="beyond-paper perf flags (flat attention, chunked CE)")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--all", action="store_true", help="run every applicable cell in subprocesses")
    p.add_argument("--out", default="results/dryrun")
    p.add_argument("--save-hlo", default=None)
    p.add_argument("--timeout", type=int, default=3000)
    args = p.parse_args()

    os.makedirs(args.out, exist_ok=True)

    if args.arch == "rsp-partition":
        result = dryrun_rsp_partition(multi_pod=args.multi_pod)
        tag = f"rsp-partition_{'multi' if args.multi_pod else 'single'}"
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(result, f, indent=1)
        print(json.dumps(result, indent=1))
        return 0

    if args.all:
        failures = []
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        all_cells = cells() + [("rsp-partition", "corpus")]
        for arch, shape in all_cells:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                out_file = os.path.join(args.out, tag + ".json")
                if os.path.exists(out_file):
                    print(f"[skip existing] {tag}")
                    continue
                if arch == "rsp-partition":
                    cmd = [
                        sys.executable, "-m", "repro.launch.dryrun",
                        "--arch", arch, "--out", args.out,
                    ] + (["--multi-pod"] if mp else [])
                    tag = f"rsp-partition_{'multi' if mp else 'single'}"
                    out_file = os.path.join(args.out, tag + ".json")
                    if os.path.exists(out_file):
                        continue
                else:
                    cmd = [
                        sys.executable, "-m", "repro.launch.dryrun",
                        "--arch", arch, "--shape", shape, "--out", args.out,
                    ] + (["--multi-pod"] if mp else [])
                print(f"[run] {tag}", flush=True)
                proc = subprocess.run(cmd, capture_output=True, text=True, timeout=args.timeout)
                if proc.returncode != 0:
                    failures.append(tag)
                    with open(os.path.join(args.out, tag + ".err"), "w") as f:
                        f.write(proc.stdout[-5000:] + "\n" + proc.stderr[-10000:])
                    print(f"[FAIL] {tag}")
        print(f"done; {len(failures)} failures: {failures}")
        return 1 if failures else 0

    if not args.arch or not args.shape:
        p.error("--arch/--shape required unless --all")
    try:
        result = dryrun_cell(
            args.arch, args.shape, multi_pod=args.multi_pod, save_hlo=args.save_hlo,
            optimized=args.optimized,
        )
    except Exception:
        traceback.print_exc()
        return 1
    tag = f"{args.arch}_{args.shape}_{'multi' if args.multi_pod else 'single'}"
    if args.optimized:
        tag += "_opt"
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    print(json.dumps({k: v for k, v in result.items() if k != "loop_trip_counts"}, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
