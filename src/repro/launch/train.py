"""Training launcher.

On a real TPU fleet this is the per-host entry point (jax.distributed
initializes from the cluster env); on CPU it runs reduced presets for local
validation.  Data always flows through the RSP loader: the corpus is
partitioned once (Algorithm 1), each host consumes block-level samples, and
the O(1) sampler state makes restarts exact.

    python -m repro.launch.train --arch llama3.2-1b --preset cpu-small \
        --steps 50 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_config
from repro.core import RSPSpec, two_stage_partition_np
from repro.data import BlockSource, RSPLoader
from repro.data.synthetic import make_token_corpus
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="llama3.2-1b")
    ap.add_argument("--preset", choices=("cpu-small", "full"), default="cpu-small")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/rsp_train_ckpt")
    ap.add_argument("--blocks", type=int, default=32)
    ap.add_argument("--sequences", type=int, default=512)
    ap.add_argument("--distributed", action="store_true",
                    help="initialize jax.distributed from cluster env (TPU fleet)")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    cfg = ARCHS[args.arch] if args.preset == "full" else smoke_config(args.arch)
    if cfg.family == "encoder":
        raise SystemExit("use the masked-prediction driver for encoder archs (see tests)")

    corpus = make_token_corpus(
        args.sequences, args.seq + 1, vocab_size=cfg.vocab_size, seed=0, drift=True
    )
    spec = RSPSpec(
        num_records=args.sequences, num_blocks=args.blocks,
        num_original_blocks=args.blocks, seed=1,
    )
    blocks = two_stage_partition_np(corpus, spec)
    loader = RSPLoader(BlockSource(blocks=blocks), batch_size=args.batch, seed=5)

    tc = TrainConfig(
        total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
        checkpoint_every=max(args.steps // 4, 1), log_every=max(args.steps // 10, 1),
        microbatch=args.microbatch, seed=0,
    )
    trainer = Trainer(
        cfg, AdamWConfig(lr=args.lr), tc, loader, args.ckpt_dir,
        batch_transform=lambda b: {"tokens": jnp.asarray(b, jnp.int32)},
    )
    trainer.run()
    print(json.dumps(trainer.history, indent=1))


if __name__ == "__main__":
    main()
