"""Serving launcher: restore a checkpoint (or init fresh) and decode batched
requests; ``--ensemble k`` serves the RSP block-ensemble (Sec. 9 combination
at decode time).

    python -m repro.launch.serve --arch qwen2-0.5b --preset cpu-small \
        --batch 4 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store as ckpt
from repro.configs import ARCHS, smoke_config
from repro.models import api
from repro.models.common import init_params
from repro.serve.engine import EnsembleServer, ServeConfig, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2-0.5b")
    ap.add_argument("--preset", choices=("cpu-small", "full"), default="cpu-small")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ensemble", type=int, default=0, help="serve k base models averaged")
    args = ap.parse_args()

    cfg = ARCHS[args.arch] if args.preset == "full" else smoke_config(args.arch)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only archs do not decode")

    specs = api.model_specs(cfg)
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        step = ckpt.latest_step(args.ckpt_dir)
        like = jax.eval_shape(lambda: init_params(specs, jax.random.PRNGKey(0)))
        state, _ = ckpt.restore(args.ckpt_dir, step, {"params": like}, )
        params = state["params"]
        print(f"restored step {step} from {args.ckpt_dir}")
    else:
        params = init_params(specs, jax.random.PRNGKey(0))
    params = jax.tree.map(lambda a: a.astype(jnp.float32), params)

    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (args.batch, args.prompt_len), np.int32)
    )
    sc = ServeConfig(temperature=args.temperature)
    if args.ensemble > 1:
        stacked = jax.tree.map(
            lambda a: jnp.stack([a] * args.ensemble), params
        )
        server = EnsembleServer(cfg, stacked, sc)
        label = f"ensemble[{args.ensemble}]"
    else:
        server = Server(cfg, params, sc)
        label = "single"

    t0 = time.time()
    out = server.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    tps = args.batch * args.new_tokens / dt
    print(f"{label}: generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    for row in out[:2]:
        print("  ", row.tolist())


if __name__ == "__main__":
    main()
