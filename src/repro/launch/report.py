"""Render the dry-run sweep into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report results/dryrun
"""

from __future__ import annotations

import json
import os
import sys

from repro.configs import ARCHS, SHAPES, cell_applicable, cells
from repro.launch.roofline import summarize_cell


def load_results(root: str, *, optimized: bool = False) -> dict[tuple[str, str, bool], dict]:
    out = {}
    for name in os.listdir(root):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(root, name)) as f:
            r = json.load(f)
        if bool(r.get("optimized")) != optimized:
            continue
        out[(r.get("arch"), r.get("shape"), bool(r.get("multi_pod")))] = r
    return out


def fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def dryrun_table(results: dict) -> str:
    lines = [
        "| arch | shape | mesh | compile | args/chip | temp/chip | fits 16G? | HLO flops/chip | collective bytes/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch, shape in cells():
        for mp in (False, True):
            r = results.get((arch, shape, mp))
            mesh = "2x16x16" if mp else "16x16"
            if r is None:
                lines.append(f"| {arch} | {shape} | {mesh} | MISSING | | | | | |")
                continue
            mem = r.get("memory", {})
            args = mem.get("argument_size_in_bytes", 0)
            temp = mem.get("temp_size_in_bytes", 0)
            fits = "yes" if (args + temp) < 16 * 1024**3 else "NO"
            a = r.get("analysis", {})
            coll = sum(v["bytes"] for v in a.get("collectives", {}).values())
            lines.append(
                f"| {arch} | {shape} | {mesh} | {r.get('compile_s', '?')}s "
                f"| {fmt_bytes(args)} | {fmt_bytes(temp)} | {fits} "
                f"| {a.get('flops', 0):.2e} | {fmt_bytes(coll)} |"
            )
    return "\n".join(lines)


def skip_table() -> str:
    lines = ["| arch | shape | reason |", "|---|---|---|"]
    for arch in ARCHS:
        for shape in SHAPES:
            ok, why = cell_applicable(arch, shape)
            if not ok:
                lines.append(f"| {arch} | {shape} | {why} |")
    return "\n".join(lines)


def roofline_table(results: dict) -> str:
    lines = [
        "| arch | shape | T_compute | T_memory | T_collective | dominant | MODEL_FLOPS | useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch, shape in cells():
        r = results.get((arch, shape, False))
        if r is None or "analysis" not in r:
            continue
        t = summarize_cell(r, ARCHS[arch], SHAPES[shape])
        lines.append(
            f"| {arch} | {shape} | {fmt_s(t['t_compute_s'])} | {fmt_s(t['t_memory_s'])} "
            f"| {fmt_s(t['t_collective_s'])} | **{t['dominant']}** "
            f"| {t['model_flops_global']:.2e} | {t['useful_ratio']:.2f} "
            f"| {t['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


def rsp_partition_rows(results: dict) -> str:
    lines = ["| mesh | shape | compile | flops/chip | bytes/chip | all-to-all bytes/chip |", "|---|---|---|---|---|---|"]
    for (a, s, mp), r in sorted(results.items(), key=lambda kv: kv[0][2]):
        if a != "rsp-partition":
            continue
        an = r.get("analysis", {})
        a2a = an.get("collectives", {}).get("all-to-all", {}).get("bytes", 0)
        lines.append(
            f"| {'2x16x16' if mp else '16x16'} | {s} | {r['compile_s']}s "
            f"| {an.get('flops', 0):.2e} | {fmt_bytes(an.get('bytes', 0))} | {fmt_bytes(a2a)} |"
        )
    return "\n".join(lines)


def worst_cells(results: dict, n: int = 8) -> list[tuple]:
    scored = []
    for arch, shape in cells():
        r = results.get((arch, shape, False))
        if r is None or "analysis" not in r:
            continue
        t = summarize_cell(r, ARCHS[arch], SHAPES[shape])
        scored.append((t["roofline_fraction"], arch, shape, t["dominant"], t))
    scored.sort()
    return scored[:n]


def perf_comparison(base: dict, opt: dict) -> str:
    lines = [
        "| arch | shape | T_mem base | T_mem opt | T_coll base | T_coll opt | frac base | frac opt | speedup |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch, shape in cells():
        b = base.get((arch, shape, False))
        o = opt.get((arch, shape, False))
        if b is None or o is None or "analysis" not in b or "analysis" not in o:
            continue
        tb = summarize_cell(b, ARCHS[arch], SHAPES[shape])
        to = summarize_cell(o, ARCHS[arch], SHAPES[shape])
        speed = tb["step_time_s"] / to["step_time_s"] if to["step_time_s"] else float("nan")
        lines.append(
            f"| {arch} | {shape} | {fmt_s(tb['t_memory_s'])} | {fmt_s(to['t_memory_s'])} "
            f"| {fmt_s(tb['t_collective_s'])} | {fmt_s(to['t_collective_s'])} "
            f"| {tb['roofline_fraction']:.4f} | {to['roofline_fraction']:.4f} "
            f"| **{speed:.1f}x** |"
        )
    return "\n".join(lines)


def main() -> None:
    root = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    results = load_results(root)
    print("## Dry-run table (both meshes)\n")
    print(dryrun_table(results))
    print("\n## Skipped cells\n")
    print(skip_table())
    print("\n## Roofline (single-pod, per chip)\n")
    print(roofline_table(results))
    print("\n## RSP partition collective program\n")
    print(rsp_partition_rows(results))
    print("\n## Worst roofline fractions (hillclimb candidates)\n")
    for frac, arch, shape, dom, _ in worst_cells(results):
        print(f"- {arch} x {shape}: frac={frac:.4f} dominant={dom}")
    opt = load_results(root, optimized=True)
    if opt:
        print("\n## Baseline vs optimized (single-pod)\n")
        print(perf_comparison(results, opt))


if __name__ == "__main__":
    main()
