from repro.train.loop import TrainConfig, Trainer, init_state, make_train_step

__all__ = [k for k in dir() if not k.startswith("_")]
