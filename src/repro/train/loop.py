"""Training loop: step builder (mixed precision + ZeRO sharding constraints +
optional microbatch gradient accumulation) and a preemption-safe Trainer.

The data pipeline is the RSP loader: every batch is a block-level sample
(Definition 4), and its O(1) sampler state rides along in each checkpoint so
a restart reproduces the exact batch sequence.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import store as ckpt
from repro.distributed.sharding import ShardingRules, activation_sharding
from repro.models import api
from repro.models.common import init_params
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import SCHEDULES


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    warmup_steps: int = 10
    schedule: str = "cosine"
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    log_every: int = 10
    microbatch: int = 0          # 0 = no accumulation; else per-step microbatch count
    moe_groups: int = 1
    seed: int = 0


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    train_cfg: TrainConfig,
    *,
    rules: ShardingRules | None = None,
) -> Callable:
    """Pure (state, batch) -> (state, metrics).  state = {params, opt}."""
    loss_fn = api.make_loss_fn(cfg, moe_groups=train_cfg.moe_groups)
    schedule = SCHEDULES[train_cfg.schedule]

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step_fn(state, batch):
        with activation_sharding(rules):
            params = state["params"]
            if train_cfg.microbatch > 1:
                # split the global batch into microbatches; accumulate fp32
                n = train_cfg.microbatch
                parts = jax.tree.map(lambda a: a.reshape(n, a.shape[0] // n, *a.shape[1:]), batch)

                def acc_body(carry, mb):
                    loss_a, grads_a = carry
                    loss, metrics, grads = grads_of(params, mb)
                    grads = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), grads_a, grads)
                    return (loss_a + loss / n, grads), metrics

                zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss, grads), metrics_all = jax.lax.scan(acc_body, (0.0, zero), parts)
                grads = jax.tree.map(lambda g: g / n, grads)
                metrics = jax.tree.map(lambda a: a[-1], metrics_all)
            else:
                loss, metrics, grads = grads_of(params, batch)

            lr_scale = schedule(
                state["opt"]["step"],
                warmup_steps=train_cfg.warmup_steps,
                total_steps=train_cfg.total_steps,
            )
            new_opt, new_params, stats = adamw_update(
                state["opt"], grads, opt_cfg, lr_scale=lr_scale
            )
            out_metrics = {"loss": loss, **metrics, **stats}
            return {"params": new_params, "opt": new_opt}, out_metrics

    return step_fn


def init_state(cfg: ModelConfig, seed: int = 0, compute_dtype=jnp.bfloat16) -> dict:
    specs = api.model_specs(cfg)
    master = init_params(specs, jax.random.PRNGKey(seed))
    opt = adamw_init(master)
    params = jax.tree.map(lambda p: p.astype(compute_dtype), master)
    return {"params": params, "opt": opt}


class Trainer:
    """Checkpoint/restart training driver.

    Fault tolerance: SIGTERM/SIGINT triggers a final checkpoint; on start,
    the latest checkpoint (params, optimizer, *and loader state*) is restored
    so a killed run resumes exactly where it stopped.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: AdamWConfig,
        train_cfg: TrainConfig,
        loader,                       # RSPLoader-compatible (next_batch/state_dict)
        ckpt_dir: str,
        *,
        rules: ShardingRules | None = None,
        batch_transform: Callable | None = None,
    ):
        self.cfg, self.opt_cfg, self.train_cfg = cfg, opt_cfg, train_cfg
        self.loader = loader
        self.ckpt_dir = ckpt_dir
        self.rules = rules
        self.batch_transform = batch_transform or (lambda b: b)
        self.step_fn = jax.jit(make_train_step(cfg, opt_cfg, train_cfg, rules=rules))
        self.checkpointer = ckpt.AsyncCheckpointer(ckpt_dir, keep_last=train_cfg.keep_checkpoints)
        self.history: list[dict] = []
        self._preempted = False

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not on main thread

    def run(self, state: dict | None = None, *, stop_after_steps: int | None = None) -> dict:
        """``stop_after_steps`` emulates preemption after N steps (the final
        checkpoint is written exactly as the SIGTERM path would)."""
        self._install_signal_handlers()
        start_step = 0
        if state is None:
            latest = ckpt.latest_step(self.ckpt_dir)
            if latest is not None:
                like = jax.eval_shape(lambda: init_state(self.cfg, self.train_cfg.seed))
                state, extra = ckpt.restore(self.ckpt_dir, latest, like)
                self.loader.load_state_dict(extra["loader"])
                start_step = latest
            else:
                state = init_state(self.cfg, self.train_cfg.seed)

        for step in range(start_step, self.train_cfg.total_steps):
            if stop_after_steps is not None and step - start_step >= stop_after_steps:
                self._preempted = True
                self.checkpointer.save(step, state, extra={"loader": self.loader.state_dict()})
                break
            batch = self.batch_transform(self.loader.next_batch())
            t0 = time.time()
            state, metrics = self.step_fn(state, batch)
            if (step + 1) % self.train_cfg.log_every == 0 or step == start_step:
                metrics = jax.tree.map(lambda a: float(a), metrics)
                metrics.update(step=step + 1, sec_per_step=time.time() - t0)
                self.history.append(metrics)
            if (step + 1) % self.train_cfg.checkpoint_every == 0 or self._preempted:
                self.checkpointer.save(
                    step + 1, state, extra={"loader": self.loader.state_dict()}
                )
            if self._preempted:
                break
        self.checkpointer.wait()
        return state
