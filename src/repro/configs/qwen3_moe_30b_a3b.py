"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768
(per expert) vocab=151936, MoE 128 experts top-8, qk-norm, head_dim 128.
[hf:Qwen/Qwen3-30B-A3B]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    qk_norm=True,
    num_experts=128,
    num_experts_per_token=8,
    rope_theta=1000000.0,
)
