"""hubert-xlarge [audio]: 48L d_model=1280 16H (MHA kv=16) d_ff=5120
vocab=504 -- encoder-only; the waveform/CNN frontend is a stub
(input_specs provides precomputed frame embeddings).  [arXiv:2106.07447]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    rope=False,
)
