"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 -- early-fusion: VQ image tokens share the text vocabulary, so
the backbone is a dense decoder and the VQ tokenizer frontend is a stub
(input_specs provides token ids).  Uses qk-norm per the paper.
[arXiv:2405.09818]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="dense",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    rope_theta=10000.0,
)
