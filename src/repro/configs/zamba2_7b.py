"""zamba2-7b [hybrid]: 81L d_model=3584 32H (MHA kv=32) d_ff=14336
vocab=32000, ssm_state=64 -- Mamba2 stack + shared attention block every 6
layers (one shared block; see DESIGN.md for the simplification vs the
paper's two alternating blocks).  [arXiv:2411.15242]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_kernel=4,
    attn_every=6,
    rope_theta=10000.0,
)
