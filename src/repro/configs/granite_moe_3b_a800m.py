"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
(per expert) vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-3b-a800m-base]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    num_experts_per_token=8,
    rope_theta=10000.0,
    tie_embeddings=True,
)
