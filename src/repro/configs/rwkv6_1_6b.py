"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536 --
Finch: data-dependent per-channel decay linear attention.
[arXiv:2404.05892]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="rwkv",
    num_layers=24,
    d_model=2048,
    num_heads=32,          # d_model / rwkv_head_dim
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    rwkv_head_dim=64,
    rope=False,
)
