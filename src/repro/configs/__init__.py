"""Architecture registry: the 10 assigned configs, reduced smoke variants,
shape cells, and (arch x shape) applicability.

Cells skipped per the assignment (recorded in EXPERIMENTS.md):
  * long_500k -- only for sub-quadratic archs (zamba2-7b, rwkv6-1.6b)
  * decode shapes -- skipped for encoder-only (hubert-xlarge)
"""

from __future__ import annotations

import dataclasses

from repro.configs.shapes import SHAPES, ShapeCell
from repro.models.config import ModelConfig

from repro.configs.llama3_2_1b import CONFIG as LLAMA32_1B
from repro.configs.granite_20b import CONFIG as GRANITE_20B
from repro.configs.qwen3_14b import CONFIG as QWEN3_14B
from repro.configs.qwen2_0_5b import CONFIG as QWEN2_05B
from repro.configs.zamba2_7b import CONFIG as ZAMBA2_7B
from repro.configs.chameleon_34b import CONFIG as CHAMELEON_34B
from repro.configs.granite_moe_3b_a800m import CONFIG as GRANITE_MOE
from repro.configs.qwen3_moe_30b_a3b import CONFIG as QWEN3_MOE
from repro.configs.rwkv6_1_6b import CONFIG as RWKV6_16B
from repro.configs.hubert_xlarge import CONFIG as HUBERT_XL

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        LLAMA32_1B,
        GRANITE_20B,
        QWEN3_14B,
        QWEN2_05B,
        ZAMBA2_7B,
        CHAMELEON_34B,
        GRANITE_MOE,
        QWEN3_MOE,
        RWKV6_16B,
        HUBERT_XL,
    ]
}

# archs allowed to run the long_500k decode cell (sub-quadratic context)
SUBQUADRATIC = {"zamba2-7b", "rwkv6-1.6b"}


def cell_applicable(arch: str, shape: str) -> tuple[bool, str]:
    cfg = ARCHS[arch]
    cell = SHAPES[shape]
    if cfg.family == "encoder" and cell.kind == "decode":
        return False, "encoder-only: no decode step"
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, "full-attention arch: long_500k restricted to SSM/hybrid"
    return True, ""


def cells() -> list[tuple[str, str]]:
    """All applicable (arch, shape) dry-run cells."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            ok, _ = cell_applicable(arch, shape)
            if ok:
                out.append((arch, shape))
    return out


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    cfg = ARCHS[arch]
    shrink: dict = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        k_block=16,
    )
    if cfg.family == "moe":
        # ample capacity so smoke decode-vs-forward comparisons see no drops
        shrink.update(num_experts=8, num_experts_per_token=2, d_ff=32, moe_capacity_factor=8.0)
    if cfg.family == "hybrid":
        # exercise the epilogue: 5 layers, shared attn every 2 -> 2 rounds + 1
        shrink.update(num_layers=5, attn_every=2, ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    if cfg.family == "rwkv":
        shrink.update(rwkv_head_dim=16, lora_rank=8, num_heads=4, num_kv_heads=4)
    return dataclasses.replace(cfg, **shrink)


__all__ = [
    "ARCHS",
    "SHAPES",
    "ShapeCell",
    "SUBQUADRATIC",
    "cell_applicable",
    "cells",
    "smoke_config",
]
