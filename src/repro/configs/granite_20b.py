"""granite-20b [dense]: 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 -- code model (GPT-BigCode lineage: MQA + 2-matrix GELU MLP;
the 2-matrix MLP is what lands the parameter count at ~20B).
[arXiv:2405.04324]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="gelu",
    rope_theta=10000.0,
)
