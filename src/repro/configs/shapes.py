"""Assigned input-shape cells (LM-family): seq_len x global_batch.

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), not ``train_step``.  ``long_500k`` requires
sub-quadratic context handling and runs only for SSM/hybrid archs;
encoder-only archs have no decode shapes.  Applicability is resolved in
``repro.configs.cells()``.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str              # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}
