"""RSP-backed training data loader.

The loader realizes the paper's pipeline for model training: the corpus is an
RSP (materialized via ``core.registry.RSPStore`` or held in memory), each host
consumes a block-level sample stream (Definition 4, or a sketch-guided
``SamplingPolicy``), and global batches are assembled from the records of the
currently open blocks.  By Lemma 1 every global batch is a random sample of
the corpus -- with no run-time global shuffle, and with O(1)-sized resumable
state.

Block movement is delegated to ``repro.rsp.engine.BlockExecutor``: the loader
keeps ``open_blocks + prefetch`` blocks in flight (fetched *and* permuted on
the executor's worker threads), and worker exceptions propagate to
``next_batch()`` instead of hanging the consumer.
"""

from __future__ import annotations

import collections
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Iterator

import numpy as np

from repro.core.registry import RSPStore
from repro.core.sampler import SamplingPolicy, make_policy


class BlockSource:
    """Uniform interface over in-memory stacked blocks, an RSPStore, or an
    ``repro.rsp.RSPDataset`` (anything with ``num_blocks`` / ``block(k)``)."""

    def __init__(
        self,
        blocks: np.ndarray | None = None,
        store: RSPStore | None = None,
        dataset=None,
    ):
        if sum(x is not None for x in (blocks, store, dataset)) != 1:
            raise ValueError("provide exactly one of blocks / store / dataset")
        self._blocks = blocks
        self._store = store
        self._dataset = dataset
        self._summaries = None

    @property
    def num_blocks(self) -> int:
        if self._blocks is not None:
            return self._blocks.shape[0]
        if self._dataset is not None:
            return self._dataset.num_blocks
        return self._store.num_blocks()

    def load(self, block_id: int) -> np.ndarray:
        if self._blocks is not None:
            return np.asarray(self._blocks[block_id])
        if self._dataset is not None:
            return np.asarray(self._dataset.block(block_id))
        return np.asarray(self._store.load_block(block_id))

    def summaries(self):
        """Per-block sketches for sketch-guided sampling policies: from the
        dataset / store manifest when present, else computed once from the
        blocks (one full scan, cached)."""
        if self._dataset is not None:
            return self._dataset.summaries
        from repro.rsp.sketch import load_summaries
        from repro.rsp.summaries import summarize_blocks

        if self._summaries is None:
            raw = self._store.summaries() if self._store is not None else None
            if raw is not None:
                self._summaries = load_summaries(raw)
            else:
                self._summaries = summarize_blocks(
                    self.load(k) for k in range(self.num_blocks)
                )
        return self._summaries


class _OpenBlock:
    """One sampled block in the loader's pool: id, permutation tag, the
    (possibly still in-flight) permuted records, and the read cursor."""

    __slots__ = ("block_id", "tag", "cursor", "_future", "_records")

    def __init__(self, block_id: int, tag: int, future: Future, cursor: int = 0):
        self.block_id = block_id
        self.tag = tag
        self.cursor = cursor
        self._future = future
        self._records: np.ndarray | None = None

    def records(self) -> np.ndarray:
        """The permuted block; blocks until the fetch lands and re-raises any
        worker exception here."""
        if self._records is None:
            self._records = np.asarray(self._future.result())
        return self._records

    def cancel(self) -> None:
        self._future.cancel()


class RSPLoader:
    """Per-host batch iterator over an RSP corpus.

    Batches of ``batch_size`` records are drawn from a rolling pool of
    sampled blocks; when a block is exhausted the policy provides the next
    one.  Records inside a block are consumed in a per-visit permuted order
    (cheap: block fits in memory by construction).  The engine keeps
    ``open_blocks + prefetch`` blocks in flight on worker threads
    (``prefetch=0`` falls back to synchronous loads).

    ``state_dict``/``load_state_dict`` capture (policy state, open-pool
    block ids + cursors) for exact O(open-pool) restart -- resuming reloads
    only the blocks that were open, never the consumed history.
    """

    def __init__(
        self,
        source: BlockSource,
        *,
        batch_size: int,
        seed: int = 0,
        open_blocks: int = 2,
        drop_last: bool = True,
        transform: Callable[[np.ndarray], np.ndarray] | None = None,
        policy: str | SamplingPolicy = "uniform",
        prefetch: int = 2,
        fetcher=None,
        executor=None,
    ):
        from repro.rsp.engine import BlockExecutor, as_fetcher

        self.source = source
        self.batch_size = batch_size
        self.open_blocks = open_blocks
        self.drop_last = drop_last
        self.transform = transform
        self._seed = seed
        needs_sketches = isinstance(policy, str) and policy != "uniform"
        self.policy = make_policy(
            policy,
            source.num_blocks,
            seed=seed,
            summaries=source.summaries() if needs_sketches else None,
        )
        self._owns_executor = executor is None
        # blocks are consumed once per epoch: no LRU benefit, so cache off.
        # ``fetcher`` overrides where blocks come from (e.g. the dataset's
        # configured mmap/custom fetcher) while ``source`` still provides
        # num_blocks and sketches.
        self._executor = executor if executor is not None else BlockExecutor(
            as_fetcher(source if fetcher is None else fetcher),
            prefetch=prefetch,
            cache_blocks=0,
        )
        self._pool: collections.deque[_OpenBlock] = collections.deque()
        self._consumed_batches = 0

    @property
    def sampler(self):
        """The underlying ``BlockSampler`` (uniform policy only; else None)."""
        return getattr(self.policy, "sampler", None)

    # -- iteration -----------------------------------------------------------
    def _permute(self, block_id: int, tag: int, block: np.ndarray) -> np.ndarray:
        block = np.asarray(block)
        rng = np.random.default_rng(
            np.random.SeedSequence([self._seed, 0xD47A, tag, block_id])
        )
        return block[rng.permutation(block.shape[0])]

    def _request(self, block_id: int, tag: int, cursor: int = 0) -> None:
        """Start fetching + permuting one block on the engine's workers."""
        fut = self._executor.fetch_async(
            block_id, lambda b, _id=block_id, _t=tag: self._permute(_id, _t, b)
        )
        self._pool.append(_OpenBlock(block_id, tag, fut, cursor))

    def _refill(self) -> None:
        target = self.open_blocks + self._executor.prefetch
        while len(self._pool) < target:
            (bid,) = self.policy.sample(1)
            self._request(bid, self.policy.epoch)

    def next_batch(self) -> np.ndarray:
        out: list[np.ndarray] = []
        need = self.batch_size
        while need > 0:
            self._refill()
            entry = self._pool[0]
            records = entry.records()  # propagates worker exceptions
            take = min(need, records.shape[0] - entry.cursor)
            out.append(records[entry.cursor : entry.cursor + take])
            entry.cursor += take
            need -= take
            if entry.cursor >= records.shape[0]:
                self._pool.popleft()
        batch = np.concatenate(out, axis=0)
        self._consumed_batches += 1
        return self.transform(batch) if self.transform else batch

    def __iter__(self) -> Iterator[np.ndarray]:
        while True:
            yield self.next_batch()

    def close(self) -> None:
        """Terminal: cancels in-flight fetches and releases worker threads.
        The open-pool position is discarded -- ``state_dict()`` first if the
        stream should be resumable.  (A dropped loader is also reclaimed by
        GC -- idle engine workers exit once the executor is collected -- but
        explicit close / ``with`` is deterministic.)"""
        for entry in self._pool:
            entry.cancel()
        self._pool.clear()
        if self._owns_executor:
            self._executor.close()

    def __enter__(self) -> "RSPLoader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- checkpointing ---------------------------------------------------------
    def state_dict(self) -> dict:
        """Block-granular state: policy position + the open pool's
        (block id, permutation tag, cursor) triples.  In-flight prefetched
        blocks are pool entries with cursor 0, so nothing is lost."""
        return {
            "version": 2,
            "seed": self._seed,  # permutation seed: resume is self-contained
            "policy": self.policy.state_dict(),
            "consumed_batches": self._consumed_batches,
            "pool": [
                {"block_id": e.block_id, "tag": e.tag, "cursor": e.cursor}
                for e in self._pool
            ],
        }

    def load_state_dict(self, state: dict) -> None:
        """Exact resume in O(open-pool): restore the policy position and
        reload only the blocks that were open (same ids, same permutation
        tags, same cursors).  Legacy v1 states (no pool) fall back to
        replaying the consumed batches."""
        if "pool" not in state:
            self._load_legacy(state)
            return
        kind = state["policy"].get("kind")
        if kind != self.policy.name:
            raise ValueError(
                f"checkpoint policy {kind!r} != loader policy {self.policy.name!r}"
            )
        self._seed = int(state.get("seed", self._seed))
        self.policy.load_state_dict(state["policy"])
        for entry in self._pool:
            entry.cancel()
        self._pool.clear()
        for e in state["pool"]:
            self._request(int(e["block_id"]), int(e["tag"]), int(e["cursor"]))
        self._consumed_batches = int(state["consumed_batches"])

    def _load_legacy(self, state: dict) -> None:
        # v1 checkpoints carried only (sampler seed, consumed batch count);
        # the stream is deterministic, so replay reproduces it exactly --
        # at O(consumed batches) cost.  New checkpoints never take this path.
        if self.policy.name != "uniform":
            raise ValueError(
                "legacy (v1) checkpoints are uniform-only; cannot resume a"
                f" {self.policy.name!r}-policy loader from one"
            )
        self._seed = int(state["sampler"]["seed"])  # permutations keyed off it
        self.policy = make_policy("uniform", self.source.num_blocks, seed=self._seed)
        for entry in self._pool:
            entry.cancel()
        self._pool.clear()
        self._consumed_batches = 0
        for _ in range(int(state["consumed_batches"])):
            self.next_batch()


class PrefetchLoader:
    """Background *batch* prefetch (double buffering) on one worker thread.

    ``RSPLoader`` already prefetches blocks; this wrapper additionally
    overlaps batch assembly + transform with the consumer's compute.  Worker
    exceptions propagate out of ``next_batch()`` at the point the failing
    batch would have been delivered -- never swallowed, never a silent hang.
    """

    def __init__(self, loader: RSPLoader, depth: int = 2):
        self.loader = loader
        self._executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="rsp-batch"
        )
        self._futures: collections.deque[Future] = collections.deque()
        for _ in range(max(1, depth)):
            self._futures.append(self._executor.submit(loader.next_batch))

    def next_batch(self) -> np.ndarray:
        fut = self._futures.popleft()
        self._futures.append(self._executor.submit(self.loader.next_batch))
        return fut.result()

    def close(self) -> None:
        """Terminal: stops the batch thread and closes the wrapped loader
        (its executor threads and in-flight fetches included)."""
        for fut in self._futures:
            fut.cancel()
        self._futures.clear()
        self._executor.shutdown(wait=True, cancel_futures=True)
        self.loader.close()

    def __enter__(self) -> "PrefetchLoader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
