"""RSP-backed training data loader.

The loader realizes the paper's pipeline for model training: the corpus is an
RSP (materialized via ``core.registry.RSPStore`` or held in memory), each host
consumes a block-level sample stream (Definition 4), and global batches are
assembled from the records of the currently open blocks.  By Lemma 1 every
global batch is a random sample of the corpus -- with no run-time global
shuffle, and with O(1)-sized resumable state.
"""

from __future__ import annotations

import collections
import threading
import queue
from typing import Callable, Iterator

import numpy as np

from repro.core.registry import RSPStore
from repro.core.sampler import BlockSampler


class BlockSource:
    """Uniform interface over in-memory stacked blocks, an RSPStore, or an
    ``repro.rsp.RSPDataset`` (anything with ``num_blocks`` / ``block(k)``)."""

    def __init__(
        self,
        blocks: np.ndarray | None = None,
        store: RSPStore | None = None,
        dataset=None,
    ):
        if sum(x is not None for x in (blocks, store, dataset)) != 1:
            raise ValueError("provide exactly one of blocks / store / dataset")
        self._blocks = blocks
        self._store = store
        self._dataset = dataset

    @property
    def num_blocks(self) -> int:
        if self._blocks is not None:
            return self._blocks.shape[0]
        if self._dataset is not None:
            return self._dataset.num_blocks
        return self._store.num_blocks()

    def load(self, block_id: int) -> np.ndarray:
        if self._blocks is not None:
            return np.asarray(self._blocks[block_id])
        if self._dataset is not None:
            return np.asarray(self._dataset.block(block_id))
        return np.asarray(self._store.load_block(block_id))


class RSPLoader:
    """Per-host batch iterator over an RSP corpus.

    Batches of ``batch_size`` records are drawn from a rolling pool of
    ``open_blocks`` sampled blocks; when a block is exhausted the sampler
    provides the next one.  Records inside a block are consumed in a
    per-block permuted order (cheap: block fits in memory by construction).
    ``state_dict``/``load_state_dict`` capture (sampler state, pool progress)
    for exact restart.
    """

    def __init__(
        self,
        source: BlockSource,
        *,
        batch_size: int,
        seed: int = 0,
        open_blocks: int = 2,
        drop_last: bool = True,
        transform: Callable[[np.ndarray], np.ndarray] | None = None,
    ):
        self.source = source
        self.batch_size = batch_size
        self.open_blocks = open_blocks
        self.drop_last = drop_last
        self.transform = transform
        self.sampler = BlockSampler(source.num_blocks, seed=seed)
        self._pool: collections.deque[tuple[int, np.ndarray, int]] = collections.deque()
        self._consumed_batches = 0

    # -- iteration -----------------------------------------------------------
    def _refill(self) -> None:
        while len(self._pool) < self.open_blocks:
            (bid,) = self.sampler.sample(1)
            block = self.source.load(bid)
            rng = np.random.default_rng(
                np.random.SeedSequence([self.sampler.state.seed, 0xD47A, self.sampler.state.epoch, bid])
            )
            block = block[rng.permutation(block.shape[0])]
            self._pool.append((bid, block, 0))

    def next_batch(self) -> np.ndarray:
        out: list[np.ndarray] = []
        need = self.batch_size
        while need > 0:
            self._refill()
            bid, block, cursor = self._pool[0]
            take = min(need, block.shape[0] - cursor)
            out.append(block[cursor : cursor + take])
            cursor += take
            need -= take
            if cursor >= block.shape[0]:
                self._pool.popleft()
            else:
                self._pool[0] = (bid, block, cursor)
        batch = np.concatenate(out, axis=0)
        self._consumed_batches += 1
        return self.transform(batch) if self.transform else batch

    def __iter__(self) -> Iterator[np.ndarray]:
        while True:
            yield self.next_batch()

    # -- checkpointing ---------------------------------------------------------
    def state_dict(self) -> dict:
        return {
            "sampler": self.sampler.state_dict(),
            "consumed_batches": self._consumed_batches,
        }

    def load_state_dict(self, state: dict) -> None:
        """Exact-resume: replay is cheap because state is block-granular."""
        self.sampler = BlockSampler.from_state_dict(self.source.num_blocks, state["sampler"])
        # Rebuild the open pool by replaying batch consumption from the last
        # epoch boundary.  Pool progress is a deterministic function of
        # (sampler state, consumed batches); replay only touches block ids,
        # not data, until the final open blocks are loaded.
        target = state["consumed_batches"]
        self.sampler = BlockSampler(self.source.num_blocks, seed=state["sampler"]["seed"])
        self._pool.clear()
        self._consumed_batches = 0
        for _ in range(target):
            self.next_batch()


class PrefetchLoader:
    """Background-thread prefetch wrapper (double buffering)."""

    def __init__(self, loader: RSPLoader, depth: int = 2):
        self.loader = loader
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        while not self._stop.is_set():
            batch = self.loader.next_batch()
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next_batch(self) -> np.ndarray:
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
