"""Synthetic data generators.

``make_higgs_like`` reproduces the statistical shape of the paper's HIGGS
experiments (two-class, 28 continuous features, moderately separable) without
the 11M-record download.  ``make_token_corpus`` builds a Zipf-distributed LM
corpus of fixed-length sequences -- the 'record' of the RSP model for language
model training.
"""

from __future__ import annotations

import numpy as np


def make_higgs_like(
    num_records: int,
    *,
    num_features: int = 28,
    num_informative: int = 8,
    class_sep: float = 1.0,
    seed: int = 0,
    shuffle: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """Two-class Gaussian-mixture tabular data, HIGGS-shaped.

    Informative features get class-dependent means drawn once per dataset;
    the rest are pure noise (like HIGGS's low-level kinematic features).
    Returns (X [N, F] float32, y [N] int32).
    """
    rng = np.random.default_rng(seed)
    num_informative = min(num_informative, num_features)
    n1 = num_records // 2
    n0 = num_records - n1
    means = np.zeros((2, num_features), dtype=np.float32)
    direction = rng.normal(size=num_informative).astype(np.float32)
    direction /= np.linalg.norm(direction)
    means[1, :num_informative] = class_sep * direction
    cov_scale = rng.uniform(0.8, 1.4, size=num_features).astype(np.float32)

    x0 = rng.normal(size=(n0, num_features)).astype(np.float32) * cov_scale + means[0]
    x1 = rng.normal(size=(n1, num_features)).astype(np.float32) * cov_scale + means[1]
    x = np.concatenate([x0, x1], axis=0)
    y = np.concatenate([np.zeros(n0, np.int32), np.ones(n1, np.int32)])
    if shuffle:
        perm = rng.permutation(num_records)
        x, y = x[perm], y[perm]
    return x, y


def make_nonrandom_higgs_like(num_records: int, **kw) -> tuple[np.ndarray, np.ndarray]:
    """Class-sorted (non-randomized) variant: the pathological storage order
    the paper warns about -- sequential chunking of this data yields blocks
    that are NOT random samples."""
    x, y = make_higgs_like(num_records, shuffle=False, **kw)
    order = np.argsort(y, kind="stable")
    return x[order], y[order]


def make_token_corpus(
    num_sequences: int,
    seq_len: int,
    *,
    vocab_size: int = 32000,
    seed: int = 0,
    zipf_a: float = 1.2,
    drift: bool = False,
) -> np.ndarray:
    """Zipf token corpus of shape [num_sequences, seq_len] int32.

    ``drift=True`` makes the token distribution drift across the corpus
    (document-ordered storage) -- the non-randomized case where sequential
    chunking breaks the random-sample property for LM data.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = ranks**-zipf_a
    probs /= probs.sum()
    out = np.empty((num_sequences, seq_len), dtype=np.int32)
    if not drift:
        flat = rng.choice(vocab_size, size=num_sequences * seq_len, p=probs)
        out[:] = flat.reshape(num_sequences, seq_len).astype(np.int32)
    else:
        # Topic drift: rotate the zipf ranking gradually across the corpus.
        for i in range(num_sequences):
            shift = int(vocab_size * i / max(num_sequences, 1) * 0.5)
            p = np.roll(probs, shift)
            out[i] = rng.choice(vocab_size, size=seq_len, p=p).astype(np.int32)
    return out


def make_regression_like(
    num_records: int, *, num_features: int = 16, noise: float = 0.1, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Linear-with-interactions regression data for estimator tests."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(num_records, num_features)).astype(np.float32)
    w = rng.normal(size=num_features).astype(np.float32)
    y = x @ w + 0.5 * x[:, 0] * x[:, 1] + noise * rng.normal(size=num_records).astype(np.float32)
    return x, y.astype(np.float32)
