from repro.data.synthetic import (
    make_higgs_like,
    make_nonrandom_higgs_like,
    make_regression_like,
    make_token_corpus,
)
from repro.data.loader import BlockSource, PrefetchLoader, RSPLoader

__all__ = [k for k in dir() if not k.startswith("_")]
