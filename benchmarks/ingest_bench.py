"""Out-of-core ingest benchmark: streamed partitioning under a memory cap.

The point of ``repro.rsp.ingest`` is that an RSP dataset can be *built* from
a corpus that never fits in RAM -- the premise of the paper's "generated in
advance" blocks.  Two measurements:

1. **Capped streaming ingest** -- a record-batch generator (the corpus never
   exists whole anywhere, not even on disk) streams through
   ``rsp.from_source`` into a stored RSP.  ``tracemalloc`` meters the peak
   allocated working set (numpy buffers are traced; the memmapped block
   files are exactly the out-of-core part, backed by disk); the cap is
   enforced -- ``--smoke`` exits non-zero if the peak exceeds it -- and the
   corpus is several times larger than it.

2. **Sketch-only equivalence** -- the finished store answers
   ``query(["mean", "count"])`` from its partition-time sketches (zero
   block reads, witnessed by the executor's fetch counter) and the answer
   must match a full-scan pass over a regenerated copy of the stream: the
   single ingest pass loses nothing.

Usage::

    PYTHONPATH=src python -m benchmarks.ingest_bench            # full sizes
    PYTHONPATH=src python -m benchmarks.ingest_bench --smoke    # CI gate

``--smoke`` uses small sizes and exits non-zero unless (a) peak traced
memory stays under the cap, (b) the corpus is >= 4x the cap, and (c) the
sketch-only query matches the full-scan answer -- so regressions in the
bounded-memory claim fail loudly.
"""

from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time
import tracemalloc

import numpy as np

from benchmarks.artifact import write_artifact
from repro import rsp


def _chunk_stream(num_chunks: int, chunk_records: int, features: int, seed: int = 9):
    """Deterministic record-batch generator; rebuildable for the verify scan."""
    for c in range(num_chunks):
        rng = np.random.default_rng(np.random.SeedSequence([seed, c]))
        yield rng.normal(loc=1.5, scale=2.0, size=(chunk_records, features)).astype(
            np.float32
        )


def _full_scan_truth(num_chunks: int, chunk_records: int, features: int):
    """Corpus mean/count from a plain streaming accumulation (the answer the
    store's sketches must reproduce)."""
    total = np.zeros(features, dtype=np.float64)
    count = 0
    for chunk in _chunk_stream(num_chunks, chunk_records, features):
        total += chunk.sum(axis=0, dtype=np.float64)
        count += chunk.shape[0]
    return total / count, count


def bench_capped_ingest(
    *,
    blocks: int,
    block_records: int,
    features: int,
    chunk_records: int,
    cap_bytes: int,
) -> dict[str, float]:
    n = blocks * block_records
    corpus_bytes = n * features * 4
    num_chunks = n // chunk_records
    with tempfile.TemporaryDirectory() as tmp:
        out = os.path.join(tmp, "corpus.rsp")
        source = rsp.IterChunkSource(
            _chunk_stream(num_chunks, chunk_records, features),
            num_records=n,
            record_shape=(features,),
            dtype=np.float32,
        )
        tracemalloc.start()
        tracemalloc.reset_peak()
        t0 = time.perf_counter()
        ds = rsp.from_source(source, blocks=blocks, out=out, seed=1,
                             chunk_records=chunk_records)
        elapsed = time.perf_counter() - t0
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()

        assert ds.store is not None and ds.has_summaries
        before = ds.executor.stats()
        res = ds.query(["mean", "count"])
        fetched = (ds.executor.stats() - before).blocks_fetched
        truth_mean, truth_count = _full_scan_truth(num_chunks, chunk_records, features)
        mean_err = float(np.max(np.abs(res["mean"].estimate - truth_mean)))
        count_err = abs(float(res["count"].estimate) - truth_count)
        ds.store.load_block(0, mmap=False, verify=True)  # checksums are real
        ds.close()
    return {
        "corpus_bytes": corpus_bytes,
        "cap_bytes": cap_bytes,
        "peak_bytes": float(peak),
        "records_per_s": n / elapsed,
        "sketch_mean_err": mean_err,
        "sketch_count_err": count_err,
        "sketch_blocks_fetched": float(fetched),
        "from_sketches": float(res.from_sketches),
    }


# the cap covers the scatter working set plus the per-block sketch-suite
# state (KLL + KMV columns; O(K * F * k), independent of corpus size); the
# corpus must still be >= 4x the cap so the out-of-core claim stays real
SMOKE_SIZES = dict(blocks=16, block_records=24576, features=32,
                   chunk_records=2048, cap_bytes=12 << 20)
FULL_SIZES = dict(blocks=32, block_records=65536, features=32,
                  chunk_records=16384, cap_bytes=32 << 20)


def _rows(r: dict[str, float]) -> list[tuple[str, float, str]]:
    ratio = r["corpus_bytes"] / r["cap_bytes"]
    return [
        (
            "ingest_capped_stream",
            r["records_per_s"],
            f"records_per_s={r['records_per_s']:,.0f} "
            f"corpus_mb={r['corpus_bytes'] / 2**20:.0f} "
            f"cap_mb={r['cap_bytes'] / 2**20:.0f} "
            f"peak_mb={r['peak_bytes'] / 2**20:.1f} ratio={ratio:.1f}x",
        ),
        (
            "ingest_sketch_equivalence",
            r["sketch_mean_err"],
            f"mean_err={r['sketch_mean_err']:.2e} count_err={r['sketch_count_err']:.0f} "
            f"blocks_fetched={r['sketch_blocks_fetched']:.0f} "
            f"from_sketches={bool(r['from_sketches'])}",
        ),
    ]


def ingest_rows(smoke: bool = False) -> list[tuple[str, float, str]]:
    """``benchmarks.run``-style rows: (name, value, derived)."""
    return _rows(bench_capped_ingest(**(SMOKE_SIZES if smoke else FULL_SIZES)))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + hard pass/fail gate")
    args = ap.parse_args()

    r = bench_capped_ingest(**(SMOKE_SIZES if args.smoke else FULL_SIZES))
    ratio = r["corpus_bytes"] / r["cap_bytes"]
    rows = _rows(r)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.1f},{derived}")
    # the standalone entry point must leave the same machine-readable
    # artifact benchmarks.run would (CI uploads BENCH_*.json; this one was
    # silently missing)
    path = write_artifact("ingest", rows, extra={"smoke": args.smoke, "raw": r})

    if args.smoke:
        ok = True
        if not os.path.isfile(path) or os.path.getsize(path) == 0:
            print(f"SMOKE FAIL: artifact {path} was not written", file=sys.stderr)
            ok = False
        if r["peak_bytes"] > r["cap_bytes"]:
            print(
                f"SMOKE FAIL: ingest peak {r['peak_bytes'] / 2**20:.1f} MB exceeds"
                f" the {r['cap_bytes'] / 2**20:.0f} MB memory cap",
                file=sys.stderr,
            )
            ok = False
        if ratio < 4.0:
            print(f"SMOKE FAIL: corpus only {ratio:.1f}x the cap (< 4x)", file=sys.stderr)
            ok = False
        if not bool(r["from_sketches"]) or r["sketch_blocks_fetched"] != 0:
            print("SMOKE FAIL: sketch query read block data", file=sys.stderr)
            ok = False
        if r["sketch_mean_err"] > 1e-5 or r["sketch_count_err"] != 0:
            print(
                f"SMOKE FAIL: sketch answer diverges from full scan"
                f" (mean_err={r['sketch_mean_err']:.2e},"
                f" count_err={r['sketch_count_err']:.0f})",
                file=sys.stderr,
            )
            ok = False
        if not ok:
            sys.exit(1)
        print(
            f"SMOKE OK: {ratio:.1f}x-cap corpus streamed at peak"
            f" {r['peak_bytes'] / 2**20:.1f} MB; sketch query == full scan"
            f" with 0 block reads"
        )


if __name__ == "__main__":
    main()
