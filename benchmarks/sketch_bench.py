"""Sketch-suite benchmark: KLL accuracy, sketch-only quantiles, and the
query-aware block-selection race.

The corpus is deliberately *skewed at the block level* -- a minority of
"rich" blocks holds almost all rows matching the benchmark predicate, the
way a time- or source-ordered corpus looks before RSP randomization.  That
is exactly the regime where block selection matters, and three claims of the
sketch subsystem are measured against it:

1. **KLL rank error** -- the merged per-column KLL sketch answers p50/p95
   within its analytic rank-error bound ``kll_rank_error_bound(k)`` against
   the exact sorted corpus.

2. **Sketch-only quantiles** -- ``query(["p50", "p95"], use_sketches=True)``
   answers with *zero* block fetches (the executor's counter is the
   witness) and every estimate falls inside the true value band
   ``[Q(q - eps), Q(q + eps)]``.

3. **Query-aware beats dispersion-PPS** -- a filtered progressive quantile
   query at 1% target relative error reads strictly fewer blocks under
   ``policy="query_aware"`` (predicate selectivity from the per-block KLL
   sketches) than under ``policy="weighted"`` (dispersion-only PPS), for
   p50 *and* p95, averaged over several selection seeds.

Usage::

    PYTHONPATH=src python -m benchmarks.sketch_bench            # full sizes
    PYTHONPATH=src python -m benchmarks.sketch_bench --smoke    # CI gate

``--smoke`` uses small sizes and exits non-zero unless all three gates
hold, so regressions in the sketch path fail loudly.
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks.artifact import write_artifact
from repro.core.types import RSPSpec
from repro.rsp.dataset import RSPDataset
from repro.rsp.sketch import kll_rank_error_bound, merge_suites
from repro.rsp.summaries import summarize_blocks

PREDICATE = "c0 > 1.0"
QUANTILES = {"p50": 0.5, "p95": 0.95}


def build_skewed(num_blocks: int, block_records: int, features: int, *, seed: int = 0):
    """Block-skewed corpus: every 4th block is "rich" (its column 0 sits
    around +2, so most rows pass ``c0 > 1.0``); the rest are "poor" (column
    0 around -2, essentially nothing passes).  The *other* columns are
    i.i.d. shifted normals everywhere, so the filtered quantile answer
    itself is block-invariant -- only where the matching rows live is
    skewed."""
    rng = np.random.default_rng(seed)
    blocks = np.empty((num_blocks, block_records, features), dtype=np.float32)
    for k in range(num_blocks):
        # shifted normal: non-zero quantiles, so 1% *relative* error is a
        # well-posed target for p50 and p95 alike
        x = rng.normal(5.0, 1.0, size=(block_records, features))
        loc = 2.0 if k % 4 == 0 else -2.0
        x[:, 0] = rng.normal(loc, 0.8, size=block_records)
        blocks[k] = x
    n = num_blocks * block_records
    spec = RSPSpec(
        num_records=n,
        num_blocks=num_blocks,
        num_original_blocks=num_blocks,
        record_shape=(features,),
    )
    ds = RSPDataset(spec, blocks=blocks, summaries=summarize_blocks(blocks))
    return ds, blocks.reshape(n, features).astype(np.float64)


def measured_rank_error(ds, data: np.ndarray) -> float:
    """Worst empirical rank error of the merged KLL over both gate
    quantiles and every feature."""
    kll = merge_suites(ds.summaries).get("kll")
    worst = 0.0
    srt = np.sort(data, axis=0)
    n = data.shape[0]
    for q in QUANTILES.values():
        est = kll.quantile([q])[:, 0]
        for j in range(data.shape[1]):
            rank = np.searchsorted(srt[:, j], est[j], side="right") / n
            worst = max(worst, abs(rank - q))
    return worst


def sketch_only_quantiles(ds, data: np.ndarray):
    """(blocks_fetched, within_band) for a forced sketch-only p50/p95."""
    before = ds.executor.stats()
    res = ds.query(list(QUANTILES), use_sketches=True)
    fetched = (ds.executor.stats() - before).blocks_fetched
    eps = kll_rank_error_bound(merge_suites(ds.summaries).get("kll").k)
    srt = np.sort(data, axis=0)
    n = data.shape[0]
    within = bool(res.from_sketches)
    for name, q in QUANTILES.items():
        lo = srt[max(int(np.floor((q - eps) * n)), 0)]
        hi = srt[min(int(np.ceil((q + eps) * n)), n - 1)]
        est = np.asarray(res[name].estimate, dtype=np.float64)
        within = within and bool(np.all(est >= lo) and np.all(est <= hi))
    return int(fetched), within


def policy_race(
    ds, *, target: float = 0.01, seeds=(0, 1, 2)
) -> dict[str, dict[str, float]]:
    """Mean blocks_read per policy for each filtered progressive quantile,
    averaged over selection seeds (same seeds for both policies)."""
    out: dict[str, dict[str, float]] = {name: {} for name in QUANTILES}
    for name in QUANTILES:
        for policy in ("weighted", "query_aware"):
            reads = []
            for seed in seeds:
                res = ds.query(
                    name,
                    where=PREDICATE,
                    target_rel_err=target,
                    use_sketches=False,
                    policy=policy,
                    seed=seed,
                )
                reads.append(res.blocks_read)
            out[name][policy] = float(np.mean(reads))
    return out


SMOKE_SIZES = dict(num_blocks=48, block_records=960, features=4)
FULL_SIZES = dict(num_blocks=96, block_records=4800, features=8)


def sketch_rows(smoke: bool = False) -> list[tuple]:
    """``benchmarks.run``-style rows ``(name, value, derived)``."""
    ds, data = build_skewed(**(SMOKE_SIZES if smoke else FULL_SIZES))
    try:
        eps = kll_rank_error_bound(merge_suites(ds.summaries).get("kll").k)
        rank_err = measured_rank_error(ds, data)
        fetched, within = sketch_only_quantiles(ds, data)
        race = policy_race(ds)
    finally:
        ds.close()
    rows = [
        (
            "sketch_kll_rank_error",
            rank_err,
            f"measured={rank_err:.4f} bound={eps:.4f} "
            f"ok={rank_err <= eps}",
        ),
        (
            "sketch_only_quantiles",
            fetched,
            f"blocks_fetched={fetched} within_band={within}",
        ),
    ]
    for name, reads in race.items():
        qa, wt = reads["query_aware"], reads["weighted"]
        rows.append(
            (
                f"sketch_query_aware_{name}",
                qa,
                f"query_aware={qa:.1f} weighted={wt:.1f} "
                f"saved={(1 - qa / max(wt, 1e-9)):.0%} ok={qa < wt}",
            )
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + hard pass/fail gate")
    args = ap.parse_args()

    rows = sketch_rows(smoke=args.smoke)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.3f},{derived}")
    write_artifact("sketch", rows, extra={"smoke": args.smoke})

    if args.smoke:
        ok = True
        for name, _, derived in rows:
            if "ok=False" in derived:
                print(f"SMOKE FAIL: {name}: {derived}", file=sys.stderr)
                ok = False
            if name == "sketch_only_quantiles" and "blocks_fetched=0" not in derived:
                print(f"SMOKE FAIL: {name} read block data: {derived}", file=sys.stderr)
                ok = False
        if not ok:
            sys.exit(1)
        print(
            "SMOKE OK: KLL within analytic rank bound; p50/p95 answered"
            " sketch-only with 0 block reads; query_aware beat dispersion-PPS"
            " on p50 and p95"
        )


if __name__ == "__main__":
    main()
