"""Benchmark artifacts: one machine-readable ``BENCH_<suite>.json`` per suite.

The CSV the harness prints is for eyeballs; CI and regression tooling want a
stable file.  ``write_artifact`` serializes a suite's ``(name, value,
derived)`` rows -- the exact rows the CSV shows -- to
``results/bench/BENCH_<suite>.json`` (atomic rename, so a crashed run never
leaves a half-written artifact).  ``benchmarks.run`` writes one per suite it
executes; standalone benches (``serve_bench --smoke`` etc.) call it directly.
"""

from __future__ import annotations

import json
import os
import time


def default_out_dir() -> str:
    return os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def write_artifact(
    suite: str,
    rows: list[tuple],
    *,
    extra: dict | None = None,
    out_dir: str | None = None,
) -> str:
    """Write ``BENCH_<suite>.json`` and return its path.

    ``rows`` are the harness rows ``(name, value, derived)`` with an
    optional fourth element: a dict of structured metrics (e.g.
    ``{"rows_per_s": ..., "autotune": {...}}``) recorded on the row as
    ``"metrics"`` -- throughput and winning autotuner configs live there so
    regression tooling never has to parse ``derived`` strings.  ``extra``
    merges additional top-level keys (e.g. gate outcomes) into the payload.
    """
    out_dir = out_dir or default_out_dir()
    os.makedirs(out_dir, exist_ok=True)
    records = []
    for row in rows:
        name, value, derived = row[0], row[1], row[2]
        rec: dict = {"name": name, "value": float(value), "derived": derived}
        if len(row) > 3 and row[3]:
            rec["metrics"] = dict(row[3])
        records.append(rec)
    payload: dict = {
        "suite": suite,
        "generated_unix": time.time(),
        "rows": records,
    }
    if extra:
        payload.update(extra)
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return path
