"""Benchmark artifacts: one machine-readable ``BENCH_<suite>.json`` per suite.

The CSV the harness prints is for eyeballs; CI and regression tooling want a
stable file.  ``write_artifact`` serializes a suite's ``(name, value,
derived)`` rows -- the exact rows the CSV shows -- to
``results/bench/BENCH_<suite>.json`` (atomic rename, so a crashed run never
leaves a half-written artifact).  ``benchmarks.run`` writes one per suite it
executes; standalone benches (``serve_bench --smoke`` etc.) call it directly.
"""

from __future__ import annotations

import json
import os
import platform
import subprocess
import time


def default_out_dir() -> str:
    return os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def _git(*args: str) -> str | None:
    try:
        out = subprocess.run(
            ("git", "-C", os.path.dirname(os.path.abspath(__file__))) + args,
            capture_output=True, text=True, timeout=10,
        )
    except (OSError, subprocess.SubprocessError):
        return None
    if out.returncode != 0:
        return None
    return out.stdout.strip()


def provenance() -> dict:
    """Everything needed to reproduce (or distrust) a benchmark artifact:
    git SHA + dirty flag, wall-clock timestamp, host platform, jax version
    and backend, and whether the autotuner was allowed to measure.  Every
    field degrades to ``None`` rather than raising -- artifacts must write
    even from a tarball checkout with no git."""
    sha = _git("rev-parse", "HEAD")
    status = _git("status", "--porcelain")
    try:
        import jax

        jax_version = jax.__version__
        jax_backend = jax.default_backend()
    except Exception:
        jax_version = jax_backend = None
    return {
        "git_sha": sha,
        "git_dirty": bool(status) if status is not None else None,
        "generated_iso": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "jax_version": jax_version,
        "jax_backend": jax_backend,
        "autotune": os.environ.get("REPRO_AUTOTUNE", "on"),
    }


def write_artifact(
    suite: str,
    rows: list[tuple],
    *,
    extra: dict | None = None,
    out_dir: str | None = None,
) -> str:
    """Write ``BENCH_<suite>.json`` and return its path.

    ``rows`` are the harness rows ``(name, value, derived)`` with an
    optional fourth element: a dict of structured metrics (e.g.
    ``{"rows_per_s": ..., "autotune": {...}}``) recorded on the row as
    ``"metrics"`` -- throughput and winning autotuner configs live there so
    regression tooling never has to parse ``derived`` strings.  ``extra``
    merges additional top-level keys (e.g. gate outcomes) into the payload.
    """
    out_dir = out_dir or default_out_dir()
    os.makedirs(out_dir, exist_ok=True)
    records = []
    for row in rows:
        name, value, derived = row[0], row[1], row[2]
        rec: dict = {"name": name, "value": float(value), "derived": derived}
        if len(row) > 3 and row[3]:
            rec["metrics"] = dict(row[3])
        records.append(rec)
    payload: dict = {
        "suite": suite,
        "generated_unix": time.time(),
        "provenance": provenance(),
        "rows": records,
    }
    if extra:
        payload.update(extra)
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return path
