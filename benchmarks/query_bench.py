"""Progressive-query benchmark: sketch fast path + early stopping + fused sketch.

Three measurements, mirroring what ``repro.rsp.query`` is for:

1. **Sketch fast path** -- latency of moment/count queries answered from the
   partition-time sketches alone, with the block-fetch count asserted to be
   exactly zero (the executor's stats are the witness).

2. **Progressive early stopping** -- a quantile query at 1% target relative
   error over a store-backed corpus: how many of the K blocks the anytime CI
   loop actually reads before the interval is tight enough, and the speedup
   versus scanning every block.

3. **Fused sketch kernel** -- records/sec of the fused moments+histogram
   sketch (``repro.kernels.block_sketch``, ``impl="auto"``) against the
   two-pass equivalent (separate moments and histogram sweeps) it replaces.
   On CPU these are plumbing numbers (both paths are RAM-resident); the
   single-HBM-pass win is the Pallas kernel's TPU story, like the other
   interpret-mode kernel benchmarks.

Usage::

    PYTHONPATH=src python -m benchmarks.query_bench            # full sizes
    PYTHONPATH=src python -m benchmarks.query_bench --smoke    # CI gate

``--smoke`` uses small sizes and exits non-zero unless (a) sketch-only
queries read 0 blocks and (b) the progressive quantile query stops at <50%
of the blocks at 1% target error -- so regressions in the query layer's
whole point (few blocks, zero-read fast paths) fail loudly.
"""

from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

import numpy as np

from repro import rsp
from repro.core.estimators import block_histogram, block_moments
from repro.kernels.block_sketch import block_sketch


def _build(num_blocks: int, block_records: int, features: int, *, shift: float = 5.0):
    """A shifted-normal corpus (non-zero median, so relative stopping is
    well-posed) partitioned in memory."""
    rng = np.random.default_rng(0)
    n = num_blocks * block_records
    data = rng.normal(shift, 1.0, size=(n, features)).astype(np.float32)
    return rsp.partition(data, blocks=num_blocks, seed=1), data


def bench_sketch_path(ds, repeats: int = 20) -> tuple[float, int]:
    """(us per sketch-only query, blocks fetched across all repeats)."""
    before = ds.executor.stats()
    t0 = time.perf_counter()
    for _ in range(repeats):
        res = ds.query(["mean", "var", "sum", "count"])
        assert res.from_sketches
    us = (time.perf_counter() - t0) / repeats * 1e6
    fetched = (ds.executor.stats() - before).blocks_fetched
    return us, fetched


def bench_progressive_quantile(
    ds_path: str, *, target: float = 0.01, seed: int = 0
) -> tuple[int, int, float, float]:
    """(blocks_read, total_blocks, speedup_vs_full_scan, rows_per_s) for a
    p50 query at ``target`` relative error on a store-backed dataset."""
    ds = rsp.open(ds_path, cache_blocks=0)
    t0 = time.perf_counter()
    res = ds.query(
        "median", target_rel_err=target, use_sketches=False, seed=seed
    )
    t_query = time.perf_counter() - t0
    assert res.executor_stats.blocks_fetched >= res.blocks_read  # honest I/O count
    rows_per_s = res.executor_stats.rows_fetched / max(t_query, 1e-9)
    t0 = time.perf_counter()
    full = rsp.open(ds_path, cache_blocks=0)
    full.query("median", use_sketches=False, target_rel_err=None, seed=seed)
    t_full = time.perf_counter() - t0
    ds.close()
    full.close()
    return res.blocks_read, res.total_blocks, t_full / max(t_query, 1e-9), rows_per_s


def bench_fused_sketch(block: np.ndarray, *, bins: int = 128, repeats: int = 10):
    """records/sec: fused one-pass sketch vs separate moments + histogram."""
    lo, hi = block.min(0), block.max(0)
    n = block.shape[0]

    def timed(fn):
        fn()
        t0 = time.perf_counter()
        for _ in range(repeats):
            fn()
        return n * repeats / (time.perf_counter() - t0)

    fused = timed(lambda: block_sketch(block, bins=bins, lo=lo, hi=hi, impl="auto"))
    two_pass = timed(
        lambda: (block_moments(block), block_histogram(block, bins=bins, lo=-8, hi=8))
    )
    return fused, two_pass


def query_rows(smoke: bool = False) -> list[tuple]:
    """``benchmarks.run``-style rows ``(name, value, derived, metrics)``
    with per-row rows/s throughput in the metrics dict."""
    if smoke:
        # block_records must divide by num_blocks (Algorithm 1's delta slices)
        kw = dict(num_blocks=48, block_records=2304, features=8)
    else:
        kw = dict(num_blocks=96, block_records=9216, features=16)
    rows: list[tuple] = []
    ds, _ = _build(**kw)

    us, fetched = bench_sketch_path(ds)
    rows.append(
        (
            "query_sketch_only",
            us,
            f"us_per_query={us:.0f} blocks_fetched={fetched}",
            {"rows_per_s": 0.0, "queries_per_s": 1e6 / max(us, 1e-9)},
        )
    )

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "corpus.rsp")
        ds.save(path)
        read, total, speedup, rows_per_s = bench_progressive_quantile(path)
        rows.append(
            (
                "query_progressive_p50",
                read,
                f"blocks={read}/{total} frac={read / total:.2f}"
                f" speedup_vs_full={speedup:.1f}x rows_per_s={rows_per_s:,.0f}",
                {"rows_per_s": rows_per_s},
            )
        )
    block = np.asarray(ds.block(0))
    fused, two_pass = bench_fused_sketch(block)
    ds.close()
    rows.append(
        (
            "query_fused_sketch",
            fused,
            f"records_per_s={fused:,.0f} two_pass={two_pass:,.0f}"
            f" ratio={fused / max(two_pass, 1e-9):.2f}x",
            {"rows_per_s": fused, "two_pass_rows_per_s": two_pass},
        )
    )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="small sizes + hard pass/fail gate")
    args = ap.parse_args()

    rows = query_rows(smoke=args.smoke)
    print("name,value,derived")
    for row in rows:
        print(f"{row[0]},{row[1]:.1f},{row[2]}")

    if args.smoke:
        by_name = {row[0]: row[2] for row in rows}
        ok = True
        fetched = int(by_name["query_sketch_only"].split("blocks_fetched=")[1])
        if fetched != 0:
            print(f"SMOKE FAIL: sketch-only queries fetched {fetched} blocks", file=sys.stderr)
            ok = False
        frac = float(by_name["query_progressive_p50"].split("frac=")[1].split()[0])
        if frac >= 0.5:
            print(
                f"SMOKE FAIL: progressive p50 read {frac:.0%} of blocks (>= 50%)",
                file=sys.stderr,
            )
            ok = False
        if not ok:
            sys.exit(1)
        print(
            f"SMOKE OK: sketch-only reads 0 blocks; progressive p50 stopped at"
            f" {frac:.0%} of blocks at 1% target error"
        )


if __name__ == "__main__":
    main()
